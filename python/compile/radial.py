"""Radial-factor tables h_l(t) for the GZK family (paper Eqs. 12, 22, 23; Lemma 16).

A `RadialTable` captures, for a given kernel family and truncation (q, s), the
full per-(l, i) weight applied in the feature map (Def. 8 / Eq. 13):

    phi_x(w)[i] = sum_l  sqrt(alpha_{l,d}) * [h_l(||x||)]_i * P_d^l(<x,w>/||x||)
                = sum_l  R[x][l, i] * P_d^l(<x,w>/||x||)

with R[x][l, i] = coef[l, i] * ||x||^expo[l, i] * (exp(-||x||^2 / 2) if decay).

`coef` folds BOTH the sqrt(alpha) of Eq. (13) and the per-family Mercer
coefficient of h_l; it is computed in log-domain (lgamma) for stability.

Families:
  gaussian     — Eq. (23); unit bandwidth (rescale inputs by 1/sigma for others)
  exponential  — kappa(t) = exp(gamma * t), Eq. (12) with kappa^(j)(0) = gamma^j
  polynomial   — kappa(t) = (t + c)^p, kappa^(j)(0) = p!/(p-j)! c^(p-j), j <= p
  ntk          — depth-L ReLU NTK, Lemma 16 (s is forced to 1, expo = 1)
"""

import math
from dataclasses import dataclass

import numpy as np

from . import gegenbauer as geg

__all__ = [
    "RadialTable",
    "gaussian_table",
    "exponential_table",
    "polynomial_table",
    "ntk_table",
    "radial_values",
    "suggest_q",
    "ntk_kappa",
]

_LOG_SQRT_PI = 0.5 * math.log(math.pi)


@dataclass(frozen=True)
class RadialTable:
    """Truncated radial weights for one GZK family in dimension d."""

    family: str
    d: int
    q: int
    s: int
    coef: np.ndarray  # (q+1, s) linear-domain weights (incl. sqrt(alpha_{l,d}))
    expo: np.ndarray  # (q+1, s) exponents of ||x||
    decay: bool  # multiply by exp(-||x||^2/2)?


def _base_log_coef(l: int, i: int, d: int) -> float:
    """log of sqrt(alpha_{l,d}) * sqrt(alpha_{l,d}/2^l * Gamma(d/2)/(sqrt(pi)(2i)!)
    * Gamma(i+1/2)/Gamma(i+l+d/2)) — the kappa-independent part of Eq. (12)."""
    la = geg.log_alpha_dim(l, d)
    return la - 0.5 * l * math.log(2.0) + 0.5 * (
        math.lgamma(d / 2.0)
        - _LOG_SQRT_PI
        - math.lgamma(2 * i + 1)
        + math.lgamma(i + 0.5)
        - math.lgamma(i + l + d / 2.0)
    )


def gaussian_table(d: int, q: int, s: int) -> RadialTable:
    """Unit-bandwidth Gaussian kernel e^{-||x-y||^2/2} (Eq. 23)."""
    coef = np.zeros((q + 1, s))
    expo = np.zeros((q + 1, s))
    for l in range(q + 1):
        for i in range(s):
            coef[l, i] = math.exp(_base_log_coef(l, i, d))
            expo[l, i] = l + 2 * i
    return RadialTable("gaussian", d, q, s, coef, expo, True)


def exponential_table(d: int, q: int, s: int, gamma: float = 1.0) -> RadialTable:
    """Dot-product kernel kappa(t) = exp(gamma * t)."""
    if gamma <= 0:
        raise ValueError("gamma must be > 0 for a PSD exponential kernel")
    coef = np.zeros((q + 1, s))
    expo = np.zeros((q + 1, s))
    for l in range(q + 1):
        for i in range(s):
            lg = _base_log_coef(l, i, d) + 0.5 * (l + 2 * i) * math.log(gamma)
            coef[l, i] = math.exp(lg)
            expo[l, i] = l + 2 * i
    return RadialTable("exponential", d, q, s, coef, expo, False)


def polynomial_table(d: int, p: int, c: float, q: int | None = None, s: int | None = None) -> RadialTable:
    """Dot-product kernel kappa(t) = (t + c)^p, c >= 0. Exact at q = p,
    s = p//2 + 1 (derivatives above order p vanish)."""
    if c < 0:
        raise ValueError("c must be >= 0 (Schoenberg PSD condition)")
    q = p if q is None else min(q, p)
    s = p // 2 + 1 if s is None else s
    coef = np.zeros((q + 1, s))
    expo = np.zeros((q + 1, s))
    for l in range(q + 1):
        for i in range(s):
            j = l + 2 * i
            if j > p:
                continue
            # kappa^(j)(0) = p!/(p-j)! * c^(p-j)
            lk = math.lgamma(p + 1) - math.lgamma(p - j + 1)
            lk += (p - j) * math.log(c) if c > 0 else (0.0 if j == p else -math.inf)
            if lk == -math.inf:
                continue
            coef[l, i] = math.exp(_base_log_coef(l, i, d) + 0.5 * lk)
            expo[l, i] = j
    return RadialTable("polynomial", d, q, s, coef, expo, False)


# --- NTK ------------------------------------------------------------------

def _arccos_a0(x: np.ndarray) -> np.ndarray:
    return 1.0 - np.arccos(np.clip(x, -1.0, 1.0)) / math.pi


def _arccos_a1(x: np.ndarray) -> np.ndarray:
    xc = np.clip(x, -1.0, 1.0)
    return (np.sqrt(1.0 - xc * xc) + xc * (math.pi - np.arccos(xc))) / math.pi


def ntk_kappa(x: np.ndarray, depth: int = 2) -> np.ndarray:
    """Normalized depth-L ReLU NTK K_relu^{(L)} on [-1,1] ([ZHA+21] recursion).

    sigma_0 = x; sigma_h = a1(sigma_{h-1});
    theta_0 = x; theta_h = sigma_h + theta_{h-1} * a0(sigma_{h-1}).
    Runs depth-1 recursion steps, so kappa(1) = depth; the paper's Fig.-1
    two-layer formula a1(a1(x)) + (a1(x) + x a0(x)) * a0(a1(x)) is depth=3
    in this indexing (two nested a1 applications).
    """
    sigma = np.asarray(x, dtype=np.float64)
    theta = sigma
    for _ in range(depth - 1):
        theta = _arccos_a1(sigma) + theta * _arccos_a0(sigma)
        sigma = _arccos_a1(sigma)
    return theta


def ntk_table(d: int, q: int, depth: int = 2, n_quad: int = 512) -> RadialTable:
    """Depth-`depth` ReLU NTK as a GZK (Lemma 16): h_l(t) = sqrt(c_l) * t,
    s = 1, with c_l the Gegenbauer coefficients of K_relu^{(L)}."""
    c = geg.gegenbauer_series_coeffs(lambda t: ntk_kappa(np.asarray(t), depth), q, d, n_quad)
    c = np.maximum(c, 0.0)  # clip quadrature noise; Schoenberg guarantees c_l >= 0
    coef = np.zeros((q + 1, 1))
    expo = np.ones((q + 1, 1))
    for l in range(q + 1):
        coef[l, 0] = math.sqrt(geg.alpha_dim(l, d) * c[l]) if c[l] > 0 else 0.0
    return RadialTable("ntk", d, q, 1, coef, expo, False)


# --- evaluation -----------------------------------------------------------

def radial_values(table: RadialTable, norms: np.ndarray) -> np.ndarray:
    """R[j, l, i] = coef[l,i] * norms[j]^expo[l,i] * (envelope). Shape
    (n, q+1, s). Pure numpy (host-side mirror of the jnp version in model.py)."""
    t = np.maximum(np.asarray(norms, dtype=np.float64), 1e-30)[:, None, None]
    r = table.coef[None] * np.power(t, table.expo[None])
    if table.decay:
        r = r * np.exp(-0.5 * t * t)
    return r


def suggest_q(r: float, d: int, n: int, lam: float, eps: float = 0.5) -> int:
    """Theorem-12-style truncation degree for the Gaussian kernel:
    q = max(3.7 r^2, (d/2) log(2.8 (r^2 + log(n/(eps*lam)) + d)/d) + log(n/(eps*lam)))."""
    t = math.log(max(n / (eps * lam), math.e))
    q = max(3.7 * r * r, (d / 2.0) * math.log(2.8 * (r * r + t + d) / d) + t)
    return max(2, int(math.ceil(q)))
