"""AOT lowering: jax graphs -> HLO *text* artifacts + manifest.json.

HLO text (NOT proto .serialize()) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`:  cd python && python -m compile.aot --out ../artifacts

Artifact set (fixed shapes; rust pads/slices):
  featurize_<family>_d<d>_q<q>_s<s>   — (B=256, d) x (M=128, d) -> (256, 128*s)
  krr_solve_f<F>                      — (F,F),(F,),() -> (F,)
The manifest records every artifact's geometry so rust/src/runtime/manifest.rs
can pick the right executable per dataset dimension.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import radial
from .model import build_featurize, build_krr_solve

BLOCK_B = 256
BLOCK_M = 128

# (family, d, q, s) — covers the Table-2 (d=3,4,9) and Table-3
# (d=8,10,16,21,42; unit-norm inputs) dataset geometries.
FEATURIZE_CONFIGS = [
    ("ntk", 3, 16, 1),
    ("gaussian", 3, 12, 2),
    ("gaussian", 4, 10, 2),
    ("gaussian", 8, 8, 2),
    ("gaussian", 9, 8, 2),
    ("gaussian", 10, 8, 2),
    ("gaussian", 16, 6, 2),
    ("gaussian", 21, 6, 1),
    ("gaussian", 42, 4, 1),
]

KRR_SOLVE_DIMS = [512, 1024]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print with full constant literals. The default printer
    # elides arrays above a small size threshold as `constant({...})`,
    # which the C++ text parser silently reads back as ALL ZEROS — the
    # baked-in radial coefficient tables would vanish (this produced
    # all-zero features end-to-end before the fix; guarded by
    # tests/test_model_aot.py::test_no_elided_constants and the rust
    # parity suite).
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # ... and WITHOUT per-op metadata: new jaxlib emits source_end_line /
    # source_end_column attributes the 0.5.1 text parser rejects.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def make_table(family: str, d: int, q: int, s: int) -> radial.RadialTable:
    if family == "gaussian":
        return radial.gaussian_table(d, q, s)
    if family == "exponential":
        return radial.exponential_table(d, q, s)
    if family == "ntk":
        return radial.ntk_table(d, q)
    raise ValueError(family)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"block_b": BLOCK_B, "block_m": BLOCK_M, "artifacts": []}

    for family, d, q, s in FEATURIZE_CONFIGS:
        table = make_table(family, d, q, s)
        # m_total = BLOCK_M: the graph scales by 1/sqrt(BLOCK_M); the rust
        # runtime rescales by sqrt(BLOCK_M / m_total) when chunking a larger
        # direction set through this executable.
        fn = build_featurize(table, BLOCK_B, BLOCK_M, BLOCK_M)
        x_spec = jax.ShapeDtypeStruct((BLOCK_B, d), jnp.float32)
        w_spec = jax.ShapeDtypeStruct((BLOCK_M, d), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(x_spec, w_spec))
        assert "{...}" not in text, "HLO printer elided constants"
        name = f"featurize_{family}_d{d}_q{q}_s{s}"
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name, "kind": "featurize", "family": family,
            "d": d, "q": q, "s": s, "block_b": BLOCK_B, "block_m": BLOCK_M,
            "file": fname,
        })
        print(f"wrote {fname} ({len(text)} chars)")

    for f_dim in KRR_SOLVE_DIMS:
        fn = build_krr_solve(f_dim)
        g_spec = jax.ShapeDtypeStruct((f_dim, f_dim), jnp.float32)
        b_spec = jax.ShapeDtypeStruct((f_dim,), jnp.float32)
        l_spec = jax.ShapeDtypeStruct((), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(g_spec, b_spec, l_spec))
        assert "{...}" not in text, "HLO printer elided constants"
        assert "custom-call" not in text, "krr_solve must be custom-call free"
        name = f"krr_solve_f{f_dim}"
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name, "kind": "krr_solve", "f": f_dim, "file": fname,
        })
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
