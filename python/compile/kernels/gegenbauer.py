"""L1 Pallas kernel: fused random-Gegenbauer feature tile.

Computes one (B, M*s) output tile of the feature matrix Z (Def. 8):

    T = U @ W^T                                  # one MXU matmul per tile
    P_0 = 1, P_1 = T, P_l = A_l T P_{l-1} + B_l P_{l-2}   # VPU recurrence
    Z[b, k, i] = sum_l P_l[b, k] * R[b, l, i]    # fused accumulate

Inputs are pre-normalized on the L2 side: U unit rows, R the radial values
(already folded with sqrt(alpha_{l,d}) and the 1/sqrt(m) scaling).

TPU mapping (DESIGN.md §Hardware-Adaptation): blocks of B=256 data rows by
M=128 directions keep T, the two recurrence carries and the (B, M*s)
accumulator resident in VMEM (~0.75 MB at s=2, f32); the l-loop is unrolled
at trace time since (q, s, d) are artifact-compile-time constants. The only
MXU op is the [B,d]x[d,M] contraction; everything else is elementwise VPU
work on (B, M) tiles.

MUST run with interpret=True on CPU PJRT — real TPU lowering emits a Mosaic
custom-call the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import gegenbauer as geg

__all__ = ["gegenbauer_feature_tile", "gegenbauer_features_pallas"]


def _feature_kernel(u_ref, r_ref, w_ref, o_ref, *, q: int, s: int, A, B):
    u = u_ref[...]  # [Bb, d]
    r = r_ref[...]  # [Bb, (q+1)*s]
    w = w_ref[...]  # [Mb, d]
    bb = u.shape[0]
    mb = w.shape[0]

    t = jnp.dot(u, w.T, preferred_element_type=jnp.float32)  # [Bb, Mb]

    # l = 0 term: P_0 = 1
    acc = jnp.broadcast_to(r[:, None, 0:s], (bb, mb, s)).astype(jnp.float32)
    if q >= 1:
        p_prev = jnp.ones_like(t)
        p_cur = t
        for l in range(1, q + 1):
            rl = r[:, l * s : (l + 1) * s]  # [Bb, s]
            acc = acc + p_cur[:, :, None] * rl[:, None, :]
            if l < q:
                p_nxt = (A[l + 1] * t) * p_cur + B[l + 1] * p_prev
                p_prev, p_cur = p_cur, p_nxt
    o_ref[...] = acc.reshape(bb, mb * s)


def gegenbauer_feature_tile(u, r, w, *, q: int, s: int, d: int,
                            block_b: int | None = None, block_m: int | None = None):
    """Tiled pallas_call over the full (n, m) feature matrix.

    u [n, d] unit rows; r [n, (q+1)*s] radial values; w [m, d] directions.
    Returns Z [n, m*s] in direction-major / radial-minor column order.
    """
    n, dd = u.shape
    m = w.shape[0]
    assert dd == d and r.shape == (n, (q + 1) * s), (u.shape, r.shape)
    bb = block_b or min(n, 256)
    mb = block_m or min(m, 128)
    assert n % bb == 0 and m % mb == 0, "caller pads to tile multiples"

    A, B = geg.recurrence_coeffs(q, d)
    kern = functools.partial(_feature_kernel, q=q, s=s,
                             A=tuple(float(a) for a in A),
                             B=tuple(float(b) for b in B))
    grid = (n // bb, m // mb)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, (q + 1) * s), lambda i, j: (i, 0)),
            pl.BlockSpec((mb, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, mb * s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m * s), jnp.float32),
        interpret=True,
    )(u, r, w)


def gegenbauer_features_pallas(x, w, coef, expo, decay: bool,
                               block_b: int | None = None, block_m: int | None = None):
    """Full feature map from raw points: L2 pre-processing (norms, radial
    table evaluation) in jnp + L1 pallas tile. Matches ref.py bit-for-bit up
    to f32 rounding."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    expo = jnp.asarray(expo, jnp.float32)
    q = coef.shape[0] - 1
    s = coef.shape[1]
    n, d = x.shape
    m = w.shape[0]

    norms = jnp.maximum(jnp.linalg.norm(x, axis=1), 1e-30)
    u = x / norms[:, None]
    r = coef[None] * jnp.power(norms[:, None, None], expo[None])
    if decay:
        r = r * jnp.exp(-0.5 * norms * norms)[:, None, None]
    r = (r / jnp.sqrt(jnp.float32(m))).reshape(n, (q + 1) * s)
    return gegenbauer_feature_tile(u, r, w, q=q, s=s, d=d,
                                   block_b=block_b, block_m=block_m)
