"""Pure-jnp oracle for the random Gegenbauer feature map (Def. 8).

This is the correctness reference the Pallas kernel (gegenbauer.py) and the
rust native featurizer are tested against. It evaluates the feature map
directly with stacked recurrence matrices — no tiling, no fusion.
"""

import jax.numpy as jnp
import numpy as np

from .. import gegenbauer as geg

__all__ = ["gegenbauer_features_ref", "exact_gram"]


def gegenbauer_features_ref(x, w, coef, expo, decay: bool):
    """Z [n, m*s] with Z[j, k*s + i] = (1/sqrt(m)) * sum_l R[j,l,i] * P_l(t_jk).

    x    [n, d]  raw data points
    w    [m, d]  unit directions
    coef [q+1, s], expo [q+1, s] — RadialTable contents
    """
    x = jnp.asarray(x)
    w = jnp.asarray(w)
    coef = jnp.asarray(coef, dtype=x.dtype)
    expo = jnp.asarray(expo, dtype=x.dtype)
    q = coef.shape[0] - 1
    s = coef.shape[1]
    n, d = x.shape
    m = w.shape[0]

    norms = jnp.maximum(jnp.linalg.norm(x, axis=1), 1e-30)  # [n]
    u = x / norms[:, None]
    t = u @ w.T  # [n, m]

    # radial values R [n, q+1, s]
    r = coef[None] * jnp.power(norms[:, None, None], expo[None])
    if decay:
        r = r * jnp.exp(-0.5 * norms * norms)[:, None, None]

    # stacked Gegenbauer values P [q+1, n, m]
    A, B = geg.recurrence_coeffs(q, d)
    ps = [jnp.ones_like(t)]
    if q >= 1:
        ps.append(t)
    for l in range(2, q + 1):
        ps.append(A[l] * t * ps[l - 1] + B[l] * ps[l - 2])
    p = jnp.stack(ps)  # [q+1, n, m]

    z = jnp.einsum("lnm,nls->nms", p, r) / np.sqrt(m)
    return z.reshape(n, m * s)


def exact_gram(x, kind: str = "gaussian", **kw):
    """Exact kernel Gram matrix (ground truth for unbiasedness tests)."""
    x = np.asarray(x, dtype=np.float64)
    if kind == "gaussian":
        sq = np.sum(x * x, axis=1)
        return np.exp(-0.5 * (sq[:, None] + sq[None, :] - 2.0 * x @ x.T))
    if kind == "exponential":
        gamma = kw.get("gamma", 1.0)
        return np.exp(gamma * (x @ x.T))
    if kind == "polynomial":
        p, c = kw["p"], kw["c"]
        return (x @ x.T + c) ** p
    if kind == "ntk":
        from ..radial import ntk_kappa

        depth = kw.get("depth", 2)
        norms = np.maximum(np.linalg.norm(x, axis=1), 1e-30)
        cos = (x @ x.T) / np.outer(norms, norms)
        return np.outer(norms, norms) * ntk_kappa(np.clip(cos, -1, 1), depth)
    raise ValueError(f"unknown kernel kind {kind!r}")
