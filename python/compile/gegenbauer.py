"""Gegenbauer polynomial machinery (build-time python mirror of rust/src/special).

Normalized Gegenbauer polynomials P_d^l(t) with P_d^l(1) = 1:
  d = 2   -> Chebyshev polynomials of the first kind T_l
  d = 3   -> Legendre polynomials
  d = inf -> monomials t^l

Three-term recurrence (derived from the classical C_l^{(a)} recurrence with
a = (d-2)/2 and the normalization C_l^{(a)}(1) = binom(l+2a-1, l)):

  P_0 = 1,  P_1 = t,
  P_l = A_l * t * P_{l-1} + B_l * P_{l-2}
  A_l = (2l + d - 4) / (l + d - 3),   B_l = -(l - 1) / (l + d - 3)

which at d=2 degenerates to the Chebyshev recurrence A_l = 2, B_l = -1
(the formula hits 0/0 at l=1, d=2; l=1 is always P_1 = t).
"""

import math

import numpy as np

__all__ = [
    "recurrence_coeffs",
    "gegenbauer_all",
    "alpha_dim",
    "log_alpha_dim",
    "gegenbauer_series_coeffs",
    "surface_ratio",
]


def recurrence_coeffs(q: int, d: int) -> tuple[np.ndarray, np.ndarray]:
    """(A, B) recurrence coefficient arrays of length q+1 (index l; entries
    for l < 2 are unused placeholders)."""
    if d < 2:
        raise ValueError(f"dimension d must be >= 2, got {d}")
    A = np.zeros(q + 1)
    B = np.zeros(q + 1)
    for l in range(2, q + 1):
        if d == 2:
            A[l], B[l] = 2.0, -1.0
        else:
            A[l] = (2 * l + d - 4) / (l + d - 3)
            B[l] = -(l - 1) / (l + d - 3)
    return A, B


def gegenbauer_all(q: int, d: int, t: np.ndarray) -> np.ndarray:
    """Evaluate [P_d^0(t), ..., P_d^q(t)] -> shape (q+1, *t.shape)."""
    t = np.asarray(t, dtype=np.float64)
    A, B = recurrence_coeffs(q, d)
    out = np.empty((q + 1,) + t.shape, dtype=np.float64)
    out[0] = 1.0
    if q >= 1:
        out[1] = t
    for l in range(2, q + 1):
        out[l] = A[l] * t * out[l - 1] + B[l] * out[l - 2]
    return out


def alpha_dim(l: int, d: int) -> float:
    """alpha_{l,d}: dimension of degree-l spherical harmonics in R^d (Eq. 4)."""
    return math.exp(log_alpha_dim(l, d))


def log_alpha_dim(l: int, d: int) -> float:
    """log alpha_{l,d}, stable for large l/d via lgamma."""
    if l == 0:
        return 0.0
    if l == 1:
        return math.log(d)
    # binom(d+l-1, l) - binom(d+l-3, l-2)
    #   = binom(d+l-3, l) * [ (d+l-1)(d+l-2)/((d-1+l-... )) ... ]; do it directly
    def log_binom(n: int, k: int) -> float:
        return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)

    a = log_binom(d + l - 1, l)
    b = log_binom(d + l - 3, l - 2) if d + l - 3 >= l - 2 else -math.inf
    # a > b always (alpha > 0); use log-sub-exp
    return a + math.log1p(-math.exp(b - a)) if b > -math.inf else a


def surface_ratio(d: int) -> float:
    """|S^{d-2}| / |S^{d-1}| = Gamma(d/2) / (sqrt(pi) Gamma((d-1)/2))."""
    return math.exp(math.lgamma(d / 2) - 0.5 * math.log(math.pi) - math.lgamma((d - 1) / 2))


def gegenbauer_series_coeffs(fn, q: int, d: int, n_quad: int = 256) -> np.ndarray:
    """Gegenbauer series coefficients c_0..c_q of a scalar function on [-1,1]
    (Eq. 8):  c_l = alpha_{l,d} * |S^{d-2}|/|S^{d-1}|
                    * int_{-1}^{1} fn(t) P_d^l(t) (1-t^2)^{(d-3)/2} dt.

    Uses Gauss-Jacobi quadrature with weight (1-t^2)^{(d-3)/2} so the weight
    singularity at d=2 (Chebyshev measure) is exact.
    """
    from scipy.special import roots_jacobi

    a = (d - 3) / 2.0
    nodes, weights = roots_jacobi(n_quad, a, a)
    fvals = np.asarray([fn(t) for t in nodes], dtype=np.float64)
    P = gegenbauer_all(q, d, nodes)  # (q+1, n_quad)
    ratio = surface_ratio(d)
    coeffs = np.empty(q + 1)
    for l in range(q + 1):
        coeffs[l] = alpha_dim(l, d) * ratio * np.sum(weights * fvals * P[l])
    return coeffs
