"""L2: the jax compute graphs that get AOT-lowered to HLO text.

Two graph families:

  featurize(X, W) -> Z    raw data block (B, d) + direction block (M, d) ->
                          feature tile (B, M*s). Radial tables are baked in
                          as constants at trace time; the hot inner loop is
                          the L1 pallas kernel.

  krr_solve(G, b, lam) -> w   Cholesky solve of (G + lam*I) w = b, used by
                          the L3 leader after the one-round reduction.

Shapes are fixed per artifact (see aot.py); the rust runtime pads inputs to
the tile shape and slices the outputs.
"""

import jax
import jax.numpy as jnp

from .kernels.gegenbauer import gegenbauer_feature_tile
from .radial import RadialTable

__all__ = ["build_featurize", "build_krr_solve"]


def build_featurize(table: RadialTable, block_b: int, block_m: int, m_total: int):
    """Return f(x[B,d], w[M,d]) -> z[B, M*s] with the 1/sqrt(m_total)
    Def.-8 scaling baked in (m_total = total directions across all calls)."""
    coef = jnp.asarray(table.coef, jnp.float32)
    expo = jnp.asarray(table.expo, jnp.float32)
    q, s, d = table.q, table.s, table.d
    inv_sqrt_m = 1.0 / jnp.sqrt(jnp.float32(m_total))

    def featurize(x, w):
        norms = jnp.maximum(jnp.linalg.norm(x, axis=1), 1e-30)
        u = x / norms[:, None]
        r = coef[None] * jnp.power(norms[:, None, None], expo[None])
        if table.decay:
            r = r * jnp.exp(-0.5 * norms * norms)[:, None, None]
        r = (r * inv_sqrt_m).reshape(x.shape[0], (q + 1) * s)
        z = gegenbauer_feature_tile(u, r, w, q=q, s=s, d=d,
                                    block_b=block_b, block_m=block_m)
        return (z,)

    return featurize


def build_krr_solve(f: int, iters: int = 128):
    """Return f(g[F,F], b[F], lam[]) -> w[F]: (G + lam I)^-1 b.

    Implemented as Jacobi-preconditioned conjugate gradient with a fixed
    iteration count. Why not jnp.linalg.cholesky: jax >= 0.5 lowers the
    dense factorizations to typed-FFI custom-calls (LAPACK), which the
    xla_extension 0.5.1 runtime behind the rust `xla` crate rejects
    ("Unknown custom-call API version ... API_VERSION_TYPED_FFI"). CG
    lowers to plain HLO (dots + a while loop) and runs everywhere.
    """

    def krr_solve(g, b, lam):
        minv = 1.0 / jnp.maximum(jnp.diagonal(g) + lam, 1e-12)

        def matvec(v):
            return g @ v + lam * v

        x0 = jnp.zeros_like(b)
        r0 = b
        z0 = minv * r0
        p0 = z0
        rz0 = r0 @ z0

        def body(_, state):
            x, r, p, rz = state
            ap = matvec(p)
            alpha = rz / jnp.maximum(p @ ap, 1e-30)
            x = x + alpha * p
            r = r - alpha * ap
            z = minv * r
            rz_new = r @ z
            beta = rz_new / jnp.maximum(rz, 1e-30)
            p = z + beta * p
            return (x, r, p, rz_new)

        x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x0, r0, p0, rz0))
        return (x,)

    _ = f
    return krr_solve
