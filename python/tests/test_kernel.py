"""L1 correctness: Pallas kernel vs pure-jnp ref oracle, plus statistical
properties of the feature map (unbiasedness E[Z Z^T] = K)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import radial
from compile.kernels.gegenbauer import (gegenbauer_feature_tile,
                                        gegenbauer_features_pallas)
from compile.kernels.ref import exact_gram, gegenbauer_features_ref

jax.config.update("jax_enable_x64", False)


def sphere(rng, m, d):
    w = rng.normal(size=(m, d))
    return w / np.linalg.norm(w, axis=1, keepdims=True)


class TestPallasVsRef:
    @pytest.mark.parametrize("d,q,s", [(3, 12, 2), (4, 8, 3), (9, 6, 2), (2, 10, 1)])
    def test_matches_ref_gaussian(self, d, q, s):
        rng = np.random.default_rng(7)
        n, m = 8, 16
        x = rng.normal(size=(n, d)).astype(np.float32)
        w = sphere(rng, m, d).astype(np.float32)
        table = radial.gaussian_table(d, q, s)
        z_ref = gegenbauer_features_ref(x, w, table.coef, table.expo, table.decay)
        z_pal = gegenbauer_features_pallas(x, w, table.coef, table.expo,
                                           table.decay, block_b=n, block_m=m)
        np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref),
                                   rtol=2e-4, atol=2e-5)

    def test_matches_ref_ntk(self):
        rng = np.random.default_rng(8)
        d, q = 4, 12
        x = rng.normal(size=(8, d)).astype(np.float32)
        x /= np.linalg.norm(x, axis=1, keepdims=True)
        w = sphere(rng, 8, d).astype(np.float32)
        table = radial.ntk_table(d, q)
        z_ref = gegenbauer_features_ref(x, w, table.coef, table.expo, table.decay)
        z_pal = gegenbauer_features_pallas(x, w, table.coef, table.expo,
                                           table.decay, block_b=8, block_m=8)
        np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref),
                                   rtol=2e-4, atol=2e-5)

    def test_tiling_invariance(self):
        # gridding over (n, m) blocks must not change the result
        rng = np.random.default_rng(9)
        d, q, s = 3, 8, 2
        n, m = 32, 32
        x = rng.normal(size=(n, d)).astype(np.float32)
        w = sphere(rng, m, d).astype(np.float32)
        table = radial.gaussian_table(d, q, s)
        z1 = gegenbauer_features_pallas(x, w, table.coef, table.expo,
                                        table.decay, block_b=32, block_m=32)
        z2 = gegenbauer_features_pallas(x, w, table.coef, table.expo,
                                        table.decay, block_b=8, block_m=8)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                   rtol=1e-5, atol=1e-6)

    @given(
        d=st.integers(2, 8),
        q=st.integers(1, 10),
        s=st.integers(1, 3),
        nb=st.sampled_from([4, 8]),
        mb=st.sampled_from([4, 8]),
    )
    @settings(max_examples=12, deadline=None)
    def test_hypothesis_shape_sweep(self, d, q, s, nb, mb):
        rng = np.random.default_rng(q * 100 + d * 10 + s)
        x = rng.normal(size=(nb, d)).astype(np.float32)
        w = sphere(rng, mb, d).astype(np.float32)
        table = radial.gaussian_table(d, q, s)
        z_ref = gegenbauer_features_ref(x, w, table.coef, table.expo, table.decay)
        z_pal = gegenbauer_features_pallas(x, w, table.coef, table.expo,
                                           table.decay, block_b=nb, block_m=mb)
        assert z_pal.shape == (nb, mb * s)
        np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref),
                                   rtol=5e-4, atol=5e-5)

    def test_raw_tile_entry_formula(self):
        # check one entry of the tile against a scalar-by-scalar evaluation
        from compile import gegenbauer as geg
        rng = np.random.default_rng(10)
        d, q, s = 3, 5, 2
        u = sphere(rng, 4, d).astype(np.float32)
        w = sphere(rng, 4, d).astype(np.float32)
        r = rng.uniform(0.1, 1.0, size=(4, (q + 1) * s)).astype(np.float32)
        z = gegenbauer_feature_tile(jnp.asarray(u), jnp.asarray(r),
                                    jnp.asarray(w), q=q, s=s, d=d,
                                    block_b=4, block_m=4)
        b, k, i = 2, 3, 1
        t = float(u[b] @ w[k])
        P = geg.gegenbauer_all(q, d, np.array([t]))[:, 0]
        expect = sum(P[l] * r[b, l * s + i] for l in range(q + 1))
        assert float(z[b, k * s + i]) == pytest.approx(expect, rel=1e-4)


class TestOtherFamiliesThroughPallas:
    @pytest.mark.parametrize("table_fn", [
        lambda d: radial.exponential_table(d, 10, 3, gamma=0.7),
        lambda d: radial.polynomial_table(d, 3, 1.0),
        lambda d: radial.ntk_table(d, 14),
    ])
    def test_family_matches_ref(self, table_fn):
        rng = np.random.default_rng(20)
        d = 4
        table = table_fn(d)
        x = rng.normal(size=(8, d)).astype(np.float32) * 0.6
        w = sphere(rng, 8, d).astype(np.float32)
        z_ref = gegenbauer_features_ref(x, w, table.coef, table.expo, table.decay)
        z_pal = gegenbauer_features_pallas(x, w, table.coef, table.expo,
                                           table.decay, block_b=8, block_m=8)
        np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref),
                                   rtol=5e-4, atol=5e-5)

    def test_float32_inputs_stay_finite_at_radius(self):
        # larger-radius inputs exercise the log-domain radial tables in f32
        rng = np.random.default_rng(21)
        table = radial.gaussian_table(3, 16, 6)
        x = (rng.normal(size=(16, 3)) * 2.0).astype(np.float32)
        w = sphere(rng, 16, 3).astype(np.float32)
        z = gegenbauer_features_pallas(x, w, table.coef, table.expo,
                                       table.decay, block_b=16, block_m=16)
        assert np.all(np.isfinite(np.asarray(z)))


class TestKrrSolveCg:
    def test_cg_residual_on_conditioned_system(self):
        from compile.model import build_krr_solve
        import jax
        rng = np.random.default_rng(22)
        F = 64
        a = rng.normal(size=(F, F)).astype(np.float32) / np.sqrt(F)
        g = a @ a.T
        b = rng.normal(size=F).astype(np.float32)
        lam = np.float32(0.3)
        (w,) = jax.jit(build_krr_solve(F))(g, b, lam)
        resid = (g + lam * np.eye(F)) @ np.asarray(w, np.float64) - b
        assert np.max(np.abs(resid)) < 1e-3, np.max(np.abs(resid))

    def test_cg_graph_has_no_custom_calls(self):
        from compile.aot import to_hlo_text
        from compile.model import build_krr_solve
        import jax
        text = to_hlo_text(jax.jit(build_krr_solve(32)).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((32,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32)))
        assert "custom-call" not in text


class TestUnbiasedness:
    @pytest.mark.parametrize("kind,kw,table_fn", [
        ("gaussian", {}, lambda d: radial.gaussian_table(d, 14, 6)),
        ("exponential", {"gamma": 1.0}, lambda d: radial.exponential_table(d, 14, 6)),
    ])
    def test_zzt_approximates_k(self, kind, kw, table_fn):
        rng = np.random.default_rng(11)
        d, n, m = 3, 24, 4096
        x = (rng.normal(size=(n, d)) * 0.6).astype(np.float32)
        w = sphere(rng, m, d).astype(np.float32)
        table = table_fn(d)
        z = np.asarray(gegenbauer_features_ref(
            x, w, table.coef, table.expo, table.decay), dtype=np.float64)
        K_hat = z @ z.T
        K = exact_gram(x, kind, **kw)
        err = np.max(np.abs(K_hat - K)) / np.max(np.abs(K))
        assert err < 0.15, f"max relative-to-scale error {err}"
