"""The AOT artifact set must cover every dataset geometry the experiments
use — a drift guard between python/compile/aot.py and the rust benches."""

from compile.aot import BLOCK_B, BLOCK_M, FEATURIZE_CONFIGS, KRR_SOLVE_DIMS

# Table 2: elevation S^2 (d=3), CO2/Climate [S^2,R] (d=4), protein R^9
TABLE2_DIMS = {3, 4, 9}
# Table 3: abalone 8, pendigits 16, mushroom 21, magic 10, statlog 9,
# connect-4 42
TABLE3_DIMS = {8, 16, 21, 10, 9, 42}


def test_gaussian_artifacts_cover_experiment_dims():
    covered = {d for (fam, d, _, _) in FEATURIZE_CONFIGS if fam == "gaussian"}
    missing = (TABLE2_DIMS | TABLE3_DIMS) - covered
    assert not missing, f"no gaussian artifact for input dims {missing}"


def test_ntk_artifact_present():
    assert any(fam == "ntk" for (fam, *_rest) in FEATURIZE_CONFIGS)


def test_block_geometry_sane():
    # rust runtime pads rows to BLOCK_B and chunks directions by BLOCK_M;
    # both must be powers of two so padding stays cheap and the pallas
    # BlockSpec tiles evenly
    assert BLOCK_B & (BLOCK_B - 1) == 0
    assert BLOCK_M & (BLOCK_M - 1) == 0
    assert BLOCK_B >= BLOCK_M


def test_truncation_decreases_with_dimension():
    # the q chosen per artifact must not grow with d (alpha_{l,d} explodes);
    # this mirrors the Theorem-12 guidance and keeps artifact sizes sane
    gaussian = sorted((d, q) for (fam, d, q, _) in FEATURIZE_CONFIGS if fam == "gaussian")
    qs = [q for _, q in gaussian]
    assert all(qs[i] >= qs[i + 1] for i in range(len(qs) - 1)), gaussian


def test_krr_solver_dims_cover_feature_budgets():
    # the paper uses m=1024 (Table 2) and m=512 (Table 3)
    assert 512 in KRR_SOLVE_DIMS
    assert 1024 in KRR_SOLVE_DIMS
