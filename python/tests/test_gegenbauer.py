"""Unit tests for the Gegenbauer polynomial machinery."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import gegenbauer as geg


def chebyshev_t(l, t):
    return np.cos(l * np.arccos(np.clip(t, -1, 1)))


def legendre(l, t):
    # explicit Bonnet recurrence, independent implementation
    p0, p1 = np.ones_like(t), t
    if l == 0:
        return p0
    for k in range(2, l + 1):
        p0, p1 = p1, ((2 * k - 1) * t * p1 - (k - 1) * p0) / k
    return p1 if l >= 1 else p0


class TestRecurrence:
    def test_d2_is_chebyshev(self):
        t = np.linspace(-1, 1, 101)
        P = geg.gegenbauer_all(10, 2, t)
        for l in range(11):
            np.testing.assert_allclose(P[l], chebyshev_t(l, t), atol=1e-10)

    def test_d3_is_legendre(self):
        t = np.linspace(-1, 1, 101)
        P = geg.gegenbauer_all(10, 3, t)
        for l in range(11):
            np.testing.assert_allclose(P[l], legendre(l, t), atol=1e-10)

    def test_large_d_approaches_monomials(self):
        t = np.linspace(-1, 1, 11)
        P = geg.gegenbauer_all(5, 100000, t)
        for l in range(6):
            np.testing.assert_allclose(P[l], t**l, atol=1e-3)

    @pytest.mark.parametrize("d", [2, 3, 4, 8, 32])
    def test_normalized_at_one(self, d):
        P = geg.gegenbauer_all(15, d, np.array([1.0]))
        np.testing.assert_allclose(P[:, 0], 1.0, atol=1e-12)

    @pytest.mark.parametrize("d", [2, 3, 4, 8])
    def test_parity(self, d):
        # P_l(-t) = (-1)^l P_l(t)
        t = np.linspace(0, 1, 33)
        Pp = geg.gegenbauer_all(9, d, t)
        Pm = geg.gegenbauer_all(9, d, -t)
        for l in range(10):
            np.testing.assert_allclose(Pm[l], (-1) ** l * Pp[l], atol=1e-12)

    @given(st.integers(3, 40), st.integers(0, 20),
           st.floats(-1.0, 1.0, allow_nan=False))
    @settings(max_examples=100, deadline=None)
    def test_bounded_on_interval(self, d, l, t):
        # |P_d^l(t)| <= 1 on [-1,1] (AH12 Eq. 2.116)
        P = geg.gegenbauer_all(l, d, np.array([t]))
        assert abs(P[l, 0]) <= 1.0 + 1e-9

    def test_matches_explicit_formula_eq2(self):
        # Eq. (2) of the paper: P_d^l(t) = sum_j c_j t^{l-2j} (1-t^2)^j
        rng = np.random.default_rng(0)
        for d in (3, 5, 8):
            for l in (2, 3, 5, 8):
                c = [1.0]
                for j in range(l // 2):
                    c.append(-c[-1] * (l - 2 * j) * (l - 2 * j - 1)
                             / (2 * (j + 1) * (d - 1 + 2 * j)))
                t = rng.uniform(-1, 1, 17)
                direct = sum(cj * t ** (l - 2 * j) * (1 - t * t) ** j
                             for j, cj in enumerate(c))
                P = geg.gegenbauer_all(l, d, t)
                np.testing.assert_allclose(P[l], direct, atol=1e-10)


class TestAlpha:
    def test_small_values(self):
        # alpha_{0,d}=1, alpha_{1,d}=d, alpha_{2,3}=5 (2l+1 for d=3)
        assert geg.alpha_dim(0, 3) == pytest.approx(1)
        assert geg.alpha_dim(1, 3) == pytest.approx(3)
        for l in range(8):
            assert geg.alpha_dim(l, 3) == pytest.approx(2 * l + 1)

    def test_d2_is_two(self):
        for l in range(1, 10):
            assert geg.alpha_dim(l, 2) == pytest.approx(2.0)

    @pytest.mark.parametrize("d", [3, 4, 7, 12])
    def test_binomial_identity(self, d):
        def binom(n, k):
            return math.comb(n, k) if 0 <= k <= n else 0
        for l in range(2, 12):
            expect = binom(d + l - 1, l) - binom(d + l - 3, l - 2)
            assert geg.alpha_dim(l, d) == pytest.approx(expect, rel=1e-10)


class TestOrthogonalityAndReproducing:
    @pytest.mark.parametrize("d", [2, 3, 5, 9])
    def test_quadrature_orthogonality(self, d):
        # Eq. (3): weighted integral of P_l P_l' is diagonal with value
        # |S^{d-1}| / (alpha_{l,d} |S^{d-2}|).
        from scipy.special import roots_jacobi
        a = (d - 3) / 2
        nodes, wts = roots_jacobi(128, a, a)
        P = geg.gegenbauer_all(8, d, nodes)
        ratio = geg.surface_ratio(d)  # |S^{d-2}|/|S^{d-1}|
        G = (P * wts) @ P.T
        for l in range(9):
            for lp in range(9):
                if l == lp:
                    expect = 1.0 / (geg.alpha_dim(l, d) * ratio)
                    assert G[l, lp] == pytest.approx(expect, rel=1e-8)
                else:
                    assert abs(G[l, lp]) < 1e-10

    def test_reproducing_property_monte_carlo(self):
        # Lemma 1: P_l(<x,y>) = alpha_{l,d} E_w[P_l(<x,w>) P_l(<y,w>)]
        rng = np.random.default_rng(1)
        d, l, n_mc = 4, 3, 400_000
        x = rng.normal(size=d); x /= np.linalg.norm(x)
        y = rng.normal(size=d); y /= np.linalg.norm(y)
        w = rng.normal(size=(n_mc, d))
        w /= np.linalg.norm(w, axis=1, keepdims=True)
        Px = geg.gegenbauer_all(l, d, w @ x)[l]
        Py = geg.gegenbauer_all(l, d, w @ y)[l]
        est = geg.alpha_dim(l, d) * np.mean(Px * Py)
        expect = geg.gegenbauer_all(l, d, np.array([x @ y]))[l, 0]
        assert est == pytest.approx(expect, abs=0.02)


class TestSeries:
    @pytest.mark.parametrize("d", [2, 3, 4, 8, 32])
    def test_exp_series_converges(self, d):
        # kappa(t)=exp(2t), degree-15 Gegenbauer series max error well below
        # the Taylor tail (Fig. 1 behaviour).
        c = geg.gegenbauer_series_coeffs(lambda t: math.exp(2 * t), 15, d)
        t = np.linspace(-1, 1, 501)
        P = geg.gegenbauer_all(15, d, t)
        approx = c @ P
        err = np.max(np.abs(approx - np.exp(2 * t)))
        assert err < 1e-6
        assert np.all(c >= -1e-9)  # Schoenberg: PSD kernel -> c_l >= 0

    def test_series_recovers_polynomial_exactly(self):
        # t^3 has an exact degree-3 expansion in any d
        d = 5
        c = geg.gegenbauer_series_coeffs(lambda t: t**3, 8, d)
        assert np.allclose(c[4:], 0, atol=1e-12)
        t = np.linspace(-1, 1, 101)
        P = geg.gegenbauer_all(8, d, t)
        np.testing.assert_allclose(c @ P, t**3, atol=1e-12)
