"""Tests for the radial tables: the GZK truncations must reconstruct their
exact kernels via k(x,y) = sum_l <h_l(|x|),h_l(|y|)> P_l(cos) (Def. 3)."""

import math

import numpy as np
import pytest

from compile import gegenbauer as geg
from compile import radial
from compile.kernels.ref import exact_gram


def gzk_kernel_from_table(table, x, y):
    """Evaluate the truncated GZK k_{q,s}(x,y) directly from Def. 3.

    radial_values folds sqrt(alpha_{l,d}) into R, so
    <R_x[l], R_y[l]> = alpha * <h_l,h_l> and we divide it back out."""
    nx = max(np.linalg.norm(x), 1e-30)
    ny = max(np.linalg.norm(y), 1e-30)
    cos = float(np.clip(x @ y / (nx * ny), -1, 1))
    rx = radial.radial_values(table, np.array([nx]))[0]  # (q+1, s)
    ry = radial.radial_values(table, np.array([ny]))[0]
    P = geg.gegenbauer_all(table.q, table.d, np.array([cos]))[:, 0]
    total = 0.0
    for l in range(table.q + 1):
        alpha = geg.alpha_dim(l, table.d)
        total += (rx[l] @ ry[l]) / alpha * P[l]
    return total


@pytest.mark.parametrize("d", [3, 4, 6])
def test_gaussian_truncation_converges(d):
    rng = np.random.default_rng(2)
    table = radial.gaussian_table(d, q=20, s=10)
    for _ in range(20):
        x = rng.normal(size=d) * 0.7
        y = rng.normal(size=d) * 0.7
        k_exact = math.exp(-0.5 * np.sum((x - y) ** 2))
        k_gzk = gzk_kernel_from_table(table, x, y)
        assert k_gzk == pytest.approx(k_exact, abs=1e-6)


@pytest.mark.parametrize("d,gamma", [(3, 1.0), (5, 0.5), (4, 2.0)])
def test_exponential_truncation_converges(d, gamma):
    rng = np.random.default_rng(3)
    table = radial.exponential_table(d, q=22, s=11, gamma=gamma)
    for _ in range(20):
        x = rng.normal(size=d) * 0.6
        y = rng.normal(size=d) * 0.6
        k_exact = math.exp(gamma * (x @ y))
        k_gzk = gzk_kernel_from_table(table, x, y)
        assert k_gzk == pytest.approx(k_exact, rel=1e-5, abs=1e-6)


@pytest.mark.parametrize("p,c", [(2, 1.0), (3, 0.5), (4, 1.0), (3, 0.0)])
def test_polynomial_is_exact(p, c):
    d = 4
    rng = np.random.default_rng(4)
    table = radial.polynomial_table(d, p, c)
    for _ in range(20):
        x = rng.normal(size=d)
        y = rng.normal(size=d)
        k_exact = (x @ y + c) ** p
        k_gzk = gzk_kernel_from_table(table, x, y)
        assert k_gzk == pytest.approx(k_exact, rel=1e-8, abs=1e-8)


def test_ntk_kappa_fixed_points():
    # K_relu is a normalized kernel: kappa(1) = depth (each layer contributes 1)
    assert radial.ntk_kappa(np.array([1.0]), depth=2)[0] == pytest.approx(2.0)
    assert radial.ntk_kappa(np.array([1.0]), depth=3)[0] == pytest.approx(3.0)


@pytest.mark.parametrize("depth", [2, 3])
def test_ntk_truncation_converges_on_sphere(depth):
    d = 4
    rng = np.random.default_rng(5)
    table = radial.ntk_table(d, q=40, depth=depth)
    for _ in range(10):
        x = rng.normal(size=d); x /= np.linalg.norm(x)
        y = rng.normal(size=d); y /= np.linalg.norm(y)
        cos = np.clip(x @ y, -1, 1)
        k_exact = radial.ntk_kappa(np.array([cos]), depth)[0]
        k_gzk = gzk_kernel_from_table(table, x, y)
        # NTK kappa is non-smooth at |t|=1 -> algebraic Gegenbauer decay
        assert k_gzk == pytest.approx(k_exact, abs=5e-3)


def test_radial_decay_in_l():
    # Section 5: sum_j |h_l|^2 decays fast in l for bounded radius
    table = radial.gaussian_table(4, q=16, s=4)
    r = radial.radial_values(table, np.array([1.5]))[0]  # (q+1, s)
    energy = np.sum(r * r, axis=1)
    assert energy[12] < energy[2] * 1e-4


def test_suggest_q_monotone():
    q1 = radial.suggest_q(r=1.0, d=3, n=1000, lam=1e-3)
    q2 = radial.suggest_q(r=2.0, d=3, n=1000, lam=1e-3)
    q3 = radial.suggest_q(r=1.0, d=3, n=100000, lam=1e-6)
    assert q2 >= q1 and q3 >= q1


def test_exact_gram_kinds():
    rng = np.random.default_rng(6)
    x = rng.normal(size=(5, 3))
    for kind, kw in [("gaussian", {}), ("exponential", {"gamma": 0.5}),
                     ("polynomial", {"p": 2, "c": 1.0}), ("ntk", {"depth": 2})]:
        K = exact_gram(x, kind, **kw)
        assert K.shape == (5, 5)
        np.testing.assert_allclose(K, K.T, atol=1e-12)
        evals = np.linalg.eigvalsh(K)
        assert evals.min() > -1e-8 * max(1, evals.max())
