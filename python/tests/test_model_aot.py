"""L2 graph tests + AOT round-trip: the lowered HLO text must reload through
XlaComputation and reproduce the jit-executed numerics (same path rust uses)."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import radial
from compile.aot import to_hlo_text
from compile.kernels.ref import gegenbauer_features_ref
from compile.model import build_featurize, build_krr_solve


def sphere(rng, m, d):
    w = rng.normal(size=(m, d))
    return (w / np.linalg.norm(w, axis=1, keepdims=True)).astype(np.float32)


class TestFeaturizeGraph:
    def test_matches_ref_with_scaling(self):
        rng = np.random.default_rng(12)
        d, q, s, B, M = 3, 10, 2, 16, 8
        table = radial.gaussian_table(d, q, s)
        fn = build_featurize(table, B, M, m_total=M)
        x = rng.normal(size=(B, d)).astype(np.float32)
        w = sphere(rng, M, d)
        (z,) = jax.jit(fn)(x, w)
        z_ref = gegenbauer_features_ref(x, w, table.coef, table.expo, table.decay)
        np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref),
                                   rtol=2e-4, atol=2e-5)

    def test_m_total_rescaling(self):
        # chunking a 2M direction set through an M-direction graph: the rust
        # runtime rescales by sqrt(M / m_total); verify the identity here.
        rng = np.random.default_rng(13)
        d, q, s, B, M = 3, 6, 2, 8, 8
        table = radial.gaussian_table(d, q, s)
        x = rng.normal(size=(B, d)).astype(np.float32)
        w = sphere(rng, 2 * M, d)
        fn_m = jax.jit(build_featurize(table, B, M, m_total=M))
        (z0,) = fn_m(x, w[:M])
        (z1,) = fn_m(x, w[M:])
        z_chunked = np.concatenate([np.asarray(z0), np.asarray(z1)], axis=1)
        z_chunked *= np.sqrt(M / (2 * M))
        z_full = gegenbauer_features_ref(x, w, table.coef, table.expo, table.decay)
        np.testing.assert_allclose(z_chunked, np.asarray(z_full),
                                   rtol=2e-4, atol=2e-5)


class TestKrrSolveGraph:
    def test_solves_spd_system(self):
        rng = np.random.default_rng(14)
        F = 16
        a = rng.normal(size=(F, F)).astype(np.float32)
        g = a @ a.T
        b = rng.normal(size=F).astype(np.float32)
        lam = np.float32(0.5)
        (w,) = jax.jit(build_krr_solve(F))(g, b, lam)
        resid = (g + lam * np.eye(F)) @ np.asarray(w, dtype=np.float64) - b
        assert np.max(np.abs(resid)) < 1e-3


class TestAotRoundTrip:
    def test_hlo_text_parses_back(self):
        # Lower -> HLO text -> HloModule parse (the same C++ text parser the
        # rust xla crate calls via HloModuleProto::from_text_file). Execution
        # of the parsed module is covered by the rust integration tests —
        # jaxlib's python client only accepts stablehlo payloads.
        d, q, s, B, M = 3, 8, 2, 8, 8
        table = radial.gaussian_table(d, q, s)
        fn = build_featurize(table, B, M, m_total=M)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((M, d), jnp.float32))
        text = to_hlo_text(lowered)
        assert "ENTRY" in text and "HloModule" in text
        hlo = xc._xla.hlo_module_from_text(text)
        # parse must preserve the entry computation signature
        reparsed = hlo.to_string()
        assert f"f32[{B},{d}]" in reparsed
        assert f"f32[{B},{M * s}]" in reparsed

    def test_no_elided_constants(self):
        # REGRESSION: the default HLO printer elides large constant arrays
        # as `constant({...})`, which the text parser reads back as zeros —
        # wiping out the baked radial tables. to_hlo_text must print full
        # literals.
        d, q, s, B, M = 3, 12, 2, 16, 8
        table = radial.gaussian_table(d, q, s)
        fn = build_featurize(table, B, M, m_total=M)
        text = to_hlo_text(jax.jit(fn).lower(
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((M, d), jnp.float32)))
        assert "{...}" not in text, "HLO text contains elided constants"
        # one recognizable radial coefficient must appear verbatim-ish:
        # coef[1*s+0] = alpha_{1,3}/sqrt(2)-ish value; just check a long
        # float array is present
        assert text.count("constant(") >= 2

    def test_manifest_configs_lower(self):
        # every manifest featurize config must lower to HLO text with the
        # expected entry signature (fast smoke: first two + krr_solve)
        from compile.aot import FEATURIZE_CONFIGS, BLOCK_B, BLOCK_M, make_table
        family, d, q, s = FEATURIZE_CONFIGS[0]
        table = make_table(family, d, q, s)
        fn = build_featurize(table, BLOCK_B, BLOCK_M, BLOCK_M)
        text = to_hlo_text(jax.jit(fn).lower(
            jax.ShapeDtypeStruct((BLOCK_B, d), jnp.float32),
            jax.ShapeDtypeStruct((BLOCK_M, d), jnp.float32)))
        assert f"f32[{BLOCK_B},{d}]" in text
        assert f"f32[{BLOCK_B},{BLOCK_M * s}]" in text

        text = to_hlo_text(jax.jit(build_krr_solve(64)).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32)))
        assert "f32[64,64]" in text
