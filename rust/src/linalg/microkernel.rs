//! Register-blocked, cache-tiled microkernel engine for the dense hot
//! path (DESIGN.md §2d).
//!
//! Every dense product in the system — `matmul` / `matmul_nt` /
//! `matmul_tn` / SYRK / `matvec` — funnels into [`Gemm`]: one driver that
//! views each operand as a K×W panel in k-major orientation ([`Panel`]),
//! packs the operand panels contiguously, and updates the output through
//! an MR×NR register tile ([`tile_kernel`]) whose inner loop streams the
//! packed panels with `chunks_exact`, so the autovectorizer emits packed
//! multiply/add over the NR accumulator lanes.
//!
//! **Why tiling preserves bit-identity.** The PR-3 contract — parallel
//! kernels bit-identical to serial at every thread count, and the
//! `data::pipeline` chunk-invariance contract on top — pins, for every
//! output cell, a single owner and a fixed k-ascending reduction order.
//! The microkernels keep both invariants by construction:
//!
//! * Register blocking groups *cells* (an MR×NR tile of independent
//!   accumulators), never the reduction: each accumulator still receives
//!   its `a[k] * b[k]` contributions one at a time in ascending k. SIMD
//!   lanes run across the NR output columns, so vector width cannot
//!   change any cell's rounding sequence.
//! * Cache tiling over k (KC-deep panels) spills the accumulator tile to
//!   the output cell between panels and reloads it for the next one.
//!   An f64 store/load is exact, so `(((c + p0) + p1) + p2)…` is the same
//!   value chain whether the accumulator lives in a register for the
//!   whole reduction or round-trips through memory at panel boundaries.
//!   The same argument is what already made SYRK bit-invariant to the
//!   pipeline's `chunk_rows`.
//! * Remainder edges (rows past the last MR tile, columns past the last
//!   NR panel, diagonal-straddling SYRK tiles) run a scalar tail that
//!   performs the *identical* per-cell operation sequence — load cell,
//!   ascending multiply-adds, store cell — so a cell's bits do not depend
//!   on which worker's tile grid it lands in. That is what keeps the
//!   parallel row partition (whose tile grid is aligned to each worker's
//!   `lo`, not to row 0) bit-identical to the serial kernel.
//! * Packing is a pure copy; it never reassociates anything.
//!
//! **The `== 0.0` skips are gone — symmetrically.** The pre-microkernel
//! `matmul`/`matmul_tn`/SYRK bodies skipped zero multiplier entries; a
//! branch per k step would defeat the vectorizer, so both the serial and
//! the parallel path (one body serves both) now add every `a[k] * b[k]`
//! term. For finite inputs this is bit-exact with the skipping kernels:
//! the skipped terms are `±0.0 * b` = `±0.0`, and `acc + ±0.0 == acc`
//! for every accumulator reachable from a `+0.0` start (a running sum
//! seeded at `+0.0` can never become `-0.0`). Only non-finite inputs
//! (where `0.0 * inf` is `NaN`) could observe the difference; every data
//! path validates finiteness at the boundary. The frozen pre-PR kernels
//! live on in [`naive`] as the property-test reference and the bench
//! baseline, and `tests/linalg_props.rs` asserts 0-ULP agreement across
//! shape sweeps, thread counts, tile geometries and KC depths.
//!
//! The default geometry is [`MR`]×[`NR`] with [`KC`]-deep panels — sized
//! for the baseline x86-64 target (16 SIMD registers: a 4×4 f64 tile
//! leaves room for the broadcast and panel loads). `benches/hotpath.rs`
//! sweeps MR×NR ∈ {4×4, 8×4, 8×8} × KC ∈ {128, 256, 512} through
//! [`matmul_with_tile`] and fails if this default is not within 10% of
//! the sweep winner on the bench host.

use super::matrix::triangle_bounds;
use crate::exec::Pool;
use crate::linalg::Mat;

/// Default register-tile rows (accumulator tile height).
pub const MR: usize = 4;
/// Default register-tile columns (accumulator tile width — the SIMD axis).
pub const NR: usize = 4;
/// Default k-panel depth: `KC * NR * 8` bytes of packed B per panel stay
/// cache-resident while a row block streams over them.
pub const KC: usize = 256;

/// One GEMM operand, viewed as a K×W matrix in k-major orientation: the
/// reduction index `kk` runs over K, the panel index `w` over W output
/// rows (the A operand) or output columns (the B operand).
#[derive(Clone, Copy)]
enum Panel<'a> {
    /// Panel entries are *rows* of a row-major (W_total × K) matrix:
    /// element `(kk, w)` is `data[w * k + kk]`. Packing transposes.
    Rows { data: &'a [f64], k: usize },
    /// Panel entries are *columns* of a row-major (K × stride) matrix:
    /// element `(kk, w)` is `data[kk * stride + w]`. Already k-major;
    /// packing gathers contiguous row segments.
    Cols { data: &'a [f64], stride: usize },
}

impl Panel<'_> {
    #[inline(always)]
    fn at(&self, kk: usize, w: usize) -> f64 {
        match *self {
            Panel::Rows { data, k } => data[w * k + kk],
            Panel::Cols { data, stride } => data[kk * stride + w],
        }
    }

    /// Append the `W`-wide panel starting at `w0`, rows `kc0 .. kc0+kcl`
    /// of the reduction, to `out` in k-major layout (`out[kk * W + w]`).
    fn pack_append<const W: usize>(&self, w0: usize, kc0: usize, kcl: usize, out: &mut Vec<f64>) {
        let base = out.len();
        match *self {
            Panel::Rows { data, k } => {
                out.resize(base + kcl * W, 0.0);
                let dst = &mut out[base..];
                for w in 0..W {
                    let row = &data[(w0 + w) * k + kc0..(w0 + w) * k + kc0 + kcl];
                    for (kk, &v) in row.iter().enumerate() {
                        dst[kk * W + w] = v;
                    }
                }
            }
            Panel::Cols { data, stride } => {
                out.reserve(kcl * W);
                for kk in kc0..kc0 + kcl {
                    let s = kk * stride + w0;
                    out.extend_from_slice(&data[s..s + W]);
                }
            }
        }
    }

    /// Pack panels `[p0, p1)` (each `W` wide, at `w0 = p * W`) for the
    /// `kc0 .. kc0+kcl` reduction window, back to back.
    fn pack_range<const W: usize>(
        &self,
        p0: usize,
        p1: usize,
        kc0: usize,
        kcl: usize,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        for p in p0..p1 {
            self.pack_append::<W>(p * W, kc0, kcl, out);
        }
    }
}

/// The MR×NR register microkernel: load the output tile, stream the two
/// packed panels in lockstep over the `kcl` reduction steps, store the
/// tile back. `apack` is `kcl × MRV` k-major, `bpack` is `kcl × NRV`
/// k-major, `c` points at the tile's top-left cell with row stride `ldc`.
///
/// The `chunks_exact` iteration hands the optimizer fixed-size rows, the
/// MRV×NRV accumulator array lives in registers after unrolling, and the
/// NRV-wide inner loop is the packed-SIMD axis. Per accumulator the
/// reduction is a plain ascending `acc += a * b` chain — exactly the
/// scalar kernels' order.
#[inline]
fn tile_kernel<const MRV: usize, const NRV: usize>(
    apack: &[f64],
    bpack: &[f64],
    c: &mut [f64],
    ldc: usize,
) {
    let mut acc = [[0.0f64; NRV]; MRV];
    for (arow, crow) in acc.iter_mut().zip(c.chunks(ldc)) {
        arow.copy_from_slice(&crow[..NRV]);
    }
    for (ap, bp) in apack.chunks_exact(MRV).zip(bpack.chunks_exact(NRV)) {
        for (&av, arow) in ap.iter().zip(acc.iter_mut()) {
            for (cv, &bv) in arow.iter_mut().zip(bp) {
                *cv += av * bv;
            }
        }
    }
    for (arow, crow) in acc.iter().zip(c.chunks_mut(ldc)) {
        crow[..NRV].copy_from_slice(arow);
    }
}

/// A dense product `C += A^T-view · B-view` over k-major operand panels —
/// the one engine behind `matmul`, `matmul_nt`, `matmul_tn` and SYRK.
///
/// A `Gemm` is a cheap borrowed descriptor; [`Gemm::run_default`] is the
/// block body handed to [`Pool::par_chunks`] / [`Pool::scatter_rows`] by
/// the `Mat` entry points, computing output rows `[lo, hi)` of the
/// product into `block` (accumulating — callers zero fresh outputs).
pub struct Gemm<'a> {
    a: Panel<'a>,
    b: Panel<'a>,
    /// Reduction depth K.
    kdim: usize,
    /// Output width (columns of C).
    n: usize,
    /// SYRK mode: only cells of the upper triangle (`j >= i`) are
    /// computed; everything below the diagonal is left untouched.
    upper: bool,
}

impl<'a> Gemm<'a> {
    /// `a * b` — output row i is a row of `a` (m × k), operand B is
    /// `b` (k × n) in natural k-major orientation.
    pub fn matmul(a: &'a Mat, b: &'a Mat) -> Gemm<'a> {
        Gemm {
            a: Panel::Rows { data: a.data(), k: a.cols() },
            b: Panel::Cols { data: b.data(), stride: b.cols() },
            kdim: a.cols(),
            n: b.cols(),
            upper: false,
        }
    }

    /// `a * b^T` — both operands are row panels reduced over their
    /// (shared) column count.
    pub fn matmul_nt(a: &'a Mat, b: &'a Mat) -> Gemm<'a> {
        Gemm {
            a: Panel::Rows { data: a.data(), k: a.cols() },
            b: Panel::Rows { data: b.data(), k: b.cols() },
            kdim: a.cols(),
            n: b.rows(),
            upper: false,
        }
    }

    /// `a^T * b` — both operands are column panels of k-row matrices.
    pub fn matmul_tn(a: &'a Mat, b: &'a Mat) -> Gemm<'a> {
        Gemm {
            a: Panel::Cols { data: a.data(), stride: a.cols() },
            b: Panel::Cols { data: b.data(), stride: b.cols() },
            kdim: a.rows(),
            n: b.cols(),
            upper: false,
        }
    }

    /// `z^T z` (upper triangle) over a flat row-major buffer of `f`-wide
    /// rows — the ridge/KPCA Gram accumulation. `z.len()` must be a whole
    /// number of rows and `f > 0` (asserted by the `Mat` entry points).
    pub fn syrk(z: &'a [f64], f: usize) -> Gemm<'a> {
        Gemm {
            a: Panel::Cols { data: z, stride: f },
            b: Panel::Cols { data: z, stride: f },
            kdim: z.len() / f,
            n: f,
            upper: true,
        }
    }

    /// [`Gemm::run`] at the default [`MR`]×[`NR`]×[`KC`] geometry.
    #[inline]
    pub fn run_default(&self, lo: usize, hi: usize, block: &mut [f64]) {
        self.run::<MR, NR>(KC, lo, hi, block);
    }

    /// Compute output rows `[lo, hi)` into `block` (a `(hi-lo) × n`
    /// row-major slice), accumulating onto whatever `block` holds, with
    /// an explicit MRV×NRV register tile and `kc`-deep cache panels.
    /// Bit-identical for every (MRV, NRV, kc) — tiling never changes a
    /// cell's reduction order (module docs).
    pub fn run<const MRV: usize, const NRV: usize>(
        &self,
        kc: usize,
        lo: usize,
        hi: usize,
        block: &mut [f64],
    ) {
        let n = self.n;
        debug_assert!(MRV > 0 && NRV > 0);
        debug_assert_eq!(block.len(), (hi - lo) * n);
        if lo >= hi || n == 0 || self.kdim == 0 {
            return;
        }
        let kc = kc.max(1);
        // panel range: [p0, p1) are the NRV-wide B panels any full tile
        // of this row block can touch (SYRK tiles never reach left of
        // the diagonal, so panels below lo's are dead weight)
        let p1 = n / NRV;
        let p0 = if self.upper { (lo / NRV).min(p1) } else { 0 };
        let has_tiles = hi - lo >= MRV && p0 < p1;
        let mut bpack: Vec<f64> = Vec::new();
        let mut apack: Vec<f64> = Vec::new();
        let mut kc0 = 0usize;
        while kc0 < self.kdim {
            let kcl = kc.min(self.kdim - kc0);
            if has_tiles {
                self.b.pack_range::<NRV>(p0, p1, kc0, kcl, &mut bpack);
            }
            let mut i0 = lo;
            while i0 < hi {
                if i0 + MRV <= hi {
                    apack.clear();
                    self.a.pack_append::<MRV>(i0, kc0, kcl, &mut apack);
                    for p in p0..p1 {
                        let j0 = p * NRV;
                        if self.upper && j0 + NRV - 1 < i0 {
                            continue; // tile entirely below the diagonal
                        }
                        if !self.upper || j0 >= i0 + MRV - 1 {
                            let bp = &bpack[(p - p0) * kcl * NRV..][..kcl * NRV];
                            let c0 = (i0 - lo) * n + j0;
                            tile_kernel::<MRV, NRV>(&apack, bp, &mut block[c0..], n);
                        } else {
                            // diagonal-straddling SYRK tile: per-cell
                            // scalar with the j >= i guard
                            for ii in 0..MRV {
                                let i = i0 + ii;
                                for j in j0.max(i)..j0 + NRV {
                                    self.cell(i, j, kc0, kcl, &mut block[(i - lo) * n + j]);
                                }
                            }
                        }
                    }
                    // columns past the last full NRV panel
                    for j in p1 * NRV..n {
                        for ii in 0..MRV {
                            let i = i0 + ii;
                            if self.upper && j < i {
                                continue;
                            }
                            self.cell(i, j, kc0, kcl, &mut block[(i - lo) * n + j]);
                        }
                    }
                    i0 += MRV;
                } else {
                    // rows past the last full MRV tile of this range
                    self.tail_row(i0, kc0, kcl, lo, block);
                    i0 += 1;
                }
            }
            kc0 += kcl;
        }
    }

    /// One output cell, one `kc`-window: load, ascending multiply-adds,
    /// store — the exact operation sequence of [`tile_kernel`] for a
    /// single accumulator, so edge cells match tile cells bit for bit.
    #[inline]
    fn cell(&self, i: usize, j: usize, kc0: usize, kcl: usize, c: &mut f64) {
        let mut acc = *c;
        for kk in kc0..kc0 + kcl {
            acc += self.at_a(kk, i) * self.b.at(kk, j);
        }
        *c = acc;
    }

    #[inline(always)]
    fn at_a(&self, kk: usize, w: usize) -> f64 {
        self.a.at(kk, w)
    }

    /// A full output row below the MRV tile grid. When B is a k-major
    /// column panel the row streams B rows axpy-style (each cell's
    /// memory accumulator receives its terms in the same ascending
    /// order — an exact spill per step); a row-panel B keeps the
    /// cache-friendly per-cell dot instead.
    fn tail_row(&self, i: usize, kc0: usize, kcl: usize, lo: usize, block: &mut [f64]) {
        let n = self.n;
        let jstart = if self.upper { i.min(n) } else { 0 };
        match self.b {
            Panel::Cols { data, stride } => {
                let crow = &mut block[(i - lo) * n + jstart..(i - lo) * n + n];
                for kk in kc0..kc0 + kcl {
                    let av = self.a.at(kk, i);
                    let brow = &data[kk * stride + jstart..kk * stride + n];
                    for (c, &bv) in crow.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
            }
            Panel::Rows { .. } => {
                for j in jstart..n {
                    self.cell(i, j, kc0, kcl, &mut block[(i - lo) * n + j]);
                }
            }
        }
    }
}

/// `a * b` with an explicit tile geometry — the bench's tile-sweep entry
/// point. Bit-identical to [`Mat::matmul`] for every (MRV, NRV, kc).
pub fn matmul_with_tile<const MRV: usize, const NRV: usize>(
    a: &Mat,
    b: &Mat,
    kc: usize,
    pool: &Pool,
) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, n) = (a.rows(), b.cols());
    let mut out = Mat::zeros(m, n);
    let gemm = Gemm::matmul(a, b);
    pool.par_chunks(m, out.data_mut(), |lo, hi, block| {
        gemm.run::<MRV, NRV>(kc, lo, hi, block)
    });
    out
}

/// `out += z^T z` (upper triangle) with an explicit tile geometry.
/// Bit-identical to [`Mat::syrk_into_p`] for every (MRV, NRV, kc).
pub fn syrk_with_tile<const MRV: usize, const NRV: usize>(
    z: &Mat,
    kc: usize,
    pool: &Pool,
    out: &mut Mat,
) {
    let f = z.cols();
    assert_eq!(out.rows(), f, "syrk: output shape mismatch");
    assert_eq!(out.cols(), f, "syrk: output shape mismatch");
    if f == 0 {
        return;
    }
    let gemm = Gemm::syrk(z.data(), f);
    let bounds = triangle_bounds(f, pool.threads());
    pool.scatter_rows(&bounds, out.data_mut(), |lo, hi, block| {
        gemm.run::<MRV, NRV>(kc, lo, hi, block)
    });
}

/// `matvec` block body: rows `[lo, hi)` of `A x`. Four independent
/// accumulator chains hide the add latency and share the streamed `x`;
/// each chain is the exact sequential dot of the scalar kernel, so the
/// 4-row grouping (like every other tiling here) cannot change bits.
pub(crate) fn matvec_block(
    data: &[f64],
    cols: usize,
    x: &[f64],
    lo: usize,
    hi: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), hi - lo);
    let x = &x[..cols];
    const RB: usize = 4;
    let mut i = lo;
    while i + RB <= hi {
        let r0 = &data[i * cols..(i + 1) * cols];
        let r1 = &data[(i + 1) * cols..(i + 2) * cols];
        let r2 = &data[(i + 2) * cols..(i + 3) * cols];
        let r3 = &data[(i + 3) * cols..(i + 4) * cols];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for t in 0..cols {
            let xv = x[t];
            a0 += r0[t] * xv;
            a1 += r1[t] * xv;
            a2 += r2[t] * xv;
            a3 += r3[t] * xv;
        }
        out[i - lo] = a0;
        out[i - lo + 1] = a1;
        out[i - lo + 2] = a2;
        out[i - lo + 3] = a3;
        i += RB;
    }
    while i < hi {
        let row = &data[i * cols..(i + 1) * cols];
        let mut acc = 0.0f64;
        for (&a, &b) in row.iter().zip(x) {
            acc += a * b;
        }
        out[i - lo] = acc;
        i += 1;
    }
}

/// The pre-microkernel kernels, frozen verbatim (including their
/// `== 0.0` skip branches): the 0-ULP reference for
/// `tests/linalg_props.rs` and the baseline the hotpath bench's GFLOP/s
/// section measures the microkernels against. Not used by any fit or
/// serve path.
pub mod naive {
    use super::triangle_bounds;
    use crate::exec::Pool;
    use crate::linalg::Mat;

    fn matmul_block(a: &Mat, b: &Mat, lo: usize, hi: usize, block: &mut [f64]) {
        let (k, n) = (a.cols(), b.cols());
        for i in lo..hi {
            let a_row = a.row(i);
            let out_row = &mut block[(i - lo) * n..(i - lo + 1) * n];
            for (kk, &aik) in a_row.iter().enumerate().take(k) {
                if aik == 0.0 {
                    continue;
                }
                let b_row = &b.data()[kk * n..(kk + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(b_row) {
                    *o += aik * bv;
                }
            }
        }
    }

    /// Pre-PR `a * b` (serial).
    pub fn matmul(a: &Mat, b: &Mat) -> Mat {
        matmul_p(a, b, &Pool::serial())
    }

    /// Pre-PR `a * b`, output rows scattered across the pool.
    pub fn matmul_p(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
        assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
        let (m, n) = (a.rows(), b.cols());
        let mut out = Mat::zeros(m, n);
        pool.par_chunks(m, out.data_mut(), |lo, hi, block| matmul_block(a, b, lo, hi, block));
        out
    }

    fn matmul_nt_block(a: &Mat, b: &Mat, lo: usize, hi: usize, block: &mut [f64]) {
        let (n, k) = (b.rows(), a.cols());
        for i in lo..hi {
            let ar = a.row(i);
            let out_row = &mut block[(i - lo) * n..(i - lo + 1) * n];
            for (j, o) in out_row.iter_mut().enumerate() {
                let br = b.row(j);
                let mut acc = 0.0;
                for t in 0..k {
                    acc += ar[t] * br[t];
                }
                *o = acc;
            }
        }
    }

    /// Pre-PR `a * b^T` (serial).
    pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
        matmul_nt_p(a, b, &Pool::serial())
    }

    /// Pre-PR `a * b^T`, output rows scattered across the pool.
    pub fn matmul_nt_p(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
        assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
        let (m, n) = (a.rows(), b.rows());
        let mut out = Mat::zeros(m, n);
        pool.par_chunks(m, out.data_mut(), |lo, hi, block| matmul_nt_block(a, b, lo, hi, block));
        out
    }

    fn matmul_tn_block(a: &Mat, b: &Mat, lo: usize, hi: usize, block: &mut [f64]) {
        let (k, n) = (a.rows(), b.cols());
        for t in 0..k {
            let ar = a.row(t);
            let br = b.row(t);
            for i in lo..hi {
                let ai = ar[i];
                if ai == 0.0 {
                    continue;
                }
                let out_row = &mut block[(i - lo) * n..(i - lo + 1) * n];
                for (o, &bj) in out_row.iter_mut().zip(br) {
                    *o += ai * bj;
                }
            }
        }
    }

    /// Pre-PR `a^T * b` (serial).
    pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
        matmul_tn_p(a, b, &Pool::serial())
    }

    /// Pre-PR `a^T * b`, output rows scattered across the pool.
    pub fn matmul_tn_p(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
        assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
        let (m, n) = (a.cols(), b.cols());
        let mut out = Mat::zeros(m, n);
        pool.par_chunks(m, out.data_mut(), |lo, hi, block| matmul_tn_block(a, b, lo, hi, block));
        out
    }

    fn syrk_flat_block(z: &[f64], f: usize, lo: usize, hi: usize, block: &mut [f64]) {
        for zrow in z.chunks_exact(f) {
            for i in lo..hi {
                let zi = zrow[i];
                if zi == 0.0 {
                    continue;
                }
                let out_row = &mut block[(i - lo) * f..(i - lo) * f + f];
                for j in i..f {
                    out_row[j] += zi * zrow[j];
                }
            }
        }
    }

    /// Pre-PR `out += z^T z` (upper triangle) over a flat buffer.
    pub fn syrk_flat_into_p(z: &[f64], f: usize, out: &mut Mat, pool: &Pool) {
        assert_eq!(out.rows(), f, "syrk: output shape mismatch");
        assert_eq!(out.cols(), f, "syrk: output shape mismatch");
        if f == 0 {
            return;
        }
        assert_eq!(z.len() % f, 0, "syrk: buffer is not a whole number of rows");
        let bounds = triangle_bounds(f, pool.threads());
        pool.scatter_rows(&bounds, out.data_mut(), |lo, hi, block| {
            syrk_flat_block(z, f, lo, hi, block)
        });
    }

    /// Pre-PR `out += z^T z` over a `Mat` (serial).
    pub fn syrk_into(z: &Mat, out: &mut Mat) {
        syrk_flat_into_p(z.data(), z.cols(), out, &Pool::serial());
    }

    /// Pre-PR `A x` (serial).
    pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
        assert_eq!(a.cols(), x.len());
        let mut out = Vec::with_capacity(a.rows());
        for i in 0..a.rows() {
            out.push(a.row(i).iter().zip(x).map(|(&av, &b)| av * b).sum());
        }
        out
    }

    /// Pre-PR `A^T x` (serial).
    pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
        assert_eq!(a.rows(), x.len());
        let mut out = vec![0.0; a.cols()];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            for (o, &av) in out.iter_mut().zip(a.row(i)) {
                *o += xi * av;
            }
        }
        out
    }
}
