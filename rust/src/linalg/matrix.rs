//! Row-major dense f64 matrix with blocked kernels.
//!
//! Every hot kernel (`matmul` / `matmul_nt` / `matmul_tn` / `syrk_into` /
//! `matvec`) is written as a *block body* over a contiguous range of
//! output rows; the serial entry point runs the body once over the whole
//! range and the `_p` variant scatters disjoint ranges across a
//! [`Pool`](crate::exec::Pool). Because each output cell is produced by
//! exactly one worker running the exact serial inner loop — the reduction
//! order per output tile is fixed — the parallel kernels are
//! **bit-identical** to the serial ones for every thread count
//! (property-tested in `tests/exec_props.rs`).

use crate::exec::Pool;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Sub-block of whole rows [r0, r1).
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat::from_vec(r1 - r0, self.cols, self.data[r0 * self.cols..r1 * self.cols].to_vec())
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Output rows [lo, hi) of self * other into `block` (a (hi-lo) x n
    /// slice of the product). i-k-j loop order: streams `other` rows,
    /// accumulates into out rows in fixed k-ascending order.
    fn matmul_block(&self, other: &Mat, lo: usize, hi: usize, block: &mut [f64]) {
        let (k, n) = (self.cols, other.cols);
        for i in lo..hi {
            let a_row = self.row(i);
            let out_row = &mut block[(i - lo) * n..(i - lo + 1) * n];
            for (kk, &aik) in a_row.iter().enumerate().take(k) {
                if aik == 0.0 {
                    continue;
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += aik * b;
                }
            }
        }
    }

    /// self * other, blocked over k for cache friendliness.
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_p(other, &Pool::serial())
    }

    /// Parallel [`matmul`](Mat::matmul): output rows scattered across the
    /// pool, bit-identical to the serial kernel at every thread count.
    pub fn matmul_p(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        let mut out = Mat::zeros(m, n);
        pool.par_chunks(m, &mut out.data, |lo, hi, block| {
            self.matmul_block(other, lo, hi, block)
        });
        out
    }

    /// Output rows [lo, hi) of self * other^T into `block`.
    fn matmul_nt_block(&self, other: &Mat, lo: usize, hi: usize, block: &mut [f64]) {
        let (n, k) = (other.rows, self.cols);
        for i in lo..hi {
            let a = self.row(i);
            let out_row = &mut block[(i - lo) * n..(i - lo + 1) * n];
            for j in 0..n {
                let b = other.row(j);
                let mut acc = 0.0;
                for t in 0..k {
                    acc += a[t] * b[t];
                }
                out_row[j] = acc;
            }
        }
    }

    /// self * other^T — the featurizer's shape (rows x rows dot products).
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        self.matmul_nt_p(other, &Pool::serial())
    }

    /// Parallel [`matmul_nt`](Mat::matmul_nt), bit-identical to serial.
    pub fn matmul_nt_p(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        pool.par_chunks(m, &mut out.data, |lo, hi, block| {
            self.matmul_nt_block(other, lo, hi, block)
        });
        out
    }

    /// Output rows [lo, hi) of self^T * other into `block`. The reduction
    /// over t runs in fixed ascending order for every cell, so any row
    /// partition of the output yields bit-identical results.
    fn matmul_tn_block(&self, other: &Mat, lo: usize, hi: usize, block: &mut [f64]) {
        let (k, n) = (self.rows, other.cols);
        for t in 0..k {
            let a = self.row(t);
            let b = other.row(t);
            for i in lo..hi {
                let ai = a[i];
                if ai == 0.0 {
                    continue;
                }
                let out_row = &mut block[(i - lo) * n..(i - lo + 1) * n];
                for (o, &bj) in out_row.iter_mut().zip(b) {
                    *o += ai * bj;
                }
            }
        }
    }

    /// self^T * other (k x m)(k x n) -> (m x n); used for Z^T Z reductions.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        self.matmul_tn_p(other, &Pool::serial())
    }

    /// Parallel [`matmul_tn`](Mat::matmul_tn), bit-identical to serial.
    pub fn matmul_tn_p(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (m, n) = (self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        pool.par_chunks(m, &mut out.data, |lo, hi, block| {
            self.matmul_tn_block(other, lo, hi, block)
        });
        out
    }

    /// Symmetric rank-k update: out += self^T self (Gram of the rows).
    pub fn syrk_into(&self, out: &mut Mat) {
        self.syrk_into_p(out, &Pool::serial());
    }

    /// Parallel [`syrk_into`](Mat::syrk_into): output rows partitioned so
    /// each worker owns ~equal upper-triangle area (early rows are wider),
    /// bit-identical to the serial kernel at every thread count.
    pub fn syrk_into_p(&self, out: &mut Mat, pool: &Pool) {
        syrk_flat_into_p(&self.data, self.cols, out, pool)
    }

    /// Mirror the upper triangle into the lower (companion to syrk_into).
    pub fn symmetrize_from_upper(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..i {
                self.data[i * self.cols + j] = self.data[j * self.cols + i];
            }
        }
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_p(x, &Pool::serial())
    }

    /// Parallel [`matvec`](Mat::matvec): output entries scattered across
    /// the pool, bit-identical to serial (each entry is one serial dot).
    pub fn matvec_p(&self, x: &[f64], pool: &Pool) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut out = vec![0.0; self.rows];
        pool.par_chunks(self.rows, &mut out, |lo, _hi, block| {
            for (r, o) in block.iter_mut().enumerate() {
                *o = self.row(lo + r).iter().zip(x).map(|(&a, &b)| a * b).sum();
            }
        });
        out
    }

    /// self^T x (length rows) -> length cols.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0; self.cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += xi * a;
            }
        }
        out
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn add_diag(&mut self, v: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += v;
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Operator (spectral) norm via power iteration on self^T self.
    pub fn op_norm_est(&self, iters: usize) -> f64 {
        let n = self.cols;
        if n == 0 || self.rows == 0 {
            return 0.0;
        }
        let mut v: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 + 0.1).collect();
        let mut norm = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let atav = self.matvec_t(&av);
            norm = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 0.0;
            }
            for (vi, &a) in v.iter_mut().zip(&atav) {
                *vi = a / norm;
            }
        }
        norm.sqrt()
    }
}

/// Accumulate output rows [lo, hi) of the rank-k update z^T z into `block`
/// (upper triangle only; per-cell reduction over the rows of `z` in fixed
/// ascending order), where `z` is a flat row-major buffer of `f`-wide rows.
fn syrk_flat_block(z: &[f64], f: usize, lo: usize, hi: usize, block: &mut [f64]) {
    for zrow in z.chunks_exact(f) {
        for i in lo..hi {
            let zi = zrow[i];
            if zi == 0.0 {
                continue;
            }
            let out_row = &mut block[(i - lo) * f..(i - lo) * f + f];
            // only upper triangle, mirrored below
            for j in i..f {
                out_row[j] += zi * zrow[j];
            }
        }
    }
}

/// [`Mat::syrk_into_p`] over a flat row-major buffer of `f`-wide rows —
/// the out-of-core chunk path accumulates `Z^T Z` straight from its reused
/// scratch slice without wrapping it in a `Mat`. Because each output cell
/// accumulates over the rows of `z` in fixed ascending order, feeding the
/// same rows in any chunking produces bit-identical sums (the
/// chunk-invariance contract of `data::pipeline`).
pub fn syrk_flat_into_p(z: &[f64], f: usize, out: &mut Mat, pool: &Pool) {
    assert_eq!(out.rows, f, "syrk: output shape mismatch");
    assert_eq!(out.cols, f, "syrk: output shape mismatch");
    if f == 0 {
        return;
    }
    assert_eq!(z.len() % f, 0, "syrk: buffer is not a whole number of rows");
    let bounds = triangle_bounds(f, pool.threads());
    pool.scatter_rows(&bounds, &mut out.data, |lo, hi, block| {
        syrk_flat_block(z, f, lo, hi, block)
    });
}

/// Partition `0..f` into at most `parts` contiguous ranges of ~equal
/// upper-triangle area (row i of a SYRK touches `f - i` cells, so equal
/// row counts would leave the first worker with most of the work). The
/// partition only affects load balance, never values — each cell is
/// computed identically in any chunk.
fn triangle_bounds(f: usize, parts: usize) -> Vec<usize> {
    let parts = parts.clamp(1, f.max(1));
    let total = (f * (f + 1)) as f64 / 2.0;
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0);
    let mut acc = 0.0;
    let mut part = 1usize;
    for i in 0..f {
        acc += (f - i) as f64;
        if part < parts && acc >= total * part as f64 / parts as f64 {
            bounds.push(i + 1);
            part += 1;
        }
    }
    bounds.push(f);
    bounds
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_hand_case() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 5, 5);
        let c = a.matmul(&Mat::eye(5));
        assert!(a.max_abs_diff(&c) < 1e-12);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 7, 4);
        let b = random(&mut rng, 9, 4);
        let c1 = a.matmul(&b.transpose());
        let c2 = a.matmul_nt(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-12);

        let d = random(&mut rng, 7, 6);
        let e1 = a.transpose().matmul(&d);
        let e2 = a.matmul_tn(&d);
        assert!(e1.max_abs_diff(&e2) < 1e-12);
    }

    #[test]
    fn syrk_matches_matmul() {
        let mut rng = Rng::new(3);
        let z = random(&mut rng, 11, 6);
        let mut g = Mat::zeros(6, 6);
        z.syrk_into(&mut g);
        g.symmetrize_from_upper();
        let expect = z.matmul_tn(&z);
        assert!(g.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn matvec_and_transpose() {
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 6, 4);
        let x: Vec<f64> = (0..4).map(|i| i as f64 + 0.5).collect();
        let y1 = a.matvec(&x);
        let y2 = a.transpose().matvec_t(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn op_norm_of_diagonal() {
        let mut m = Mat::zeros(4, 4);
        m[(0, 0)] = 3.0;
        m[(1, 1)] = -7.0;
        m[(2, 2)] = 2.0;
        let est = m.op_norm_est(50);
        assert!((est - 7.0).abs() < 1e-6, "{est}");
    }

    #[test]
    fn row_block() {
        let a = Mat::from_fn(6, 3, |i, j| (i * 3 + j) as f64);
        let b = a.row_block(2, 4);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.row(0), &[6., 7., 8.]);
        assert_eq!(b.row(1), &[9., 10., 11.]);
    }

    #[test]
    fn triangle_bounds_tile_and_balance() {
        for (f, parts) in [(1usize, 1usize), (7, 3), (64, 4), (5, 8), (97, 13)] {
            let b = triangle_bounds(f, parts);
            assert_eq!(*b.first().unwrap(), 0, "f={f} parts={parts}");
            assert_eq!(*b.last().unwrap(), f, "f={f} parts={parts}");
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "f={f} parts={parts}: {b:?}");
            assert!(b.len() <= parts + 2, "f={f} parts={parts}: {b:?}");
            // balance: no chunk holds more than ~2x its fair triangle share
            let total = (f * (f + 1)) as f64 / 2.0;
            for w in b.windows(2) {
                let area: usize = (w[0]..w[1]).map(|i| f - i).sum();
                assert!(
                    area as f64 <= 2.0 * total / parts.min(f) as f64 + f as f64,
                    "f={f} parts={parts}: chunk {w:?} holds {area} of {total}"
                );
            }
        }
    }

    #[test]
    fn parallel_kernels_bit_identical_to_serial() {
        use crate::exec::Pool;
        let mut rng = Rng::new(7);
        // odd, non-divisible shapes on purpose
        let a = random(&mut rng, 13, 7);
        let b = random(&mut rng, 7, 11);
        let c = random(&mut rng, 17, 7);
        let x: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let serial_mm = a.matmul(&b);
        let serial_nt = a.matmul_nt(&c);
        let serial_tn = a.matmul_tn(&a);
        let serial_mv = a.matvec(&x);
        let mut serial_g = Mat::zeros(7, 7);
        a.syrk_into(&mut serial_g);
        for threads in [1usize, 2, 3, 5, 8, 32] {
            let pool = Pool::new(threads);
            assert_eq!(serial_mm, a.matmul_p(&b, &pool), "matmul threads={threads}");
            assert_eq!(serial_nt, a.matmul_nt_p(&c, &pool), "matmul_nt threads={threads}");
            assert_eq!(serial_tn, a.matmul_tn_p(&a, &pool), "matmul_tn threads={threads}");
            assert_eq!(serial_mv, a.matvec_p(&x, &pool), "matvec threads={threads}");
            let mut g = Mat::zeros(7, 7);
            a.syrk_into_p(&mut g, &pool);
            assert_eq!(serial_g, g, "syrk threads={threads}");
            // and syrk accumulation (out += ...) composes identically
            let mut g2 = serial_g.clone();
            a.syrk_into_p(&mut g2, &pool);
            let mut s2 = serial_g.clone();
            a.syrk_into(&mut s2);
            assert_eq!(s2, g2, "syrk accumulate threads={threads}");
        }
    }
}
