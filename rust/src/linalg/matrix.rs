//! Row-major dense f64 matrix over the microkernel engine.
//!
//! The hot products (`matmul` / `matmul_nt` / `matmul_tn` / `syrk_into` /
//! `matvec`) keep their PR-3 block-body shape — the serial entry point is
//! the `_p` variant on [`Pool::serial`], and the `_p` variant scatters
//! disjoint output-row ranges across a [`Pool`](crate::exec::Pool) — but
//! the block body itself is now the register-blocked, cache-tiled
//! [`microkernel`](super::microkernel) engine (DESIGN.md §2d): packed
//! operand panels, an MR×NR accumulator tile in locals for the
//! autovectorizer, and KC-deep k tiling. All tiling lives *inside* the
//! per-cell ownership boundary — each output cell has exactly one owner
//! and a fixed k-ascending reduction order — so the parallel kernels
//! remain **bit-identical** to the serial ones at every thread count
//! (property-tested in `tests/exec_props.rs` and, 0 ULP against the
//! frozen pre-microkernel kernels, in `tests/linalg_props.rs`).

use super::microkernel::{self, Gemm};
use crate::exec::Pool;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Sub-block of whole rows [r0, r1).
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat::from_vec(r1 - r0, self.cols, self.data[r0 * self.cols..r1 * self.cols].to_vec())
    }

    /// Cache-blocked transpose: TB×TB tiles keep both the source rows and
    /// the destination columns inside a handful of cache lines, instead of
    /// striding a full output column per source row.
    pub fn transpose(&self) -> Mat {
        const TB: usize = 32;
        let mut out = Mat::zeros(self.cols, self.rows);
        for i0 in (0..self.rows).step_by(TB) {
            let i1 = (i0 + TB).min(self.rows);
            for j0 in (0..self.cols).step_by(TB) {
                let j1 = (j0 + TB).min(self.cols);
                for i in i0..i1 {
                    let row = &self.data[i * self.cols + j0..i * self.cols + j1];
                    let mut o = j0 * self.rows + i;
                    for &v in row {
                        out.data[o] = v;
                        o += self.rows;
                    }
                }
            }
        }
        out
    }

    /// self * other through the microkernel engine.
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_p(other, &Pool::serial())
    }

    /// Parallel [`matmul`](Mat::matmul): output rows scattered across the
    /// pool, bit-identical to the serial kernel at every thread count.
    pub fn matmul_p(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        let mut out = Mat::zeros(m, n);
        let gemm = Gemm::matmul(self, other);
        pool.par_chunks(m, &mut out.data, |lo, hi, block| gemm.run_default(lo, hi, block));
        out
    }

    /// self * other^T — the featurizer's shape (rows x rows dot products).
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        self.matmul_nt_p(other, &Pool::serial())
    }

    /// Parallel [`matmul_nt`](Mat::matmul_nt), bit-identical to serial.
    pub fn matmul_nt_p(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        let gemm = Gemm::matmul_nt(self, other);
        pool.par_chunks(m, &mut out.data, |lo, hi, block| gemm.run_default(lo, hi, block));
        out
    }

    /// self^T * other (k x m)(k x n) -> (m x n); used for Z^T Z reductions.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        self.matmul_tn_p(other, &Pool::serial())
    }

    /// Parallel [`matmul_tn`](Mat::matmul_tn), bit-identical to serial.
    pub fn matmul_tn_p(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (m, n) = (self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        let gemm = Gemm::matmul_tn(self, other);
        pool.par_chunks(m, &mut out.data, |lo, hi, block| gemm.run_default(lo, hi, block));
        out
    }

    /// Symmetric rank-k update: out += self^T self (Gram of the rows).
    pub fn syrk_into(&self, out: &mut Mat) {
        self.syrk_into_p(out, &Pool::serial());
    }

    /// Parallel [`syrk_into`](Mat::syrk_into): output rows partitioned so
    /// each worker owns ~equal upper-triangle area (early rows are wider),
    /// bit-identical to the serial kernel at every thread count.
    pub fn syrk_into_p(&self, out: &mut Mat, pool: &Pool) {
        syrk_flat_into_p(&self.data, self.cols, out, pool)
    }

    /// Mirror the upper triangle into the lower (companion to syrk_into).
    pub fn symmetrize_from_upper(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..i {
                self.data[i * self.cols + j] = self.data[j * self.cols + i];
            }
        }
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_p(x, &Pool::serial())
    }

    /// Parallel [`matvec`](Mat::matvec): output entries scattered across
    /// the pool, bit-identical to serial (each entry is one serial dot).
    pub fn matvec_p(&self, x: &[f64], pool: &Pool) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut out = vec![0.0; self.rows];
        pool.par_chunks(self.rows, &mut out, |lo, hi, block| {
            microkernel::matvec_block(&self.data, self.cols, x, lo, hi, block)
        });
        out
    }

    /// self^T x (length rows) -> length cols.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t_p(x, &Pool::serial())
    }

    /// Parallel [`matvec_t`](Mat::matvec_t): output *columns* scattered
    /// across the pool. Each worker streams every row of `self` but only
    /// touches its own column range, so per output cell the reduction
    /// over rows runs in the same ascending order (with the same `xi == 0`
    /// skip) as the serial kernel — bit-identical at every thread count.
    pub fn matvec_t_p(&self, x: &[f64], pool: &Pool) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0; self.cols];
        if self.cols == 0 {
            return out;
        }
        pool.par_chunks(self.cols, &mut out, |lo, hi, block| {
            self.matvec_t_block(x, lo, hi, block)
        });
        out
    }

    /// Output columns [lo, hi) of self^T x — the shared serial/parallel
    /// block body of [`matvec_t`](Mat::matvec_t).
    fn matvec_t_block(&self, x: &[f64], lo: usize, hi: usize, out: &mut [f64]) {
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.row(i)[lo..hi];
            for (o, &a) in out.iter_mut().zip(row) {
                *o += xi * a;
            }
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn add_diag(&mut self, v: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += v;
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Operator (spectral) norm via power iteration on self^T self,
    /// sized-to-shape pool (see [`Pool::for_rows`]).
    pub fn op_norm_est(&self, iters: usize) -> f64 {
        self.op_norm_est_p(iters, &Pool::for_rows(self.rows.max(self.cols)))
    }

    /// [`op_norm_est`](Mat::op_norm_est) on an explicit pool: both halves
    /// of the iteration run the pooled matvec / matvec_t kernels, which
    /// are bit-identical to serial, so the estimate does not depend on
    /// the pool width.
    pub fn op_norm_est_p(&self, iters: usize, pool: &Pool) -> f64 {
        let n = self.cols;
        if n == 0 || self.rows == 0 {
            return 0.0;
        }
        let mut v: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 + 0.1).collect();
        let mut norm = 0.0;
        for _ in 0..iters {
            let av = self.matvec_p(&v, pool);
            let atav = self.matvec_t_p(&av, pool);
            norm = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 0.0;
            }
            for (vi, &a) in v.iter_mut().zip(&atav) {
                *vi = a / norm;
            }
        }
        norm.sqrt()
    }
}

/// [`Mat::syrk_into_p`] over a flat row-major buffer of `f`-wide rows —
/// the out-of-core chunk path accumulates `Z^T Z` straight from its reused
/// scratch slice without wrapping it in a `Mat`. Because each output cell
/// accumulates over the rows of `z` in fixed ascending order (tiling never
/// crosses the per-cell boundary — see the microkernel module docs),
/// feeding the same rows in any chunking produces bit-identical sums (the
/// chunk-invariance contract of `data::pipeline`).
pub fn syrk_flat_into_p(z: &[f64], f: usize, out: &mut Mat, pool: &Pool) {
    assert_eq!(out.rows, f, "syrk: output shape mismatch");
    assert_eq!(out.cols, f, "syrk: output shape mismatch");
    if f == 0 {
        return;
    }
    assert_eq!(z.len() % f, 0, "syrk: buffer is not a whole number of rows");
    let gemm = Gemm::syrk(z, f);
    let bounds = triangle_bounds(f, pool.threads());
    pool.scatter_rows(&bounds, &mut out.data, |lo, hi, block| gemm.run_default(lo, hi, block));
}

/// Partition `0..f` into at most `parts` contiguous ranges of ~equal
/// upper-triangle area (row i of a SYRK touches `f - i` cells, so equal
/// row counts would leave the first worker with most of the work). The
/// partition only affects load balance, never values — each cell is
/// computed identically in any chunk.
pub(crate) fn triangle_bounds(f: usize, parts: usize) -> Vec<usize> {
    let parts = parts.clamp(1, f.max(1));
    let total = (f * (f + 1)) as f64 / 2.0;
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0);
    let mut acc = 0.0;
    let mut part = 1usize;
    for i in 0..f {
        acc += (f - i) as f64;
        if part < parts && acc >= total * part as f64 / parts as f64 {
            bounds.push(i + 1);
            part += 1;
        }
    }
    bounds.push(f);
    bounds
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matmul_hand_case() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 5, 5);
        let c = a.matmul(&Mat::eye(5));
        assert!(a.max_abs_diff(&c) < 1e-12);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 7, 4);
        let b = random(&mut rng, 9, 4);
        let c1 = a.matmul(&b.transpose());
        let c2 = a.matmul_nt(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-12);

        let d = random(&mut rng, 7, 6);
        let e1 = a.transpose().matmul(&d);
        let e2 = a.matmul_tn(&d);
        assert!(e1.max_abs_diff(&e2) < 1e-12);
    }

    #[test]
    fn syrk_matches_matmul() {
        let mut rng = Rng::new(3);
        let z = random(&mut rng, 11, 6);
        let mut g = Mat::zeros(6, 6);
        z.syrk_into(&mut g);
        g.symmetrize_from_upper();
        let expect = z.matmul_tn(&z);
        assert!(g.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn matvec_and_transpose() {
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 6, 4);
        let x: Vec<f64> = (0..4).map(|i| i as f64 + 0.5).collect();
        let y1 = a.matvec(&x);
        let y2 = a.transpose().matvec_t(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn blocked_transpose_exact() {
        // shapes that exercise whole tiles, partial edge tiles and the
        // degenerate thin cases of the 32x32-blocked transpose
        for (r, c) in [(1usize, 1usize), (3, 97), (32, 32), (33, 31), (70, 5), (64, 64)] {
            let a = Mat::from_fn(r, c, |i, j| (i * c + j) as f64 + 0.25);
            let t = a.transpose();
            assert_eq!(t.rows(), c);
            assert_eq!(t.cols(), r);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(a[(i, j)].to_bits(), t[(j, i)].to_bits(), "({i},{j}) r={r} c={c}");
                }
            }
            assert_eq!(a, t.transpose(), "double transpose r={r} c={c}");
        }
    }

    #[test]
    fn op_norm_of_diagonal() {
        let mut m = Mat::zeros(4, 4);
        m[(0, 0)] = 3.0;
        m[(1, 1)] = -7.0;
        m[(2, 2)] = 2.0;
        let est = m.op_norm_est(50);
        assert!((est - 7.0).abs() < 1e-6, "{est}");
    }

    #[test]
    fn op_norm_pool_invariant() {
        let mut rng = Rng::new(11);
        let a = random(&mut rng, 40, 23);
        let serial = a.op_norm_est_p(25, &Pool::serial());
        for threads in [2usize, 3, 8] {
            let est = a.op_norm_est_p(25, &Pool::new(threads));
            assert_eq!(serial.to_bits(), est.to_bits(), "threads={threads}");
        }
        assert_eq!(serial.to_bits(), a.op_norm_est(25).to_bits());
    }

    #[test]
    fn row_block() {
        let a = Mat::from_fn(6, 3, |i, j| (i * 3 + j) as f64);
        let b = a.row_block(2, 4);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.row(0), &[6., 7., 8.]);
        assert_eq!(b.row(1), &[9., 10., 11.]);
    }

    #[test]
    fn triangle_bounds_tile_and_balance() {
        for (f, parts) in [(1usize, 1usize), (7, 3), (64, 4), (5, 8), (97, 13)] {
            let b = triangle_bounds(f, parts);
            assert_eq!(*b.first().unwrap(), 0, "f={f} parts={parts}");
            assert_eq!(*b.last().unwrap(), f, "f={f} parts={parts}");
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "f={f} parts={parts}: {b:?}");
            assert!(b.len() <= parts + 2, "f={f} parts={parts}: {b:?}");
            // balance: no chunk holds more than ~2x its fair triangle share
            let total = (f * (f + 1)) as f64 / 2.0;
            for w in b.windows(2) {
                let area: usize = (w[0]..w[1]).map(|i| f - i).sum();
                assert!(
                    area as f64 <= 2.0 * total / parts.min(f) as f64 + f as f64,
                    "f={f} parts={parts}: chunk {w:?} holds {area} of {total}"
                );
            }
        }
    }

    #[test]
    fn parallel_kernels_bit_identical_to_serial() {
        use crate::exec::Pool;
        let mut rng = Rng::new(7);
        // odd, non-divisible shapes on purpose
        let a = random(&mut rng, 13, 7);
        let b = random(&mut rng, 7, 11);
        let c = random(&mut rng, 17, 7);
        let x: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let xt: Vec<f64> = (0..13).map(|_| rng.normal()).collect();
        let serial_mm = a.matmul(&b);
        let serial_nt = a.matmul_nt(&c);
        let serial_tn = a.matmul_tn(&a);
        let serial_mv = a.matvec(&x);
        let serial_mvt = a.matvec_t(&xt);
        let mut serial_g = Mat::zeros(7, 7);
        a.syrk_into(&mut serial_g);
        for threads in [1usize, 2, 3, 5, 8, 32] {
            let pool = Pool::new(threads);
            assert_eq!(serial_mm, a.matmul_p(&b, &pool), "matmul threads={threads}");
            assert_eq!(serial_nt, a.matmul_nt_p(&c, &pool), "matmul_nt threads={threads}");
            assert_eq!(serial_tn, a.matmul_tn_p(&a, &pool), "matmul_tn threads={threads}");
            assert_eq!(serial_mv, a.matvec_p(&x, &pool), "matvec threads={threads}");
            assert_eq!(serial_mvt, a.matvec_t_p(&xt, &pool), "matvec_t threads={threads}");
            let mut g = Mat::zeros(7, 7);
            a.syrk_into_p(&mut g, &pool);
            assert_eq!(serial_g, g, "syrk threads={threads}");
            // and syrk accumulation (out += ...) composes identically
            let mut g2 = serial_g.clone();
            a.syrk_into_p(&mut g2, &pool);
            let mut s2 = serial_g.clone();
            a.syrk_into(&mut s2);
            assert_eq!(s2, g2, "syrk accumulate threads={threads}");
        }
    }
}
