//! Dense linear-algebra substrate (built from scratch; no external BLAS).
//!
//! [`Mat`] is a row-major f64 matrix with the operations the rest of the
//! system needs: matmul / syrk / matvec running on the register-blocked,
//! cache-tiled [`microkernel`] engine (each with a `_p` variant that
//! scatters output rows across an [`exec::Pool`](crate::exec::Pool) and is
//! bit-identical to the serial kernel at every thread count), Cholesky
//! factorization and SPD solves, a cyclic Jacobi symmetric eigensolver,
//! the fast Walsh-Hadamard transform (FastFood baseline) and a radix-2
//! complex FFT (TensorSketch baseline).

mod cholesky;
mod eigen;
mod fft;
mod fwht;
mod matrix;
pub mod microkernel;

pub use cholesky::Cholesky;
pub use eigen::sym_eigen;
pub use fft::{circular_convolve, fft_inplace, ifft_inplace};
pub use fwht::fwht_inplace;
pub use matrix::{syrk_flat_into_p, Mat};
