//! Cholesky factorization and SPD solves — the workhorse behind KRR
//! (both the rust-native path and the ground-truth exact-kernel solves).

use super::Mat;

/// Lower-triangular Cholesky factor L with A = L L^T.
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Returns None if a non-positive pivot appears
    /// (matrix not PD to working precision).
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows(), a.cols(), "Cholesky needs a square matrix");
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Factor A + jitter*I, escalating jitter until PD. Returns the factor
    /// and the jitter actually used.
    pub fn new_with_jitter(a: &Mat, mut jitter: f64) -> (Cholesky, f64) {
        let mut m = a.clone();
        if let Some(c) = Cholesky::new(&m) {
            return (c, 0.0);
        }
        loop {
            m = a.clone();
            m.add_diag(jitter);
            if let Some(c) = Cholesky::new(&m) {
                return (c, jitter);
            }
            jitter *= 10.0;
            assert!(jitter.is_finite(), "Cholesky jitter escalation diverged");
        }
    }

    pub fn factor(&self) -> &Mat {
        &self.l
    }

    /// Solve L y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            let mut sum = y[i];
            let row = self.l.row(i);
            for k in 0..i {
                sum -= row[k] * y[k];
            }
            y[i] = sum / row[i];
        }
        y
    }

    /// Solve L^T x = y (back substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(y.len(), n);
        let mut x = y.to_vec();
        for i in (0..n).rev() {
            let mut sum = x[i];
            for k in i + 1..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// Solve A X = B column-by-column.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        let mut col = vec![0.0; n];
        for j in 0..b.cols() {
            for i in 0..n {
                col[i] = b[(i, j)];
            }
            let x = self.solve(&col);
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// L^{-1} B — whitening transform, used for Nystrom features and the
    /// spectral-approximation certificate.
    pub fn whiten(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        let mut col = vec![0.0; n];
        for j in 0..b.cols() {
            for i in 0..n {
                col[i] = b[(i, j)];
            }
            let y = self.solve_lower(&col);
            for i in 0..n {
                out[(i, j)] = y[i];
            }
        }
        out
    }

    /// log determinant of A (2 * sum log diag L).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut g = a.matmul_tn(&a);
        g.add_diag(0.5);
        g
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::new(10);
        let a = spd(&mut rng, 12);
        let c = Cholesky::new(&a).expect("SPD");
        let l = c.factor();
        let llt = l.matmul_nt(l);
        assert!(llt.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_residual() {
        let mut rng = Rng::new(11);
        let a = spd(&mut rng, 20);
        let c = Cholesky::new(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let x = c.solve(&b);
        let r = a.matvec(&x);
        for (ri, bi) in r.iter().zip(&b) {
            assert!((ri - bi).abs() < 1e-9);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn jitter_escalation() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1e-9;
        let (c, jitter) = Cholesky::new_with_jitter(&a, 1e-10);
        assert!(jitter > 0.0);
        assert!(c.factor().rows() == 3);
    }

    #[test]
    fn whiten_identity() {
        // L^{-1} A L^{-T} = I when A = L L^T
        let mut rng = Rng::new(12);
        let a = spd(&mut rng, 8);
        let c = Cholesky::new(&a).unwrap();
        let w = c.whiten(&a); // L^{-1} A
        // (L^{-1} A) L^{-T}: whiten the transpose again
        let w2 = c.whiten(&w.transpose());
        assert!(w2.max_abs_diff(&Mat::eye(8)) < 1e-9);
    }

    #[test]
    fn log_det_diag() {
        let mut a = Mat::eye(3);
        a[(0, 0)] = 4.0;
        a[(1, 1)] = 9.0;
        let c = Cholesky::new(&a).unwrap();
        assert!((c.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_mat_matches_columns() {
        let mut rng = Rng::new(13);
        let a = spd(&mut rng, 6);
        let b = Mat::from_fn(6, 3, |_, _| rng.normal());
        let c = Cholesky::new(&a).unwrap();
        let x = c.solve_mat(&b);
        let back = a.matmul(&x);
        assert!(back.max_abs_diff(&b) < 1e-9);
    }
}
