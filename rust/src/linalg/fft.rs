//! Iterative radix-2 complex FFT — the polynomial-convolution engine behind
//! the TensorSketch / PolySketch baseline [PP13, AKK+20].

/// In-place forward FFT on interleaved (re, im) pairs; length power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64]) {
    fft_dir(re, im, false);
}

/// In-place inverse FFT (includes the 1/n normalization).
pub fn ifft_inplace(re: &mut [f64], im: &mut [f64]) {
    fft_dir(re, im, true);
    let n = re.len() as f64;
    for v in re.iter_mut() {
        *v /= n;
    }
    for v in im.iter_mut() {
        *v /= n;
    }
}

fn fft_dir(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "FFT length must be a power of two");
    // bit reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            let (mut cr, mut ci) = (1.0, 0.0);
            for k in 0..len / 2 {
                let (ar, ai) = (re[start + k], im[start + k]);
                let (br, bi) = (re[start + k + len / 2], im[start + k + len / 2]);
                let (tr, ti) = (br * cr - bi * ci, br * ci + bi * cr);
                re[start + k] = ar + tr;
                im[start + k] = ai + ti;
                re[start + k + len / 2] = ar - tr;
                im[start + k + len / 2] = ai - ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
        }
        len <<= 1;
    }
}

/// Circular convolution of two real vectors via FFT (lengths must match and
/// be a power of two). Exactly what TensorSketch composes per degree.
pub fn circular_convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    let n = a.len();
    assert_eq!(n, b.len());
    let (mut ar, mut ai) = (a.to_vec(), vec![0.0; n]);
    let (mut br, mut bi) = (b.to_vec(), vec![0.0; n]);
    fft_inplace(&mut ar, &mut ai);
    fft_inplace(&mut br, &mut bi);
    for k in 0..n {
        let (r, i) = (ar[k] * br[k] - ai[k] * bi[k], ar[k] * bi[k] + ai[k] * br[k]);
        ar[k] = r;
        ai[k] = i;
    }
    ifft_inplace(&mut ar, &mut ai);
    ar
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn matches_naive_dft() {
        let mut rng = Rng::new(40);
        let n = 32;
        let re0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let im0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (mut re, mut im) = (re0.clone(), im0.clone());
        fft_inplace(&mut re, &mut im);
        for k in 0..n {
            let (mut sr, mut si) = (0.0, 0.0);
            for t in 0..n {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                let (c, s) = (ang.cos(), ang.sin());
                sr += re0[t] * c - im0[t] * s;
                si += re0[t] * s + im0[t] * c;
            }
            assert!((re[k] - sr).abs() < 1e-9, "k={k}");
            assert!((im[k] - si).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(41);
        let n = 64;
        let re0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let im0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (mut re, mut im) = (re0.clone(), im0.clone());
        fft_inplace(&mut re, &mut im);
        ifft_inplace(&mut re, &mut im);
        for i in 0..n {
            assert!((re[i] - re0[i]).abs() < 1e-10);
            assert!((im[i] - im0[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn convolution_matches_naive() {
        let mut rng = Rng::new(42);
        let n = 16;
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let fast = circular_convolve(&a, &b);
        for k in 0..n {
            let slow: f64 = (0..n).map(|i| a[i] * b[(k + n - i) % n]).sum();
            assert!((fast[k] - slow).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn convolution_delta_is_identity() {
        let mut delta = vec![0.0; 8];
        delta[0] = 1.0;
        let b = vec![1., 2., 3., 4., 5., 6., 7., 8.];
        let out = circular_convolve(&delta, &b);
        for (o, e) in out.iter().zip(&b) {
            assert!((o - e).abs() < 1e-10);
        }
    }
}
