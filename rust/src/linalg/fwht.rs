//! In-place fast Walsh-Hadamard transform (unnormalized), the core of the
//! FastFood baseline [LSS+13].

/// Unnormalized FWHT; `x.len()` must be a power of two.
pub fn fwht_inplace(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        let step = h * 2;
        for block in (0..n).step_by(step) {
            for i in block..block + h {
                let (a, b) = (x[i], x[i + h]);
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h = step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn hand_case_n4() {
        let mut x = vec![1.0, 0.0, 1.0, 0.0];
        fwht_inplace(&mut x);
        assert_eq!(x, vec![2.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn involution_up_to_n() {
        // H (H x) = n x
        let mut rng = Rng::new(30);
        for &n in &[2usize, 8, 64, 256] {
            let orig: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut x = orig.clone();
            fwht_inplace(&mut x);
            fwht_inplace(&mut x);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b * n as f64).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn matches_dense_hadamard() {
        // dense H entries: (-1)^{popcount(i & j)}
        let n = 16;
        let mut rng = Rng::new(31);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut fast = v.clone();
        fwht_inplace(&mut fast);
        for i in 0..n {
            let slow: f64 = (0..n)
                .map(|j: usize| {
                    let sign = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                    sign * v[j]
                })
                .sum();
            assert!((fast[i] - slow).abs() < 1e-10);
        }
    }

    #[test]
    fn preserves_energy_scaled() {
        // ||Hx||^2 = n ||x||^2
        let mut rng = Rng::new(32);
        let n = 128;
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let e0: f64 = v.iter().map(|x| x * x).sum();
        let mut h = v;
        fwht_inplace(&mut h);
        let e1: f64 = h.iter().map(|x| x * x).sum();
        assert!((e1 - n as f64 * e0).abs() < 1e-8 * e1);
    }
}
