//! Symmetric eigendecomposition by cyclic Jacobi rotations.
//!
//! Used by the spectral validators (statistical dimension, (eps,lambda)
//! certificates, projection-cost checks) where matrices are at most a few
//! hundred rows — Jacobi's O(n^3) per sweep is fine and its accuracy on
//! symmetric problems is excellent.

use super::Mat;

/// Eigen-decomposition of a symmetric matrix: returns (eigenvalues
/// descending, eigenvectors as columns of V with A = V diag(w) V^T).
pub fn sym_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-13 * (1.0 + m.frobenius()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // apply rotation J(p,q,theta) on both sides: M <- J^T M J
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&a, &b| diag[b].partial_cmp(&diag[a]).unwrap());
    let evals: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            vecs[(i, new_j)] = v[(i, old_j)];
        }
    }
    (evals, vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn diagonal_matrix() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 5.0;
        a[(2, 2)] = 3.0;
        let (w, _) = sym_eigen(&a);
        assert!((w[0] - 5.0).abs() < 1e-12);
        assert!((w[1] - 3.0).abs() < 1e-12);
        assert!((w[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1
        let a = Mat::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let (w, v) = sym_eigen(&a);
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        // eigenvector for 3 is (1,1)/sqrt(2)
        assert!((v[(0, 0)].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_random() {
        let mut rng = Rng::new(20);
        for n in [4usize, 16, 48] {
            let b = Mat::from_fn(n, n, |_, _| rng.normal());
            let mut a = b.matmul_tn(&b);
            a.symmetrize_from_upper();
            let (w, v) = sym_eigen(&a);
            // A ?= V diag(w) V^T
            let mut vd = v.clone();
            for i in 0..n {
                for j in 0..n {
                    vd[(i, j)] *= w[j];
                }
            }
            let recon = vd.matmul(&v.transpose());
            assert!(recon.max_abs_diff(&a) < 1e-8 * (1.0 + a.frobenius()), "n={n}");
            // eigenvalues descending, PSD
            for i in 1..n {
                assert!(w[i] <= w[i - 1] + 1e-10);
            }
            assert!(w[n - 1] > -1e-8);
        }
    }

    #[test]
    fn orthonormal_vectors() {
        let mut rng = Rng::new(21);
        let b = Mat::from_fn(10, 10, |_, _| rng.normal());
        let mut a = b.matmul_tn(&b);
        a.symmetrize_from_upper();
        let (_, v) = sym_eigen(&a);
        let vtv = v.matmul_tn(&v);
        assert!(vtv.max_abs_diff(&Mat::eye(10)) < 1e-9);
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(22);
        let b = Mat::from_fn(8, 8, |_, _| rng.normal());
        let mut a = b.matmul_tn(&b);
        a.symmetrize_from_upper();
        let tr: f64 = (0..8).map(|i| a[(i, i)]).sum();
        let (w, _) = sym_eigen(&a);
        assert!((w.iter().sum::<f64>() - tr).abs() < 1e-9 * tr.abs());
    }
}
