//! Deterministic synthetic generators matched to the paper's datasets.
//!
//! Regression (Table 2):
//!   elevation — band-limited "terrain" on S^2 built from randomly oriented
//!               Legendre lobes (the S^2 analogue of band-limited spherical-
//!               harmonic fields); n = 64,800 (the 1-degree ETOPO grid).
//!   co2       — spatio-temporal plume field on [S^2, R]: point sources with
//!               seasonal modulation plus a secular trend; n = 146,040.
//!   climate   — smoother large-scale field on [S^2, R] with latitudinal
//!               gradient and seasonal cycle; n = 223,656.
//!   protein   — nonlinear feature-interaction regression in R^9 (CASP-like
//!               physicochemical features); n = 45,730.
//!
//! Clustering (Table 3): Gaussian mixtures matched in (n, d, k) to the six
//! UCI sets, l2-normalized to the sphere exactly as the paper preprocesses.

use crate::linalg::Mat;
use crate::rng::Rng;
use crate::special::gegenbauer_eval;

/// A regression or clustering dataset.
pub struct Dataset {
    pub name: &'static str,
    pub x: Mat,
    pub y: Vec<f64>,
    /// class labels for clustering sets (empty for regression)
    pub labels: Vec<usize>,
    /// number of classes (clustering) or 0
    pub k: usize,
}

fn sphere_points(rng: &mut Rng, n: usize, d: usize) -> Mat {
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        rng.sphere(x.row_mut(i));
    }
    x
}

/// Band-limited random field on S^2: f(x) = sum_k a_k P_3^{l_k}(<x, c_k>).
/// This is a positive-combination of zonal kernels — exactly the function
/// class the paper's kernels model well, and a faithful stand-in for
/// spherical-harmonic terrain.
fn zonal_field(rng: &mut Rng, n_lobes: usize, max_degree: usize) -> impl Fn(&[f64]) -> f64 {
    let d = 3;
    let mut centers = Vec::with_capacity(n_lobes);
    let mut degrees = Vec::with_capacity(n_lobes);
    let mut amps = Vec::with_capacity(n_lobes);
    for _ in 0..n_lobes {
        let mut c = vec![0.0; d];
        rng.sphere(&mut c);
        centers.push(c);
        let l = 1 + rng.below(max_degree);
        degrees.push(l);
        // higher-degree lobes get smaller amplitude (red spectrum, like
        // real topography)
        amps.push(rng.normal() / (1.0 + l as f64).sqrt());
    }
    move |x: &[f64]| {
        let mut v = 0.0;
        for k in 0..centers.len() {
            let t: f64 = x.iter().zip(&centers[k]).map(|(&a, &b)| a * b).sum();
            v += amps[k] * gegenbauer_eval(degrees[k], 3, t.clamp(-1.0, 1.0));
        }
        v
    }
}

/// Earth-elevation stand-in: n points on S^2, band-limited terrain target.
pub fn elevation(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xE1E7);
    let x = sphere_points(&mut rng, n, 3);
    let field = zonal_field(&mut rng, 40, 12);
    let mut noise = rng.fork(1);
    let y: Vec<f64> = (0..n)
        .map(|i| 2.0 * field(x.row(i)) + 0.05 * noise.normal())
        .collect();
    Dataset { name: "elevation", x, y, labels: vec![], k: 0 }
}

fn spatio_temporal(
    n: usize,
    seed: u64,
    name: &'static str,
    n_sources: usize,
    sharpness: f64,
    trend: f64,
    season_amp: f64,
    noise_sd: f64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    // spatial part on S^2, temporal coordinate in 12 discrete months scaled
    // to [0, 1] (the paper appends the temporal value to the S^2 coords)
    let sp = sphere_points(&mut rng, n, 3);
    let mut x = Mat::zeros(n, 4);
    let mut tchoice = rng.fork(2);
    for i in 0..n {
        x.row_mut(i)[..3].copy_from_slice(sp.row(i));
        x.row_mut(i)[3] = tchoice.below(12) as f64 / 11.0;
    }
    // point sources with seasonal phase
    let mut src = rng.fork(3);
    let mut sources = Vec::new();
    for _ in 0..n_sources {
        let mut c = vec![0.0; 3];
        src.sphere(&mut c);
        let amp = src.uniform_in(0.5, 2.0);
        let phase = src.uniform_in(0.0, 2.0 * std::f64::consts::PI);
        sources.push((c, amp, phase));
    }
    let mut noise = rng.fork(4);
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let row = x.row(i);
            let tau = row[3];
            let mut v = trend * tau;
            for (c, amp, phase) in &sources {
                let cos: f64 = row[..3].iter().zip(c).map(|(&a, &b)| a * b).sum();
                let bump = (sharpness * (cos - 1.0)).exp(); // von-Mises-like plume
                let seasonal = 1.0 + season_amp * (2.0 * std::f64::consts::PI * tau + phase).sin();
                v += amp * bump * seasonal;
            }
            v + noise_sd * noise.normal()
        })
        .collect();
    Dataset { name, x, y, labels: vec![], k: 0 }
}

/// ODIAC-CO2 stand-in on [S^2, R]: sharp plumes + trend + seasonality.
pub fn co2(n: usize, seed: u64) -> Dataset {
    spatio_temporal(n, seed ^ 0xC02, "co2", 25, 12.0, 0.8, 0.5, 0.05)
}

/// Berkeley-Earth climate stand-in on [S^2, R]: smooth latitudinal field.
pub fn climate(n: usize, seed: u64) -> Dataset {
    let mut ds = spatio_temporal(n, seed ^ 0xC11A, "climate", 8, 3.0, 0.3, 1.0, 0.1);
    // add the dominant latitudinal temperature gradient (z-coordinate)
    for i in 0..ds.x.rows() {
        let z = ds.x[(i, 2)];
        ds.y[i] += 3.0 * (1.0 - z * z); // warm equator, cold poles
    }
    ds
}

/// CASP-protein stand-in in R^9: standardized features, smooth nonlinear
/// interaction target (RMSD-like, strictly positive).
pub fn protein(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x9607);
    let d = 9;
    // correlated features: z = L g with a fixed random mixing matrix
    let mix = Mat::from_fn(d, d, |_, _| rng.normal() * 0.4);
    let mut x = Mat::zeros(n, d);
    let mut g = vec![0.0; d];
    for i in 0..n {
        rng.fill_normal(&mut g);
        let row = x.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r = g[j] + mix.row(j).iter().zip(&g).map(|(&a, &b)| a * b).sum::<f64>();
        }
    }
    super::standardize(&mut x);
    let mut noise = rng.fork(5);
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let r = x.row(i);
            let v = (r[0] * r[1]).tanh() + 0.8 * (r[2] + 0.5 * r[3] * r[3]).sin()
                + 0.6 * (r[4] - r[5]).abs().sqrt()
                + 0.4 * r[6] * (r[7] * 0.7).cos()
                + 0.2 * r[8];
            5.0 + 2.0 * v + 0.3 * noise.normal()
        })
        .collect();
    Dataset { name: "protein", x, y, labels: vec![], k: 0 }
}

/// Geometry of one Table-3 clustering dataset.
#[derive(Clone, Copy, Debug)]
pub struct ClusteringSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub k: usize,
}

/// The six UCI datasets of Table 3, matched in (n, d, #classes).
pub const CLUSTERING_SPECS: [ClusteringSpec; 6] = [
    ClusteringSpec { name: "abalone", n: 4_177, d: 8, k: 3 },
    ClusteringSpec { name: "pendigits", n: 7_494, d: 16, k: 10 },
    ClusteringSpec { name: "mushroom", n: 8_124, d: 21, k: 2 },
    ClusteringSpec { name: "magic", n: 19_020, d: 10, k: 2 },
    ClusteringSpec { name: "statlog", n: 43_500, d: 9, k: 7 },
    ClusteringSpec { name: "connect4", n: 67_557, d: 42, k: 3 },
];

/// Gaussian-mixture clustering dataset, l2-normalized to S^{d-1} (the
/// paper's preprocessing). Cluster separation chosen so the problem is
/// non-trivial but solvable (overlapping mixtures).
pub fn clustering_dataset(spec: ClusteringSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xC105);
    let ClusteringSpec { name, n, d, k } = spec;
    let mut centers = Mat::zeros(k, d);
    for c in 0..k {
        rng.sphere(centers.row_mut(c));
    }
    let mut x = Mat::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    let spread = 0.55;
    for i in 0..n {
        let c = i % k; // balanced classes
        labels.push(c);
        let row = x.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r = centers[(c, j)] + spread * rng.normal();
        }
    }
    super::normalize_rows(&mut x);
    Dataset { name, x, y: vec![], labels, k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elevation_geometry() {
        let ds = elevation(500, 1);
        assert_eq!(ds.x.rows(), 500);
        assert_eq!(ds.x.cols(), 3);
        for i in 0..500 {
            let norm: f64 = ds.x.row(i).iter().map(|v| v * v).sum::<f64>();
            assert!((norm - 1.0).abs() < 1e-10, "points must lie on S^2");
        }
        // target must have signal (not constant)
        let mean = ds.y.iter().sum::<f64>() / 500.0;
        let var = ds.y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 500.0;
        assert!(var > 0.01, "target variance {var}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = elevation(100, 42);
        let b = elevation(100, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = elevation(100, 43);
        assert!(a.x.max_abs_diff(&c.x) > 1e-6);
    }

    #[test]
    fn co2_and_climate_geometry() {
        for ds in [co2(300, 2), climate(300, 2)] {
            assert_eq!(ds.x.cols(), 4);
            for i in 0..300 {
                let s: f64 = ds.x.row(i)[..3].iter().map(|v| v * v).sum::<f64>();
                assert!((s - 1.0).abs() < 1e-10);
                let tau = ds.x.row(i)[3];
                assert!((0.0..=1.0).contains(&tau));
            }
        }
    }

    #[test]
    fn co2_is_seasonal() {
        // the target must actually depend on the temporal coordinate
        let ds = co2(4000, 3);
        let mut by_month = vec![(0.0, 0usize); 12];
        for i in 0..4000 {
            let m = (ds.x[(i, 3)] * 11.0).round() as usize;
            by_month[m].0 += ds.y[i];
            by_month[m].1 += 1;
        }
        let means: Vec<f64> = by_month.iter().map(|&(s, c)| s / c.max(1) as f64).collect();
        let lo = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(hi - lo > 0.1, "seasonal amplitude {}", hi - lo);
    }

    #[test]
    fn protein_standardized() {
        let ds = protein(2000, 4);
        assert_eq!(ds.x.cols(), 9);
        for j in 0..9 {
            let mean: f64 = (0..2000).map(|i| ds.x[(i, j)]).sum::<f64>() / 2000.0;
            assert!(mean.abs() < 0.1);
        }
        assert!(ds.y.iter().all(|&v| v.is_finite()));
    }

    #[test]
    fn clustering_specs_and_labels() {
        let spec = CLUSTERING_SPECS[0];
        let ds = clustering_dataset(spec, 5);
        assert_eq!(ds.x.rows(), spec.n);
        assert_eq!(ds.x.cols(), spec.d);
        assert_eq!(ds.labels.len(), spec.n);
        assert!(ds.labels.iter().all(|&l| l < spec.k));
        for i in 0..50 {
            let norm: f64 = ds.x.row(i).iter().map(|v| v * v).sum::<f64>();
            assert!((norm - 1.0).abs() < 1e-10);
        }
        // every class present
        for c in 0..spec.k {
            assert!(ds.labels.iter().any(|&l| l == c));
        }
    }

    #[test]
    fn clusters_are_separated() {
        // same-class pairs must be closer on average than cross-class pairs
        let ds = clustering_dataset(ClusteringSpec { name: "t", n: 600, d: 8, k: 3 }, 6);
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0, 0usize, 0.0, 0usize);
        for i in 0..200 {
            for j in 0..i {
                let d2: f64 = ds
                    .x
                    .row(i)
                    .iter()
                    .zip(ds.x.row(j))
                    .map(|(&a, &b)| (a - b) * (a - b))
                    .sum();
                if ds.labels[i] == ds.labels[j] {
                    same += d2;
                    same_n += 1;
                } else {
                    diff += d2;
                    diff_n += 1;
                }
            }
        }
        assert!(same / same_n as f64 + 0.05 < diff / diff_n as f64);
    }
}
