//! Single-pass chunked trainers over a [`DataSource`]: featurize each
//! bounded chunk into **one reused scratch buffer**, fold it into O(F²)
//! (or O(kF)) state, and discard it. Working memory is
//! `chunk_rows x (d + F)` regardless of n — the out-of-core regime the
//! paper's data-oblivious features enable (§1.2).
//!
//! Chunk invariance is the load-bearing contract: every consumer here
//! accumulates in strict row-ascending order (`RidgeStats::absorb_flat_with`,
//! `StreamingKmeans::absorb_flat`, the KPCA moment passes), and sources
//! return identical rows for any chunking, so a fit at `chunk_rows = 1`
//! is **bit-identical** to the fit at `chunk_rows = n` — and, for ridge
//! and KPCA, bit-identical to the legacy materialize-everything fit.
//! Property-tested across the whole method registry in
//! `tests/source_props.rs`.

use super::{chunk_ranges, gather_rows, DataSource};
use crate::exec::Pool;
use crate::features::Featurizer;
use crate::kmeans::StreamingKmeans;
use crate::kpca::KernelPca;
use crate::krr::RidgeStats;
use crate::linalg::{syrk_flat_into_p, Mat};
use crate::rng::Rng;
use std::time::Instant;

/// Default chunk height of every fit path (`--chunk-rows` overrides).
pub const DEFAULT_CHUNK_ROWS: usize = 8192;

/// Telemetry of one chunked pass: how much was streamed and how big the
/// scratch allocation actually was (the bench's peak-Z-bytes evidence).
#[derive(Clone, Copy, Debug)]
pub struct PipelineInfo {
    pub rows: usize,
    pub chunks: usize,
    pub chunk_rows: usize,
    /// bytes of the feature scratch buffer — `min(chunk_rows, n) * F * 8`,
    /// the peak feature-matrix allocation of the whole fit
    pub peak_z_bytes: usize,
    /// seconds spent featurizing (summed over chunks and passes)
    pub featurize_secs: f64,
}

/// Reusable per-chunk buffers: the raw-row chunk and the featurized
/// chunk. The **only** feature storage a chunked fit ever allocates.
struct ChunkBufs {
    x: Mat,
    y: Vec<f64>,
    z: Vec<f64>,
    f_dim: usize,
}

impl ChunkBufs {
    fn new(src: &dyn DataSource, f_dim: usize, chunk_rows: usize) -> ChunkBufs {
        let cap = chunk_rows.max(1).min(src.len().max(1));
        ChunkBufs {
            x: Mat::zeros(cap, src.dim()),
            y: vec![0.0; cap],
            z: vec![0.0; cap * f_dim],
            f_dim,
        }
    }

    /// Read rows `[lo, hi)` and featurize them; returns `(x, y, z)` slices
    /// for exactly `hi - lo` rows. Adds featurize time to `secs`.
    fn load(
        &mut self,
        src: &dyn DataSource,
        feat: &dyn Featurizer,
        lo: usize,
        hi: usize,
        pool: &Pool,
        secs: &mut f64,
    ) -> Result<(&Mat, &[f64], &[f64]), String> {
        let c = hi - lo;
        if self.x.rows() != c {
            // only chunk-height changes re-allocate: the final short chunk
            // of a pass, and the first full chunk of the next pass
            self.x = Mat::zeros(c, self.x.cols());
        }
        {
            let _span = crate::obs::span("pipeline", "chunk.read");
            src.read_into(lo, hi, &mut self.x, &mut self.y[..c])?;
        }
        let t0 = Instant::now();
        {
            let _span = crate::obs::span("pipeline", "featurize");
            feat.featurize_par_into(&self.x, &mut self.z[..c * self.f_dim], pool);
        }
        *secs += t0.elapsed().as_secs_f64();
        crate::obs::counter("pipeline.chunks").inc();
        crate::obs::counter("pipeline.rows").add(c as u64);
        Ok((&self.x, &self.y[..c], &self.z[..c * self.f_dim]))
    }
}

fn info(
    src: &dyn DataSource,
    f_dim: usize,
    chunk_rows: usize,
    passes_chunks: usize,
    secs: f64,
) -> PipelineInfo {
    let chunk = chunk_rows.max(1).min(src.len().max(1));
    PipelineInfo {
        rows: src.len(),
        chunks: passes_chunks,
        chunk_rows: chunk,
        peak_z_bytes: chunk * f_dim * 8,
        featurize_secs: secs,
    }
}

/// The shared chunk loop: stream every row of a source through the one
/// reused feature scratch and hand `(x, y, z)` slices of each chunk to
/// `body`, in row order. This is the loop every trainer here is built on,
/// exported so other consumers (the experiments' streamed evaluation
/// passes) never re-implement the buffer management — and therefore never
/// accidentally re-materialize a feature matrix.
pub fn for_each_chunk(
    feat: &dyn Featurizer,
    src: &dyn DataSource,
    chunk_rows: usize,
    pool: &Pool,
    mut body: impl FnMut(&Mat, &[f64], &[f64]),
) -> Result<PipelineInfo, String> {
    let f_dim = feat.dim();
    let mut bufs = ChunkBufs::new(src, f_dim, chunk_rows);
    let mut secs = 0.0;
    let mut chunks = 0usize;
    for (lo, hi) in chunk_ranges(src.len(), chunk_rows) {
        let (x, y, z) = bufs.load(src, feat, lo, hi, pool, &mut secs)?;
        body(x, y, z);
        chunks += 1;
    }
    Ok(info(src, f_dim, chunk_rows, chunks, secs))
}

/// Single-pass ridge sufficient statistics `(Z^T Z, Z^T y, n)` over a
/// source: per chunk, featurize into the scratch and
/// [`absorb`](RidgeStats::absorb_flat_with). Solve the result at any
/// lambda. Bit-identical to absorbing the fully materialized feature
/// matrix, at `chunk_rows x F` feature memory instead of `n x F`.
pub fn ridge_stats(
    feat: &dyn Featurizer,
    src: &dyn DataSource,
    chunk_rows: usize,
    pool: &Pool,
) -> Result<(RidgeStats, PipelineInfo), String> {
    let mut stats = RidgeStats::new(feat.dim());
    let info = for_each_chunk(feat, src, chunk_rows, pool, |_, y, z| {
        let _span = crate::obs::span("pipeline", "absorb");
        stats.absorb_flat_with(z, y, pool)
    })?;
    Ok((stats, info))
}

/// Result of a chunked k-means fit.
pub struct ChunkedKmeans {
    /// (k x F) centroids in feature space
    pub centroids: Mat,
    /// average squared distance of the source's rows to their nearest
    /// centroid (the paper's Table-3 objective, computed in a final pass)
    pub objective: f64,
}

/// Chunked kernel k-means: reservoir-sample k rows as initial centroids
/// (one cheap index pass, no data materialized), then a
/// [`StreamingKmeans`] absorb pass over the chunks, then an objective
/// pass. Three passes, O(k F) state, bit-invariant to `chunk_rows` (all
/// three passes are row-sequential).
pub fn kmeans_chunked(
    feat: &dyn Featurizer,
    src: &dyn DataSource,
    k: usize,
    chunk_rows: usize,
    seed: u64,
    pool: &Pool,
) -> Result<(ChunkedKmeans, PipelineInfo), String> {
    let n = src.len();
    if k == 0 || n < k {
        return Err(format!("k = {k} needs at least k source rows, got {n}"));
    }
    let f_dim = feat.dim();
    let mut rng = Rng::new(seed).fork(0x5EAB);
    // pass 0 (index-only): uniform reservoir sample of k init rows
    let mut keep: Vec<usize> = (0..k).collect();
    for i in k..n {
        let j = rng.below(i + 1);
        if j < k {
            keep[j] = i;
        }
    }
    let init_x = gather_rows(src, &keep)?;
    let centroids = feat.featurize(&init_x);
    let mut sk = StreamingKmeans::with_centroids(centroids);

    let mut bufs = ChunkBufs::new(src, f_dim, chunk_rows);
    let mut secs = 0.0;
    let mut chunks = 0usize;
    // pass 1: streaming absorb
    for (lo, hi) in chunk_ranges(n, chunk_rows) {
        let (_, _, z) = bufs.load(src, feat, lo, hi, pool, &mut secs)?;
        sk.absorb_flat(z);
        chunks += 1;
    }
    // pass 2: the Table-3 objective against the final centroids
    let mut total = 0.0;
    for (lo, hi) in chunk_ranges(n, chunk_rows) {
        let (_, _, z) = bufs.load(src, feat, lo, hi, pool, &mut secs)?;
        sk.accumulate_sq_dist(z, &mut total);
        chunks += 1;
    }
    let result =
        ChunkedKmeans { centroids: sk.centroids().clone(), objective: total / n as f64 };
    Ok((result, info(src, f_dim, chunk_rows, chunks, secs)))
}

/// Chunked kernel PCA: pass 1 accumulates the feature-space mean, pass 2
/// the centered covariance (both row-ascending, so the moments — and
/// hence the model — are **bit-identical** to [`KernelPca::fit`] on the
/// materialized feature matrix). O(F²) state.
pub fn kpca_chunked(
    feat: &dyn Featurizer,
    src: &dyn DataSource,
    rank: usize,
    chunk_rows: usize,
    pool: &Pool,
) -> Result<(KernelPca, PipelineInfo), String> {
    let n = src.len();
    let f_dim = feat.dim();
    if n < 2 {
        return Err("kpca needs at least 2 source rows".to_string());
    }
    if rank == 0 || rank > f_dim {
        return Err(format!("rank {rank} out of range for {f_dim} feature dimensions"));
    }
    let mut bufs = ChunkBufs::new(src, f_dim, chunk_rows);
    let mut secs = 0.0;
    let mut chunks = 0usize;
    // pass 1: column means
    let mut mean = vec![0.0; f_dim];
    for (lo, hi) in chunk_ranges(n, chunk_rows) {
        let (_, _, z) = bufs.load(src, feat, lo, hi, pool, &mut secs)?;
        for row in z.chunks_exact(f_dim) {
            for (m, &v) in mean.iter_mut().zip(row) {
                *m += v;
            }
        }
        chunks += 1;
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    // pass 2: centered covariance via the flat SYRK on the scratch
    let mut cov = Mat::zeros(f_dim, f_dim);
    for (lo, hi) in chunk_ranges(n, chunk_rows) {
        let c = hi - lo;
        bufs.load(src, feat, lo, hi, pool, &mut secs)?;
        let zc = &mut bufs.z[..c * f_dim];
        for row in zc.chunks_exact_mut(f_dim) {
            for (v, &m) in row.iter_mut().zip(&mean) {
                *v -= m;
            }
        }
        syrk_flat_into_p(zc, f_dim, &mut cov, pool);
        chunks += 1;
    }
    cov.symmetrize_from_upper();
    cov.scale(1.0 / n as f64);
    Ok((KernelPca::from_covariance(mean, &cov, rank), info(src, f_dim, chunk_rows, chunks, secs)))
}

/// Mean squared error of a fitted predictor over a source, computed chunk
/// by chunk (the evaluation side of the pipeline: no n x d or n x F
/// materialization either). `predict` maps a raw chunk to predictions.
pub fn chunked_mse(
    src: &dyn DataSource,
    chunk_rows: usize,
    mut predict: impl FnMut(&Mat) -> Vec<f64>,
) -> Result<f64, String> {
    let n = src.len();
    if n == 0 {
        return Err("cannot score an empty source".to_string());
    }
    let mut total = 0.0;
    let mut x = Mat::zeros(chunk_rows.max(1).min(n), src.dim());
    let mut y = vec![0.0; chunk_rows.max(1).min(n)];
    for (lo, hi) in chunk_ranges(n, chunk_rows) {
        let c = hi - lo;
        if x.rows() != c {
            x = Mat::zeros(c, src.dim());
        }
        {
            let _span = crate::obs::span("pipeline", "chunk.read");
            src.read_into(lo, hi, &mut x, &mut y[..c])?;
        }
        let _span = crate::obs::span("pipeline", "eval");
        let pred = predict(&x);
        assert_eq!(pred.len(), c, "predictor returned a wrong-sized chunk");
        for (p, t) in pred.iter().zip(&y[..c]) {
            total += (p - t) * (p - t);
        }
    }
    Ok(total / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{MatSource, SyntheticSource};
    use crate::features::{FeatureSpec, KernelSpec, Method};
    use crate::krr::FeatureRidge;

    fn spec(m: usize) -> FeatureSpec {
        FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            m,
            9,
        )
    }

    #[test]
    fn chunked_ridge_equals_materialized_fit() {
        let src = SyntheticSource::elevation(57, 3);
        let (x, y) = src.read_range(0, 57).unwrap();
        let feat = spec(32).build(3);
        let z = feat.featurize(&x);
        let reference = FeatureRidge::fit(&z, &y, 0.01);
        for chunk in [1usize, 13, 57] {
            let (stats, pinfo) =
                ridge_stats(feat.as_ref(), &src, chunk, &Pool::serial()).unwrap();
            let model = stats.solve(0.01);
            assert_eq!(model.weights, reference.weights, "chunk {chunk}");
            assert_eq!(stats.n, 57);
            assert_eq!(pinfo.peak_z_bytes, chunk.min(57) * 32 * 8);
        }
    }

    #[test]
    fn chunked_kpca_equals_materialized_fit() {
        let src = SyntheticSource::elevation(40, 3);
        let (x, _) = src.read_range(0, 40).unwrap();
        let feat = spec(24).build(3);
        let z = feat.featurize(&x);
        let reference = KernelPca::fit(&z, 3);
        let (pca, _) = kpca_chunked(feat.as_ref(), &src, 3, 7, &Pool::serial()).unwrap();
        assert_eq!(pca.mean(), reference.mean());
        assert_eq!(pca.components(), reference.components());
        assert_eq!(pca.eigenvalues, reference.eigenvalues);
    }

    #[test]
    fn chunked_kmeans_is_chunk_invariant_and_sane() {
        let src = SyntheticSource::by_name("abalone", 120, 3).unwrap();
        let feat = spec(24).build(8);
        let (ref_fit, _) =
            kmeans_chunked(feat.as_ref(), &src, 3, 120, 5, &Pool::serial()).unwrap();
        for chunk in [1usize, 17, 64] {
            let (fit, _) =
                kmeans_chunked(feat.as_ref(), &src, 3, chunk, 5, &Pool::serial()).unwrap();
            assert_eq!(fit.centroids, ref_fit.centroids, "chunk {chunk}");
            assert_eq!(fit.objective, ref_fit.objective, "chunk {chunk}");
        }
        assert!(ref_fit.objective.is_finite() && ref_fit.objective >= 0.0);
        assert!(kmeans_chunked(feat.as_ref(), &src, 0, 16, 5, &Pool::serial()).is_err());
        assert!(kmeans_chunked(feat.as_ref(), &src, 121, 16, 5, &Pool::serial()).is_err());
    }

    #[test]
    fn chunked_mse_matches_direct() {
        let src = SyntheticSource::elevation(30, 3);
        let (x, y) = src.read_range(0, 30).unwrap();
        let feat = spec(16).build(3);
        let z = feat.featurize(&x);
        let model = FeatureRidge::fit(&z, &y, 0.1);
        let direct = crate::krr::mse(&model.predict(&z), &y);
        let chunked =
            chunked_mse(&src, 7, |xc| model.predict(&feat.featurize(xc))).unwrap();
        assert!((direct - chunked).abs() < 1e-12, "{direct} vs {chunked}");
    }

    #[test]
    fn pool_width_does_not_change_chunked_fits() {
        let src = SyntheticSource::protein(48, 2);
        let feat = spec(20).build(9);
        let (s1, _) = ridge_stats(feat.as_ref(), &src, 11, &Pool::serial()).unwrap();
        let (s4, _) = ridge_stats(feat.as_ref(), &src, 11, &Pool::new(4)).unwrap();
        assert_eq!(s1.g, s4.g);
        assert_eq!(s1.b, s4.b);
    }

    #[test]
    fn mat_source_and_synthetic_source_agree_for_same_rows() {
        // the unification claim: an in-memory fit over MatSource is the
        // same computation as the out-of-core fit over the generator
        let src = SyntheticSource::co2(33, 6);
        let (x, y) = src.read_range(0, 33).unwrap();
        let mat = MatSource::new(&x, &y);
        let feat = spec(16).build(4);
        let (a, _) = ridge_stats(feat.as_ref(), &src, 8, &Pool::serial()).unwrap();
        let (b, _) = ridge_stats(feat.as_ref(), &mat, 8, &Pool::serial()).unwrap();
        assert_eq!(a.g, b.g);
        assert_eq!(a.b, b.b);
    }
}
