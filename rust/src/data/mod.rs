//! Dataset substrate: eager synthetic generators, and the chunked
//! out-of-core data-flow layer every fit path consumes.
//!
//! The paper's evaluation uses proprietary/remote datasets (ETOPO elevation,
//! ODIAC CO2, Berkeley Earth climate, UCI CASP protein, six UCI
//! classification sets). Those are not available in this offline
//! environment, so `synthetic` builds deterministic generators that match
//! each dataset's domain geometry (S^2, [S^2, R], R^9, ...), size and task
//! character — see DESIGN.md §6 for the substitution argument.
//!
//! `source` is the chunked layer ([`DataSource`] with in-memory, lazily
//! generated synthetic, and on-disk CSV/binary implementations) and
//! `pipeline` the single-pass trainers over it — working memory bounded by
//! the chunk, not by n (DESIGN.md §"Data pipeline").

pub mod pipeline;
mod source;
mod synthetic;

pub use source::{
    chunk_ranges, gather_rows, DataSource, FileSource, InterleavedSplit, MatSource,
    SourceSlice, SyntheticSource, BINARY_MAGIC, REGRESSION_SIZES,
};
pub use synthetic::{
    clustering_dataset, co2, climate, elevation, protein, ClusteringSpec, Dataset,
    CLUSTERING_SPECS,
};

/// Train/test split by deterministic shuffle.
pub fn split(
    x: &crate::linalg::Mat,
    y: &[f64],
    test_frac: f64,
    seed: u64,
) -> (crate::linalg::Mat, Vec<f64>, crate::linalg::Mat, Vec<f64>) {
    let n = x.rows();
    let d = x.cols();
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = crate::rng::Rng::new(seed);
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let n_train = n - n_test;
    let mut xtr = crate::linalg::Mat::zeros(n_train, d);
    let mut xte = crate::linalg::Mat::zeros(n_test, d);
    let mut ytr = Vec::with_capacity(n_train);
    let mut yte = Vec::with_capacity(n_test);
    for (pos, &i) in idx.iter().enumerate() {
        if pos < n_train {
            xtr.row_mut(pos).copy_from_slice(x.row(i));
            ytr.push(y[i]);
        } else {
            xte.row_mut(pos - n_train).copy_from_slice(x.row(i));
            yte.push(y[i]);
        }
    }
    (xtr, ytr, xte, yte)
}

/// Standardize columns to zero mean / unit variance (paper's Protein prep).
pub fn standardize(x: &mut crate::linalg::Mat) {
    let (n, d) = (x.rows(), x.cols());
    for j in 0..d {
        let mut mean = 0.0;
        for i in 0..n {
            mean += x[(i, j)];
        }
        mean /= n as f64;
        let mut var = 0.0;
        for i in 0..n {
            let v = x[(i, j)] - mean;
            var += v * v;
        }
        let std = (var / n as f64).sqrt().max(1e-12);
        for i in 0..n {
            x[(i, j)] = (x[(i, j)] - mean) / std;
        }
    }
}

/// Normalize every row to unit l2 norm (paper's k-means prep).
pub fn normalize_rows(x: &mut crate::linalg::Mat) {
    for i in 0..x.rows() {
        let row = x.row_mut(i);
        let norm: f64 = row.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for v in row.iter_mut() {
                *v /= norm;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn split_partitions() {
        let x = Mat::from_fn(100, 2, |i, j| (i * 2 + j) as f64);
        let y: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let (xtr, ytr, xte, yte) = split(&x, &y, 0.1, 7);
        assert_eq!(xtr.rows(), 90);
        assert_eq!(xte.rows(), 10);
        // every y value appears exactly once across the two splits
        let mut all: Vec<f64> = ytr.iter().chain(yte.iter()).cloned().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, v) in all.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
        // x rows stay paired with their y
        for i in 0..90 {
            assert_eq!(xtr[(i, 0)], ytr[i] * 2.0);
        }
    }

    #[test]
    fn standardize_moments() {
        let mut x = Mat::from_fn(200, 3, |i, j| (i as f64) * (j as f64 + 1.0) + 5.0);
        standardize(&mut x);
        for j in 0..3 {
            let mean: f64 = (0..200).map(|i| x[(i, j)]).sum::<f64>() / 200.0;
            let var: f64 = (0..200).map(|i| x[(i, j)] * x[(i, j)]).sum::<f64>() / 200.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn normalize_rows_unit() {
        let mut x = Mat::from_fn(10, 4, |i, j| (i + j) as f64 + 1.0);
        normalize_rows(&mut x);
        for i in 0..10 {
            let norm: f64 = x.row(i).iter().map(|v| v * v).sum::<f64>();
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }
}
