//! The chunked data-flow layer: [`DataSource`] yields bounded row chunks
//! so every fit path can run single-pass with working memory bounded by
//! the chunk, not by n.
//!
//! The paper's headline system property (§1.2) is that the features are
//! data-oblivious: each example can be featurized once, folded into O(F²)
//! sufficient statistics, and discarded. A trainer therefore never needs
//! the n x d dataset *or* the n x F feature matrix in memory — it needs a
//! stream of row chunks. This module is that stream:
//!
//! * [`DataSource`] — the trait: `(len, dim)` plus random-access
//!   `read_into(lo, hi, ...)`. Random access (rather than a forward-only
//!   iterator) is what lets the coordinator's shards read **disjoint chunk
//!   ranges of one shared source** concurrently, and lets data-dependent
//!   methods (Nystrom) gather their O(m) sample rows without a full pass.
//! * [`MatSource`] — borrowed in-memory data; the in-memory fit paths are
//!   the same code as the out-of-core ones, just over this source.
//! * [`SyntheticSource`] — the paper's elevation / co2 / climate /
//!   protein / clustering stand-ins generated **lazily per row** (row i is
//!   a pure function of `(dataset, seed, i)`), so the full-size datasets
//!   (climate is n = 223,656) never materialize. Row indices beyond the
//!   nominal `n` are valid too, which is how `gzk serve` draws held-out
//!   evaluation rows for a stored model.
//! * [`FileSource`] — real datasets from disk: CSV (one row per line,
//!   features then target in the last column) or the `GZKBIN01`
//!   little-endian binary format. Chunks are read by seek + sequential
//!   read; nothing is ever fully loaded.
//!
//! Chunk invariance: a source returns bit-identical rows regardless of how
//! the range is chunked (`read_into(0, n)` == any concatenation of
//! sub-reads), and the consumers in [`pipeline`](crate::data::pipeline)
//! accumulate in row-ascending order — together that makes every chunked
//! fit bit-identical to the single-chunk fit (`tests/source_props.rs`).

use crate::linalg::Mat;
use crate::rng::Rng;
use crate::special::gegenbauer_eval;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

/// A dataset exposed as randomly accessible row chunks. `Sync` is part of
/// the contract: the coordinator's workers read disjoint ranges of one
/// shared source concurrently.
pub trait DataSource: Sync {
    /// Dataset name, recorded in model-artifact run metadata (`gzk serve`
    /// uses it to rebuild the evaluation stream).
    fn name(&self) -> &str;

    /// Total number of rows.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Input dimension d (the target column is not counted).
    fn dim(&self) -> usize;

    /// Fill `x` ((hi-lo) x d) and `y` (hi-lo) with rows `[lo, hi)`.
    /// Implementations must be pure functions of the range: any chunking
    /// of a range yields the same bytes (the chunk-invariance contract).
    fn read_into(&self, lo: usize, hi: usize, x: &mut Mat, y: &mut [f64]) -> Result<(), String>;

    /// Allocating convenience wrapper around
    /// [`read_into`](DataSource::read_into).
    fn read_range(&self, lo: usize, hi: usize) -> Result<(Mat, Vec<f64>), String> {
        let mut x = Mat::zeros(hi - lo, self.dim());
        let mut y = vec![0.0; hi - lo];
        self.read_into(lo, hi, &mut x, &mut y)?;
        Ok((x, y))
    }
}

/// Successive `[lo, hi)` chunk bounds covering `0..n` in steps of
/// `chunk_rows` (the last chunk may be short).
pub fn chunk_ranges(n: usize, chunk_rows: usize) -> impl Iterator<Item = (usize, usize)> {
    let chunk = chunk_rows.max(1);
    (0..n).step_by(chunk).map(move |lo| (lo, (lo + chunk).min(n)))
}

/// Gather specific rows of a source into a dense matrix (targets
/// discarded) — how data-dependent fits (Nystrom landmarks, bandwidth
/// probes) pull their O(m) sample without a full pass.
pub fn gather_rows(src: &dyn DataSource, indices: &[usize]) -> Result<Mat, String> {
    let d = src.dim();
    let mut out = Mat::zeros(indices.len(), d);
    let mut row = Mat::zeros(1, d);
    let mut y = [0.0];
    for (r, &i) in indices.iter().enumerate() {
        src.read_into(i, i + 1, &mut row, &mut y)?;
        out.row_mut(r).copy_from_slice(row.row(0));
    }
    Ok(out)
}

/// A contiguous row range of another source, exposed as a source of its
/// own — how train/test splits and coordinator shards are expressed
/// without copying anything.
pub struct SourceSlice<'a> {
    inner: &'a dyn DataSource,
    lo: usize,
    hi: usize,
}

impl<'a> SourceSlice<'a> {
    pub fn new(inner: &'a dyn DataSource, lo: usize, hi: usize) -> SourceSlice<'a> {
        assert!(lo <= hi && hi <= inner.len(), "slice [{lo}, {hi}) out of bounds");
        SourceSlice { inner, lo, hi }
    }
}

impl DataSource for SourceSlice<'_> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn len(&self) -> usize {
        self.hi - self.lo
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn read_into(&self, lo: usize, hi: usize, x: &mut Mat, y: &mut [f64]) -> Result<(), String> {
        assert!(lo <= hi && hi <= self.len(), "read [{lo}, {hi}) out of slice bounds");
        self.inner.read_into(self.lo + lo, self.lo + hi, x, y)
    }
}

/// A deterministic interleaved train/test split of a source: every
/// `period`-th row (underlying indices ≡ period-1 mod period) belongs to
/// the test view, the rest to the train view. Unlike a contiguous tail
/// split, this stays honest for **ordered** file sources (a CSV sorted by
/// target or time spreads both views across the whole range) while both
/// views remain chunk-readable: a chunk read issues ONE contiguous read
/// of the underlying rows spanning it, then copies out the kept rows, so
/// working memory stays chunk-bounded (x `period` for the sparse test
/// view).
pub struct InterleavedSplit<'a> {
    inner: &'a dyn DataSource,
    period: usize,
    /// true: the every-period-th rows (test); false: the complement (train)
    test: bool,
}

impl<'a> InterleavedSplit<'a> {
    /// The training view: all rows whose index is NOT ≡ period-1 (mod period).
    pub fn train(inner: &'a dyn DataSource, period: usize) -> InterleavedSplit<'a> {
        assert!(period >= 2, "split period must be >= 2");
        InterleavedSplit { inner, period, test: false }
    }

    /// The held-out view: every `period`-th row.
    pub fn test(inner: &'a dyn DataSource, period: usize) -> InterleavedSplit<'a> {
        assert!(period >= 2, "split period must be >= 2");
        InterleavedSplit { inner, period, test: true }
    }

    /// Underlying index of this view's row `i`.
    fn map(&self, i: usize) -> usize {
        if self.test {
            i * self.period + self.period - 1
        } else {
            i + i / (self.period - 1)
        }
    }

    fn keeps(&self, underlying: usize) -> bool {
        (underlying % self.period == self.period - 1) == self.test
    }
}

impl DataSource for InterleavedSplit<'_> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn len(&self) -> usize {
        let n = self.inner.len();
        let test_rows = n / self.period;
        if self.test {
            test_rows
        } else {
            n - test_rows
        }
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn read_into(&self, lo: usize, hi: usize, x: &mut Mat, y: &mut [f64]) -> Result<(), String> {
        check_read_shape(self, lo, hi, x, y)?;
        if lo == hi {
            return Ok(());
        }
        // one contiguous underlying read spanning the requested rows, then
        // copy out the rows this view keeps
        let u_lo = self.map(lo);
        let u_hi = self.map(hi - 1) + 1;
        let (ux, uy) = self.inner.read_range(u_lo, u_hi)?;
        let mut filled = 0usize;
        for r in 0..ux.rows() {
            if self.keeps(u_lo + r) {
                x.row_mut(filled).copy_from_slice(ux.row(r));
                y[filled] = uy[r];
                filled += 1;
            }
        }
        debug_assert_eq!(filled, hi - lo);
        Ok(())
    }
}

/// Borrowed in-memory data as a source: the adapter that lets the
/// in-memory fit paths consume the same pipeline as the out-of-core ones.
pub struct MatSource<'a> {
    x: &'a Mat,
    y: Option<&'a [f64]>,
}

impl<'a> MatSource<'a> {
    pub fn new(x: &'a Mat, y: &'a [f64]) -> MatSource<'a> {
        assert_eq!(x.rows(), y.len(), "MatSource: {} rows but {} targets", x.rows(), y.len());
        MatSource { x, y: Some(y) }
    }

    /// Rows without targets (k-means / KPCA / Nystrom sampling); `y` reads
    /// as zeros.
    pub fn unlabeled(x: &'a Mat) -> MatSource<'a> {
        MatSource { x, y: None }
    }
}

impl DataSource for MatSource<'_> {
    fn name(&self) -> &str {
        "mem"
    }

    fn len(&self) -> usize {
        self.x.rows()
    }

    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn read_into(&self, lo: usize, hi: usize, x: &mut Mat, y: &mut [f64]) -> Result<(), String> {
        check_read_shape(self, lo, hi, x, y)?;
        let d = self.x.cols();
        x.data_mut().copy_from_slice(&self.x.data()[lo * d..hi * d]);
        match self.y {
            Some(src_y) => y.copy_from_slice(&src_y[lo..hi]),
            None => y.fill(0.0),
        }
        Ok(())
    }
}

/// Shared bounds/shape validation for `read_into` implementations.
fn check_read_shape(
    src: &dyn DataSource,
    lo: usize,
    hi: usize,
    x: &Mat,
    y: &[f64],
) -> Result<(), String> {
    if lo > hi || hi > src.len() {
        return Err(format!("{}: read [{lo}, {hi}) out of bounds (n = {})", src.name(), src.len()));
    }
    if x.rows() != hi - lo || x.cols() != src.dim() || y.len() != hi - lo {
        return Err(format!(
            "{}: read buffers are {}x{} + {} targets for a [{lo}, {hi}) read of d = {}",
            src.name(),
            x.rows(),
            x.cols(),
            y.len(),
            src.dim()
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// SyntheticSource
// ---------------------------------------------------------------------------

/// Band-limited zonal field on S^2 (the elevation target): fixed random
/// lobes, evaluated per row.
struct ZonalField {
    centers: Mat,
    degrees: Vec<usize>,
    amps: Vec<f64>,
}

impl ZonalField {
    fn new(rng: &mut Rng, n_lobes: usize, max_degree: usize) -> ZonalField {
        let mut centers = Mat::zeros(n_lobes, 3);
        let mut degrees = Vec::with_capacity(n_lobes);
        let mut amps = Vec::with_capacity(n_lobes);
        for k in 0..n_lobes {
            rng.sphere(centers.row_mut(k));
            let l = 1 + rng.below(max_degree);
            degrees.push(l);
            // red spectrum, like real topography
            amps.push(rng.normal() / (1.0 + l as f64).sqrt());
        }
        ZonalField { centers, degrees, amps }
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let mut v = 0.0;
        for k in 0..self.degrees.len() {
            let t: f64 = x.iter().zip(self.centers.row(k)).map(|(&a, &b)| a * b).sum();
            v += self.amps[k] * gegenbauer_eval(self.degrees[k], 3, t.clamp(-1.0, 1.0));
        }
        v
    }
}

enum SynKind {
    /// S^2 points, band-limited terrain target (d = 3).
    Elevation { field: ZonalField },
    /// [S^2, month] points, plume + trend + seasonality target (d = 4);
    /// `latitudinal` adds the climate stand-in's equator-pole gradient.
    SpatioTemporal {
        sources: Vec<(Vec<f64>, f64, f64)>,
        sharpness: f64,
        trend: f64,
        season_amp: f64,
        noise_sd: f64,
        latitudinal: f64,
    },
    /// Correlated R^9 features (analytically standardized), nonlinear
    /// interaction target.
    Protein { mix: Mat, inv_sd: Vec<f64> },
    /// Gaussian mixture on S^{d-1}; y is the class label as f64.
    Clustering { centers: Mat, spread: f64 },
}

/// Deterministic lazy generator matched to one of the paper's datasets:
/// row i is a pure function of `(dataset, seed, i)` (an independent RNG
/// stream is forked per row), so any chunking — or any shard reading any
/// disjoint range — sees identical bytes without the n x d matrix ever
/// existing.
pub struct SyntheticSource {
    name: String,
    n: usize,
    d: usize,
    base: Rng,
    kind: SynKind,
}

/// The regression datasets of Table 2 with their paper row counts.
pub const REGRESSION_SIZES: [(&str, usize); 4] =
    [("elevation", 64_800), ("co2", 146_040), ("climate", 223_656), ("protein", 45_730)];

impl SyntheticSource {
    /// Earth-elevation stand-in: n points on S^2, band-limited terrain.
    pub fn elevation(n: usize, seed: u64) -> SyntheticSource {
        let mut prng = Rng::new(seed ^ 0xE1E7);
        let field = ZonalField::new(&mut prng, 40, 12);
        let base = prng.fork(0x57AB);
        SyntheticSource {
            name: "elevation".to_string(),
            n,
            d: 3,
            base,
            kind: SynKind::Elevation { field },
        }
    }

    fn spatio_temporal(
        name: &str,
        n: usize,
        seed: u64,
        n_sources: usize,
        sharpness: f64,
        trend: f64,
        season_amp: f64,
        noise_sd: f64,
        latitudinal: f64,
    ) -> SyntheticSource {
        let mut prng = Rng::new(seed);
        let mut sources = Vec::with_capacity(n_sources);
        for _ in 0..n_sources {
            let mut c = vec![0.0; 3];
            prng.sphere(&mut c);
            let amp = prng.uniform_in(0.5, 2.0);
            let phase = prng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
            sources.push((c, amp, phase));
        }
        let base = prng.fork(0x57AB);
        SyntheticSource {
            name: name.to_string(),
            n,
            d: 4,
            base,
            kind: SynKind::SpatioTemporal {
                sources,
                sharpness,
                trend,
                season_amp,
                noise_sd,
                latitudinal,
            },
        }
    }

    /// ODIAC-CO2 stand-in on [S^2, R]: sharp plumes + trend + seasonality.
    pub fn co2(n: usize, seed: u64) -> SyntheticSource {
        Self::spatio_temporal("co2", n, seed ^ 0xC02, 25, 12.0, 0.8, 0.5, 0.05, 0.0)
    }

    /// Berkeley-Earth climate stand-in: smooth field + latitudinal
    /// gradient (warm equator, cold poles).
    pub fn climate(n: usize, seed: u64) -> SyntheticSource {
        Self::spatio_temporal("climate", n, seed ^ 0xC11A, 8, 3.0, 0.3, 1.0, 0.1, 3.0)
    }

    /// CASP-protein stand-in in R^9. Unlike the eager generator, the
    /// features are standardized **analytically** (x = g + M g with
    /// g ~ N(0, I) has zero mean and a known per-coordinate variance), so
    /// standardization needs no data pass and each row stays independent.
    pub fn protein(n: usize, seed: u64) -> SyntheticSource {
        let mut prng = Rng::new(seed ^ 0x9607);
        let d = 9;
        let mix = Mat::from_fn(d, d, |_, _| prng.normal() * 0.4);
        // var(x_j) = sum_k (delta_jk + M[j,k])^2
        let inv_sd: Vec<f64> = (0..d)
            .map(|j| {
                let v: f64 = (0..d)
                    .map(|k| {
                        let c = if j == k { 1.0 } else { 0.0 } + mix[(j, k)];
                        c * c
                    })
                    .sum();
                1.0 / v.sqrt().max(1e-12)
            })
            .collect();
        let base = prng.fork(0x57AB);
        SyntheticSource {
            name: "protein".to_string(),
            n,
            d,
            base,
            kind: SynKind::Protein { mix, inv_sd },
        }
    }

    /// Gaussian-mixture clustering stand-in on S^{d-1} with balanced
    /// classes (`y` carries the class label).
    pub fn clustering(name: &str, n: usize, d: usize, k: usize, seed: u64) -> SyntheticSource {
        assert!(k >= 1 && d >= 1);
        let mut prng = Rng::new(seed ^ 0xC105);
        let mut centers = Mat::zeros(k, d);
        for c in 0..k {
            prng.sphere(centers.row_mut(c));
        }
        let base = prng.fork(0x57AB);
        SyntheticSource {
            name: name.to_string(),
            n,
            d,
            base,
            kind: SynKind::Clustering { centers, spread: 0.55 },
        }
    }

    /// Resolve a dataset by name: the four Table-2 regression sets or any
    /// of the six Table-3 clustering geometries, at `n` rows. This is the
    /// CLI's `--dataset` registry and how `gzk serve` rebuilds the
    /// evaluation stream recorded in a model artifact.
    pub fn by_name(name: &str, n: usize, seed: u64) -> Result<SyntheticSource, String> {
        match name {
            "elevation" => Ok(Self::elevation(n, seed)),
            "co2" => Ok(Self::co2(n, seed)),
            "climate" => Ok(Self::climate(n, seed)),
            "protein" => Ok(Self::protein(n, seed)),
            other => {
                if let Some(spec) =
                    super::CLUSTERING_SPECS.iter().find(|s| s.name == other)
                {
                    return Ok(Self::clustering(spec.name, n, spec.d, spec.k, seed));
                }
                let mut names: Vec<&str> = REGRESSION_SIZES.iter().map(|(n, _)| *n).collect();
                names.extend(super::CLUSTERING_SPECS.iter().map(|s| s.name));
                Err(format!(
                    "unknown synthetic dataset {other:?}; known: {}",
                    names.join(", ")
                ))
            }
        }
    }

    /// Number of classes for the clustering kinds (0 otherwise).
    pub fn k(&self) -> usize {
        match &self.kind {
            SynKind::Clustering { centers, .. } => centers.rows(),
            _ => 0,
        }
    }

    fn gen_row(&self, i: usize, x: &mut [f64], y: &mut f64) {
        let mut rng = self.base.fork(i as u64);
        match &self.kind {
            SynKind::Elevation { field } => {
                rng.sphere(x);
                *y = 2.0 * field.eval(x) + 0.05 * rng.normal();
            }
            SynKind::SpatioTemporal {
                sources,
                sharpness,
                trend,
                season_amp,
                noise_sd,
                latitudinal,
            } => {
                rng.sphere(&mut x[..3]);
                let tau = rng.below(12) as f64 / 11.0;
                x[3] = tau;
                let mut v = trend * tau;
                for (c, amp, phase) in sources {
                    let cos: f64 = x[..3].iter().zip(c).map(|(&a, &b)| a * b).sum();
                    let bump = (sharpness * (cos - 1.0)).exp(); // von-Mises-like plume
                    let seasonal =
                        1.0 + season_amp * (2.0 * std::f64::consts::PI * tau + phase).sin();
                    v += amp * bump * seasonal;
                }
                let z = x[2];
                v += latitudinal * (1.0 - z * z);
                *y = v + noise_sd * rng.normal();
            }
            SynKind::Protein { mix, inv_sd } => {
                let d = x.len();
                let mut g = vec![0.0; d];
                rng.fill_normal(&mut g);
                for j in 0..d {
                    let v = g[j] + mix.row(j).iter().zip(&g).map(|(&a, &b)| a * b).sum::<f64>();
                    x[j] = v * inv_sd[j];
                }
                let r = &*x;
                let v = (r[0] * r[1]).tanh()
                    + 0.8 * (r[2] + 0.5 * r[3] * r[3]).sin()
                    + 0.6 * (r[4] - r[5]).abs().sqrt()
                    + 0.4 * r[6] * (r[7] * 0.7).cos()
                    + 0.2 * r[8];
                *y = 5.0 + 2.0 * v + 0.3 * rng.normal();
            }
            SynKind::Clustering { centers, spread } => {
                let c = i % centers.rows();
                for (j, v) in x.iter_mut().enumerate() {
                    *v = centers[(c, j)] + spread * rng.normal();
                }
                let norm: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
                if norm > 1e-12 {
                    for v in x.iter_mut() {
                        *v /= norm;
                    }
                }
                *y = c as f64;
            }
        }
    }
}

impl DataSource for SyntheticSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn len(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn read_into(&self, lo: usize, hi: usize, x: &mut Mat, y: &mut [f64]) -> Result<(), String> {
        // rows past the nominal n are deliberately allowed: the generator
        // is an infinite stream, and `gzk serve` evaluates a stored model
        // on rows the training range never touched
        if lo > hi {
            return Err(format!("{}: read [{lo}, {hi}) is inverted", self.name));
        }
        if x.rows() != hi - lo || x.cols() != self.d || y.len() != hi - lo {
            return Err(format!("{}: read buffers mismatch [{lo}, {hi})", self.name));
        }
        for (r, i) in (lo..hi).enumerate() {
            self.gen_row(i, x.row_mut(r), &mut y[r]);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// FileSource
// ---------------------------------------------------------------------------

/// Magic prefix of the binary format: 8 bytes, then little-endian u64 row
/// count and u64 feature dimension, then n x (d+1) little-endian f64 rows
/// (d features followed by the target).
pub const BINARY_MAGIC: &[u8; 8] = b"GZKBIN01";
const BINARY_HEADER: usize = 24;

enum FileKind {
    /// Byte offset + 1-based line number of each data row.
    Csv { rows: Vec<(u64, usize)> },
    Binary,
}

/// A dataset on disk, read chunk by chunk — never fully loaded.
///
/// Two formats, sniffed by magic bytes:
/// * **CSV** — one row per line, comma-separated, the **last column is the
///   target**; blank lines and `#` comments are skipped. Opening scans the
///   file once to index row offsets and validate the column count (a
///   ragged row fails fast); numeric parsing happens per chunk at read
///   time.
/// * **binary** — [`BINARY_MAGIC`] header then fixed-width rows; random
///   access is a seek. Write one with
///   [`write_binary`](FileSource::write_binary).
pub struct FileSource {
    path: PathBuf,
    name: String,
    n: usize,
    d: usize,
    kind: FileKind,
}

impl FileSource {
    pub fn open(path: impl Into<PathBuf>) -> Result<FileSource, String> {
        let path = path.into();
        let mut file =
            std::fs::File::open(&path).map_err(|e| format!("open {path:?}: {e}"))?;
        let mut magic = [0u8; 8];
        let is_binary = match file.read_exact(&mut magic) {
            Ok(()) => &magic == BINARY_MAGIC,
            Err(_) => false, // shorter than 8 bytes: try CSV, fail with a line count of 0
        };
        let name = format!("file:{}", path.display());
        if is_binary {
            let (n, d) = Self::read_binary_header(&path, &mut file)?;
            Ok(FileSource { path, name, n, d, kind: FileKind::Binary })
        } else {
            let (rows, d) = Self::index_csv(&path)?;
            Ok(FileSource { path, name, n: rows.len(), d, kind: FileKind::Csv { rows } })
        }
    }

    fn read_binary_header(path: &Path, file: &mut std::fs::File) -> Result<(usize, usize), String> {
        let mut head = [0u8; 16];
        file.read_exact(&mut head)
            .map_err(|e| format!("{path:?}: truncated binary header: {e}"))?;
        let n = u64::from_le_bytes(head[..8].try_into().unwrap()) as usize;
        let d = u64::from_le_bytes(head[8..].try_into().unwrap()) as usize;
        if d == 0 {
            return Err(format!("{path:?}: binary header declares d = 0"));
        }
        let expect = (BINARY_HEADER as u64)
            .checked_add((n as u64).checked_mul((d as u64 + 1) * 8).ok_or_else(|| {
                format!("{path:?}: binary header declares an impossible size (n = {n}, d = {d})")
            })?)
            .ok_or_else(|| format!("{path:?}: binary header overflows"))?;
        let actual = file
            .metadata()
            .map_err(|e| format!("stat {path:?}: {e}"))?
            .len();
        if actual != expect {
            return Err(format!(
                "{path:?}: binary file is {actual} bytes but the header (n = {n}, d = {d}) \
                 requires {expect} — truncated or corrupt"
            ));
        }
        Ok((n, d))
    }

    /// One pass over a CSV file: index the byte offset of every data row
    /// and validate the column count. Floats are parsed later, per chunk.
    fn index_csv(path: &Path) -> Result<(Vec<(u64, usize)>, usize), String> {
        let file = std::fs::File::open(path).map_err(|e| format!("open {path:?}: {e}"))?;
        let mut reader = BufReader::new(file);
        let mut rows = Vec::new();
        let mut cols = 0usize;
        let mut offset = 0u64;
        let mut line_no = 0usize;
        let mut line = Vec::new();
        loop {
            line.clear();
            let n_read = reader
                .read_until(b'\n', &mut line)
                .map_err(|e| format!("read {path:?}: {e}"))?;
            if n_read == 0 {
                break;
            }
            line_no += 1;
            let text = std::str::from_utf8(&line)
                .map_err(|_| format!("{path:?} line {line_no}: not valid UTF-8"))?
                .trim();
            if !(text.is_empty() || text.starts_with('#')) {
                let fields = text.split(',').count();
                if fields < 2 {
                    return Err(format!(
                        "{path:?} line {line_no}: a data row needs at least one feature \
                         column and the target column, got {fields} field(s)"
                    ));
                }
                if rows.is_empty() {
                    cols = fields;
                } else if fields != cols {
                    return Err(format!(
                        "{path:?} line {line_no}: ragged row — expected {cols} fields \
                         (as in the first data row), got {fields}"
                    ));
                }
                rows.push((offset, line_no));
            }
            offset += n_read as u64;
        }
        if rows.is_empty() {
            return Err(format!("{path:?}: no data rows (CSV needs features,...,target lines)"));
        }
        Ok((rows, cols - 1))
    }

    fn read_csv_chunk(
        &self,
        rows: &[(u64, usize)],
        lo: usize,
        hi: usize,
        x: &mut Mat,
        y: &mut [f64],
    ) -> Result<(), String> {
        let path = &self.path;
        let file = std::fs::File::open(path).map_err(|e| format!("open {path:?}: {e}"))?;
        let mut reader = BufReader::new(file);
        reader
            .seek(SeekFrom::Start(rows[lo].0))
            .map_err(|e| format!("seek {path:?}: {e}"))?;
        let mut filled = 0usize;
        let mut line = String::new();
        while filled < hi - lo {
            line.clear();
            let n_read = reader
                .read_line(&mut line)
                .map_err(|e| format!("read {path:?}: {e}"))?;
            if n_read == 0 {
                return Err(format!("{path:?}: file shrank since it was opened"));
            }
            let text = line.trim();
            if text.is_empty() || text.starts_with('#') {
                continue;
            }
            let line_no = rows[lo + filled].1;
            let xrow = x.row_mut(filled);
            let mut fields = text.split(',');
            for (j, slot) in xrow.iter_mut().enumerate() {
                let field = fields.next().ok_or_else(|| {
                    format!("{path:?} line {line_no}: ragged row (missing field {})", j + 1)
                })?;
                *slot = parse_field(field, path, line_no, j + 1)?;
            }
            let target = fields.next().ok_or_else(|| {
                format!("{path:?} line {line_no}: ragged row (missing the target column)")
            })?;
            y[filled] = parse_field(target, path, line_no, self.d + 1)?;
            if fields.next().is_some() {
                return Err(format!(
                    "{path:?} line {line_no}: ragged row (more than {} fields)",
                    self.d + 1
                ));
            }
            filled += 1;
        }
        Ok(())
    }

    fn read_binary_chunk(
        &self,
        lo: usize,
        hi: usize,
        x: &mut Mat,
        y: &mut [f64],
    ) -> Result<(), String> {
        let path = &self.path;
        let stride = self.d + 1;
        let mut file = std::fs::File::open(path).map_err(|e| format!("open {path:?}: {e}"))?;
        file.seek(SeekFrom::Start((BINARY_HEADER + lo * stride * 8) as u64))
            .map_err(|e| format!("seek {path:?}: {e}"))?;
        let mut bytes = vec![0u8; (hi - lo) * stride * 8];
        file.read_exact(&mut bytes)
            .map_err(|e| format!("{path:?}: truncated binary payload: {e}"))?;
        for (r, rec) in bytes.chunks_exact(stride * 8).enumerate() {
            let xrow = x.row_mut(r);
            for (j, v) in rec.chunks_exact(8).enumerate() {
                let val = f64::from_le_bytes(v.try_into().unwrap());
                if j < self.d {
                    xrow[j] = val;
                } else {
                    y[r] = val;
                }
            }
        }
        Ok(())
    }

    /// Write `(x, y)` as the binary format (shortest random-access form).
    pub fn write_binary(path: impl AsRef<Path>, x: &Mat, y: &[f64]) -> Result<(), String> {
        let path = path.as_ref();
        assert_eq!(x.rows(), y.len(), "write_binary: row/target mismatch");
        let mut bytes =
            Vec::with_capacity(BINARY_HEADER + x.rows() * (x.cols() + 1) * 8);
        bytes.extend_from_slice(BINARY_MAGIC);
        bytes.extend_from_slice(&(x.rows() as u64).to_le_bytes());
        bytes.extend_from_slice(&(x.cols() as u64).to_le_bytes());
        for i in 0..x.rows() {
            for &v in x.row(i) {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            bytes.extend_from_slice(&y[i].to_le_bytes());
        }
        std::fs::write(path, bytes).map_err(|e| format!("write {path:?}: {e}"))
    }

    /// Write `(x, y)` as CSV (features then target per line, shortest
    /// round-trip float formatting).
    pub fn write_csv(path: impl AsRef<Path>, x: &Mat, y: &[f64]) -> Result<(), String> {
        let path = path.as_ref();
        assert_eq!(x.rows(), y.len(), "write_csv: row/target mismatch");
        let mut text = String::new();
        for i in 0..x.rows() {
            for v in x.row(i) {
                text.push_str(&format!("{v:?},"));
            }
            text.push_str(&format!("{:?}\n", y[i]));
        }
        std::fs::write(path, text).map_err(|e| format!("write {path:?}: {e}"))
    }
}

impl DataSource for FileSource {
    fn name(&self) -> &str {
        &self.name
    }

    fn len(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn read_into(&self, lo: usize, hi: usize, x: &mut Mat, y: &mut [f64]) -> Result<(), String> {
        check_read_shape(self, lo, hi, x, y)?;
        if lo == hi {
            return Ok(());
        }
        match &self.kind {
            FileKind::Csv { rows } => self.read_csv_chunk(rows, lo, hi, x, y),
            FileKind::Binary => self.read_binary_chunk(lo, hi, x, y),
        }
    }
}

fn parse_field(field: &str, path: &Path, line_no: usize, col: usize) -> Result<f64, String> {
    field.trim().parse::<f64>().map_err(|_| {
        format!("{path:?} line {line_no}, field {col}: cannot parse {:?} as a number", field.trim())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gzk-source-{}-{tag}", std::process::id()))
    }

    fn toy_data(n: usize, d: usize) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(91);
        let x = Mat::from_fn(n, d, |_, _| rng.normal());
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (x, y)
    }

    #[test]
    fn mat_source_chunked_reads_match_memory() {
        let (x, y) = toy_data(23, 3);
        let src = MatSource::new(&x, &y);
        assert_eq!((src.len(), src.dim()), (23, 3));
        for chunk in [1usize, 7, 23, 100] {
            let mut got_x = Vec::new();
            let mut got_y = Vec::new();
            for (lo, hi) in chunk_ranges(src.len(), chunk) {
                let (cx, cy) = src.read_range(lo, hi).unwrap();
                got_x.extend_from_slice(cx.data());
                got_y.extend_from_slice(&cy);
            }
            assert_eq!(&got_x, x.data(), "chunk {chunk}");
            assert_eq!(got_y, y, "chunk {chunk}");
        }
        // unlabeled source reads zero targets
        let un = MatSource::unlabeled(&x);
        let (_, zy) = un.read_range(0, 5).unwrap();
        assert!(zy.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn slice_offsets_reads() {
        let (x, y) = toy_data(20, 2);
        let src = MatSource::new(&x, &y);
        let sl = SourceSlice::new(&src, 5, 15);
        assert_eq!(sl.len(), 10);
        let (sx, sy) = sl.read_range(2, 6).unwrap();
        assert_eq!(sx.data(), x.row_block(7, 11).data());
        assert_eq!(sy, &y[7..11]);
    }

    #[test]
    fn synthetic_sources_are_deterministic_and_chunk_invariant() {
        for name in ["elevation", "co2", "climate", "protein", "abalone"] {
            let a = SyntheticSource::by_name(name, 40, 7).unwrap();
            let b = SyntheticSource::by_name(name, 40, 7).unwrap();
            let (xa, ya) = a.read_range(0, 40).unwrap();
            let (xb, yb) = b.read_range(0, 40).unwrap();
            assert_eq!(xa, xb, "{name}");
            assert_eq!(ya, yb, "{name}");
            // chunked reads re-assemble the one-shot read bit for bit
            for chunk in [1usize, 7, 40] {
                let mut got = Vec::new();
                for (lo, hi) in chunk_ranges(40, chunk) {
                    got.extend_from_slice(a.read_range(lo, hi).unwrap().0.data());
                }
                assert_eq!(&got, xa.data(), "{name} chunk {chunk}");
            }
            // a different seed gives different rows
            let c = SyntheticSource::by_name(name, 40, 8).unwrap();
            assert!(c.read_range(0, 40).unwrap().0.max_abs_diff(&xa) > 1e-9, "{name}");
        }
        assert!(SyntheticSource::by_name("no-such-set", 10, 1).is_err());
    }

    #[test]
    fn synthetic_geometry_matches_the_paper_stand_ins() {
        let el = SyntheticSource::elevation(200, 3);
        let (x, y) = el.read_range(0, 200).unwrap();
        for i in 0..200 {
            let norm: f64 = x.row(i).iter().map(|v| v * v).sum();
            assert!((norm - 1.0).abs() < 1e-10, "elevation points live on S^2");
        }
        let mean = y.iter().sum::<f64>() / 200.0;
        let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 200.0;
        assert!(var > 0.01, "elevation target has signal, var = {var}");

        let cl = SyntheticSource::climate(150, 3);
        let (x, _) = cl.read_range(0, 150).unwrap();
        for i in 0..150 {
            let s: f64 = x.row(i)[..3].iter().map(|v| v * v).sum();
            assert!((s - 1.0).abs() < 1e-10);
            assert!((0.0..=1.0).contains(&x.row(i)[3]));
        }

        // protein: analytic standardization keeps empirical moments close
        let pr = SyntheticSource::protein(4000, 5);
        let (x, y) = pr.read_range(0, 4000).unwrap();
        for j in 0..9 {
            let mean: f64 = (0..4000).map(|i| x[(i, j)]).sum::<f64>() / 4000.0;
            let var: f64 = (0..4000).map(|i| x[(i, j)] * x[(i, j)]).sum::<f64>() / 4000.0;
            assert!(mean.abs() < 0.1, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 0.15, "col {j} var {var}");
        }
        assert!(y.iter().all(|v| v.is_finite()));

        // clustering: unit rows, labels in range, every class present
        let ab = SyntheticSource::by_name("abalone", 90, 2).unwrap();
        assert_eq!(ab.k(), 3);
        let (x, y) = ab.read_range(0, 90).unwrap();
        for i in 0..90 {
            let norm: f64 = x.row(i).iter().map(|v| v * v).sum();
            assert!((norm - 1.0).abs() < 1e-10);
            assert!(y[i] == (i % 3) as f64);
        }
    }

    #[test]
    fn synthetic_rows_past_n_are_fresh_but_deterministic() {
        // serve's held-out evaluation reads past the nominal n
        let a = SyntheticSource::elevation(10, 4);
        let (xa, _) = a.read_range(10, 20).unwrap();
        let (xb, _) = SyntheticSource::elevation(10, 4).read_range(10, 20).unwrap();
        assert_eq!(xa, xb);
        let (x0, _) = a.read_range(0, 10).unwrap();
        assert!(xa.max_abs_diff(&x0) > 1e-9);
    }

    #[test]
    fn csv_roundtrip_and_chunked_reads() {
        let (x, y) = toy_data(31, 4);
        let path = tmp_path("roundtrip.csv");
        FileSource::write_csv(&path, &x, &y).unwrap();
        let src = FileSource::open(&path).unwrap();
        assert_eq!((src.len(), src.dim()), (31, 4));
        assert!(src.name().starts_with("file:"));
        let (rx, ry) = src.read_range(0, 31).unwrap();
        assert_eq!(rx, x, "shortest round-trip floats survive CSV");
        assert_eq!(ry, y);
        for chunk in [1usize, 5, 31] {
            let mut got = Vec::new();
            for (lo, hi) in chunk_ranges(31, chunk) {
                got.extend_from_slice(src.read_range(lo, hi).unwrap().0.data());
            }
            assert_eq!(&got, x.data(), "chunk {chunk}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_skips_comments_and_blank_lines() {
        let path = tmp_path("comments.csv");
        std::fs::write(&path, "# header comment\n1.0,2.0,3.0\n\n4.0,5.0,6.0\n").unwrap();
        let src = FileSource::open(&path).unwrap();
        assert_eq!((src.len(), src.dim()), (2, 2));
        let (x, y) = src.read_range(0, 2).unwrap();
        assert_eq!(x.data(), &[1.0, 2.0, 4.0, 5.0]);
        assert_eq!(y, vec![3.0, 6.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn binary_roundtrip_and_random_access() {
        let (x, y) = toy_data(17, 3);
        let path = tmp_path("roundtrip.bin");
        FileSource::write_binary(&path, &x, &y).unwrap();
        let src = FileSource::open(&path).unwrap();
        assert_eq!((src.len(), src.dim()), (17, 3));
        let (rx, ry) = src.read_range(0, 17).unwrap();
        assert_eq!(rx, x, "binary floats are bit-exact");
        assert_eq!(ry, y);
        // random access: a middle chunk matches the in-memory rows
        let (mx, my) = src.read_range(5, 9).unwrap();
        assert_eq!(mx, x.row_block(5, 9));
        assert_eq!(my, &y[5..9]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_csv_is_a_clean_error() {
        // ragged row: fails fast at open, naming the line
        let path = tmp_path("ragged.csv");
        std::fs::write(&path, "1.0,2.0,3.0\n4.0,5.0\n").unwrap();
        let err = FileSource::open(&path).unwrap_err();
        assert!(err.contains("line 2") && err.contains("ragged"), "{err}");
        let _ = std::fs::remove_file(&path);

        // non-numeric field: open succeeds (offsets only), read names the cell
        let path = tmp_path("nonnum.csv");
        std::fs::write(&path, "1.0,2.0,3.0\n4.0,oops,6.0\n").unwrap();
        let src = FileSource::open(&path).unwrap();
        let err = src.read_range(0, 2).unwrap_err();
        assert!(err.contains("line 2") && err.contains("oops"), "{err}");
        // ...but the clean rows before it still read
        assert!(src.read_range(0, 1).is_ok());
        let _ = std::fs::remove_file(&path);

        // a single-column file has no feature/target split
        let path = tmp_path("thin.csv");
        std::fs::write(&path, "1.0\n2.0\n").unwrap();
        let err = FileSource::open(&path).unwrap_err();
        assert!(err.contains("target"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_binary_is_a_clean_error() {
        let (x, y) = toy_data(6, 2);
        let path = tmp_path("trunc.bin");
        FileSource::write_binary(&path, &x, &y).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 9]).unwrap();
        let err = FileSource::open(&path).unwrap_err();
        assert!(err.contains("truncated") || err.contains("corrupt"), "{err}");
        // a header alone (no payload) is also caught
        std::fs::write(&path, &full[..BINARY_HEADER]).unwrap();
        assert!(FileSource::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn interleaved_split_partitions_and_is_chunk_invariant() {
        let x = Mat::from_fn(23, 2, |i, j| (i * 2 + j) as f64);
        let y: Vec<f64> = (0..23).map(|i| i as f64).collect();
        let src = MatSource::new(&x, &y);
        for period in [2usize, 3, 10] {
            let train = InterleavedSplit::train(&src, period);
            let test = InterleavedSplit::test(&src, period);
            assert_eq!(train.len() + test.len(), 23, "period {period}");
            assert_eq!(test.len(), 23 / period);
            // the two views partition the rows exactly (checked via y,
            // which enumerates the underlying row index)
            let (_, ty) = test.read_range(0, test.len()).unwrap();
            let (_, ny) = train.read_range(0, train.len()).unwrap();
            let mut all: Vec<f64> = ty.iter().chain(ny.iter()).cloned().collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for (i, v) in all.iter().enumerate() {
                assert_eq!(*v, i as f64, "period {period}");
            }
            // test rows are spread across the range, not a contiguous tail
            assert_eq!(ty[0], (period - 1) as f64);
            // chunked reads re-assemble the one-shot read bit for bit
            for chunk in [1usize, 4, 23] {
                let mut got = Vec::new();
                for (lo, hi) in chunk_ranges(train.len(), chunk) {
                    got.extend_from_slice(&train.read_range(lo, hi).unwrap().1);
                }
                assert_eq!(got, ny, "period {period} chunk {chunk}");
            }
            // rows stay paired with their targets
            let (tx, ty2) = train.read_range(0, train.len()).unwrap();
            for i in 0..train.len() {
                assert_eq!(tx[(i, 0)], ty2[i] * 2.0, "period {period}");
            }
        }
    }

    #[test]
    fn gather_rows_pulls_exact_rows() {
        let (x, y) = toy_data(12, 3);
        let src = MatSource::new(&x, &y);
        let g = gather_rows(&src, &[3, 0, 11, 3]).unwrap();
        assert_eq!(g.rows(), 4);
        assert_eq!(g.row(0), x.row(3));
        assert_eq!(g.row(1), x.row(0));
        assert_eq!(g.row(2), x.row(11));
        assert_eq!(g.row(3), x.row(3));
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        let bounds: Vec<(usize, usize)> = chunk_ranges(10, 4).collect();
        assert_eq!(bounds, vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(chunk_ranges(0, 4).count(), 0);
        assert_eq!(chunk_ranges(3, 0).collect::<Vec<_>>(), vec![(0, 1), (1, 2), (2, 3)]);
    }
}
