//! Exact kernel functions and Gram matrices — the ground truth every
//! random-feature method is measured against.
//!
//! Families mirror the paper's experiments: Gaussian (Tables 2/3),
//! dot-product kernels (Lemma 4; exponential & polynomial instances) and
//! the depth-L ReLU Neural Tangent Kernel (Lemma 16 / Fig. 1).

use crate::linalg::Mat;

/// A kernel function with an exact pointwise evaluation.
#[derive(Clone, Debug, PartialEq)]
pub enum Kernel {
    /// exp(-||x-y||^2 / (2 sigma^2))
    Gaussian { bandwidth: f64 },
    /// exp(gamma <x,y>)
    Exponential { gamma: f64 },
    /// (<x,y> + c)^p
    Polynomial { p: u32, c: f64 },
    /// depth-L ReLU NTK, Theta(x,y) = ||x|| ||y|| K_relu^{(L)}(cos)
    Ntk { depth: usize },
}

impl Kernel {
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match *self {
            Kernel::Gaussian { bandwidth } => {
                let sq: f64 = x.iter().zip(y).map(|(&a, &b)| (a - b) * (a - b)).sum();
                (-0.5 * sq / (bandwidth * bandwidth)).exp()
            }
            Kernel::Exponential { gamma } => (gamma * dot(x, y)).exp(),
            Kernel::Polynomial { p, c } => (dot(x, y) + c).powi(p as i32),
            Kernel::Ntk { depth } => {
                let nx = norm(x).max(1e-30);
                let ny = norm(y).max(1e-30);
                let cos = (dot(x, y) / (nx * ny)).clamp(-1.0, 1.0);
                nx * ny * ntk_kappa(cos, depth)
            }
        }
    }

    /// Dense Gram matrix K[i][j] = k(x_i, x_j) for row-major points (n x d).
    pub fn gram(&self, x: &Mat) -> Mat {
        let n = x.rows();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.eval(x.row(i), x.row(j));
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        k
    }

    /// Cross Gram K[i][j] = k(a_i, b_j).
    pub fn cross_gram(&self, a: &Mat, b: &Mat) -> Mat {
        let mut k = Mat::zeros(a.rows(), b.rows());
        for i in 0..a.rows() {
            for j in 0..b.rows() {
                k[(i, j)] = self.eval(a.row(i), b.row(j));
            }
        }
        k
    }
}

#[inline]
fn dot(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(&a, &b)| a * b).sum()
}

#[inline]
fn norm(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Arc-cosine kernel of degree 0: a0(t) = 1 - acos(t)/pi.
pub fn arccos_a0(t: f64) -> f64 {
    1.0 - t.clamp(-1.0, 1.0).acos() / std::f64::consts::PI
}

/// Arc-cosine kernel of degree 1:
/// a1(t) = (sqrt(1-t^2) + t (pi - acos(t))) / pi.
pub fn arccos_a1(t: f64) -> f64 {
    let tc = t.clamp(-1.0, 1.0);
    ((1.0 - tc * tc).sqrt() + tc * (std::f64::consts::PI - tc.acos())) / std::f64::consts::PI
}

/// Normalized ReLU NTK K_relu on [-1, 1] ([ZHA+21] recursion), with
/// `depth - 1` recursion steps so that kappa(1) = depth. The paper's
/// Fig.-1 "two-layer ReLU" target
/// `a1(a1(x)) + (a1(x) + x a0(x)) a0(a1(x))` is `depth = 3` in this
/// indexing (two nested applications of a1).
pub fn ntk_kappa(t: f64, depth: usize) -> f64 {
    let mut sigma = t;
    let mut theta = t;
    for _ in 0..depth.saturating_sub(1) {
        theta = arccos_a1(sigma) + theta * arccos_a0(sigma);
        sigma = arccos_a1(sigma);
    }
    theta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sym_eigen;
    use crate::rng::Rng;

    #[test]
    fn gaussian_basics() {
        let k = Kernel::Gaussian { bandwidth: 1.0 };
        assert!((k.eval(&[0.0, 0.0], &[0.0, 0.0]) - 1.0).abs() < 1e-15);
        let v = k.eval(&[1.0, 0.0], &[0.0, 0.0]);
        assert!((v - (-0.5f64).exp()).abs() < 1e-15);
        // bandwidth scaling: k_sigma(x,y) = k_1(x/sigma, y/sigma)
        let k2 = Kernel::Gaussian { bandwidth: 2.0 };
        let a = [0.7, -0.3];
        let b = [0.1, 0.9];
        let scaled = Kernel::Gaussian { bandwidth: 1.0 }
            .eval(&[a[0] / 2.0, a[1] / 2.0], &[b[0] / 2.0, b[1] / 2.0]);
        assert!((k2.eval(&a, &b) - scaled).abs() < 1e-15);
    }

    #[test]
    fn gaussian_factorization() {
        // exp(-|x-y|^2/2) = exp(-|x|^2/2) exp(-|y|^2/2) exp(<x,y>)
        let g = Kernel::Gaussian { bandwidth: 1.0 };
        let e = Kernel::Exponential { gamma: 1.0 };
        let x = [0.4, -0.2, 0.9];
        let y = [-0.5, 0.3, 0.1];
        let nx2: f64 = x.iter().map(|v| v * v).sum();
        let ny2: f64 = y.iter().map(|v| v * v).sum();
        let lhs = g.eval(&x, &y);
        let rhs = (-0.5 * nx2).exp() * (-0.5 * ny2).exp() * e.eval(&x, &y);
        assert!((lhs - rhs).abs() < 1e-14);
    }

    #[test]
    fn polynomial_values() {
        let k = Kernel::Polynomial { p: 2, c: 1.0 };
        assert!((k.eval(&[1.0, 2.0], &[3.0, 4.0]) - 144.0).abs() < 1e-12); // (11+1)^2
    }

    #[test]
    fn ntk_fixed_points() {
        // kappa(1) = depth (each recursion level contributes 1)
        assert!((ntk_kappa(1.0, 2) - 2.0).abs() < 1e-12);
        assert!((ntk_kappa(1.0, 3) - 3.0).abs() < 1e-12);
        // the paper's Fig.-1 two-layer formula is depth = 3 here
        for &t in &[-0.9, -0.2, 0.3, 0.8] {
            let expect = arccos_a1(arccos_a1(t))
                + (arccos_a1(t) + t * arccos_a0(t)) * arccos_a0(arccos_a1(t));
            assert!((ntk_kappa(t, 3) - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn arccos_endpoints() {
        assert!((arccos_a0(1.0) - 1.0).abs() < 1e-12);
        assert!(arccos_a0(-1.0).abs() < 1e-12);
        assert!((arccos_a1(1.0) - 1.0).abs() < 1e-12);
        assert!(arccos_a1(-1.0).abs() < 1e-12);
    }

    #[test]
    fn grams_are_psd() {
        let mut rng = Rng::new(50);
        let x = Mat::from_fn(12, 3, |_, _| rng.normal() * 0.7);
        for k in [
            Kernel::Gaussian { bandwidth: 1.0 },
            Kernel::Exponential { gamma: 0.5 },
            Kernel::Polynomial { p: 3, c: 1.0 },
            Kernel::Ntk { depth: 2 },
        ] {
            let g = k.gram(&x);
            // symmetry
            assert!(g.max_abs_diff(&g.transpose()) < 1e-12);
            let (w, _) = sym_eigen(&g);
            let wmax = w[0].max(1.0);
            assert!(w.iter().all(|&v| v > -1e-8 * wmax), "{k:?}: {:?}", &w[w.len() - 3..]);
        }
    }

    #[test]
    fn cross_gram_consistency() {
        let mut rng = Rng::new(51);
        let x = Mat::from_fn(6, 4, |_, _| rng.normal());
        let k = Kernel::Gaussian { bandwidth: 1.3 };
        let g = k.gram(&x);
        let c = k.cross_gram(&x, &x);
        assert!(g.max_abs_diff(&c) < 1e-14);
    }
}
