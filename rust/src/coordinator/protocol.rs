//! Typed protocol of the one-round distributed featurization system.
//!
//! The registry's feature maps are *data-oblivious*: the entire feature
//! map is determined by a [`FeatureSpec`] — kernel + method + budget +
//! seed, bound to an input dimension. That is the whole point of the
//! protocol: the leader broadcasts the spec (a few bytes of JSON — see
//! [`FeatureSpec::to_json`]), workers derive identical feature maps
//! locally through the `features::spec` registry, and the only data that
//! ever travels is the additive sufficient statistics `(Z^T Z, Z^T y, n)`
//! of size O(F^2), independent of shard size.
//!
//! The wire spec is a thin re-export of [`crate::features::BoundSpec`]:
//! any registered *oblivious* method (Gegenbauer, Fourier, FastFood,
//! PolySketch, Maclaurin) can be broadcast; the data-dependent Nystrom
//! baseline cannot — which is exactly the paper's §1.2 contrast.
//!
//! Work items are **row ranges of one shared
//! [`DataSource`](crate::data::DataSource)**, not copies of the rows: a
//! shard assignment is three integers, each worker reads its own disjoint
//! chunk range directly from the source, and the leader never materializes
//! the dataset. That is both the realistic deployment shape (shards read
//! from shared storage) and what keeps peak memory at
//! O(workers · rows_per_shard · (d + F)) instead of O(n · d).

pub use crate::features::{BoundSpec as FeatureSpec, KernelSpec, Method};

use crate::krr::RidgeStats;

/// Work item sent to a worker: a contiguous row range `[lo, hi)` of the
/// shared data source.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRange {
    pub shard_id: usize,
    pub lo: usize,
    pub hi: usize,
}

/// A worker's reply: additive sufficient statistics for its shard.
pub struct ShardStats {
    pub shard_id: usize,
    pub worker_id: usize,
    pub stats: RidgeStats,
    /// wall time spent featurizing (seconds)
    pub featurize_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureSpec as Spec, Featurizer as _};
    use crate::linalg::Mat;

    fn gaussian_geg(m: usize, seed: u64) -> Spec {
        Spec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 8, s: 2 },
            m,
            seed,
        )
    }

    #[test]
    fn determinism_across_builders() {
        // the broadcast invariant: every holder of the same spec builds a
        // bit-identical feature map — including a holder that received the
        // spec over the wire (encode -> decode -> build)
        let spec = gaussian_geg(128, 99).bind(3);
        let f1 = spec.build();
        let f2 = spec.build();
        let wire = FeatureSpec::from_json(&spec.to_json()).expect("wire decode");
        assert_eq!(wire, spec);
        let f3 = wire.build();
        let mut rng = crate::rng::Rng::new(1);
        let x = Mat::from_fn(5, 3, |_, _| rng.normal());
        let z1 = f1.featurize(&x);
        assert_eq!(z1, f2.featurize(&x));
        assert_eq!(z1, f3.featurize(&x));
    }

    #[test]
    fn determinism_for_non_gegenbauer_methods() {
        // the same invariant for every other oblivious registry method
        let mut rng = crate::rng::Rng::new(2);
        let x = Mat::from_fn(6, 4, |_, _| rng.normal());
        for method in Method::registry().into_iter().filter(|m| m.is_oblivious()) {
            let spec =
                Spec::new(KernelSpec::Gaussian { bandwidth: 1.0 }, method, 64, 7).bind(4);
            let wire = FeatureSpec::from_json(&spec.to_json()).expect("wire decode");
            assert_eq!(
                spec.build().featurize(&x),
                wire.build().featurize(&x),
                "{}",
                spec.spec.method.name()
            );
        }
    }

    #[test]
    fn bandwidth_scaling() {
        let spec = Spec::new(
            KernelSpec::Gaussian { bandwidth: 2.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            32,
            1,
        )
        .bind(2);
        let x = Mat::from_vec(1, 2, vec![4.0, 2.0]);
        let xs = spec.scale_inputs(&x);
        assert_eq!(xs.row(0), &[2.0, 1.0]);
    }

    #[test]
    fn feature_dim() {
        let spec = Spec::new(
            KernelSpec::Ntk { depth: 2 },
            Method::Gegenbauer { q: 10, s: 1 },
            128,
            0,
        )
        .bind(4);
        assert_eq!(spec.feature_dim(), 128);
        // NTK tables are single-channel regardless of the requested s
        assert_eq!(spec.spec.radial_table(4).unwrap().s, 1);
    }
}
