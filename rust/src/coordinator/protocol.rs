//! Typed protocol of the one-round distributed featurization system.
//!
//! The paper's random features are *data-oblivious*: the entire feature map
//! is determined by `(FeatureSpec)` — table parameters plus a seed. That is
//! the whole point of the protocol: the leader broadcasts the spec (a few
//! bytes), workers derive identical direction sets locally, and the only
//! data that ever travels is the additive sufficient statistics
//! `(Z^T Z, Z^T y, n)` of size O(F^2), independent of shard size.

use crate::features::{GegenbauerFeatures, RadialTable};
use crate::krr::RidgeStats;
use crate::linalg::Mat;

/// Kernel family selector for the GZK radial tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Family {
    /// Gaussian with bandwidth sigma (inputs are scaled by 1/sigma).
    Gaussian { bandwidth: f64 },
    /// exp(gamma <x,y>)
    Exponential { gamma: f64 },
    /// (<x,y> + c)^p — exact GZK of degree p (q/s are derived from p)
    Polynomial { p: usize, c: f64 },
    /// depth-L ReLU NTK
    Ntk { depth: usize },
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Gaussian { .. } => "gaussian",
            Family::Exponential { .. } => "exponential",
            Family::Polynomial { .. } => "polynomial",
            Family::Ntk { .. } => "ntk",
        }
    }
}

/// Everything needed to reconstruct the feature map anywhere — the
/// broadcast message of the one-round protocol.
#[derive(Clone, Debug)]
pub struct FeatureSpec {
    pub family: Family,
    pub d: usize,
    /// Gegenbauer truncation degree
    pub q: usize,
    /// radial order
    pub s: usize,
    /// number of random directions (feature dim = m * s)
    pub m: usize,
    pub seed: u64,
}

impl FeatureSpec {
    pub fn feature_dim(&self) -> usize {
        self.m * self.s
    }

    pub fn radial_table(&self) -> RadialTable {
        match self.family {
            Family::Gaussian { .. } => RadialTable::gaussian(self.d, self.q, self.s),
            Family::Exponential { gamma } => {
                RadialTable::exponential(self.d, self.q, self.s, gamma)
            }
            Family::Polynomial { p, c } => RadialTable::polynomial(self.d, p, c),
            Family::Ntk { depth } => RadialTable::ntk(self.d, self.q, depth),
        }
    }

    /// Input preprocessing implied by the family (bandwidth folding).
    pub fn scale_inputs(&self, x: &Mat) -> Mat {
        match self.family {
            Family::Gaussian { bandwidth } if bandwidth != 1.0 => {
                let mut y = x.clone();
                y.scale(1.0 / bandwidth);
                y
            }
            _ => x.clone(),
        }
    }

    /// Build the native featurizer. Every holder of the same spec builds a
    /// bit-identical map (tested in `determinism_across_builders`).
    pub fn build(&self) -> GegenbauerFeatures {
        GegenbauerFeatures::new(self.radial_table(), self.m, self.seed)
    }
}

/// Work item sent to a worker: a shard of rows plus targets.
pub struct ShardTask {
    pub shard_id: usize,
    pub x: Mat,
    pub y: Vec<f64>,
}

/// A worker's reply: additive sufficient statistics for its shard.
pub struct ShardStats {
    pub shard_id: usize,
    pub worker_id: usize,
    pub stats: RidgeStats,
    /// wall time spent featurizing (seconds)
    pub featurize_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::Featurizer as _;
    use crate::rng::Rng;

    #[test]
    fn determinism_across_builders() {
        let spec = FeatureSpec {
            family: Family::Gaussian { bandwidth: 1.0 },
            d: 3,
            q: 8,
            s: 2,
            m: 64,
            seed: 99,
        };
        let f1 = spec.build();
        let f2 = spec.build();
        assert_eq!(f1.directions(), f2.directions());
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(5, 3, |_, _| rng.normal());
        assert_eq!(f1.featurize(&x), f2.featurize(&x));
    }

    #[test]
    fn bandwidth_scaling() {
        let spec = FeatureSpec {
            family: Family::Gaussian { bandwidth: 2.0 },
            d: 2,
            q: 6,
            s: 2,
            m: 16,
            seed: 1,
        };
        let x = Mat::from_vec(1, 2, vec![4.0, 2.0]);
        let xs = spec.scale_inputs(&x);
        assert_eq!(xs.row(0), &[2.0, 1.0]);
    }

    #[test]
    fn feature_dim() {
        let spec = FeatureSpec {
            family: Family::Ntk { depth: 2 },
            d: 4,
            q: 10,
            s: 1,
            m: 128,
            seed: 0,
        };
        assert_eq!(spec.feature_dim(), 128);
        assert_eq!(spec.radial_table().s, 1);
    }
}
