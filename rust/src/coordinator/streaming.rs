//! Single-pass streaming KRR — the second system property data-oblivious
//! features buy (paper §1.2): each example is featurized once, folded into
//! `(Z^T Z, Z^T y)`, and discarded. Memory is O(F^2) regardless of stream
//! length.
//!
//! A bounded channel provides backpressure: producers block when the
//! consumer (featurize + absorb) falls behind.
//!
//! Threading: the consumer is a long-lived *control* thread (it blocks on
//! the batch channel, which pool workers must never do) but its compute —
//! featurization and the `Z^T Z` fold — draws from the global
//! [`Pool`](crate::exec::Pool), so the stream keeps up with producers at
//! whatever width `--threads` grants without spawning helpers of its own.

use super::protocol::FeatureSpec;
use crate::data::{chunk_ranges, DataSource};
use crate::exec::Pool;
use crate::features::Featurizer;
use crate::krr::{FeatureRidge, RidgeStats};
use crate::linalg::Mat;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};

/// One streamed batch of rows.
pub struct StreamBatch {
    pub x: Mat,
    pub y: Vec<f64>,
}

/// Handle used by producers to push batches into the stream.
pub struct StreamHandle {
    tx: SyncSender<StreamBatch>,
}

impl StreamHandle {
    /// Blocking push (backpressure applies).
    pub fn push(&self, batch: StreamBatch) -> Result<(), &'static str> {
        self.tx.send(batch).map_err(|_| "stream closed")
    }

    /// Non-blocking push; returns the batch back if the queue is full.
    pub fn try_push(&self, batch: StreamBatch) -> Result<(), Option<StreamBatch>> {
        match self.tx.try_send(batch) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(b)) => Err(Some(b)),
            Err(TrySendError::Disconnected(_)) => Err(None),
        }
    }

    /// Stream every row of a [`DataSource`] through the queue in
    /// `chunk_rows`-sized batches (blocking pushes, so backpressure
    /// bounds in-flight memory at `queue_batches * chunk_rows` rows) —
    /// the producer half that makes `StreamingKrr` a consumer of the same
    /// chunked pipeline as every other fit path.
    pub fn push_source(&self, src: &dyn DataSource, chunk_rows: usize) -> Result<(), String> {
        for (lo, hi) in chunk_ranges(src.len(), chunk_rows) {
            let (x, y) = src.read_range(lo, hi)?;
            self.push(StreamBatch { x, y }).map_err(|e| e.to_string())?;
        }
        Ok(())
    }
}

/// Streaming KRR accumulator: owns the consumer thread.
pub struct StreamingKrr {
    handle: Option<StreamHandle>,
    consumer: Option<std::thread::JoinHandle<RidgeStats>>,
}

impl StreamingKrr {
    /// Start the consumer with a queue of `queue_batches` in-flight batches.
    pub fn start(spec: FeatureSpec, queue_batches: usize) -> StreamingKrr {
        let (tx, rx): (SyncSender<StreamBatch>, Receiver<StreamBatch>) =
            sync_channel(queue_batches.max(1));
        let consumer = std::thread::spawn(move || {
            // any registered oblivious method: the registry-built
            // featurizer consumes raw rows (bandwidth folding included)
            let feat: Box<dyn Featurizer> = spec.build();
            let f_dim = spec.feature_dim();
            let mut stats = RidgeStats::new(f_dim);
            // one growable feature scratch for the whole stream — the same
            // featurize-into-scratch + absorb chunk body as data::pipeline
            let mut scratch: Vec<f64> = Vec::new();
            for batch in rx {
                // per-batch compute draws from the pool, clamped so tiny
                // batches stay on the consumer thread
                let pool = Pool::for_rows(batch.x.rows());
                let need = batch.x.rows() * f_dim;
                if scratch.len() < need {
                    scratch.resize(need, 0.0);
                }
                feat.featurize_par_into(&batch.x, &mut scratch[..need], &pool);
                stats.absorb_flat_with(&scratch[..need], &batch.y, &pool);
            }
            stats
        });
        StreamingKrr { handle: Some(StreamHandle { tx }), consumer: Some(consumer) }
    }

    pub fn handle(&self) -> &StreamHandle {
        self.handle.as_ref().expect("stream still open")
    }

    /// Close the stream and solve the ridge system.
    pub fn finalize(mut self, lambda: f64) -> (FeatureRidge, RidgeStats) {
        drop(self.handle.take()); // close channel
        let stats = self.consumer.take().expect("not finalized twice").join().expect("consumer");
        (stats.solve(lambda), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{KernelSpec, Method};
    use crate::krr::FeatureRidge;
    use crate::rng::Rng;

    fn spec() -> FeatureSpec {
        crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            48,
            8,
        )
        .bind(2)
    }

    #[test]
    fn stream_equals_batch() {
        let mut rng = Rng::new(9);
        let x = Mat::from_fn(37, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..37).map(|_| rng.normal()).collect();

        let stream = StreamingKrr::start(spec(), 2);
        for lo in (0..37).step_by(5) {
            let hi = (lo + 5).min(37);
            stream
                .handle()
                .push(StreamBatch { x: x.row_block(lo, hi), y: y[lo..hi].to_vec() })
                .unwrap();
        }
        let (model, stats) = stream.finalize(0.05);
        assert_eq!(stats.n, 37);

        let z = spec().build().featurize(&x);
        let reference = FeatureRidge::fit(&z, &y, 0.05);
        for (a, b) in model.weights.iter().zip(&reference.weights) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn backpressure_try_push() {
        let stream = StreamingKrr::start(spec(), 1);
        let mut rng = Rng::new(10);
        // hammer with try_push; everything either lands or is returned,
        // nothing is lost silently
        let mut pushed = 0;
        for _ in 0..50 {
            let x = Mat::from_fn(3, 2, |_, _| rng.normal());
            let y = vec![1.0; 3];
            let mut batch = StreamBatch { x, y };
            loop {
                match stream.handle().try_push(batch) {
                    Ok(()) => {
                        pushed += 3;
                        break;
                    }
                    Err(Some(b)) => {
                        batch = b;
                        std::thread::yield_now();
                    }
                    Err(None) => panic!("stream closed early"),
                }
            }
        }
        let (_, stats) = stream.finalize(0.1);
        assert_eq!(stats.n, pushed);
    }

    #[test]
    fn source_stream_equals_batch() {
        // the pipeline unification: a DataSource pushed through the stream
        // reproduces the one-shot fit over the materialized rows exactly
        let src = crate::data::SyntheticSource::elevation(41, 6);
        let spec = crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            48,
            8,
        )
        .bind(3);
        let stream = StreamingKrr::start(spec.clone(), 2);
        stream.handle().push_source(&src, 7).unwrap();
        let (model, stats) = stream.finalize(0.05);
        assert_eq!(stats.n, 41);
        let (x, y) = src.read_range(0, 41).unwrap();
        let z = spec.build().featurize(&x);
        let reference = FeatureRidge::fit(&z, &y, 0.05);
        assert_eq!(model.weights, reference.weights);
    }

    #[test]
    fn empty_stream_finalizes() {
        let stream = StreamingKrr::start(spec(), 4);
        let (model, stats) = stream.finalize(1.0);
        assert_eq!(stats.n, 0);
        // all-zero stats -> zero weights
        assert!(model.weights.iter().all(|&w| w == 0.0));
    }
}
