//! Leader side of the one-round distributed KRR protocol.
//!
//! Round trip:
//!   1. leader picks `FeatureSpec` (incl. the shared seed) — the broadcast;
//!   2. shards the data source into row ranges, assigned round-robin to
//!      worker loops (a task is three integers — workers read their own
//!      disjoint chunk ranges of the shared source);
//!   3. workers reply once with additive `(Z^T Z, Z^T y, n)` partials;
//!   4. leader merges and solves `(G + lambda I) w = b`.
//!
//! No iteration, no second round — the property the paper highlights over
//! data-dependent methods like Nystrom (§1.2 / Related Work). Because
//! shards are ranges of a [`DataSource`], the protocol never materializes
//! the dataset: peak memory is O(workers · rows_per_shard · (d + F)), so
//! the same code path fits an in-memory `MatSource` or an out-of-core
//! file/synthetic source past RAM.

use super::protocol::{FeatureSpec, ShardRange, ShardStats};
use super::worker::{worker_loop, Backend, WorkerConfig};
use crate::data::{DataSource, MatSource};
use crate::exec::Pool;
use crate::krr::{FeatureRidge, RidgeStats};
use crate::linalg::Mat;
use crate::model::{FittedMap, RidgeModel};
use std::sync::mpsc;
use std::time::Instant;

/// Outcome of a distributed fit, with enough telemetry for the benches.
pub struct DistributedFit {
    pub model: FeatureRidge,
    pub stats: RidgeStats,
    pub n_shards: usize,
    pub n_workers: usize,
    /// wall time of the whole round (seconds)
    pub wall_secs: f64,
    /// sum of per-worker featurize seconds (CPU time proxy)
    pub featurize_secs_total: f64,
    /// shards whose replies never arrived and were recomputed by the
    /// leader (fault tolerance path)
    pub recovered_shards: usize,
}

/// Run the one-round protocol over any [`DataSource`].
///
/// `rows_per_shard` controls task granularity; `n_workers` the width of
/// the worker *wave* — each worker loop is a job drawn from the global
/// [`Pool`] (no ad-hoc thread spawning), so at most `Pool::global()`
/// worker loops run concurrently and a `--threads 1` process executes the
/// whole protocol sequentially. Deterministic: the result is a pure
/// function of (spec, source rows, lambda), independent of `n_workers`,
/// shard order and pool width (property-tested in
/// `rust/tests/coordinator_props.rs`). Errors only on source I/O failure
/// (after the recovery pass has retried the lost shards).
pub fn fit_one_round_source(
    spec: &FeatureSpec,
    src: &dyn DataSource,
    lambda: f64,
    n_workers: usize,
    rows_per_shard: usize,
    backend: Backend,
) -> Result<DistributedFit, String> {
    assert!(n_workers >= 1 && rows_per_shard >= 1);
    if src.dim() != spec.d {
        return Err(format!(
            "source {} has d = {} but the broadcast spec is bound to d = {}",
            src.name(),
            src.dim(),
            spec.d
        ));
    }
    let t0 = Instant::now();
    let n = src.len();
    let f_dim = spec.feature_dim();
    let pool = Pool::global();

    let (res_tx, res_rx) = mpsc::channel::<ShardStats>();
    let mut task_txs = Vec::with_capacity(n_workers);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n_workers);
    for worker_id in 0..n_workers {
        let (task_tx, task_rx) = mpsc::channel::<ShardRange>();
        let cfg = WorkerConfig {
            worker_id,
            spec: spec.clone(),
            backend: backend.clone(),
            source: src,
        };
        let res_tx = res_tx.clone();
        jobs.push(Box::new(move || worker_loop(cfg, task_rx, res_tx)));
        task_txs.push(task_tx);
    }
    drop(res_tx);

    // Shard round-robin BEFORE the wave runs: a task is a row range (three
    // integers), so the fully buffered per-worker channels cost O(shards)
    // — not a copy of the dataset — and the channels close right away, so
    // worker loops drain-and-exit at whatever concurrency the pool grants,
    // no deadlock even when the pool is narrower than the wave. Each
    // shard's range doubles as the recovery recipe: the leader re-reads
    // any shard whose reply never arrives.
    let mut shard_ranges = Vec::new();
    for (sid, lo) in (0..n).step_by(rows_per_shard).enumerate() {
        let hi = (lo + rows_per_shard).min(n);
        let task = ShardRange { shard_id: sid, lo, hi };
        task_txs[sid % n_workers].send(task).expect("worker queue alive");
        shard_ranges.push((lo, hi));
    }
    let n_shards = shard_ranges.len();
    drop(task_txs); // close channels -> workers terminate after draining

    // run the worker wave on the shared pool (blocks until it drains)
    {
        let _span = crate::obs::span("fit", "scatter");
        pool.run_jobs(jobs);
    }

    // The single reduction. Every reply is already buffered, so sort by
    // shard id before merging: float addition is not order-invariant, and
    // mpsc arrival order depends on scheduling — merging in fixed shard
    // order is what makes the fit bitwise reproducible at any pool width.
    let merge_span = crate::obs::span("fit", "merge");
    let mut replies: Vec<ShardStats> = res_rx.iter().collect();
    replies.sort_by_key(|r| r.shard_id);
    let mut merged = RidgeStats::new(f_dim);
    let mut featurize_secs_total = 0.0;
    let mut seen = vec![false; n_shards];
    for reply in &replies {
        merged.merge(&reply.stats);
        featurize_secs_total += reply.featurize_secs;
        seen[reply.shard_id] = true;
    }
    drop(merge_span);

    // fault tolerance: recompute missing shards locally. Because the
    // feature map is data-oblivious the leader can produce byte-identical
    // statistics for a lost shard — no coordination with the (possibly
    // dead) worker required, just a re-read of the range. The wave is
    // over, so the leader draws the whole pool for the recomputation. A
    // shard lost to a source I/O error surfaces that error here.
    let mut recovered_shards = 0;
    if seen.iter().any(|&s| !s) {
        use crate::features::Featurizer;
        let _span = crate::obs::span("fit", "recover");
        let feat = spec.build();
        for (sid, &(lo, hi)) in shard_ranges.iter().enumerate() {
            if !seen[sid] {
                let (x, y) = src.read_range(lo, hi)?;
                let z = {
                    let _span = crate::obs::span("pipeline", "featurize");
                    feat.featurize_par(&x, &pool)
                };
                {
                    let _span = crate::obs::span("pipeline", "absorb");
                    merged.absorb_with(&z, &y, &pool);
                }
                recovered_shards += 1;
            }
        }
        crate::obs::counter("fit.shards_recovered").add(recovered_shards as u64);
        crate::obs::warn(
            "coordinator.leader",
            "shard replies missing; recomputed locally",
            &[("recovered", recovered_shards.into()), ("shards", n_shards.into())],
        );
    }
    if merged.n != n {
        return Err(format!(
            "one-round fit lost rows even after shard recovery: absorbed {} of {n}",
            merged.n
        ));
    }

    let model = {
        let _span = crate::obs::span("fit", "solve");
        merged.solve(lambda)
    };
    Ok(DistributedFit {
        model,
        stats: merged,
        n_shards,
        n_workers,
        wall_secs: t0.elapsed().as_secs_f64(),
        featurize_secs_total,
        recovered_shards,
    })
}

/// [`fit_one_round_source`] over borrowed in-memory data — the same
/// pipeline, just consumed through a [`MatSource`] (whose reads cannot
/// fail).
pub fn fit_one_round(
    spec: &FeatureSpec,
    x: &Mat,
    y: &[f64],
    lambda: f64,
    n_workers: usize,
    rows_per_shard: usize,
    backend: Backend,
) -> DistributedFit {
    assert_eq!(x.rows(), y.len());
    fit_one_round_source(spec, &MatSource::new(x, y), lambda, n_workers, rows_per_shard, backend)
        .expect("in-memory source reads cannot fail")
}

/// The one-round protocol finished into a deployable artifact: run
/// [`fit_one_round_source`], then bundle the solved weights with the
/// broadcast spec as a [`RidgeModel`] — ready for a
/// [`ModelStore`](crate::model::ModelStore) and the serving batcher.
/// Errors if the spec's method is data-dependent (those cannot be
/// broadcast; fit them with [`RidgeModel::fit_source`] instead) or on
/// source I/O failure.
pub fn fit_ridge_source(
    spec: &FeatureSpec,
    src: &dyn DataSource,
    lambda: f64,
    n_workers: usize,
    rows_per_shard: usize,
    backend: Backend,
) -> Result<(RidgeModel, DistributedFit), String> {
    let map = FittedMap::rebuild(spec.clone(), None).map_err(|e| format!("fit_ridge: {e}"))?;
    let fit = fit_one_round_source(spec, src, lambda, n_workers, rows_per_shard, backend)?;
    Ok((RidgeModel::from_parts(map, fit.model.clone()), fit))
}

/// [`fit_ridge_source`] over borrowed in-memory data. Panics if the
/// spec's method is data-dependent.
pub fn fit_ridge(
    spec: &FeatureSpec,
    x: &Mat,
    y: &[f64],
    lambda: f64,
    n_workers: usize,
    rows_per_shard: usize,
    backend: Backend,
) -> (RidgeModel, DistributedFit) {
    assert_eq!(x.rows(), y.len());
    fit_ridge_source(spec, &MatSource::new(x, y), lambda, n_workers, rows_per_shard, backend)
        .unwrap_or_else(|e| panic!("fit_ridge: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{KernelSpec, Method};
    use crate::data::SyntheticSource;
    use crate::features::Featurizer;
    use crate::krr::FeatureRidge;
    use crate::rng::Rng;

    fn spec() -> FeatureSpec {
        crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 8, s: 2 },
            96,
            5,
        )
        .bind(3)
    }

    fn dataset(n: usize) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(n, 3, |_, _| rng.normal() * 0.7);
        let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] * 2.0).sin() + 0.05 * rng.normal()).collect();
        (x, y)
    }

    #[test]
    fn matches_single_node_fit() {
        let (x, y) = dataset(60);
        let fit = fit_one_round(&spec(), &x, &y, 0.01, 3, 7, Backend::Native);
        // single-node reference
        let z = spec().build().featurize(&x);
        let reference = FeatureRidge::fit(&z, &y, 0.01);
        for (a, b) in fit.model.weights.iter().zip(&reference.weights) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert_eq!(fit.stats.n, 60);
        assert_eq!(fit.n_workers, 3);
    }

    #[test]
    fn worker_count_invariance() {
        let (x, y) = dataset(50);
        let f1 = fit_one_round(&spec(), &x, &y, 0.1, 1, 9, Backend::Native);
        let f4 = fit_one_round(&spec(), &x, &y, 0.1, 4, 9, Backend::Native);
        for (a, b) in f1.model.weights.iter().zip(&f4.model.weights) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn recovers_dropped_shards() {
        // failure injection: every 3rd shard reply is lost; the leader must
        // recompute them locally and produce the exact single-node result
        let (x, y) = dataset(55);
        let flaky = fit_one_round(
            &spec(), &x, &y, 0.05, 2, 5, Backend::Flaky { drop_every: 3 },
        );
        assert!(flaky.recovered_shards > 0, "injection did not trigger");
        assert_eq!(flaky.stats.n, 55);
        let clean = fit_one_round(&spec(), &x, &y, 0.05, 2, 5, Backend::Native);
        assert_eq!(clean.recovered_shards, 0);
        for (a, b) in flaky.model.weights.iter().zip(&clean.model.weights) {
            assert!((a - b).abs() < 1e-9, "recovered fit differs: {a} vs {b}");
        }
    }

    #[test]
    fn shards_of_an_out_of_core_source_match_the_materialized_fit() {
        // the tentpole property at the protocol layer: workers reading
        // disjoint chunk ranges of one lazy source reproduce the fit over
        // the materialized rows exactly
        let src = SyntheticSource::elevation(64, 11);
        let (x, y) = src.read_range(0, 64).unwrap();
        let dist =
            fit_one_round_source(&spec(), &src, 0.01, 3, 10, Backend::Native).unwrap();
        let mem = fit_one_round(&spec(), &x, &y, 0.01, 3, 10, Backend::Native);
        assert_eq!(dist.model.weights, mem.model.weights);
        assert_eq!(dist.stats.n, 64);
        // and the spec/source dimension mismatch is a clean error
        let bad = SyntheticSource::protein(20, 1);
        assert!(fit_one_round_source(&spec(), &bad, 0.01, 2, 8, Backend::Native).is_err());
    }

    #[test]
    fn non_gegenbauer_method_over_the_wire() {
        // the widened protocol: a Fourier spec broadcast through the same
        // one-round machinery reproduces the single-node fit exactly
        let (x, y) = dataset(48);
        let spec = crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Fourier,
            64,
            9,
        )
        .bind(3);
        let fit = fit_one_round(&spec, &x, &y, 0.01, 3, 7, Backend::Native);
        let z = spec.build().featurize(&x);
        let reference = FeatureRidge::fit(&z, &y, 0.01);
        for (a, b) in fit.model.weights.iter().zip(&reference.weights) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert_eq!(fit.stats.n, 48);
    }

    #[test]
    fn fit_ridge_bundles_the_one_round_weights() {
        // the model finished by fit_ridge predicts exactly like the raw
        // one-round weights applied to locally built features — and its
        // artifact round-trips (the train half of train-once/serve-later)
        let (x, y) = dataset(45);
        let (model, fit) = fit_ridge(&spec(), &x, &y, 0.05, 2, 9, Backend::Native);
        let z = spec().build().featurize(&x);
        assert_eq!(model.predict_vec(&x), fit.model.predict(&z));
        let loaded = crate::model::from_artifact(&model.to_artifact()).expect("roundtrip");
        use crate::model::Model as _;
        assert_eq!(loaded.predict(&x), model.predict(&x));
        assert_eq!(loaded.feature_spec(), &spec());
    }

    #[test]
    fn shard_size_invariance() {
        let (x, y) = dataset(40);
        let fa = fit_one_round(&spec(), &x, &y, 0.1, 2, 3, Backend::Native);
        let fb = fit_one_round(&spec(), &x, &y, 0.1, 2, 40, Backend::Native);
        for (a, b) in fa.model.weights.iter().zip(&fb.model.weights) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(fa.n_shards > fb.n_shards);
    }
}
