//! Dynamic batcher for serving predictions (the vLLM-router-shaped piece
//! of L3): requests queue up, the service thread drains up to `max_batch`
//! of them or waits at most `max_wait`, runs the whole batch through the
//! model in one shot (amortizing the Gegenbauer recurrence across rows)
//! and answers each request on its own reply channel.
//!
//! The service is generic over the fitted-model subsystem: any
//! [`Model`](crate::model::Model) — ridge, k-means assignment, KPCA
//! embedding, loaded fresh from a [`ModelStore`](crate::model::ModelStore)
//! artifact or fitted in-process — serves through the same loop via
//! [`PredictionService::serve`]. [`PredictionService::start`] remains the
//! scalar-ridge convenience used by the KRR demos.

use super::protocol::FeatureSpec;
use crate::exec::Pool;
use crate::krr::FeatureRidge;
use crate::linalg::Mat;
use crate::model::{FittedMap, Model, RidgeModel};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Request {
    x: Vec<f64>,
    reply: Sender<Vec<f64>>,
}

/// Telemetry the serving bench reads.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub batches: usize,
    /// sum of per-batch sizes (== requests) and of batch latencies
    pub batch_secs_total: f64,
    pub max_batch_seen: usize,
}

/// Client handle: cheap to clone, safe to use from many threads.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Request>,
}

impl ServiceClient {
    /// Blocking predict for one point; the model's first output (the
    /// regression value / cluster index / first principal coordinate).
    pub fn predict(&self, x: &[f64]) -> Result<f64, &'static str> {
        self.predict_vec(x).map(|v| v[0])
    }

    /// Blocking predict for one point, all `output_dim` values.
    pub fn predict_vec(&self, x: &[f64]) -> Result<Vec<f64>, &'static str> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request { x: x.to_vec(), reply: reply_tx })
            .map_err(|_| "service stopped")?;
        reply_rx.recv().map_err(|_| "service dropped request")
    }
}

/// A running prediction service.
pub struct PredictionService {
    client: ServiceClient,
    metrics: Arc<Mutex<ServeMetrics>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Spawn the service thread around a trained scalar ridge model (the
    /// one-round protocol's output). Convenience wrapper over
    /// [`serve`](PredictionService::serve).
    pub fn start(
        spec: FeatureSpec,
        model: FeatureRidge,
        max_batch: usize,
        max_wait: Duration,
    ) -> PredictionService {
        let map = FittedMap::rebuild(spec, None)
            .unwrap_or_else(|e| panic!("PredictionService::start: {e}"));
        Self::serve(Box::new(RidgeModel::from_parts(map, model)), max_batch, max_wait)
    }

    /// Spawn the service thread around **any** fitted model — including
    /// one just loaded from a `ModelStore` artifact, which is how the
    /// serving demo runs: no refitting in the serving process.
    pub fn serve(model: Box<dyn Model>, max_batch: usize, max_wait: Duration) -> PredictionService {
        assert!(max_batch >= 1);
        assert!(model.output_dim() >= 1, "model must emit at least one output");
        let d = model.feature_spec().d;
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let metrics_thread = Arc::clone(&metrics);
        let handle = std::thread::spawn(move || {
            let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
            'serve: loop {
                // block for the first request of a batch
                match rx.recv() {
                    Ok(req) => pending.push(req),
                    Err(_) => break 'serve,
                }
                // Drain whatever is already queued, up to max_batch, without
                // blocking: while the previous batch was being featurized,
                // new requests piled up — that IS the batching window
                // (vLLM-style continuous batching). `max_wait` only applies
                // as an optional extra wait for the SECOND request when the
                // queue was empty, to help bursty low-rate clients; with
                // max_wait = 0 the service is pure drain-available.
                // Perf note (EXPERIMENTS.md §Perf): the previous
                // fixed-deadline version put max_wait on every request's
                // critical path (p50 ~ max_wait + compute).
                if pending.len() < max_batch && !max_wait.is_zero() {
                    match rx.recv_timeout(max_wait) {
                        Ok(req) => pending.push(req),
                        Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {}
                    }
                }
                while pending.len() < max_batch {
                    match rx.try_recv() {
                        Ok(req) => pending.push(req),
                        Err(_) => break,
                    }
                }
                // Run the whole batch through the model at once. The
                // service loop is a control thread; batch *compute* draws
                // from the global pool, clamped so single-row requests
                // never pay a thread spawn on the latency path (results
                // are bit-identical at any width).
                let t0 = Instant::now();
                let mut x = Mat::zeros(pending.len(), d);
                for (i, req) in pending.iter().enumerate() {
                    x.row_mut(i).copy_from_slice(&req.x);
                }
                let out = model.predict_with(&x, &Pool::for_rows(pending.len()));
                // metrics BEFORE replying: once a client holds its answer,
                // the request is guaranteed to be counted (tested by
                // prop_service_answers_every_request_exactly_once)
                let dt = t0.elapsed().as_secs_f64();
                {
                    let mut m = metrics_thread.lock().unwrap();
                    m.requests += pending.len();
                    m.batches += 1;
                    m.batch_secs_total += dt;
                    m.max_batch_seen = m.max_batch_seen.max(pending.len());
                }
                for (i, req) in pending.iter().enumerate() {
                    let _ = req.reply.send(out.row(i).to_vec()); // client may have gone away
                }
                pending.clear();
            }
        });
        PredictionService { client: ServiceClient { tx }, metrics, handle: Some(handle) }
    }

    pub fn client(&self) -> ServiceClient {
        self.client.clone()
    }

    pub fn metrics(&self) -> ServeMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop the service thread (drops the queue).
    pub fn shutdown(mut self) -> ServeMetrics {
        // drop our client sender; thread exits when all clients are gone
        let ServiceClient { tx } = self.client.clone();
        drop(tx);
        // replace internal client to drop the original sender
        self.client = ServiceClient { tx: channel().0 };
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        // detach: leave the thread to exit once all clients drop
        self.handle.take();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{KernelSpec, Method};
    use crate::features::Featurizer as _;
    use crate::model::KmeansModel;
    use crate::rng::Rng;

    fn trained() -> (FeatureSpec, FeatureRidge, Mat, Vec<f64>) {
        let spec = crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            64,
            21,
        )
        .bind(2);
        let mut rng = Rng::new(22);
        let x = Mat::from_fn(80, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..80).map(|i| x[(i, 0)] + x[(i, 1)]).collect();
        let z = spec.build().featurize(&x);
        let model = FeatureRidge::fit(&z, &y, 1e-4);
        (spec, model, x, y)
    }

    #[test]
    fn serves_correct_predictions() {
        let (spec, model, x, _) = trained();
        // reference: direct featurize + predict
        let z = spec.build().featurize(&x);
        let expect = model.predict(&z);
        let svc = PredictionService::start(spec, model, 8, Duration::from_millis(1));
        let client = svc.client();
        for i in 0..20 {
            let p = client.predict(x.row(i)).unwrap();
            assert!((p - expect[i]).abs() < 1e-10, "req {i}");
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 20);
        assert!(m.batches >= 1);
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let (spec, model, x, _) = trained();
        let z = spec.build().featurize(&x);
        let expect = model.predict(&z);
        let svc = PredictionService::start(spec, model, 16, Duration::from_millis(2));
        let mut joins = Vec::new();
        for t in 0..8 {
            let client = svc.client();
            let rows = Mat::from_fn(10, x.cols(), |i, j| x[((t * 10 + i) % 80, j)]);
            let exp: Vec<f64> = (0..10).map(|i| expect[(t * 10 + i) % 80]).collect();
            joins.push(std::thread::spawn(move || {
                for (i, e) in exp.iter().enumerate() {
                    let p = client.predict(rows.row(i)).unwrap();
                    assert!((p - e).abs() < 1e-10);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 80);
        // batching actually happened under concurrency (not 1 req/batch)
        assert!(m.batches <= 80);
    }

    #[test]
    fn batches_respect_max_batch() {
        let (spec, model, x, _) = trained();
        let svc = PredictionService::start(spec, model, 4, Duration::from_millis(5));
        let client = svc.client();
        let mut joins = Vec::new();
        for i in 0..12 {
            let c = client.clone();
            let row = x.row(i).to_vec();
            joins.push(std::thread::spawn(move || c.predict(&row).unwrap()));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(svc.metrics().max_batch_seen <= 4);
    }

    #[test]
    fn serves_a_reloaded_kmeans_artifact() {
        // the generic path: a non-ridge model, loaded from its artifact,
        // answers through the same batcher with multi-output predict_vec
        let mut rng = Rng::new(23);
        let x = Mat::from_fn(40, 2, |i, _| {
            let center = if i % 2 == 0 { 2.0 } else { -2.0 };
            center + 0.2 * rng.normal()
        });
        let spec = crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 5, s: 1 },
            24,
            31,
        )
        .bind(2);
        let fitted = KmeansModel::fit(spec, &x, 2, 30).unwrap();
        let expect = fitted.assign(&x);
        let loaded =
            crate::model::from_artifact(&crate::model::Model::to_artifact(&fitted)).unwrap();
        let svc = PredictionService::serve(loaded, 8, Duration::ZERO);
        let client = svc.client();
        for i in 0..x.rows() {
            let out = client.predict_vec(x.row(i)).unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0], expect[i] as f64, "row {i}");
        }
    }
}
