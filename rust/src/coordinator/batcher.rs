//! Dynamic batcher for serving predictions (the vLLM-router-shaped piece
//! of L3): requests queue up, the service thread drains up to `max_batch`
//! of them or waits at most `max_wait`, runs the whole batch through the
//! model in one shot (amortizing the Gegenbauer recurrence across rows)
//! and answers each request on its own reply channel.
//!
//! The service is generic over the fitted-model subsystem: any
//! [`Model`](crate::model::Model) — ridge, k-means assignment, KPCA
//! embedding, loaded fresh from a [`ModelStore`](crate::model::ModelStore)
//! artifact or fitted in-process — serves through the same loop via
//! [`PredictionService::serve`]. [`PredictionService::start`] remains the
//! scalar-ridge convenience used by the KRR demos.

use super::protocol::FeatureSpec;
use crate::exec::Pool;
use crate::krr::FeatureRidge;
use crate::linalg::Mat;
use crate::model::{FittedMap, Model, RidgeModel};
use crate::obs::registry;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Rung after a reply is sent — how the event-loop listener learns a
/// reply is ready without blocking a thread per in-flight request (it
/// wakes its `poll(2)` loop; see `server::mux`). Must be cheap and must
/// never block: it runs on the service thread, inside the batch loop.
pub type ReplyNotify = Arc<dyn Fn() + Send + Sync>;

struct Request {
    x: Vec<f64>,
    /// when the client handed the request to the service — the latency
    /// histogram measures enqueue → reply-ready, so queue wait and the
    /// batching window are part of every recorded sample
    enqueued: Instant,
    reply: Sender<Vec<f64>>,
    /// optional doorbell rung after `reply` is sent
    notify: Option<ReplyNotify>,
}

/// Fixed-bucket latency histogram on a 1–2–5 log ladder from 1 µs to 50 s
/// (plus one overflow bucket). Fixed buckets keep recording O(1) and the
/// struct `Clone`-cheap, so the serving loop can update it inside the
/// metrics lock and the network layer can snapshot it per `stats` request;
/// quantiles are resolved to the upper bound of their bucket (≤ one ladder
/// step of error — plenty for p50/p95/p99 tail reporting).
#[derive(Clone, Debug)]
pub struct LatencyHist {
    /// one count per `BOUNDS` entry plus the overflow bucket
    counts: [u64; 25],
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist { counts: [0; 25] }
    }
}

impl LatencyHist {
    /// Bucket upper bounds in seconds: {1, 2, 5} × 10^e for e in -6..=1.
    /// The ladder now lives in the observability layer
    /// ([`registry::LADDER_BOUNDS`]) so every histogram in the process —
    /// serving latency here, registry hists everywhere else — is
    /// bucket-for-bucket comparable offline.
    pub const BOUNDS: [f64; 24] = registry::LADDER_BOUNDS;

    /// Count one observation of `secs` into its ladder bucket.
    pub fn record(&mut self, secs: f64) {
        self.counts[registry::ladder_bucket(secs)] += 1;
    }

    /// Total number of recorded observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// The `q`-quantile (`0.0 < q <= 1.0`) in seconds, resolved to the
    /// upper bound of the bucket it lands in; 0.0 when nothing was
    /// recorded, and the overflow bucket reports 2× the last bound
    /// (shared semantics: [`registry::quantile_of`]).
    pub fn quantile(&self, q: f64) -> f64 {
        registry::quantile_of(&self.counts, q)
    }
}

// the counts array is the ladder plus one overflow bucket, exactly
const _: () = assert!(LatencyHist::BOUNDS.len() + 1 == 25);
const _: () = assert!(registry::LADDER_CELLS == 25);

/// Telemetry the serving bench and the network layer's `stats` command
/// read.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub batches: usize,
    /// sum of per-batch sizes (== requests) and of batch latencies
    pub batch_secs_total: f64,
    pub max_batch_seen: usize,
    /// per-request latency (enqueue → reply ready): p50/p95/p99 via
    /// [`LatencyHist::quantile`]
    pub latency: LatencyHist,
}

/// Client handle: cheap to clone, safe to use from many threads.
#[derive(Clone)]
pub struct ServiceClient {
    tx: Sender<Request>,
    /// the model's input dimension — validated at the client so a
    /// wrong-length row is an error reply, never a poisoned batch
    d: usize,
}

impl ServiceClient {
    /// The input dimension every request must match.
    pub fn input_dim(&self) -> usize {
        self.d
    }

    /// Blocking predict for one point; the model's first output (the
    /// regression value / cluster index / first principal coordinate).
    pub fn predict(&self, x: &[f64]) -> Result<f64, String> {
        self.predict_vec(x).map(|v| v[0])
    }

    /// Blocking predict for one point, all `output_dim` values.
    pub fn predict_vec(&self, x: &[f64]) -> Result<Vec<f64>, String> {
        self.submit(x)?
            .recv()
            .map_err(|_| "service dropped request".to_string())
    }

    /// Enqueue one point and return the reply channel without blocking —
    /// the pipelined form the network layer uses (submit on the reader
    /// thread, await on the writer thread, so requests from one
    /// connection can share a batch). The input dimension is validated
    /// HERE: a wrong-length row never reaches the shared service loop.
    pub fn submit(&self, x: &[f64]) -> Result<Receiver<Vec<f64>>, String> {
        self.submit_notify(x, None)
    }

    /// [`submit`](ServiceClient::submit) with an optional doorbell: the
    /// service rings `notify` right after the reply lands in the channel.
    /// The event-loop listener passes a closure that wakes the loop
    /// owning the connection, so a ready reply interrupts its `poll(2)`
    /// instead of waiting out the sweep timeout.
    pub fn submit_notify(
        &self,
        x: &[f64],
        notify: Option<ReplyNotify>,
    ) -> Result<Receiver<Vec<f64>>, String> {
        if x.len() != self.d {
            return Err(format!(
                "input has {} values but the model expects d = {}",
                x.len(),
                self.d
            ));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request { x: x.to_vec(), enqueued: Instant::now(), reply: reply_tx, notify })
            .map_err(|_| "service stopped".to_string())?;
        Ok(reply_rx)
    }
}

/// A running prediction service.
pub struct PredictionService {
    client: ServiceClient,
    metrics: Arc<Mutex<ServeMetrics>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Spawn the service thread around a trained scalar ridge model (the
    /// one-round protocol's output). Convenience wrapper over
    /// [`serve`](PredictionService::serve). Errors (rather than panics)
    /// when the spec's feature map cannot be rebuilt — e.g. a
    /// data-dependent spec with no fitted state.
    pub fn start(
        spec: FeatureSpec,
        model: FeatureRidge,
        max_batch: usize,
        max_wait: Duration,
    ) -> Result<PredictionService, String> {
        let map = FittedMap::rebuild(spec, None)?;
        Ok(Self::serve(Box::new(RidgeModel::from_parts(map, model)), max_batch, max_wait))
    }

    /// Spawn the service thread around **any** fitted model — including
    /// one just loaded from a `ModelStore` artifact, which is how the
    /// serving demo runs: no refitting in the serving process.
    pub fn serve(model: Box<dyn Model>, max_batch: usize, max_wait: Duration) -> PredictionService {
        assert!(max_batch >= 1);
        assert!(model.output_dim() >= 1, "model must emit at least one output");
        let d = model.feature_spec().d;
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let metrics_thread = Arc::clone(&metrics);
        let handle = std::thread::spawn(move || {
            // registry twins of ServeMetrics: process-wide aggregates the
            // wire `metrics` command exposes (handles registered once,
            // updated with relaxed atomics — never inside the lock below)
            let reg_requests = registry::counter("serve.requests");
            let reg_batches = registry::counter("serve.batches");
            let reg_latency = registry::hist("serve.latency_s");
            let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
            'serve: loop {
                // block for the first request of a batch
                match rx.recv() {
                    Ok(req) => pending.push(req),
                    Err(_) => break 'serve,
                }
                // Drain whatever is already queued, up to max_batch, without
                // blocking: while the previous batch was being featurized,
                // new requests piled up — that IS the batching window
                // (vLLM-style continuous batching). `max_wait` only applies
                // as an optional extra wait for the SECOND request when the
                // queue was empty, to help bursty low-rate clients; with
                // max_wait = 0 the service is pure drain-available.
                // Perf note (EXPERIMENTS.md §Perf): the previous
                // fixed-deadline version put max_wait on every request's
                // critical path (p50 ~ max_wait + compute).
                if pending.len() < max_batch && !max_wait.is_zero() {
                    match rx.recv_timeout(max_wait) {
                        Ok(req) => pending.push(req),
                        Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {}
                    }
                }
                while pending.len() < max_batch {
                    match rx.try_recv() {
                        Ok(req) => pending.push(req),
                        Err(_) => break,
                    }
                }
                // Defensive: `ServiceClient::submit` validates the input
                // dimension, so a mismatched row cannot arrive through the
                // public API — but if one ever does, drop it (the client's
                // recv errors) instead of letting `copy_from_slice` panic
                // and kill the loop every other client shares.
                pending.retain(|req| req.x.len() == d);
                if pending.is_empty() {
                    continue 'serve;
                }
                // Run the whole batch through the model at once. The
                // service loop is a control thread; batch *compute* draws
                // from the global pool, clamped so single-row requests
                // never pay a thread spawn on the latency path (results
                // are bit-identical at any width).
                let t0 = Instant::now();
                let mut x = Mat::zeros(pending.len(), d);
                for (i, req) in pending.iter().enumerate() {
                    x.row_mut(i).copy_from_slice(&req.x);
                }
                let out = model.predict_with(&x, &Pool::for_rows(pending.len()));
                // metrics BEFORE replying: once a client holds its answer,
                // the request is guaranteed to be counted (tested by
                // prop_service_answers_every_request_exactly_once)
                let dt = t0.elapsed().as_secs_f64();
                reg_requests.add(pending.len() as u64);
                reg_batches.inc();
                {
                    let mut m = metrics_thread.lock().unwrap();
                    m.requests += pending.len();
                    m.batches += 1;
                    m.batch_secs_total += dt;
                    m.max_batch_seen = m.max_batch_seen.max(pending.len());
                    for req in &pending {
                        let secs = req.enqueued.elapsed().as_secs_f64();
                        m.latency.record(secs);
                        reg_latency.record(secs);
                    }
                }
                for (i, req) in pending.iter().enumerate() {
                    let _ = req.reply.send(out.row(i).to_vec()); // client may have gone away
                    if let Some(bell) = &req.notify {
                        bell(); // wake the event loop that owns this reply
                    }
                }
                pending.clear();
            }
        });
        PredictionService { client: ServiceClient { tx, d }, metrics, handle: Some(handle) }
    }

    pub fn client(&self) -> ServiceClient {
        self.client.clone()
    }

    pub fn metrics(&self) -> ServeMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop the service thread (drops the queue).
    pub fn shutdown(mut self) -> ServeMetrics {
        // replace internal client to drop the original sender; thread
        // exits when all clients are gone
        let d = self.client.d;
        self.client = ServiceClient { tx: channel().0, d };
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        // detach: leave the thread to exit once all clients drop
        self.handle.take();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{KernelSpec, Method};
    use crate::features::Featurizer as _;
    use crate::model::KmeansModel;
    use crate::rng::Rng;

    fn trained() -> (FeatureSpec, FeatureRidge, Mat, Vec<f64>) {
        let spec = crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            64,
            21,
        )
        .bind(2);
        let mut rng = Rng::new(22);
        let x = Mat::from_fn(80, 2, |_, _| rng.normal());
        let y: Vec<f64> = (0..80).map(|i| x[(i, 0)] + x[(i, 1)]).collect();
        let z = spec.build().featurize(&x);
        let model = FeatureRidge::fit(&z, &y, 1e-4);
        (spec, model, x, y)
    }

    #[test]
    fn serves_correct_predictions() {
        let (spec, model, x, _) = trained();
        // reference: direct featurize + predict
        let z = spec.build().featurize(&x);
        let expect = model.predict(&z);
        let svc = PredictionService::start(spec, model, 8, Duration::from_millis(1)).unwrap();
        let client = svc.client();
        for i in 0..20 {
            let p = client.predict(x.row(i)).unwrap();
            assert!((p - expect[i]).abs() < 1e-10, "req {i}");
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 20);
        assert!(m.batches >= 1);
        // every answered request left one latency sample
        assert_eq!(m.latency.count(), 20);
        assert!(m.latency.quantile(0.5) > 0.0);
    }

    #[test]
    fn wrong_dimension_is_an_error_reply_and_the_loop_survives() {
        let (spec, model, x, _) = trained();
        let svc = PredictionService::start(spec, model, 8, Duration::ZERO).unwrap();
        let client = svc.client();
        assert_eq!(client.input_dim(), 2);
        let err = client.predict_vec(&[1.0, 2.0, 3.0]).unwrap_err();
        assert!(err.contains("expects d = 2"), "{err}");
        let err = client.predict_vec(&[]).unwrap_err();
        assert!(err.contains("0 values"), "{err}");
        // the shared service loop is still alive and still correct
        let p = client.predict(x.row(0));
        assert!(p.is_ok(), "{p:?}");
        let m = svc.metrics();
        assert_eq!(m.requests, 1, "rejected requests must not be counted");
    }

    #[test]
    fn start_surfaces_rebuild_failure_as_err() {
        // a data-dependent spec has no fitted state to rebuild from: start
        // must return Err, not panic inside library code
        let (_, model, _, _) = trained();
        let spec = crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Nystrom { lambda: 1e-3 },
            16,
            3,
        )
        .bind(2);
        let err = PredictionService::start(spec, model, 8, Duration::ZERO).unwrap_err();
        assert!(err.contains("nystrom"), "{err}");
    }

    #[test]
    fn latency_hist_records_and_resolves_quantiles() {
        let mut h = LatencyHist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        // 90 fast observations, 10 slow ones: p50 resolves to the fast
        // bucket's bound, p99 to the slow one's
        for _ in 0..90 {
            h.record(1.5e-6); // -> 2us bucket
        }
        for _ in 0..10 {
            h.record(0.3); // -> 0.5s bucket
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile(0.5), 2e-6);
        assert_eq!(h.quantile(0.9), 2e-6);
        assert_eq!(h.quantile(0.99), 0.5);
        assert_eq!(h.quantile(1.0), 0.5);
        // overflow: beyond the last bound still counts, reported as 2x it
        h.record(1e4);
        assert_eq!(h.quantile(1.0), 100.0);
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let (spec, model, x, _) = trained();
        let z = spec.build().featurize(&x);
        let expect = model.predict(&z);
        let svc = PredictionService::start(spec, model, 16, Duration::from_millis(2)).unwrap();
        let mut joins = Vec::new();
        for t in 0..8 {
            let client = svc.client();
            let rows = Mat::from_fn(10, x.cols(), |i, j| x[((t * 10 + i) % 80, j)]);
            let exp: Vec<f64> = (0..10).map(|i| expect[(t * 10 + i) % 80]).collect();
            joins.push(std::thread::spawn(move || {
                for (i, e) in exp.iter().enumerate() {
                    let p = client.predict(rows.row(i)).unwrap();
                    assert!((p - e).abs() < 1e-10);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 80);
        // batching actually happened under concurrency (not 1 req/batch)
        assert!(m.batches <= 80);
    }

    #[test]
    fn batches_respect_max_batch() {
        let (spec, model, x, _) = trained();
        let svc = PredictionService::start(spec, model, 4, Duration::from_millis(5)).unwrap();
        let client = svc.client();
        let mut joins = Vec::new();
        for i in 0..12 {
            let c = client.clone();
            let row = x.row(i).to_vec();
            joins.push(std::thread::spawn(move || c.predict(&row).unwrap()));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(svc.metrics().max_batch_seen <= 4);
    }

    #[test]
    fn serves_a_reloaded_kmeans_artifact() {
        // the generic path: a non-ridge model, loaded from its artifact,
        // answers through the same batcher with multi-output predict_vec
        let mut rng = Rng::new(23);
        let x = Mat::from_fn(40, 2, |i, _| {
            let center = if i % 2 == 0 { 2.0 } else { -2.0 };
            center + 0.2 * rng.normal()
        });
        let spec = crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 5, s: 1 },
            24,
            31,
        )
        .bind(2);
        let fitted = KmeansModel::fit(spec, &x, 2, 30).unwrap();
        let expect = fitted.assign(&x);
        let loaded =
            crate::model::from_artifact(&crate::model::Model::to_artifact(&fitted)).unwrap();
        let svc = PredictionService::serve(loaded, 8, Duration::ZERO);
        let client = svc.client();
        for i in 0..x.rows() {
            let out = client.predict_vec(x.row(i)).unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0], expect[i] as f64, "row {i}");
        }
    }
}
