//! Worker side of the one-round protocol: featurize shards, return
//! additive sufficient statistics.
//!
//! Each worker is a plain OS thread (tokio is not available offline and the
//! workload is CPU-bound). A worker may featurize through either backend:
//!
//! * native — the pure-rust hot path in `features::gegenbauer`;
//! * PJRT   — the AOT jax/Pallas executable, one `Runtime` per worker
//!            thread (PJRT handles are not Send).
//!
//! Both backends produce the same feature map for the same `FeatureSpec`
//! (checked in `rust/tests/pjrt_roundtrip.rs`).

use super::protocol::{FeatureSpec, ShardStats, ShardTask};
use crate::features::{Featurizer, GegenbauerFeatures};
use crate::krr::RidgeStats;
use crate::linalg::Mat;
use crate::runtime::Runtime;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

/// Which compute backend a worker should use for featurization.
#[derive(Clone, Debug)]
pub enum Backend {
    Native,
    /// artifact directory; the worker opens its own PJRT client
    Pjrt { artifact_dir: PathBuf },
    /// failure injection for tests: behaves like Native but silently drops
    /// the reply for every `drop_every`-th shard — exercises the leader's
    /// missing-shard recovery path.
    Flaky { drop_every: usize },
}

pub struct WorkerConfig {
    pub worker_id: usize,
    pub spec: FeatureSpec,
    pub backend: Backend,
}

enum BackendState {
    Native(GegenbauerFeatures),
    Pjrt { runtime: Runtime, w: Mat, family: &'static str, native: GegenbauerFeatures },
}

impl BackendState {
    fn new(cfg: &WorkerConfig) -> Self {
        let native = cfg.spec.build();
        match &cfg.backend {
            Backend::Native | Backend::Flaky { .. } => BackendState::Native(native),
            Backend::Pjrt { artifact_dir } => {
                let runtime = Runtime::open(artifact_dir).expect("open PJRT runtime");
                let w = native.directions().clone();
                BackendState::Pjrt { runtime, w, family: cfg.spec.family.name(), native }
            }
        }
    }

    fn featurize(&self, spec: &FeatureSpec, x: &Mat) -> Mat {
        let xs = spec.scale_inputs(x);
        match self {
            BackendState::Native(feat) => feat.featurize(&xs),
            BackendState::Pjrt { runtime, w, family, native } => {
                // PJRT artifacts exist for specific (family, d, q, s); if
                // the runtime can't serve this spec fall back to native so
                // the protocol still completes.
                match runtime.featurize(family, &xs, w) {
                    Ok(z) => z,
                    Err(_) => native.featurize(&xs),
                }
            }
        }
    }
}

/// Run a worker loop: consume `ShardTask`s, emit `ShardStats`. Terminates
/// when the task channel closes. This is the function each worker thread
/// executes.
pub fn worker_loop(cfg: WorkerConfig, tasks: Receiver<ShardTask>, results: Sender<ShardStats>) {
    let backend = BackendState::new(&cfg);
    let f_dim = cfg.spec.feature_dim();
    for task in tasks {
        if let Backend::Flaky { drop_every } = cfg.backend {
            if drop_every > 0 && task.shard_id % drop_every == drop_every - 1 {
                continue; // inject a lost shard
            }
        }
        let t0 = Instant::now();
        let z = backend.featurize(&cfg.spec, &task.x);
        let featurize_secs = t0.elapsed().as_secs_f64();
        let mut stats = RidgeStats::new(f_dim);
        stats.absorb(&z, &task.y);
        let reply = ShardStats {
            shard_id: task.shard_id,
            worker_id: cfg.worker_id,
            stats,
            featurize_secs,
        };
        if results.send(reply).is_err() {
            break; // leader went away
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Family;
    use crate::rng::Rng;
    use std::sync::mpsc;

    fn spec() -> FeatureSpec {
        FeatureSpec {
            family: Family::Gaussian { bandwidth: 1.0 },
            d: 3,
            q: 8,
            s: 2,
            m: 32,
            seed: 77,
        }
    }

    #[test]
    fn worker_produces_correct_stats() {
        let (task_tx, task_rx) = mpsc::channel();
        let (res_tx, res_rx) = mpsc::channel();
        let cfg = WorkerConfig { worker_id: 0, spec: spec(), backend: Backend::Native };
        let handle = std::thread::spawn(move || worker_loop(cfg, task_rx, res_tx));

        let mut rng = Rng::new(2);
        let x = Mat::from_fn(10, 3, |_, _| rng.normal());
        let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        task_tx.send(ShardTask { shard_id: 0, x: x.clone(), y: y.clone() }).unwrap();
        drop(task_tx);
        let reply = res_rx.recv().unwrap();
        handle.join().unwrap();

        // reference: featurize locally with the same spec
        let z = spec().build().featurize(&x);
        let mut expect = RidgeStats::new(64);
        expect.absorb(&z, &y);
        assert!(reply.stats.g.max_abs_diff(&expect.g) < 1e-12);
        assert_eq!(reply.stats.n, 10);
    }

    #[test]
    fn worker_handles_multiple_shards() {
        let (task_tx, task_rx) = mpsc::channel();
        let (res_tx, res_rx) = mpsc::channel();
        let cfg = WorkerConfig { worker_id: 3, spec: spec(), backend: Backend::Native };
        let handle = std::thread::spawn(move || worker_loop(cfg, task_rx, res_tx));
        let mut rng = Rng::new(3);
        for sid in 0..4 {
            let x = Mat::from_fn(5, 3, |_, _| rng.normal());
            let y: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
            task_tx.send(ShardTask { shard_id: sid, x, y }).unwrap();
        }
        drop(task_tx);
        let mut got: Vec<usize> = res_rx.iter().map(|r| r.shard_id).collect();
        handle.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }
}
