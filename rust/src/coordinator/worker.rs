//! Worker side of the one-round protocol: read assigned row ranges from
//! the shared data source, featurize them, return additive sufficient
//! statistics.
//!
//! Each worker loop is a coarse job the leader schedules on the global
//! [`Pool`](crate::exec::Pool) (`Pool::run_jobs`) — the workers ARE the
//! parallel axis of the protocol, so everything inside a worker runs
//! serially ([`Pool::serial`](crate::exec::Pool::serial)): nesting
//! data-parallel kernels inside the worker wave would oversubscribe the
//! machine. A worker rebuilds its featurizer from the broadcast
//! [`FeatureSpec`] through the `features::spec` registry — any
//! data-oblivious method works — and reads its shards **itself** from the
//! shared [`DataSource`]: a task is three integers, so worker memory is
//! bounded by one shard's rows, never by n. Featurization may go through
//! either backend:
//!
//! * native — the registry-built featurizer (the pure-rust hot path);
//! * PJRT   — the AOT jax/Pallas executable, one `Runtime` per worker
//!            thread (PJRT handles are not Send). Only the Gegenbauer
//!            method has AOT artifacts; other methods fall back to native.
//!
//! Both backends produce the same feature map for the same `FeatureSpec`
//! (checked in `rust/tests/pjrt_roundtrip.rs`).
//!
//! A shard whose source read fails is skipped (with a structured warn
//! event, see [`crate::obs`]);
//! the leader's missing-shard recovery re-reads it and surfaces the I/O
//! error if it persists — a reply is never fabricated.

use super::protocol::{FeatureSpec, ShardRange, ShardStats};
use crate::data::DataSource;
use crate::features::{Featurizer, GegenbauerFeatures};
use crate::krr::RidgeStats;
use crate::linalg::Mat;
use crate::runtime::Runtime;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

/// Which compute backend a worker should use for featurization.
#[derive(Clone, Debug)]
pub enum Backend {
    Native,
    /// artifact directory; the worker opens its own PJRT client
    Pjrt { artifact_dir: PathBuf },
    /// failure injection for tests: behaves like Native but silently drops
    /// the reply for every `drop_every`-th shard — exercises the leader's
    /// missing-shard recovery path.
    Flaky { drop_every: usize },
}

pub struct WorkerConfig<'a> {
    pub worker_id: usize,
    pub spec: FeatureSpec,
    pub backend: Backend,
    /// the shared source every shard range refers into
    pub source: &'a dyn DataSource,
}

enum BackendState {
    Native(Box<dyn Featurizer>),
    Pjrt {
        runtime: Runtime,
        /// unscaled Gegenbauer map (the artifact consumes raw directions)
        geg: GegenbauerFeatures,
        family: &'static str,
        /// registry-built native featurizer for artifact-miss fallback
        fallback: Box<dyn Featurizer>,
    },
}

impl BackendState {
    fn new(cfg: &WorkerConfig<'_>) -> Self {
        match &cfg.backend {
            Backend::Native | Backend::Flaky { .. } => BackendState::Native(cfg.spec.build()),
            Backend::Pjrt { artifact_dir } => match cfg.spec.build_gegenbauer() {
                Some(geg) => {
                    let runtime = Runtime::open(artifact_dir).expect("open PJRT runtime");
                    BackendState::Pjrt {
                        runtime,
                        geg,
                        family: cfg.spec.kernel_name(),
                        fallback: cfg.spec.build(),
                    }
                }
                // PJRT artifacts exist only for the Gegenbauer method;
                // every other registry method runs native.
                None => BackendState::Native(cfg.spec.build()),
            },
        }
    }

    fn featurize(&self, spec: &FeatureSpec, x: &Mat) -> Mat {
        match self {
            BackendState::Native(feat) => feat.featurize(x),
            BackendState::Pjrt { runtime, geg, family, fallback } => {
                // the artifact consumes pre-scaled inputs (registry-built
                // featurizers fold the bandwidth in themselves); if the
                // runtime can't serve this spec fall back to native so the
                // protocol still completes.
                let xs = spec.scale_inputs(x);
                match runtime.featurize(family, &xs, geg.directions()) {
                    Ok(z) => z,
                    Err(_) => fallback.featurize(x),
                }
            }
        }
    }
}

/// Run a worker loop: consume `ShardRange`s, read each range from the
/// shared source, emit `ShardStats`. Terminates when the task channel
/// closes. This is the job each worker executes on the leader's pool wave.
pub fn worker_loop(
    cfg: WorkerConfig<'_>,
    tasks: Receiver<ShardRange>,
    results: Sender<ShardStats>,
) {
    let backend = BackendState::new(&cfg);
    let f_dim = cfg.spec.feature_dim();
    for task in tasks {
        if let Backend::Flaky { drop_every } = cfg.backend {
            if drop_every > 0 && task.shard_id % drop_every == drop_every - 1 {
                continue; // inject a lost shard
            }
        }
        let (x, y) = match cfg.source.read_range(task.lo, task.hi) {
            Ok(chunk) => chunk,
            Err(e) => {
                // no reply: the leader recomputes this range and surfaces
                // the error if the source really is broken
                crate::obs::warn(
                    "coordinator.worker",
                    &format!("shard read failed ({e}); leaving it to leader recovery"),
                    &[("worker", cfg.worker_id.into()), ("shard", task.shard_id.into())],
                );
                continue;
            }
        };
        let t0 = Instant::now();
        let z = {
            let _span = crate::obs::span("pipeline", "featurize");
            backend.featurize(&cfg.spec, &x)
        };
        let featurize_secs = t0.elapsed().as_secs_f64();
        let mut stats = RidgeStats::new(f_dim);
        // serial on purpose: the worker wave is the parallel axis
        {
            let _span = crate::obs::span("pipeline", "absorb");
            stats.absorb_with(&z, &y, &crate::exec::Pool::serial());
        }
        let reply = ShardStats {
            shard_id: task.shard_id,
            worker_id: cfg.worker_id,
            stats,
            featurize_secs,
        };
        if results.send(reply).is_err() {
            break; // leader went away
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{KernelSpec, Method};
    use crate::data::MatSource;
    use crate::rng::Rng;
    use std::sync::mpsc;

    fn spec() -> FeatureSpec {
        crate::features::FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 8, s: 2 },
            64,
            77,
        )
        .bind(3)
    }

    /// Run one worker loop over `shards` of a shared in-memory source.
    fn run_worker(
        spec: FeatureSpec,
        x: &Mat,
        y: &[f64],
        shards: &[(usize, usize)],
    ) -> Vec<ShardStats> {
        let source = MatSource::new(x, y);
        let (task_tx, task_rx) = mpsc::channel();
        let (res_tx, res_rx) = mpsc::channel();
        for (sid, &(lo, hi)) in shards.iter().enumerate() {
            task_tx.send(ShardRange { shard_id: sid, lo, hi }).unwrap();
        }
        drop(task_tx);
        let cfg =
            WorkerConfig { worker_id: 0, spec, backend: Backend::Native, source: &source };
        std::thread::scope(|scope| {
            scope.spawn(move || worker_loop(cfg, task_rx, res_tx));
        });
        res_rx.iter().collect()
    }

    #[test]
    fn worker_produces_correct_stats() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(10, 3, |_, _| rng.normal());
        let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let replies = run_worker(spec(), &x, &y, &[(0, 10)]);
        assert_eq!(replies.len(), 1);

        // reference: featurize locally with the same spec
        use crate::features::Featurizer as _;
        let z = spec().build().featurize(&x);
        let mut expect = RidgeStats::new(64);
        expect.absorb(&z, &y);
        assert!(replies[0].stats.g.max_abs_diff(&expect.g) < 1e-12);
        assert_eq!(replies[0].stats.n, 10);
    }

    #[test]
    fn worker_handles_multiple_shards() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(20, 3, |_, _| rng.normal());
        let y: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let replies = run_worker(spec(), &x, &y, &[(0, 5), (5, 10), (10, 15), (15, 20)]);
        let mut got: Vec<usize> = replies.iter().map(|r| r.shard_id).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(replies.iter().map(|r| r.stats.n).sum::<usize>(), 20);
    }

    #[test]
    fn worker_runs_every_oblivious_method() {
        // the widened wire: any oblivious registry method works end to end
        let mut rng = Rng::new(4);
        let x = Mat::from_fn(9, 3, |_, _| rng.normal());
        let y: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        for method in Method::registry().into_iter().filter(|m| m.is_oblivious()) {
            let spec = crate::features::FeatureSpec::new(
                KernelSpec::Gaussian { bandwidth: 1.0 },
                method,
                32,
                5,
            )
            .bind(3);
            let replies = run_worker(spec.clone(), &x, &y, &[(0, 9)]);
            use crate::features::Featurizer as _;
            let z = spec.build().featurize(&x);
            let mut expect = RidgeStats::new(spec.feature_dim());
            expect.absorb(&z, &y);
            assert!(
                replies[0].stats.g.max_abs_diff(&expect.g) < 1e-12,
                "{}",
                spec.spec.method.name()
            );
        }
    }
}
