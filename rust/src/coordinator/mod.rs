//! L3 coordinator — the system the registry's data-oblivious features
//! enable.
//!
//! * [`protocol`] — the broadcast `FeatureSpec` (a re-export of
//!   [`crate::features::BoundSpec`]) and the shard/stats types;
//! * [`worker`] — worker loops (native or PJRT featurization backend),
//!   scheduled as jobs on the global [`Pool`](crate::exec::Pool) rather
//!   than ad-hoc threads;
//! * [`leader`] — one-round distributed KRR over any
//!   [`DataSource`](crate::data::DataSource) ([`fit_one_round_source`]):
//!   broadcast spec, workers read disjoint chunk ranges of the shared
//!   source, one reduction; optionally finished into a persistable
//!   [`RidgeModel`](crate::model::RidgeModel) ([`fit_ridge_source`]).
//!   [`fit_one_round`] / [`fit_ridge`] are the in-memory wrappers;
//! * [`streaming`] — single-pass streaming KRR with backpressure; the
//!   consumer's compute draws from the pool;
//! * [`batcher`] — dynamic batcher serving predictions; serves any fitted
//!   [`Model`](crate::model::Model), including one reloaded from a
//!   [`ModelStore`](crate::model::ModelStore) artifact, with batch
//!   compute drawn from the pool.
//!
//! ```
//! use gzk::coordinator::{fit_one_round, Backend};
//! use gzk::features::{FeatureSpec, KernelSpec, Method};
//! use gzk::linalg::Mat;
//! use gzk::rng::Rng;
//!
//! let spec = FeatureSpec::new(
//!     KernelSpec::Gaussian { bandwidth: 1.0 },
//!     Method::Gegenbauer { q: 8, s: 2 },
//!     /* feature budget */ 64,
//!     /* seed */ 5,
//! )
//! .bind(/* d = */ 3);
//! let mut rng = Rng::new(1);
//! let x = Mat::from_fn(40, 3, |_, _| rng.normal());
//! let y: Vec<f64> = (0..40).map(|i| x[(i, 0)]).collect();
//! // broadcast the spec, featurize shards on 2 workers, reduce once, solve
//! let fit = fit_one_round(&spec, &x, &y, 1e-3, 2, 8, Backend::Native);
//! assert_eq!(fit.stats.n, 40);
//! assert_eq!(fit.recovered_shards, 0);
//! ```

pub mod batcher;
pub mod leader;
pub mod protocol;
pub mod streaming;
pub mod worker;

pub use batcher::{LatencyHist, PredictionService, ReplyNotify, ServeMetrics, ServiceClient};
pub use leader::{
    fit_one_round, fit_one_round_source, fit_ridge, fit_ridge_source, DistributedFit,
};
pub use protocol::{FeatureSpec, KernelSpec, Method, ShardRange, ShardStats};
pub use streaming::{StreamBatch, StreamHandle, StreamingKrr};
pub use worker::{Backend, WorkerConfig};
