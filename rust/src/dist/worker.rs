//! The `gzk worker` process: connect to a leader, receive the broadcast
//! job, compute per-shard sufficient statistics, stream them back.
//!
//! A worker is stateless and data-local: it rebuilds the feature map
//! from the broadcast [`BoundSpec`] (bit-identical on every machine —
//! the registry determinism contract) and opens its **own**
//! [`DataSource`](crate::data::DataSource) from the job's
//! [`DataSpec`](super::wire::DataSpec); an assignment is three integers.
//! Inside the process the worker draws the global
//! [`Pool`](crate::exec::Pool) for featurize and absorb — bit-identical
//! to the serial path at any width (the PR-3 contract), so a shard's
//! statistics do not depend on which machine computed them or how many
//! threads it had. That is the whole bit-identity story: the leader can
//! merge replies from any mix of workers (or recompute a lost shard
//! itself) and still reproduce the single-process fit exactly.
//!
//! A shard whose source read fails is answered with an error message
//! (never a fabricated reply); the leader recovers that shard locally,
//! exactly like the in-process protocol.

use super::wire::{self, DistMsg, MAX_FRAME_BYTES};
use crate::exec::Pool;
use crate::features::Featurizer;
use crate::krr::RidgeStats;
use crate::obs;
use crate::server::listener::{read_line_bounded, LineRead};
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Knobs for [`run_worker`]; the defaults match the CLI's.
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    /// connection attempts before giving up (the leader may not be
    /// listening yet — workers and leader are launched concurrently)
    pub connect_attempts: usize,
    /// delay between connection attempts
    pub connect_delay: Duration,
    /// give up if the leader is silent for this long (covers the gap
    /// while the leader waits for the rest of the fleet to register)
    pub idle_timeout: Duration,
    /// fault injection for tests: drop the connection (mid-protocol,
    /// without replying) when the (n+1)-th assignment arrives — the
    /// network twin of the in-process `Backend::Flaky`
    pub die_after_shards: Option<usize>,
}

impl Default for WorkerOptions {
    fn default() -> WorkerOptions {
        WorkerOptions {
            connect_attempts: 50,
            connect_delay: Duration::from_millis(200),
            idle_timeout: Duration::from_secs(300),
            die_after_shards: None,
        }
    }
}

/// What a clean worker run reports (the CLI prints it).
#[derive(Clone, Copy, Debug)]
pub struct WorkerReport {
    pub worker_id: usize,
    pub shards: usize,
    pub rows: usize,
    pub featurize_secs: f64,
}

fn send_line(stream: &mut TcpStream, line: &str) -> Result<(), String> {
    stream
        .write_all(line.as_bytes())
        .and_then(|()| stream.write_all(b"\n"))
        .map_err(|e| format!("send to leader: {e}"))
}

fn read_msg(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    idle: Duration,
) -> Result<DistMsg, String> {
    match read_line_bounded(reader, buf, MAX_FRAME_BYTES, Some(idle)) {
        LineRead::Line => {}
        LineRead::Eof | LineRead::Gone => return Err("leader closed the connection".to_string()),
        LineRead::Idle => return Err("leader went silent (idle timeout)".to_string()),
        LineRead::Overlong => {
            return Err(format!("leader sent a frame over {MAX_FRAME_BYTES} bytes"));
        }
    }
    let line = std::str::from_utf8(buf).map_err(|_| "leader frame is not UTF-8".to_string())?;
    wire::parse_msg(line.trim())
}

/// Run one worker to completion: register, receive the job, serve
/// assignments until the leader says done. Returns a summary on a clean
/// run; any protocol or I/O failure is an `Err` (the CLI exits 1).
pub fn run_worker(addr: &str, opts: &WorkerOptions) -> Result<WorkerReport, String> {
    let mut stream = connect_with_retry(addr, opts)?;
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(opts.idle_timeout))
        .map_err(|e| format!("set read timeout: {e}"))?;
    send_line(&mut stream, &wire::register_msg())?;
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| format!("clone leader connection: {e}"))?,
    );
    let mut buf = Vec::new();

    let (worker_id, spec, data, job_tid) =
        match read_msg(&mut reader, &mut buf, opts.idle_timeout)? {
            DistMsg::Job { worker_id, spec, data, tid } => (worker_id, spec, data, tid),
            DistMsg::Error { error, .. } => {
                return Err(format!("leader rejected registration: {error}"))
            }
            other => return Err(format!("expected a job after registering, got {other:?}")),
        };
    // adopt the run's trace ID as this thread's ambient trace: every
    // shard/featurize/absorb span below inherits it and stitches into
    // the leader's timeline via `gzk trace-merge`
    let _trace_ctx = obs::trace::with_trace(job_tid);
    obs::info(
        "dist.worker",
        "registered with the leader; job received",
        &[("worker", worker_id.into()), ("dataset", data.name.as_str().into())],
    );
    let src = data.open()?;
    if src.dim() != spec.d {
        return Err(format!(
            "data source {:?} has d = {} but the broadcast spec is bound to d = {}",
            data.name,
            src.dim(),
            spec.d
        ));
    }
    let feat = spec.build();
    let f_dim = spec.feature_dim();
    let pool = Pool::global();
    let mut report =
        WorkerReport { worker_id, shards: 0, rows: 0, featurize_secs: 0.0 };

    loop {
        let (task, task_tid) = match read_msg(&mut reader, &mut buf, opts.idle_timeout)? {
            DistMsg::Assign(t, tid) => (t, tid),
            DistMsg::Done => return Ok(report),
            DistMsg::Error { error, .. } => return Err(format!("leader error: {error}")),
            other => return Err(format!("expected assign/done, got {other:?}")),
        };
        if opts.die_after_shards == Some(report.shards) {
            // fault injection: vanish mid-protocol, assignment unanswered
            return Ok(report);
        }
        if task.hi > src.len() {
            send_line(
                &mut stream,
                &wire::error_msg(
                    &format!("assigned range [{}, {}) exceeds {} rows", task.lo, task.hi, src.len()),
                    Some(task.shard_id),
                ),
            )?;
            continue;
        }
        let shard_span = obs::span("dist", &format!("shard {}", task.shard_id));
        let (x, y) = match src.read_range(task.lo, task.hi) {
            Ok(chunk) => chunk,
            Err(e) => {
                // no fabricated reply: report the shard as failed and let
                // the leader recover it (its own read surfaces a real
                // source error)
                obs::warn(
                    "dist.worker",
                    &format!("shard read failed: {e}"),
                    &[("worker", worker_id.into()), ("shard", task.shard_id.into())],
                );
                send_line(
                    &mut stream,
                    &wire::error_msg(&format!("shard read failed: {e}"), Some(task.shard_id)),
                )?;
                continue;
            }
        };
        let t0 = Instant::now();
        let z = {
            let _span = obs::span("pipeline", "featurize");
            feat.featurize_par(&x, &pool)
        };
        let featurize_secs = t0.elapsed().as_secs_f64();
        let mut stats = RidgeStats::new(f_dim);
        {
            let _span = obs::span("pipeline", "absorb");
            stats.absorb_with(&z, &y, &pool);
        }
        drop(shard_span);
        let reply = wire::WireStats {
            shard_id: task.shard_id,
            worker_id,
            featurize_secs,
            tid: if task_tid != 0 { task_tid } else { job_tid },
            stats,
        };
        match wire::stats_msg(&reply) {
            Ok(line) => send_line(&mut stream, &line)?,
            Err(e) => send_line(&mut stream, &wire::error_msg(&e, Some(task.shard_id)))?,
        }
        report.shards += 1;
        report.rows += task.hi - task.lo;
        report.featurize_secs += featurize_secs;
        obs::debug(
            "dist.worker",
            "shard done",
            &[
                ("worker", worker_id.into()),
                ("shard", task.shard_id.into()),
                ("rows", (task.hi - task.lo).into()),
                ("featurize_secs", featurize_secs.into()),
            ],
        );
    }
}

fn connect_with_retry(addr: &str, opts: &WorkerOptions) -> Result<TcpStream, String> {
    let attempts = opts.connect_attempts.max(1);
    let mut last = String::new();
    for attempt in 0..attempts {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = e.to_string(),
        }
        if attempt + 1 < attempts {
            std::thread::sleep(opts.connect_delay);
        }
    }
    Err(format!("connect to leader {addr}: {last} (after {attempts} attempts)"))
}
