//! `gzk proxy` — a thin line-level load balancer in front of N `gzk
//! server` replicas.
//!
//! The proxy speaks the *serving* wire protocol on both sides: a client
//! connects to the proxy exactly as it would to a server, and every
//! request line is forwarded verbatim to one replica (round-robin over
//! the healthy set), the reply line returned verbatim. Three behaviors
//! make it more than a byte pipe:
//!
//! - **Retry on backpressure.** A replica replying `"retry":true`
//!   (admission or connection-budget overload) is not an error — the
//!   proxy backs off briefly (doubling, bounded) and retries the *next*
//!   replica. Only when every attempt is exhausted does the client see an
//!   overload reply, so N replicas genuinely pool their admission
//!   capacity.
//! - **Eject and probe.** A replica that fails at the transport level
//!   (connect refused, dropped mid-roundtrip) accrues consecutive-failure
//!   strikes; past the threshold it is ejected from rotation. A prober
//!   thread periodically sends it a `stats` command and readmits it on
//!   the first healthy reply — logging the server's uptime, reload count,
//!   and admission-reject counters, which is where the fleet's health
//!   telemetry surfaces. If *every* replica is ejected, rotation falls
//!   back to all of them (pass-through) so a full outage heals without
//!   waiting a probe period.
//! - **Shutdown fan-out.** The wire `shutdown` command (loopback-gated,
//!   same [`is_loopback_ip`] rule as the server) is broadcast best-effort
//!   to every replica, then shuts the proxy down — one line tears down
//!   the whole serving tier, which is what the CI smoke job and loadgen
//!   `--shutdown` rely on.
//!
//! Three wire commands get special handling beyond shutdown: `metrics`
//! and `flightrec` are answered **locally** (the registry snapshot and
//! the crash-ring dump describe this proxy process — each replica
//! answers its own); `stats` is forwarded to a replica as usual and the
//! proxy then splices a `"proxy":{"replicas":[...]}` section (healthy
//! flag, forwarded / strikes / ejections / readmissions / retries
//! counters) into the reply, so one stats line shows both a replica's
//! view and the balancer's.
//!
//! **Tracing.** The proxy is a trace *ingress*: with tracing enabled
//! (`--trace-out`), a predict line that arrives without a `"tid"` gets
//! one minted here and injected before forwarding — the only rewrite
//! the proxy ever performs, and only when tracing is on — while a
//! client-minted tid is adopted as-is. Either way the forward is timed
//! as a `proxy/forward` span under that tid, so `gzk trace-merge`
//! stitches the proxy hop between the client's span and the replica's.
//! Replies are never rewritten (they carry no tid by design), so the
//! byte-for-byte reply contract survives tracing. On the frame path the
//! client's GZF2 header carries the tid; the proxy never mints there.
//!
//! A client that negotiates the **binary frame mode** (`{"cmd":"binary"}`
//! or the v2 offer `{"cmd":"binary","v":2}` — see [`frame`]) is acked
//! locally and the connection switches to a frame relay: each request
//! frame is forwarded **verbatim** (bytes, not re-encoded) to a replica
//! connection the proxy upgraded to binary (offering v2) on first use,
//! and the reply frame is returned verbatim. A GZF2 request headed for a
//! replica that declined v2 is re-headed as GZF1 (payload untouched; the
//! tid is dropped on that hop — old replicas interoperate, just
//! untraced). Only the status byte is peeked, so `ST_RETRY` replies get
//! the same backoff-and-failover treatment as JSON `"retry":true` — the
//! frame path keeps capacity pooling without ever decoding a float.
//!
//! The proxy never parses predict bodies (it routes lines, not models),
//! so it adds microseconds, not a deserialization round-trip.

use crate::obs::{self, Counter};
use crate::server::frame;
use crate::server::listener::{is_loopback_ip, read_line_bounded, LineRead, MAX_LINE_BYTES};
use crate::server::loadgen::ClientConn;
use crate::server::wire;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Knobs for a [`Proxy`]; the defaults match the CLI's.
#[derive(Clone, Copy, Debug)]
pub struct ProxyConfig {
    /// consecutive transport failures before a replica is ejected
    pub eject_after: u32,
    /// how often the prober re-checks ejected replicas
    pub probe_interval: Duration,
    /// forwarding attempts per request; 0 = twice the replica count
    pub attempts: usize,
    /// close a client connection after this long with no request bytes
    pub idle_timeout: Option<Duration>,
    /// honor the wire `shutdown` command from non-loopback peers
    pub allow_remote_shutdown: bool,
}

impl Default for ProxyConfig {
    fn default() -> ProxyConfig {
        ProxyConfig {
            eject_after: 3,
            probe_interval: Duration::from_millis(500),
            attempts: 0,
            idle_timeout: Some(Duration::from_secs(300)),
            allow_remote_shutdown: false,
        }
    }
}

/// Per-replica rotation state.
struct Replica {
    addr: String,
    healthy: AtomicBool,
    consecutive_failures: AtomicU32,
    /// requests this replica answered (including `"retry":true` answers)
    forwarded: AtomicU64,
    /// registry twins under `proxy.replica.<addr>.*` — exposed by the
    /// wire `metrics` snapshot and spliced into the `stats` reply
    strikes: Counter,
    ejections: Counter,
    readmissions: Counter,
    retries: Counter,
}

impl Replica {
    fn new(addr: String) -> Replica {
        let key = |what: &str| format!("proxy.replica.{addr}.{what}");
        Replica {
            healthy: AtomicBool::new(true),
            consecutive_failures: AtomicU32::new(0),
            forwarded: AtomicU64::new(0),
            strikes: obs::counter(&key("strikes")),
            ejections: obs::counter(&key("ejections")),
            readmissions: obs::counter(&key("readmissions")),
            retries: obs::counter(&key("retries")),
            addr,
        }
    }

    fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.healthy.store(true, Ordering::Relaxed);
        self.forwarded.fetch_add(1, Ordering::Relaxed);
    }

    fn record_failure(&self, eject_after: u32) {
        self.strikes.inc();
        let strikes = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if strikes >= eject_after && self.healthy.swap(false, Ordering::Relaxed) {
            self.ejections.inc();
            obs::warn(
                "dist.proxy",
                "replica ejected after consecutive transport failures",
                &[("replica", self.addr.as_str().into()), ("strikes", strikes.into())],
            );
        }
    }

    /// One entry of the `"proxy":{"replicas":[...]}` stats section.
    fn stats_json(&self) -> String {
        format!(
            concat!(
                r#"{{"addr":{},"healthy":{},"forwarded":{},"strikes":{},"#,
                r#""ejections":{},"readmissions":{},"retries":{}}}"#
            ),
            wire::json_string(&self.addr),
            self.healthy.load(Ordering::Relaxed),
            self.forwarded.load(Ordering::Relaxed),
            self.strikes.get(),
            self.ejections.get(),
            self.readmissions.get(),
            self.retries.get()
        )
    }
}

struct ProxyShared {
    replicas: Vec<Replica>,
    rr: AtomicUsize,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
    addr: SocketAddr,
    cfg: ProxyConfig,
}

impl ProxyShared {
    fn begin_shutdown(&self) {
        // flip the flag and poke the blocking accept with a throwaway
        // self-connection (same dance as the server listener)
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            let _ = TcpStream::connect(self.addr);
        }
    }

    /// Pick the next replica index, preferring healthy ones; when every
    /// replica is ejected, rotate over all of them so a total outage
    /// heals on the first successful forward, not the next probe tick.
    fn pick(&self) -> usize {
        let n = self.replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        for off in 0..n {
            let i = (start + off) % n;
            if self.replicas[i].healthy.load(Ordering::Relaxed) {
                return i;
            }
        }
        start % n
    }
}

/// A running proxy (accept thread + prober thread).
pub struct Proxy {
    shared: Arc<ProxyShared>,
    accept: Option<std::thread::JoinHandle<()>>,
    prober: Option<std::thread::JoinHandle<()>>,
}

impl Proxy {
    /// Bind `addr` (port 0 = ephemeral) and start balancing over
    /// `replicas`.
    pub fn start(addr: &str, replicas: Vec<String>, cfg: ProxyConfig) -> Result<Proxy, String> {
        if replicas.is_empty() {
            return Err("proxy needs at least one replica address".to_string());
        }
        if cfg.eject_after < 1 {
            return Err("eject_after must be >= 1".to_string());
        }
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let bound = listener.local_addr().map_err(|e| format!("local addr: {e}"))?;
        let shared = Arc::new(ProxyShared {
            replicas: replicas.into_iter().map(Replica::new).collect(),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            addr: bound,
            cfg,
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(listener, &accept_shared));
        let prober_shared = Arc::clone(&shared);
        let prober = std::thread::spawn(move || probe_loop(&prober_shared));
        Ok(Proxy { shared, accept: Some(accept), prober: Some(prober) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }

    /// Block until shutdown, then return a one-line forwarding summary
    /// (per-replica answered counts — the CLI prints it on exit).
    pub fn wait(mut self) -> String {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
        // bounded grace for in-flight client connections to drain
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while self.shared.active_conns.load(Ordering::Acquire) > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        let per: Vec<String> = self
            .shared
            .replicas
            .iter()
            .map(|r| format!("{}={}", r.addr, r.forwarded.load(Ordering::Relaxed)))
            .collect();
        format!("forwarded {}", per.join(" "))
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<ProxyShared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        shared.active_conns.fetch_add(1, Ordering::AcqRel);
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            handle_client(stream, &shared);
            // drain this thread's trace buffer before releasing the
            // connection count: `Proxy::wait` gates on it, and the CLI
            // writes the trace file right after `wait` returns —
            // detached threads get no join to run their TLS drains
            obs::trace::flush_thread();
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
        });
    }
}

/// Re-check ejected replicas with a `stats` roundtrip; a healthy reply
/// readmits the replica and logs the server-side telemetry (uptime,
/// reloads, admission rejects) that the stats command now carries.
fn probe_loop(shared: &Arc<ProxyShared>) {
    let stats_line = wire::cmd_request("stats");
    while !shared.shutdown.load(Ordering::Acquire) {
        std::thread::sleep(shared.cfg.probe_interval);
        for r in &shared.replicas {
            if r.healthy.load(Ordering::Relaxed) || shared.shutdown.load(Ordering::Acquire) {
                continue;
            }
            let probe = ClientConn::connect(&r.addr)
                .and_then(|mut conn| conn.roundtrip(&stats_line));
            if let Ok(reply) = probe {
                if reply.ok {
                    r.consecutive_failures.store(0, Ordering::Relaxed);
                    r.healthy.store(true, Ordering::Relaxed);
                    r.readmissions.inc();
                    let uptime = reply.body.get("uptime_s").and_then(|v| v.as_f64());
                    let reloads = reply.body.get("reloads").and_then(|v| v.as_usize());
                    let rejects = reply.body.get("total_rejects").and_then(|v| v.as_usize());
                    obs::info(
                        "dist.proxy",
                        "replica readmitted after a healthy stats probe",
                        &[
                            ("replica", r.addr.as_str().into()),
                            // -1 / null mark fields the probe reply lacked
                            ("uptime_s", uptime.unwrap_or(f64::NAN).into()),
                            ("reloads", reloads.map(|v| v as i64).unwrap_or(-1).into()),
                            ("total_rejects", rejects.map(|v| v as i64).unwrap_or(-1).into()),
                        ],
                    );
                }
            }
        }
    }
}

fn handle_client(stream: TcpStream, shared: &Arc<ProxyShared>) {
    let _ = stream.set_nodelay(true);
    if let Some(idle) = shared.cfg.idle_timeout {
        let _ = stream.set_read_timeout(Some(idle));
        let _ = stream.set_write_timeout(Some(idle));
    }
    let peer_is_loopback = stream.peer_addr().map(|a| is_loopback_ip(a.ip())).unwrap_or(false);
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = std::io::BufReader::new(stream);
    let mut buf = Vec::new();
    // per-connection replica connections, opened lazily and kept for the
    // life of the client connection (so a pipelining client reuses them)
    let mut conns: Vec<Option<ClientConn>> = (0..shared.replicas.len()).map(|_| None).collect();
    let send = |writer: &mut TcpStream, line: &str| {
        writer.write_all(line.as_bytes()).and_then(|()| writer.write_all(b"\n")).is_ok()
    };
    loop {
        match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES, shared.cfg.idle_timeout) {
            LineRead::Line => {}
            LineRead::Eof | LineRead::Gone => return,
            LineRead::Idle => {
                let _ = send(&mut writer, &wire::error_reply("idle timeout; closing connection"));
                return;
            }
            LineRead::Overlong => {
                let _ = send(
                    &mut writer,
                    &wire::error_reply(&format!(
                        "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
                    )),
                );
                return;
            }
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(l) => l.trim(),
            Err(_) => {
                if !send(&mut writer, &wire::error_reply("request is not UTF-8")) {
                    return;
                }
                continue;
            }
        };
        if line.is_empty() {
            continue;
        }
        // the proxy parses just enough to spot shutdown (fan-out),
        // metrics (answered locally) and stats (forwarded, then
        // augmented); every other line (predict, ping, models, even
        // malformed input) is the replica's to answer verbatim
        let parsed = wire::parse_request(line);
        if matches!(parsed, Ok(wire::Request::Shutdown)) {
            if !peer_is_loopback && !shared.cfg.allow_remote_shutdown {
                obs::warn("dist.proxy", "shutdown refused from a non-loopback peer", &[]);
                if !send(
                    &mut writer,
                    &wire::error_reply(
                        "shutdown refused from a non-loopback peer (the proxy \
                         must opt in with --allow-remote-shutdown)",
                    ),
                ) {
                    return;
                }
                continue;
            }
            obs::info("dist.proxy", "wire shutdown accepted; fanning out to replicas", &[]);
            broadcast_shutdown(shared);
            let _ = send(&mut writer, &wire::shutdown_reply());
            shared.begin_shutdown();
            return;
        }
        if matches!(parsed, Ok(wire::Request::Metrics)) {
            // never forwarded: the snapshot describes THIS process; each
            // replica answers its own `metrics`
            if !send(&mut writer, &wire::metrics_reply()) {
                return;
            }
            continue;
        }
        if matches!(parsed, Ok(wire::Request::Flightrec)) {
            // like metrics: the crash ring describes THIS process
            if !send(&mut writer, &wire::flightrec_reply()) {
                return;
            }
            continue;
        }
        if let Ok(wire::Request::Binary { v2 }) = parsed {
            // ack locally (echoing the v2 offer when made), then relay
            // frames until the client hangs up. The cached JSON-mode
            // replica connections stay JSON; the relay upgrades its own
            // on first use.
            let ack = if v2 { wire::binary_reply_v2() } else { wire::binary_reply() };
            if !send(&mut writer, &ack) {
                return;
            }
            binary_relay(shared, &mut reader, &mut writer);
            return;
        }
        // trace ingress: adopt the client's tid, or mint one here when
        // tracing is on and the predict arrived untraced — injected
        // before the closing brace, the proxy's only request rewrite
        let mut tid = 0u64;
        let mut traced_line = None;
        if let Ok(wire::Request::Predict { tid: req_tid, .. }) = &parsed {
            if obs::trace::enabled() {
                tid = *req_tid;
                if tid == 0 {
                    tid = obs::trace::mint_trace_id();
                    let body = &line[..line.len() - 1]; // parsed => ends in '}'
                    traced_line = Some(format!("{body},\"tid\":\"{tid}\"}}"));
                }
            }
        }
        let t0 = std::time::Instant::now();
        let mut reply = forward(shared, &mut conns, traced_line.as_deref().unwrap_or(line));
        if tid != 0 {
            obs::trace::record_since("proxy", "forward", tid, t0);
        }
        if matches!(parsed, Ok(wire::Request::Stats)) {
            reply = splice_proxy_stats(shared, reply);
        }
        if !send(&mut writer, &reply) {
            return;
        }
    }
}

/// Relay binary frames after a client's upgrade: request frames in,
/// reply frames out, both verbatim. Runs until the client disconnects
/// or breaks framing (the SO_RCVTIMEO idle timeout set on the socket
/// also surfaces here, as a read error mid-header).
fn binary_relay(
    shared: &Arc<ProxyShared>,
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut TcpStream,
) {
    let mut conns: Vec<Option<(ClientConn, bool)>> =
        (0..shared.replicas.len()).map(|_| None).collect();
    loop {
        let req = match frame::read_frame(reader) {
            Ok(Some(f)) => f,
            // clean EOF at a frame boundary, or hostile/truncated
            // framing (bad magic, oversized, idle mid-frame): close —
            // same discipline as the server's frame path
            Ok(None) | Err(_) => return,
        };
        // a GZF2 header carries the client-minted tid; time the forward
        // under it (the proxy never mints on the frame path)
        let tid = frame::frame_tid(&req);
        let t0 = std::time::Instant::now();
        let reply = forward_frame(shared, &mut conns, &req);
        if tid != 0 {
            obs::trace::record_since("proxy", "forward", tid, t0);
        }
        if writer.write_all(&reply).is_err() {
            return;
        }
    }
}

/// Forward one request frame verbatim, failing over across replicas —
/// the frame twin of [`forward`]. Replica connections are upgraded to
/// binary (offering GZF2) on first use and cached with the negotiated
/// version; a GZF2 request headed for a replica still on GZF1 is
/// re-headed (payload byte-for-byte, tid dropped on that hop). Only the
/// reply's status byte is inspected (`ST_RETRY` → back off, try the
/// next replica), never the payload, so predictions stay byte-for-byte
/// the replica's.
fn forward_frame(
    shared: &Arc<ProxyShared>,
    conns: &mut [Option<(ClientConn, bool)>],
    req: &[u8],
) -> Vec<u8> {
    let attempts = match shared.cfg.attempts {
        0 => (2 * shared.replicas.len()).max(2),
        a => a,
    };
    let mut backoff = Duration::from_micros(200);
    for _ in 0..attempts {
        let i = shared.pick();
        let replica = &shared.replicas[i];
        if conns[i].is_none() {
            let upgraded = ClientConn::connect(&replica.addr).and_then(|mut c| {
                let v2 = c.upgrade_binary_v2()?;
                Ok((c, v2))
            });
            match upgraded {
                Ok(c) => conns[i] = Some(c),
                Err(_) => {
                    replica.record_failure(shared.cfg.eject_after);
                    continue;
                }
            }
        }
        let (conn, v2) = conns[i].as_mut().expect("connection just ensured");
        let downgraded;
        let send: &[u8] = if !*v2 && req.starts_with(&frame::MAGIC2) {
            downgraded = frame::frame(frame::payload(req));
            &downgraded
        } else {
            req
        };
        match conn.roundtrip_frame(send) {
            Ok(reply) => {
                replica.record_success();
                if frame::reply_status(&reply) == Some(frame::ST_RETRY) {
                    replica.retries.inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(10));
                    continue;
                }
                return reply;
            }
            Err(_) => {
                conns[i] = None; // the cached connection is poisoned
                replica.record_failure(shared.cfg.eject_after);
            }
        }
    }
    frame::frame(&frame::status_payload(
        frame::ST_RETRY,
        &format!("all {} replicas busy or down; retry", shared.replicas.len()),
    ))
}

/// Splice the proxy's own per-replica section into a forwarded `stats`
/// reply: insert `,"proxy":{"replicas":[...]}` before the closing brace
/// of the (replica-formatted) JSON object, leaving the replica's floats
/// byte-for-byte untouched — the crate has no JSON serializer, and
/// re-encoding could perturb them.
fn splice_proxy_stats(shared: &Arc<ProxyShared>, reply: String) -> String {
    if !(reply.len() > 2 && reply.starts_with('{') && reply.ends_with('}')) {
        return reply; // not a non-empty object: pass through untouched
    }
    let per: Vec<String> = shared.replicas.iter().map(Replica::stats_json).collect();
    format!(
        "{},\"proxy\":{{\"replicas\":[{}]}}}}",
        &reply[..reply.len() - 1],
        per.join(",")
    )
}

/// Fan the wire `shutdown` out to every replica, best-effort: a replica
/// that is already down is already shut down.
fn broadcast_shutdown(shared: &Arc<ProxyShared>) {
    let line = wire::cmd_request("shutdown");
    for r in &shared.replicas {
        if let Ok(mut conn) = ClientConn::connect(&r.addr) {
            let _ = conn.roundtrip(&line);
        }
    }
}

/// Forward one request line, failing over across replicas: transport
/// failures strike the replica and move on immediately; `"retry":true`
/// replies back off briefly (doubling, bounded) and try the next replica.
fn forward(
    shared: &Arc<ProxyShared>,
    conns: &mut [Option<ClientConn>],
    line: &str,
) -> String {
    let attempts = match shared.cfg.attempts {
        0 => (2 * shared.replicas.len()).max(2),
        a => a,
    };
    let mut backoff = Duration::from_micros(200);
    for _ in 0..attempts {
        let i = shared.pick();
        let replica = &shared.replicas[i];
        if conns[i].is_none() {
            match ClientConn::connect(&replica.addr) {
                Ok(c) => conns[i] = Some(c),
                Err(_) => {
                    replica.record_failure(shared.cfg.eject_after);
                    continue;
                }
            }
        }
        let conn = conns[i].as_mut().expect("connection just ensured");
        match conn.roundtrip(line) {
            Ok(reply) => {
                replica.record_success();
                if reply.retry {
                    // the replica is up but saturated: back off, try the
                    // next one — this is where replicas pool capacity
                    replica.retries.inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(10));
                    continue;
                }
                return reply.raw;
            }
            Err(_) => {
                conns[i] = None; // the cached connection is poisoned
                replica.record_failure(shared.cfg.eject_after);
            }
        }
    }
    wire::overload_reply(&format!("all {} replicas busy or down; retry", shared.replicas.len()))
}
