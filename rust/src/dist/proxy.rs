//! `gzk proxy` — a thin line-level load balancer in front of N `gzk
//! server` replicas.
//!
//! The proxy speaks the *serving* wire protocol on both sides: a client
//! connects to the proxy exactly as it would to a server, and every
//! request line is forwarded verbatim to one replica (round-robin over
//! the healthy set), the reply line returned verbatim. Three behaviors
//! make it more than a byte pipe:
//!
//! - **Retry on backpressure.** A replica replying `"retry":true`
//!   (admission or connection-budget overload) is not an error — the
//!   proxy backs off briefly (doubling, bounded) and retries the *next*
//!   replica. Only when every attempt is exhausted does the client see an
//!   overload reply, so N replicas genuinely pool their admission
//!   capacity.
//! - **Eject and probe.** A replica that fails at the transport level
//!   (connect refused, dropped mid-roundtrip) accrues consecutive-failure
//!   strikes; past the threshold it is ejected from rotation. A prober
//!   thread periodically sends it a `stats` command and readmits it on
//!   the first healthy reply — logging the server's uptime, reload count,
//!   and admission-reject counters, which is where the fleet's health
//!   telemetry surfaces. If *every* replica is ejected, rotation falls
//!   back to all of them (pass-through) so a full outage heals without
//!   waiting a probe period.
//! - **Shutdown fan-out.** The wire `shutdown` command (loopback-gated,
//!   same [`is_loopback_ip`] rule as the server) is broadcast best-effort
//!   to every replica, then shuts the proxy down — one line tears down
//!   the whole serving tier, which is what the CI smoke job and loadgen
//!   `--shutdown` rely on.
//!
//! The proxy never parses predict bodies (it routes lines, not models),
//! so it adds microseconds, not a deserialization round-trip.

use crate::server::listener::{is_loopback_ip, read_line_bounded, LineRead, MAX_LINE_BYTES};
use crate::server::loadgen::ClientConn;
use crate::server::wire;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Knobs for a [`Proxy`]; the defaults match the CLI's.
#[derive(Clone, Copy, Debug)]
pub struct ProxyConfig {
    /// consecutive transport failures before a replica is ejected
    pub eject_after: u32,
    /// how often the prober re-checks ejected replicas
    pub probe_interval: Duration,
    /// forwarding attempts per request; 0 = twice the replica count
    pub attempts: usize,
    /// close a client connection after this long with no request bytes
    pub idle_timeout: Option<Duration>,
    /// honor the wire `shutdown` command from non-loopback peers
    pub allow_remote_shutdown: bool,
}

impl Default for ProxyConfig {
    fn default() -> ProxyConfig {
        ProxyConfig {
            eject_after: 3,
            probe_interval: Duration::from_millis(500),
            attempts: 0,
            idle_timeout: Some(Duration::from_secs(300)),
            allow_remote_shutdown: false,
        }
    }
}

/// Per-replica rotation state.
struct Replica {
    addr: String,
    healthy: AtomicBool,
    consecutive_failures: AtomicU32,
    /// requests this replica answered (including `"retry":true` answers)
    forwarded: AtomicU64,
}

impl Replica {
    fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.healthy.store(true, Ordering::Relaxed);
        self.forwarded.fetch_add(1, Ordering::Relaxed);
    }

    fn record_failure(&self, eject_after: u32) {
        let strikes = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if strikes >= eject_after && self.healthy.swap(false, Ordering::Relaxed) {
            eprintln!(
                "gzk proxy: replica {} ejected after {strikes} consecutive failures",
                self.addr
            );
        }
    }
}

struct ProxyShared {
    replicas: Vec<Replica>,
    rr: AtomicUsize,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
    addr: SocketAddr,
    cfg: ProxyConfig,
}

impl ProxyShared {
    fn begin_shutdown(&self) {
        // flip the flag and poke the blocking accept with a throwaway
        // self-connection (same dance as the server listener)
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            let _ = TcpStream::connect(self.addr);
        }
    }

    /// Pick the next replica index, preferring healthy ones; when every
    /// replica is ejected, rotate over all of them so a total outage
    /// heals on the first successful forward, not the next probe tick.
    fn pick(&self) -> usize {
        let n = self.replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        for off in 0..n {
            let i = (start + off) % n;
            if self.replicas[i].healthy.load(Ordering::Relaxed) {
                return i;
            }
        }
        start % n
    }
}

/// A running proxy (accept thread + prober thread).
pub struct Proxy {
    shared: Arc<ProxyShared>,
    accept: Option<std::thread::JoinHandle<()>>,
    prober: Option<std::thread::JoinHandle<()>>,
}

impl Proxy {
    /// Bind `addr` (port 0 = ephemeral) and start balancing over
    /// `replicas`.
    pub fn start(addr: &str, replicas: Vec<String>, cfg: ProxyConfig) -> Result<Proxy, String> {
        if replicas.is_empty() {
            return Err("proxy needs at least one replica address".to_string());
        }
        if cfg.eject_after < 1 {
            return Err("eject_after must be >= 1".to_string());
        }
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let bound = listener.local_addr().map_err(|e| format!("local addr: {e}"))?;
        let shared = Arc::new(ProxyShared {
            replicas: replicas
                .into_iter()
                .map(|addr| Replica {
                    addr,
                    healthy: AtomicBool::new(true),
                    consecutive_failures: AtomicU32::new(0),
                    forwarded: AtomicU64::new(0),
                })
                .collect(),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            addr: bound,
            cfg,
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(listener, &accept_shared));
        let prober_shared = Arc::clone(&shared);
        let prober = std::thread::spawn(move || probe_loop(&prober_shared));
        Ok(Proxy { shared, accept: Some(accept), prober: Some(prober) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }

    /// Block until shutdown, then return a one-line forwarding summary
    /// (per-replica answered counts — the CLI prints it on exit).
    pub fn wait(mut self) -> String {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
        // bounded grace for in-flight client connections to drain
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while self.shared.active_conns.load(Ordering::Acquire) > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        let per: Vec<String> = self
            .shared
            .replicas
            .iter()
            .map(|r| format!("{}={}", r.addr, r.forwarded.load(Ordering::Relaxed)))
            .collect();
        format!("forwarded {}", per.join(" "))
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<ProxyShared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        shared.active_conns.fetch_add(1, Ordering::AcqRel);
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            handle_client(stream, &shared);
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
        });
    }
}

/// Re-check ejected replicas with a `stats` roundtrip; a healthy reply
/// readmits the replica and logs the server-side telemetry (uptime,
/// reloads, admission rejects) that the stats command now carries.
fn probe_loop(shared: &Arc<ProxyShared>) {
    let stats_line = wire::cmd_request("stats");
    while !shared.shutdown.load(Ordering::Acquire) {
        std::thread::sleep(shared.cfg.probe_interval);
        for r in &shared.replicas {
            if r.healthy.load(Ordering::Relaxed) || shared.shutdown.load(Ordering::Acquire) {
                continue;
            }
            let probe = ClientConn::connect(&r.addr)
                .and_then(|mut conn| conn.roundtrip(&stats_line));
            if let Ok(reply) = probe {
                if reply.ok {
                    r.consecutive_failures.store(0, Ordering::Relaxed);
                    r.healthy.store(true, Ordering::Relaxed);
                    let uptime = reply.body.get("uptime_s").and_then(|v| v.as_f64());
                    let reloads = reply.body.get("reloads").and_then(|v| v.as_usize());
                    let rejects = reply.body.get("total_rejects").and_then(|v| v.as_usize());
                    eprintln!(
                        "gzk proxy: replica {} readmitted (uptime_s {:?}, reloads {:?}, \
                         total_rejects {:?})",
                        r.addr, uptime, reloads, rejects
                    );
                }
            }
        }
    }
}

fn handle_client(stream: TcpStream, shared: &Arc<ProxyShared>) {
    let _ = stream.set_nodelay(true);
    if let Some(idle) = shared.cfg.idle_timeout {
        let _ = stream.set_read_timeout(Some(idle));
        let _ = stream.set_write_timeout(Some(idle));
    }
    let peer_is_loopback = stream.peer_addr().map(|a| is_loopback_ip(a.ip())).unwrap_or(false);
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = std::io::BufReader::new(stream);
    let mut buf = Vec::new();
    // per-connection replica connections, opened lazily and kept for the
    // life of the client connection (so a pipelining client reuses them)
    let mut conns: Vec<Option<ClientConn>> = (0..shared.replicas.len()).map(|_| None).collect();
    let send = |writer: &mut TcpStream, line: &str| {
        writer.write_all(line.as_bytes()).and_then(|()| writer.write_all(b"\n")).is_ok()
    };
    loop {
        match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES, shared.cfg.idle_timeout) {
            LineRead::Line => {}
            LineRead::Eof | LineRead::Gone => return,
            LineRead::Idle => {
                let _ = send(&mut writer, &wire::error_reply("idle timeout; closing connection"));
                return;
            }
            LineRead::Overlong => {
                let _ = send(
                    &mut writer,
                    &wire::error_reply(&format!(
                        "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
                    )),
                );
                return;
            }
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(l) => l.trim(),
            Err(_) => {
                if !send(&mut writer, &wire::error_reply("request is not UTF-8")) {
                    return;
                }
                continue;
            }
        };
        if line.is_empty() {
            continue;
        }
        // the proxy parses just enough to spot the shutdown command; every
        // other line (predict, ping, models, stats, even malformed input)
        // is the replica's to answer
        if matches!(wire::parse_request(line), Ok(wire::Request::Shutdown)) {
            if !peer_is_loopback && !shared.cfg.allow_remote_shutdown {
                if !send(
                    &mut writer,
                    &wire::error_reply(
                        "shutdown refused from a non-loopback peer (the proxy \
                         must opt in with --allow-remote-shutdown)",
                    ),
                ) {
                    return;
                }
                continue;
            }
            broadcast_shutdown(shared);
            let _ = send(&mut writer, &wire::shutdown_reply());
            shared.begin_shutdown();
            return;
        }
        let reply = forward(shared, &mut conns, line);
        if !send(&mut writer, &reply) {
            return;
        }
    }
}

/// Fan the wire `shutdown` out to every replica, best-effort: a replica
/// that is already down is already shut down.
fn broadcast_shutdown(shared: &Arc<ProxyShared>) {
    let line = wire::cmd_request("shutdown");
    for r in &shared.replicas {
        if let Ok(mut conn) = ClientConn::connect(&r.addr) {
            let _ = conn.roundtrip(&line);
        }
    }
}

/// Forward one request line, failing over across replicas: transport
/// failures strike the replica and move on immediately; `"retry":true`
/// replies back off briefly (doubling, bounded) and try the next replica.
fn forward(
    shared: &Arc<ProxyShared>,
    conns: &mut [Option<ClientConn>],
    line: &str,
) -> String {
    let attempts = match shared.cfg.attempts {
        0 => (2 * shared.replicas.len()).max(2),
        a => a,
    };
    let mut backoff = Duration::from_micros(200);
    for _ in 0..attempts {
        let i = shared.pick();
        let replica = &shared.replicas[i];
        if conns[i].is_none() {
            match ClientConn::connect(&replica.addr) {
                Ok(c) => conns[i] = Some(c),
                Err(_) => {
                    replica.record_failure(shared.cfg.eject_after);
                    continue;
                }
            }
        }
        let conn = conns[i].as_mut().expect("connection just ensured");
        match conn.roundtrip(line) {
            Ok(reply) => {
                replica.record_success();
                if reply.retry {
                    // the replica is up but saturated: back off, try the
                    // next one — this is where replicas pool capacity
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(10));
                    continue;
                }
                return reply.raw;
            }
            Err(_) => {
                conns[i] = None; // the cached connection is poisoned
                replica.record_failure(shared.cfg.eject_after);
            }
        }
    }
    wire::overload_reply(&format!("all {} replicas busy or down; retry", shared.replicas.len()))
}
