//! `gzk proxy` — a thin line-level load balancer in front of N `gzk
//! server` replicas.
//!
//! The proxy speaks the *serving* wire protocol on both sides: a client
//! connects to the proxy exactly as it would to a server, and every
//! request line is forwarded verbatim to one replica (round-robin over
//! the healthy set), the reply line returned verbatim. Three behaviors
//! make it more than a byte pipe:
//!
//! - **Retry on backpressure.** A replica replying `"retry":true`
//!   (admission or connection-budget overload) is not an error — the
//!   proxy backs off briefly (doubling, bounded) and retries the *next*
//!   replica. Only when every attempt is exhausted does the client see an
//!   overload reply, so N replicas genuinely pool their admission
//!   capacity.
//! - **Eject and probe.** A replica that fails at the transport level
//!   (connect refused, dropped mid-roundtrip) accrues consecutive-failure
//!   strikes; past the threshold it is ejected from rotation. A prober
//!   thread periodically sends it a `stats` command and readmits it on
//!   the first healthy reply — logging the server's uptime, reload count,
//!   and admission-reject counters, which is where the fleet's health
//!   telemetry surfaces. If *every* replica is ejected, rotation falls
//!   back to all of them (pass-through) so a full outage heals without
//!   waiting a probe period.
//! - **Shutdown fan-out.** The wire `shutdown` command (loopback-gated,
//!   same [`is_loopback_ip`] rule as the server) is broadcast best-effort
//!   to every replica, then shuts the proxy down — one line tears down
//!   the whole serving tier, which is what the CI smoke job and loadgen
//!   `--shutdown` rely on.
//!
//! Two wire commands get special handling beyond shutdown: `metrics` is
//! answered **locally** (the snapshot describes this proxy process —
//! including the per-replica `proxy.replica.<addr>.*` counters — and
//! each replica answers its own); `stats` is forwarded to a replica as
//! usual and the proxy then splices a `"proxy":{"replicas":[...]}`
//! section (healthy flag, forwarded / strikes / ejections /
//! readmissions / retries counters) into the reply, so one stats line
//! shows both a replica's view and the balancer's.
//!
//! A client that negotiates the **binary frame mode** (`{"cmd":"binary"}`
//! — see [`frame`]) is acked locally and the connection switches to a
//! frame relay: each request frame is forwarded **verbatim** (bytes, not
//! re-encoded) to a replica connection the proxy upgraded to binary on
//! first use, and the reply frame is returned verbatim. Only the status
//! byte is peeked, so `ST_RETRY` replies get the same backoff-and-failover
//! treatment as JSON `"retry":true` — the frame path keeps capacity
//! pooling without ever decoding a float.
//!
//! The proxy never parses predict bodies (it routes lines, not models),
//! so it adds microseconds, not a deserialization round-trip.

use crate::obs::{self, Counter};
use crate::server::frame;
use crate::server::listener::{is_loopback_ip, read_line_bounded, LineRead, MAX_LINE_BYTES};
use crate::server::loadgen::ClientConn;
use crate::server::wire;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Knobs for a [`Proxy`]; the defaults match the CLI's.
#[derive(Clone, Copy, Debug)]
pub struct ProxyConfig {
    /// consecutive transport failures before a replica is ejected
    pub eject_after: u32,
    /// how often the prober re-checks ejected replicas
    pub probe_interval: Duration,
    /// forwarding attempts per request; 0 = twice the replica count
    pub attempts: usize,
    /// close a client connection after this long with no request bytes
    pub idle_timeout: Option<Duration>,
    /// honor the wire `shutdown` command from non-loopback peers
    pub allow_remote_shutdown: bool,
}

impl Default for ProxyConfig {
    fn default() -> ProxyConfig {
        ProxyConfig {
            eject_after: 3,
            probe_interval: Duration::from_millis(500),
            attempts: 0,
            idle_timeout: Some(Duration::from_secs(300)),
            allow_remote_shutdown: false,
        }
    }
}

/// Per-replica rotation state.
struct Replica {
    addr: String,
    healthy: AtomicBool,
    consecutive_failures: AtomicU32,
    /// requests this replica answered (including `"retry":true` answers)
    forwarded: AtomicU64,
    /// registry twins under `proxy.replica.<addr>.*` — exposed by the
    /// wire `metrics` snapshot and spliced into the `stats` reply
    strikes: Counter,
    ejections: Counter,
    readmissions: Counter,
    retries: Counter,
}

impl Replica {
    fn new(addr: String) -> Replica {
        let key = |what: &str| format!("proxy.replica.{addr}.{what}");
        Replica {
            healthy: AtomicBool::new(true),
            consecutive_failures: AtomicU32::new(0),
            forwarded: AtomicU64::new(0),
            strikes: obs::counter(&key("strikes")),
            ejections: obs::counter(&key("ejections")),
            readmissions: obs::counter(&key("readmissions")),
            retries: obs::counter(&key("retries")),
            addr,
        }
    }

    fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        self.healthy.store(true, Ordering::Relaxed);
        self.forwarded.fetch_add(1, Ordering::Relaxed);
    }

    fn record_failure(&self, eject_after: u32) {
        self.strikes.inc();
        let strikes = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if strikes >= eject_after && self.healthy.swap(false, Ordering::Relaxed) {
            self.ejections.inc();
            obs::warn(
                "dist.proxy",
                "replica ejected after consecutive transport failures",
                &[("replica", self.addr.as_str().into()), ("strikes", strikes.into())],
            );
        }
    }

    /// One entry of the `"proxy":{"replicas":[...]}` stats section.
    fn stats_json(&self) -> String {
        format!(
            concat!(
                r#"{{"addr":{},"healthy":{},"forwarded":{},"strikes":{},"#,
                r#""ejections":{},"readmissions":{},"retries":{}}}"#
            ),
            wire::json_string(&self.addr),
            self.healthy.load(Ordering::Relaxed),
            self.forwarded.load(Ordering::Relaxed),
            self.strikes.get(),
            self.ejections.get(),
            self.readmissions.get(),
            self.retries.get()
        )
    }
}

struct ProxyShared {
    replicas: Vec<Replica>,
    rr: AtomicUsize,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
    addr: SocketAddr,
    cfg: ProxyConfig,
}

impl ProxyShared {
    fn begin_shutdown(&self) {
        // flip the flag and poke the blocking accept with a throwaway
        // self-connection (same dance as the server listener)
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            let _ = TcpStream::connect(self.addr);
        }
    }

    /// Pick the next replica index, preferring healthy ones; when every
    /// replica is ejected, rotate over all of them so a total outage
    /// heals on the first successful forward, not the next probe tick.
    fn pick(&self) -> usize {
        let n = self.replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        for off in 0..n {
            let i = (start + off) % n;
            if self.replicas[i].healthy.load(Ordering::Relaxed) {
                return i;
            }
        }
        start % n
    }
}

/// A running proxy (accept thread + prober thread).
pub struct Proxy {
    shared: Arc<ProxyShared>,
    accept: Option<std::thread::JoinHandle<()>>,
    prober: Option<std::thread::JoinHandle<()>>,
}

impl Proxy {
    /// Bind `addr` (port 0 = ephemeral) and start balancing over
    /// `replicas`.
    pub fn start(addr: &str, replicas: Vec<String>, cfg: ProxyConfig) -> Result<Proxy, String> {
        if replicas.is_empty() {
            return Err("proxy needs at least one replica address".to_string());
        }
        if cfg.eject_after < 1 {
            return Err("eject_after must be >= 1".to_string());
        }
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let bound = listener.local_addr().map_err(|e| format!("local addr: {e}"))?;
        let shared = Arc::new(ProxyShared {
            replicas: replicas.into_iter().map(Replica::new).collect(),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            addr: bound,
            cfg,
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(listener, &accept_shared));
        let prober_shared = Arc::clone(&shared);
        let prober = std::thread::spawn(move || probe_loop(&prober_shared));
        Ok(Proxy { shared, accept: Some(accept), prober: Some(prober) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }

    /// Block until shutdown, then return a one-line forwarding summary
    /// (per-replica answered counts — the CLI prints it on exit).
    pub fn wait(mut self) -> String {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
        // bounded grace for in-flight client connections to drain
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while self.shared.active_conns.load(Ordering::Acquire) > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        let per: Vec<String> = self
            .shared
            .replicas
            .iter()
            .map(|r| format!("{}={}", r.addr, r.forwarded.load(Ordering::Relaxed)))
            .collect();
        format!("forwarded {}", per.join(" "))
    }
}

fn accept_loop(listener: TcpListener, shared: &Arc<ProxyShared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        shared.active_conns.fetch_add(1, Ordering::AcqRel);
        let shared = Arc::clone(shared);
        std::thread::spawn(move || {
            handle_client(stream, &shared);
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
        });
    }
}

/// Re-check ejected replicas with a `stats` roundtrip; a healthy reply
/// readmits the replica and logs the server-side telemetry (uptime,
/// reloads, admission rejects) that the stats command now carries.
fn probe_loop(shared: &Arc<ProxyShared>) {
    let stats_line = wire::cmd_request("stats");
    while !shared.shutdown.load(Ordering::Acquire) {
        std::thread::sleep(shared.cfg.probe_interval);
        for r in &shared.replicas {
            if r.healthy.load(Ordering::Relaxed) || shared.shutdown.load(Ordering::Acquire) {
                continue;
            }
            let probe = ClientConn::connect(&r.addr)
                .and_then(|mut conn| conn.roundtrip(&stats_line));
            if let Ok(reply) = probe {
                if reply.ok {
                    r.consecutive_failures.store(0, Ordering::Relaxed);
                    r.healthy.store(true, Ordering::Relaxed);
                    r.readmissions.inc();
                    let uptime = reply.body.get("uptime_s").and_then(|v| v.as_f64());
                    let reloads = reply.body.get("reloads").and_then(|v| v.as_usize());
                    let rejects = reply.body.get("total_rejects").and_then(|v| v.as_usize());
                    obs::info(
                        "dist.proxy",
                        "replica readmitted after a healthy stats probe",
                        &[
                            ("replica", r.addr.as_str().into()),
                            // -1 / null mark fields the probe reply lacked
                            ("uptime_s", uptime.unwrap_or(f64::NAN).into()),
                            ("reloads", reloads.map(|v| v as i64).unwrap_or(-1).into()),
                            ("total_rejects", rejects.map(|v| v as i64).unwrap_or(-1).into()),
                        ],
                    );
                }
            }
        }
    }
}

fn handle_client(stream: TcpStream, shared: &Arc<ProxyShared>) {
    let _ = stream.set_nodelay(true);
    if let Some(idle) = shared.cfg.idle_timeout {
        let _ = stream.set_read_timeout(Some(idle));
        let _ = stream.set_write_timeout(Some(idle));
    }
    let peer_is_loopback = stream.peer_addr().map(|a| is_loopback_ip(a.ip())).unwrap_or(false);
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = std::io::BufReader::new(stream);
    let mut buf = Vec::new();
    // per-connection replica connections, opened lazily and kept for the
    // life of the client connection (so a pipelining client reuses them)
    let mut conns: Vec<Option<ClientConn>> = (0..shared.replicas.len()).map(|_| None).collect();
    let send = |writer: &mut TcpStream, line: &str| {
        writer.write_all(line.as_bytes()).and_then(|()| writer.write_all(b"\n")).is_ok()
    };
    loop {
        match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES, shared.cfg.idle_timeout) {
            LineRead::Line => {}
            LineRead::Eof | LineRead::Gone => return,
            LineRead::Idle => {
                let _ = send(&mut writer, &wire::error_reply("idle timeout; closing connection"));
                return;
            }
            LineRead::Overlong => {
                let _ = send(
                    &mut writer,
                    &wire::error_reply(&format!(
                        "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
                    )),
                );
                return;
            }
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(l) => l.trim(),
            Err(_) => {
                if !send(&mut writer, &wire::error_reply("request is not UTF-8")) {
                    return;
                }
                continue;
            }
        };
        if line.is_empty() {
            continue;
        }
        // the proxy parses just enough to spot shutdown (fan-out),
        // metrics (answered locally) and stats (forwarded, then
        // augmented); every other line (predict, ping, models, even
        // malformed input) is the replica's to answer verbatim
        let parsed = wire::parse_request(line);
        if matches!(parsed, Ok(wire::Request::Shutdown)) {
            if !peer_is_loopback && !shared.cfg.allow_remote_shutdown {
                obs::warn("dist.proxy", "shutdown refused from a non-loopback peer", &[]);
                if !send(
                    &mut writer,
                    &wire::error_reply(
                        "shutdown refused from a non-loopback peer (the proxy \
                         must opt in with --allow-remote-shutdown)",
                    ),
                ) {
                    return;
                }
                continue;
            }
            obs::info("dist.proxy", "wire shutdown accepted; fanning out to replicas", &[]);
            broadcast_shutdown(shared);
            let _ = send(&mut writer, &wire::shutdown_reply());
            shared.begin_shutdown();
            return;
        }
        if matches!(parsed, Ok(wire::Request::Metrics)) {
            // never forwarded: the snapshot describes THIS process; each
            // replica answers its own `metrics`
            if !send(&mut writer, &wire::metrics_reply()) {
                return;
            }
            continue;
        }
        if matches!(parsed, Ok(wire::Request::Binary)) {
            // ack locally, then relay frames until the client hangs up.
            // The cached JSON-mode replica connections stay JSON; the
            // relay upgrades its own on first use.
            if !send(&mut writer, &wire::binary_reply()) {
                return;
            }
            binary_relay(shared, &mut reader, &mut writer);
            return;
        }
        let mut reply = forward(shared, &mut conns, line);
        if matches!(parsed, Ok(wire::Request::Stats)) {
            reply = splice_proxy_stats(shared, reply);
        }
        if !send(&mut writer, &reply) {
            return;
        }
    }
}

/// Relay binary frames after a client's upgrade: request frames in,
/// reply frames out, both verbatim. Runs until the client disconnects
/// or breaks framing (the SO_RCVTIMEO idle timeout set on the socket
/// also surfaces here, as a read error mid-header).
fn binary_relay(
    shared: &Arc<ProxyShared>,
    reader: &mut std::io::BufReader<TcpStream>,
    writer: &mut TcpStream,
) {
    let mut conns: Vec<Option<ClientConn>> = (0..shared.replicas.len()).map(|_| None).collect();
    loop {
        let req = match frame::read_frame(reader) {
            Ok(Some(f)) => f,
            // clean EOF at a frame boundary, or hostile/truncated
            // framing (bad magic, oversized, idle mid-frame): close —
            // same discipline as the server's frame path
            Ok(None) | Err(_) => return,
        };
        let reply = forward_frame(shared, &mut conns, &req);
        if writer.write_all(&reply).is_err() {
            return;
        }
    }
}

/// Forward one request frame verbatim, failing over across replicas —
/// the frame twin of [`forward`]. Replica connections are upgraded to
/// binary on first use and cached; only the reply's status byte is
/// inspected (`ST_RETRY` → back off, try the next replica), never the
/// payload, so predictions stay byte-for-byte the replica's.
fn forward_frame(
    shared: &Arc<ProxyShared>,
    conns: &mut [Option<ClientConn>],
    req: &[u8],
) -> Vec<u8> {
    let attempts = match shared.cfg.attempts {
        0 => (2 * shared.replicas.len()).max(2),
        a => a,
    };
    let mut backoff = Duration::from_micros(200);
    for _ in 0..attempts {
        let i = shared.pick();
        let replica = &shared.replicas[i];
        if conns[i].is_none() {
            let upgraded = ClientConn::connect(&replica.addr).and_then(|mut c| {
                c.upgrade_binary()?;
                Ok(c)
            });
            match upgraded {
                Ok(c) => conns[i] = Some(c),
                Err(_) => {
                    replica.record_failure(shared.cfg.eject_after);
                    continue;
                }
            }
        }
        let conn = conns[i].as_mut().expect("connection just ensured");
        match conn.roundtrip_frame(req) {
            Ok(reply) => {
                replica.record_success();
                if frame::reply_status(&reply) == Some(frame::ST_RETRY) {
                    replica.retries.inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(10));
                    continue;
                }
                return reply;
            }
            Err(_) => {
                conns[i] = None; // the cached connection is poisoned
                replica.record_failure(shared.cfg.eject_after);
            }
        }
    }
    frame::frame(&frame::status_payload(
        frame::ST_RETRY,
        &format!("all {} replicas busy or down; retry", shared.replicas.len()),
    ))
}

/// Splice the proxy's own per-replica section into a forwarded `stats`
/// reply: insert `,"proxy":{"replicas":[...]}` before the closing brace
/// of the (replica-formatted) JSON object, leaving the replica's floats
/// byte-for-byte untouched — the crate has no JSON serializer, and
/// re-encoding could perturb them.
fn splice_proxy_stats(shared: &Arc<ProxyShared>, reply: String) -> String {
    if !(reply.len() > 2 && reply.starts_with('{') && reply.ends_with('}')) {
        return reply; // not a non-empty object: pass through untouched
    }
    let per: Vec<String> = shared.replicas.iter().map(Replica::stats_json).collect();
    format!(
        "{},\"proxy\":{{\"replicas\":[{}]}}}}",
        &reply[..reply.len() - 1],
        per.join(",")
    )
}

/// Fan the wire `shutdown` out to every replica, best-effort: a replica
/// that is already down is already shut down.
fn broadcast_shutdown(shared: &Arc<ProxyShared>) {
    let line = wire::cmd_request("shutdown");
    for r in &shared.replicas {
        if let Ok(mut conn) = ClientConn::connect(&r.addr) {
            let _ = conn.roundtrip(&line);
        }
    }
}

/// Forward one request line, failing over across replicas: transport
/// failures strike the replica and move on immediately; `"retry":true`
/// replies back off briefly (doubling, bounded) and try the next replica.
fn forward(
    shared: &Arc<ProxyShared>,
    conns: &mut [Option<ClientConn>],
    line: &str,
) -> String {
    let attempts = match shared.cfg.attempts {
        0 => (2 * shared.replicas.len()).max(2),
        a => a,
    };
    let mut backoff = Duration::from_micros(200);
    for _ in 0..attempts {
        let i = shared.pick();
        let replica = &shared.replicas[i];
        if conns[i].is_none() {
            match ClientConn::connect(&replica.addr) {
                Ok(c) => conns[i] = Some(c),
                Err(_) => {
                    replica.record_failure(shared.cfg.eject_after);
                    continue;
                }
            }
        }
        let conn = conns[i].as_mut().expect("connection just ensured");
        match conn.roundtrip(line) {
            Ok(reply) => {
                replica.record_success();
                if reply.retry {
                    // the replica is up but saturated: back off, try the
                    // next one — this is where replicas pool capacity
                    replica.retries.inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(10));
                    continue;
                }
                return reply.raw;
            }
            Err(_) => {
                conns[i] = None; // the cached connection is poisoned
                replica.record_failure(shared.cfg.eject_after);
            }
        }
    }
    wire::overload_reply(&format!("all {} replicas busy or down; retry", shared.replicas.len()))
}
