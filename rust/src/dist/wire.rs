//! Wire codec for the distributed-fit protocol: newline-delimited JSON,
//! one message object per line, over a plain TCP connection between a
//! `gzk leader` and its `gzk worker` fleet.
//!
//! Message grammar (direction in brackets; see DESIGN.md §3d):
//!
//! ```text
//! {"dist":"register","proto":1}                 [worker -> leader] hello
//! {"dist":"job","proto":1,"worker":0,
//!  "spec":{...BoundSpec...},
//!  "data":{"name":"elevation","rows":4000,"seed":"7"}}
//!                                               [leader -> worker] broadcast
//! {"dist":"assign","shard_id":3,"lo":24576,"hi":32768}
//!                                               [leader -> worker] one task
//! {"dist":"stats","shard_id":3,"worker":0,"featurize_secs":0.12,
//!  "n":8192,"yy":41.5,"b":[...],"g":{"rows":F,"cols":F,"data":[...]}}
//!                                               [worker -> leader] one reply
//! {"dist":"done"}                               [leader -> worker] no more work
//! {"dist":"error","error":"...","shard_id":3}   [either] shard_id optional
//! ```
//!
//! Job, assign and stats messages additionally accept an **optional**
//! `"tid":"N"` field — the distributed trace ID as a u64 decimal string
//! (the seed convention; the f64-backed JSON number is exact only to
//! 2^53). The leader mints one ID per run when tracing is on and stamps
//! every job/assign; workers echo it on stats and adopt it as their
//! ambient trace so shard spans land in the fleet timeline. Untraced
//! (tid 0) messages omit the field entirely, so their bytes — and an
//! old peer's view of the protocol — are unchanged.
//!
//! The broadcast is the whole point of the protocol: a [`BoundSpec`] is a
//! few bytes of JSON and every holder rebuilds a bit-identical feature
//! map from it, so the only bulk payload is the per-shard sufficient
//! statistics — O(F^2), independent of shard size. Floats reuse the
//! model-artifact convention ([`fmt_f64`]: shortest round-trip `{:?}`
//! formatting, parsed back via `str::parse::<f64>`), so `RidgeStats`
//! cross the wire **bit-exactly** and the leader's merge reproduces the
//! in-process fit to the last bit.
//!
//! Every inbound byte is untrusted: frames are read through the bounded
//! line reader ([`crate::server::listener::read_line_bounded`]) with the
//! [`MAX_FRAME_BYTES`] cap (larger than the serving cap — a stats frame
//! carries an F x F Gram block), the JSON parser bounds nesting depth,
//! and [`parse_msg`] rejects non-finite floats and mismatched dimensions
//! — a hostile or buggy peer degrades to a protocol error, never a
//! poisoned merge or a panic in the float formatter.

use crate::data::{DataSource, FileSource, SyntheticSource};
use crate::features::BoundSpec;
use crate::krr::RidgeStats;
use crate::model::artifact::{json_string, mat_from_json, mat_to_json, vec_from_json, vec_to_json};
use crate::runtime::Json;

pub use crate::coordinator::ShardRange;

/// Protocol version; a mismatch is a registration error, not a guess.
pub const DIST_PROTO: usize = 1;

/// Longest accepted dist frame (64 MiB). A stats frame is dominated by
/// the F x F Gram block at ~20 bytes per float, so this admits feature
/// budgets up to roughly m = 1800 while still bounding a hostile peer
/// that streams bytes without a newline.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// The dataset a job reads: every worker opens its **own** source from
/// this descriptor (shards are row ranges, nothing is copied over the
/// wire). A name starting with `file:` opens that CSV/GZKBIN01 path —
/// the shared-filesystem deployment shape — anything else is a
/// [`SyntheticSource`] name whose row i is a pure function of
/// `(name, seed, i)` on every machine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DataSpec {
    pub name: String,
    /// rows the job consumes: every shard range lies inside `[0, rows)`
    pub rows: usize,
    /// generator seed (ignored by `file:` sources)
    pub seed: u64,
}

impl DataSpec {
    /// Open this descriptor as a live source, checking it actually holds
    /// `rows` rows.
    pub fn open(&self) -> Result<Box<dyn DataSource>, String> {
        let src: Box<dyn DataSource> = match self.name.strip_prefix("file:") {
            Some(path) => Box::new(FileSource::open(path)?),
            None => Box::new(SyntheticSource::by_name(&self.name, self.rows, self.seed)?),
        };
        if src.len() < self.rows {
            return Err(format!(
                "data source {:?} holds {} rows but the job needs {}",
                self.name,
                src.len(),
                self.rows
            ));
        }
        Ok(src)
    }

    fn to_json(&self) -> String {
        // the seed is a decimal string so the full u64 range survives the
        // f64-backed JSON number type (same convention as BoundSpec)
        format!(
            r#"{{"name":{},"rows":{},"seed":"{}"}}"#,
            json_string(&self.name),
            self.rows,
            self.seed
        )
    }

    fn from_json_value(j: &Json) -> Result<DataSpec, String> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "data spec missing string field \"name\"".to_string())?
            .to_string();
        if name.is_empty() {
            return Err("data spec \"name\" must not be empty".to_string());
        }
        let rows = j
            .get("rows")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| "data spec missing integer field \"rows\"".to_string())?;
        let seed = j
            .get("seed")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "data spec missing string field \"seed\"".to_string())?
            .parse::<u64>()
            .map_err(|_| "data spec \"seed\" must be a decimal u64 string".to_string())?;
        Ok(DataSpec { name, rows, seed })
    }
}

/// A worker's per-shard reply as it crosses the wire.
#[derive(Clone, Debug)]
pub struct WireStats {
    pub shard_id: usize,
    pub worker_id: usize,
    /// wall time the worker spent featurizing this shard (seconds)
    pub featurize_secs: f64,
    /// echoed distributed trace ID (0 = untraced run)
    pub tid: u64,
    pub stats: RidgeStats,
}

/// One parsed dist message. `tid` fields are the run's distributed
/// trace ID (0 = untraced) — observability metadata only, never part of
/// the computation.
#[derive(Debug)]
pub enum DistMsg {
    Register { proto: usize },
    Job { worker_id: usize, spec: BoundSpec, data: DataSpec, tid: u64 },
    Assign(ShardRange, u64),
    Stats(Box<WireStats>),
    Done,
    Error { error: String, shard_id: Option<usize> },
}

pub fn register_msg() -> String {
    format!(r#"{{"dist":"register","proto":{DIST_PROTO}}}"#)
}

/// The optional trace-ID wire fragment: empty for an untraced run so
/// the untraced bytes are unchanged from protocol v1 without the field.
fn tid_fragment(tid: u64) -> String {
    if tid == 0 {
        String::new()
    } else {
        format!(r#","tid":"{tid}""#)
    }
}

fn parse_tid(j: &Json) -> Result<u64, String> {
    match j.get("tid") {
        None => Ok(0),
        Some(v) => v
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| "\"tid\" must be a u64 decimal string".to_string()),
    }
}

pub fn job_msg(worker_id: usize, spec: &BoundSpec, data: &DataSpec, tid: u64) -> String {
    format!(
        r#"{{"dist":"job","proto":{DIST_PROTO},"worker":{worker_id},"spec":{},"data":{}{}}}"#,
        spec.to_json(),
        data.to_json(),
        tid_fragment(tid)
    )
}

pub fn assign_msg(t: ShardRange, tid: u64) -> String {
    format!(
        r#"{{"dist":"assign","shard_id":{},"lo":{},"hi":{}{}}}"#,
        t.shard_id,
        t.lo,
        t.hi,
        tid_fragment(tid)
    )
}

/// Encode a stats reply. Errs (instead of panicking in the artifact
/// float formatter) if any statistic is non-finite — the worker then
/// degrades to an error message for this shard and the leader recovers
/// it locally.
pub fn stats_msg(s: &WireStats) -> Result<String, String> {
    let finite = s.featurize_secs.is_finite()
        && s.stats.yy.is_finite()
        && s.stats.b.iter().all(|v| v.is_finite())
        && s.stats.g.data().iter().all(|v| v.is_finite());
    if !finite {
        return Err(format!("shard {} produced non-finite statistics", s.shard_id));
    }
    Ok(format!(
        concat!(
            r#"{{"dist":"stats","shard_id":{},"worker":{},"featurize_secs":{},"#,
            r#""n":{},"yy":{},"b":{},"g":{}{}}}"#
        ),
        s.shard_id,
        s.worker_id,
        crate::model::artifact::fmt_f64(s.featurize_secs),
        s.stats.n,
        crate::model::artifact::fmt_f64(s.stats.yy),
        vec_to_json(&s.stats.b),
        mat_to_json(&s.stats.g),
        tid_fragment(s.tid)
    ))
}

pub fn done_msg() -> String {
    r#"{"dist":"done"}"#.to_string()
}

pub fn error_msg(error: &str, shard_id: Option<usize>) -> String {
    match shard_id {
        Some(sid) => {
            format!(r#"{{"dist":"error","error":{},"shard_id":{sid}}}"#, json_string(error))
        }
        None => format!(r#"{{"dist":"error","error":{}}}"#, json_string(error)),
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| format!("dist message missing integer field {key:?}"))
}

/// Parse one dist message line. Malformed input is an error *message* —
/// never a panic, since every byte is peer-controlled. Stats frames are
/// validated here (finite floats, consistent dimensions) so a lying peer
/// cannot push a NaN or a shape mismatch into the leader's merge.
pub fn parse_msg(line: &str) -> Result<DistMsg, String> {
    let j = Json::parse(line).map_err(|e| format!("malformed dist message: {e}"))?;
    let tag = j
        .get("dist")
        .and_then(|t| t.as_str())
        .ok_or_else(|| "message missing string field \"dist\"".to_string())?;
    match tag {
        "register" => {
            let proto = req_usize(&j, "proto")?;
            if proto != DIST_PROTO {
                return Err(format!("protocol mismatch: peer speaks v{proto}, this is v{DIST_PROTO}"));
            }
            Ok(DistMsg::Register { proto })
        }
        "job" => {
            let proto = req_usize(&j, "proto")?;
            if proto != DIST_PROTO {
                return Err(format!("protocol mismatch: peer speaks v{proto}, this is v{DIST_PROTO}"));
            }
            let worker_id = req_usize(&j, "worker")?;
            let spec = BoundSpec::from_json_value(
                j.get("spec").ok_or_else(|| "job missing \"spec\"".to_string())?,
            )?;
            let data = DataSpec::from_json_value(
                j.get("data").ok_or_else(|| "job missing \"data\"".to_string())?,
            )?;
            Ok(DistMsg::Job { worker_id, spec, data, tid: parse_tid(&j)? })
        }
        "assign" => {
            let shard_id = req_usize(&j, "shard_id")?;
            let lo = req_usize(&j, "lo")?;
            let hi = req_usize(&j, "hi")?;
            if lo >= hi {
                return Err(format!("assign shard {shard_id}: empty range [{lo}, {hi})"));
            }
            Ok(DistMsg::Assign(ShardRange { shard_id, lo, hi }, parse_tid(&j)?))
        }
        "stats" => {
            let shard_id = req_usize(&j, "shard_id")?;
            let worker_id = req_usize(&j, "worker")?;
            let featurize_secs = j
                .get("featurize_secs")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| "stats missing number field \"featurize_secs\"".to_string())?;
            let n = req_usize(&j, "n")?;
            let yy = j
                .get("yy")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| "stats missing number field \"yy\"".to_string())?;
            let b = vec_from_json(
                j.get("b").ok_or_else(|| "stats missing \"b\"".to_string())?,
            )?;
            let g = mat_from_json(
                j.get("g").ok_or_else(|| "stats missing \"g\"".to_string())?,
            )?;
            if g.rows() != g.cols() || g.rows() != b.len() {
                return Err(format!(
                    "stats shard {shard_id}: inconsistent dimensions (g {}x{}, b {})",
                    g.rows(),
                    g.cols(),
                    b.len()
                ));
            }
            // "1e999" parses to inf: refuse it here so a hostile worker can
            // never poison the merge (fmt_f64 would panic on the way out)
            let finite = featurize_secs.is_finite()
                && yy.is_finite()
                && b.iter().all(|v| v.is_finite())
                && g.data().iter().all(|v| v.is_finite());
            if !finite {
                return Err(format!("stats shard {shard_id}: non-finite statistics"));
            }
            Ok(DistMsg::Stats(Box::new(WireStats {
                shard_id,
                worker_id,
                featurize_secs,
                tid: parse_tid(&j)?,
                stats: RidgeStats { g, b, n, yy },
            })))
        }
        "done" => Ok(DistMsg::Done),
        "error" => {
            let error = j
                .get("error")
                .and_then(|e| e.as_str())
                .ok_or_else(|| "error message missing string field \"error\"".to_string())?
                .to_string();
            let shard_id = j.get("shard_id").and_then(|v| v.as_usize());
            Ok(DistMsg::Error { error, shard_id })
        }
        other => Err(format!(
            "unknown dist message {other:?}; known: register, job, assign, stats, done, error"
        )),
    }
}
