//! Distributed execution over TCP: the in-process one-round protocol
//! ([`crate::coordinator`]) lifted across machines, plus a replica proxy
//! for the serving tier. See DESIGN.md §3d.
//!
//! Three process roles, all std-only TCP speaking newline-delimited
//! JSON:
//!
//! - [`worker`] — `gzk worker`: registers with a leader, rebuilds the
//!   broadcast [`BoundSpec`](crate::features::BoundSpec), opens its own
//!   [`DataSource`](crate::data::DataSource), answers `ShardRange`
//!   assignments with per-shard [`RidgeStats`](crate::krr::RidgeStats).
//! - [`leader`] — `gzk leader`: scatters shards over the registered
//!   fleet, reassigns on worker death, recovers unreadable shards
//!   locally, merges in deterministic shard order (bit-identical to
//!   [`fit_one_round_source`](crate::coordinator::fit_one_round_source)),
//!   refuses to finalize a partial model.
//! - [`proxy`] — `gzk proxy`: round-robin load balancer over `gzk
//!   server` replicas with retry-on-backpressure and eject-and-probe
//!   replica health.
//!
//! The [`wire`] module holds the codec shared by all three.

pub mod leader;
pub mod proxy;
pub mod wire;
pub mod worker;

pub use leader::{DistLeader, LeaderConfig, NetFit};
pub use proxy::{Proxy, ProxyConfig};
pub use wire::{DataSpec, DistMsg, WireStats, DIST_PROTO, MAX_FRAME_BYTES};
pub use worker::{run_worker, WorkerOptions, WorkerReport};
