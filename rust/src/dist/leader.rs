//! The `gzk leader` side of the distributed fit: accept worker
//! registrations, broadcast the job, scatter shard ranges, gather
//! per-shard statistics, merge in deterministic order, solve.
//!
//! ```text
//!   bind ── accept until n_workers registered (or timeout) ──┐
//!                                                            ▼
//!   pending shards ◄── one driver thread per worker: pop, assign,
//!        ▲              await the stats reply (deadline), validate
//!        │ repush on death/timeout/protocol violation
//!        │
//!   replies (BTreeMap by shard_id, first reply wins) ── missing
//!   shards recomputed locally ── merge in shard_id order ── solve
//! ```
//!
//! **Failure semantics.** A worker that dies, times out, or violates the
//! protocol mid-shard has its in-flight range pushed back onto the
//! pending queue for the surviving workers (the connection is abandoned
//! — after a reply deadline passes the leader cannot tell a dead worker
//! from a slow one, so it never accepts a late reply that could race a
//! reassignment). A worker that *reports* a shard error (source I/O)
//! stays in the fleet, but its shard goes to leader-local recovery
//! rather than back on the queue — re-assigning it would loop forever if
//! the data really is unreadable. Whatever is still missing after the
//! fleet drains is recomputed by the leader from its own copy of the
//! source, per shard; [`merge_in_shard_order`] then refuses to finalize
//! unless exactly every shard is present exactly once.
//!
//! **Bit-identity contract.** Per-shard statistics are a pure function
//! of (spec, source, range) — the feature map is data-oblivious and
//! every parallel kernel is bit-identical to serial — and float
//! accumulation order is fixed by merging buffered per-shard stats in
//! ascending shard_id, exactly like the in-process
//! [`fit_one_round_source`](crate::coordinator::fit_one_round_source)
//! clean path. So the distributed fit is **bit-identical** to the
//! single-process fit for any worker count, any shard interleaving, and
//! any injected worker death (tested in `tests/dist_e2e.rs`).

use super::wire::{self, DataSpec, DistMsg, WireStats, MAX_FRAME_BYTES};
use crate::coordinator::ShardRange;
use crate::exec::Pool;
use crate::features::Featurizer;
use crate::krr::{FeatureRidge, RidgeStats};
use crate::obs;
use crate::server::listener::{read_line_bounded, LineRead};
use std::collections::BTreeMap;
use std::io::{BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// Knobs for a [`DistLeader`]; the defaults match the CLI's.
#[derive(Clone, Copy, Debug)]
pub struct LeaderConfig {
    /// fleet size to wait for before scattering
    pub n_workers: usize,
    /// rows per shard (the task granularity, like `--chunk-rows`)
    pub rows_per_shard: usize,
    /// how long to wait for the fleet to register; if at least one worker
    /// registered by then, the fit proceeds with the partial fleet
    pub register_timeout: Duration,
    /// per-shard reply deadline; past it the worker is abandoned and its
    /// shard reassigned
    pub shard_timeout: Duration,
}

impl Default for LeaderConfig {
    fn default() -> LeaderConfig {
        LeaderConfig {
            n_workers: 2,
            rows_per_shard: 8192,
            register_timeout: Duration::from_secs(60),
            shard_timeout: Duration::from_secs(120),
        }
    }
}

/// Outcome of a distributed fit over TCP — the network twin of
/// [`DistributedFit`](crate::coordinator::DistributedFit), with the
/// failure-path telemetry the smoke tests and benches assert on.
pub struct NetFit {
    pub model: FeatureRidge,
    pub stats: RidgeStats,
    pub n_shards: usize,
    /// workers that actually registered (may be fewer than requested)
    pub n_workers: usize,
    /// wall time from scatter start to solve (seconds)
    pub wall_secs: f64,
    /// sum of per-shard featurize seconds across the fleet + recovery
    pub featurize_secs_total: f64,
    /// shards pushed back after a worker died / timed out / misbehaved
    pub reassigned_shards: usize,
    /// shards the leader recomputed locally
    pub recovered_shards: usize,
    /// workers abandoned mid-protocol
    pub dead_workers: usize,
}

/// One registered worker connection (post-handshake).
struct WorkerConn {
    id: usize,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// A bound leader, not yet running — split from [`DistLeader::run`] so
/// callers (tests, the CLI) can learn the ephemeral port of an
/// `addr` like `127.0.0.1:0` before any worker connects.
pub struct DistLeader {
    listener: TcpListener,
    cfg: LeaderConfig,
}

impl DistLeader {
    pub fn bind(addr: &str, cfg: LeaderConfig) -> Result<DistLeader, String> {
        if cfg.n_workers < 1 {
            return Err("leader needs at least one worker".to_string());
        }
        if cfg.rows_per_shard < 1 {
            return Err("rows_per_shard must be >= 1".to_string());
        }
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        Ok(DistLeader { listener, cfg })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr, String> {
        self.listener.local_addr().map_err(|e| format!("local addr: {e}"))
    }

    /// Run the one-round protocol over the fleet. The leader opens its
    /// own copy of the source (for validation and lost-shard recovery);
    /// `data.rows` rows are fitted.
    pub fn run(
        &self,
        spec: &crate::features::BoundSpec,
        data: &DataSpec,
        lambda: f64,
    ) -> Result<NetFit, String> {
        if !spec.spec.method.is_oblivious() {
            return Err(format!(
                "method {} is data-dependent and cannot be broadcast",
                spec.spec.method.name()
            ));
        }
        let src = data.open()?;
        if src.dim() != spec.d {
            return Err(format!(
                "data source {:?} has d = {} but the spec is bound to d = {}",
                data.name,
                src.dim(),
                spec.d
            ));
        }
        let n = data.rows;
        if n == 0 {
            return Err("cannot fit zero rows".to_string());
        }
        let f_dim = spec.feature_dim();
        // one distributed trace ID per run (when tracing is on): stamped
        // on the job broadcast and every assignment, adopted by every
        // worker, echoed on stats — the join key `gzk trace-merge` uses
        // to stitch leader and worker trace files into one timeline
        let run_tid =
            if obs::trace::enabled() { obs::trace::mint_trace_id() } else { 0 };
        let _trace_ctx = obs::trace::with_trace(run_tid);
        let conns = {
            let _span = obs::span("dist", "register");
            self.register_fleet(spec, data, run_tid)?
        };
        let n_registered = conns.len();
        obs::gauge("dist.leader.workers").set(n_registered as i64);
        obs::info(
            "dist.leader",
            "fleet registered; scattering shards",
            &[("workers", n_registered.into()), ("rows", n.into())],
        );

        let t0 = Instant::now();
        let shard_ranges: Vec<ShardRange> = (0..n)
            .step_by(self.cfg.rows_per_shard)
            .enumerate()
            .map(|(sid, lo)| ShardRange {
                shard_id: sid,
                lo,
                hi: (lo + self.cfg.rows_per_shard).min(n),
            })
            .collect();
        let n_shards = shard_ranges.len();

        // pull scheduling: drivers pop the next pending shard, so a slow
        // worker naturally takes fewer shards and a dead worker's range
        // goes back on the queue for the survivors
        let pending = Mutex::new(shard_ranges.clone());
        // worker-reported shard errors: straight to leader recovery (a
        // repush would ping-pong forever if the data really is unreadable)
        let failed = Mutex::new(Vec::<usize>::new());
        let reassigned = AtomicUsize::new(0);
        let dead = AtomicUsize::new(0);
        let (res_tx, res_rx) = mpsc::channel::<WireStats>();
        let scatter_span = obs::span("dist", "scatter");
        std::thread::scope(|scope| {
            for conn in conns {
                let res_tx = res_tx.clone();
                let pending = &pending;
                let failed = &failed;
                let reassigned = &reassigned;
                let dead = &dead;
                let shard_timeout = self.cfg.shard_timeout;
                scope.spawn(move || {
                    // ambient trace is thread-local: re-establish the run's
                    // ID on each driver thread so its shard spans stitch
                    let _trace_ctx = obs::trace::with_trace(run_tid);
                    if !drive_worker(
                        conn,
                        pending,
                        failed,
                        &res_tx,
                        f_dim,
                        reassigned,
                        shard_timeout,
                        run_tid,
                    ) {
                        dead.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        drop(res_tx);
        drop(scatter_span);

        // Gather, deduplicating by shard id: the driver protocol never
        // accepts a late reply after a reassignment, but the merge still
        // enforces exactly-once (first reply wins) as a belt-and-braces
        // guard — a duplicate must never be double-counted.
        let mut replies: BTreeMap<usize, WireStats> = BTreeMap::new();
        for reply in res_rx {
            replies.entry(reply.shard_id).or_insert(reply);
        }

        let failed = failed.into_inner().expect("failed lock");
        if !failed.is_empty() {
            obs::warn(
                "dist.leader",
                "shard(s) failed on workers; recovering locally",
                &[("failed_shards", failed.len().into())],
            );
        }

        // leader-local recovery: recompute whatever is missing, per shard
        // from zeroed statistics — bit-identical to what a worker would
        // have produced, so the merge below cannot tell the difference
        let mut recovered = 0usize;
        if replies.len() < n_shards {
            let _span = obs::span("dist", "recover");
            let feat = spec.build();
            let pool = Pool::global();
            for t in &shard_ranges {
                if replies.contains_key(&t.shard_id) {
                    continue;
                }
                let (x, y) = src.read_range(t.lo, t.hi)?;
                let t1 = Instant::now();
                let z = {
                    let _span = obs::span("pipeline", "featurize");
                    feat.featurize_par(&x, &pool)
                };
                let featurize_secs = t1.elapsed().as_secs_f64();
                let mut stats = RidgeStats::new(f_dim);
                {
                    let _span = obs::span("pipeline", "absorb");
                    stats.absorb_with(&z, &y, &pool);
                }
                replies.insert(
                    t.shard_id,
                    WireStats {
                        shard_id: t.shard_id,
                        worker_id: usize::MAX,
                        featurize_secs,
                        tid: run_tid,
                        stats,
                    },
                );
                recovered += 1;
            }
        }

        let (merged, featurize_secs_total) = {
            let _span = obs::span("fit", "merge");
            merge_in_shard_order(&replies, n_shards, n, f_dim)?
        };
        let model = {
            let _span = obs::span("fit", "solve");
            merged.solve(lambda)
        };
        obs::counter("dist.leader.shards_reassigned")
            .add(reassigned.load(Ordering::Relaxed) as u64);
        obs::counter("dist.leader.shards_recovered").add(recovered as u64);
        obs::counter("dist.leader.dead_workers").add(dead.load(Ordering::Relaxed) as u64);
        obs::info(
            "dist.leader",
            "distributed fit merged and solved",
            &[
                ("shards", n_shards.into()),
                ("reassigned", reassigned.load(Ordering::Relaxed).into()),
                ("recovered", recovered.into()),
                ("dead_workers", dead.load(Ordering::Relaxed).into()),
            ],
        );
        Ok(NetFit {
            model,
            stats: merged,
            n_shards,
            n_workers: n_registered,
            wall_secs: t0.elapsed().as_secs_f64(),
            featurize_secs_total,
            reassigned_shards: reassigned.load(Ordering::Relaxed),
            recovered_shards: recovered,
            dead_workers: dead.load(Ordering::Relaxed),
        })
    }

    /// Accept-and-handshake until the requested fleet size registered or
    /// the registration window closes (a partial fleet proceeds; an empty
    /// one is an error).
    fn register_fleet(
        &self,
        spec: &crate::features::BoundSpec,
        data: &DataSpec,
        run_tid: u64,
    ) -> Result<Vec<WorkerConn>, String> {
        self.listener
            .set_nonblocking(true)
            .map_err(|e| format!("nonblocking accept: {e}"))?;
        let deadline = Instant::now() + self.cfg.register_timeout;
        let mut conns = Vec::new();
        while conns.len() < self.cfg.n_workers {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    let id = conns.len();
                    match handshake(stream, id, spec, data, self.cfg.shard_timeout, run_tid) {
                        Ok(conn) => conns.push(conn),
                        Err(e) => obs::warn(
                            "dist.leader",
                            &format!("rejected peer: {e}"),
                            &[("peer", peer.to_string().into())],
                        ),
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => continue, // transient accept failure
            }
        }
        if conns.is_empty() {
            return Err(format!(
                "no workers registered within {:?} (start `gzk worker --addr <leader>`)",
                self.cfg.register_timeout
            ));
        }
        if conns.len() < self.cfg.n_workers {
            obs::warn(
                "dist.leader",
                "registration window closed with a partial fleet; proceeding",
                &[("registered", conns.len().into()), ("requested", self.cfg.n_workers.into())],
            );
        }
        Ok(conns)
    }
}

fn handshake(
    mut stream: TcpStream,
    id: usize,
    spec: &crate::features::BoundSpec,
    data: &DataSpec,
    shard_timeout: Duration,
    run_tid: u64,
) -> Result<WorkerConn, String> {
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(shard_timeout))
        .map_err(|e| format!("set read timeout: {e}"))?;
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| format!("clone worker connection: {e}"))?,
    );
    let mut buf = Vec::new();
    match read_line_bounded(&mut reader, &mut buf, MAX_FRAME_BYTES, Some(shard_timeout)) {
        LineRead::Line => {}
        _ => return Err("no registration line".to_string()),
    }
    let line = std::str::from_utf8(&buf).map_err(|_| "registration is not UTF-8".to_string())?;
    match wire::parse_msg(line.trim()) {
        Ok(DistMsg::Register { .. }) => {}
        Ok(other) => {
            let _ = send_line(&mut stream, &wire::error_msg("expected register", None));
            return Err(format!("expected register, got {other:?}"));
        }
        Err(e) => {
            let _ = send_line(&mut stream, &wire::error_msg(&e, None));
            return Err(e);
        }
    }
    send_line(&mut stream, &wire::job_msg(id, spec, data, run_tid))?;
    Ok(WorkerConn { id, stream, reader })
}

fn send_line(stream: &mut TcpStream, line: &str) -> Result<(), String> {
    stream
        .write_all(line.as_bytes())
        .and_then(|()| stream.write_all(b"\n"))
        .map_err(|e| format!("send: {e}"))
}

/// Drive one worker connection to completion. Returns `false` when the
/// worker was abandoned mid-protocol (its in-flight shard repushed);
/// `true` on a clean drain.
#[allow(clippy::too_many_arguments)]
fn drive_worker(
    mut conn: WorkerConn,
    pending: &Mutex<Vec<ShardRange>>,
    failed: &Mutex<Vec<usize>>,
    res_tx: &mpsc::Sender<WireStats>,
    f_dim: usize,
    reassigned: &AtomicUsize,
    shard_timeout: Duration,
    run_tid: u64,
) -> bool {
    let mut buf = Vec::new();
    // assign → reply latency per shard, across the whole fleet; the per-
    // worker breakdown is in the trace (one driver thread = one trace tid)
    let reply_hist = obs::hist("dist.leader.shard_reply_s");
    loop {
        let task = match pending.lock().expect("pending lock").pop() {
            Some(t) => t,
            None => {
                let _ = send_line(&mut conn.stream, &wire::done_msg());
                return true;
            }
        };
        let abandon = |task: ShardRange, why: &str| {
            obs::warn(
                "dist.leader",
                &format!("worker abandoned mid-shard ({why}); reassigning"),
                &[("worker", conn.id.into()), ("shard", task.shard_id.into())],
            );
            pending.lock().expect("pending lock").push(task);
            reassigned.fetch_add(1, Ordering::Relaxed);
        };
        let _span = obs::span("dist", &format!("shard {}", task.shard_id));
        let t0 = Instant::now();
        if let Err(e) = send_line(&mut conn.stream, &wire::assign_msg(task, run_tid)) {
            abandon(task, &e);
            return false;
        }
        match read_reply(&mut conn.reader, &mut buf, shard_timeout) {
            Ok(DistMsg::Stats(ws)) => {
                // lockstep validation: the reply must answer exactly the
                // assignment in flight, with the right shape and row
                // count — anything else is a protocol violation and the
                // worker is abandoned (its shard reassigned)
                let ws = *ws;
                if ws.shard_id != task.shard_id
                    || ws.stats.n != task.hi - task.lo
                    || ws.stats.b.len() != f_dim
                {
                    abandon(task, "reply does not match the assignment");
                    return false;
                }
                reply_hist.record(t0.elapsed().as_secs_f64());
                let _ = res_tx.send(ws);
            }
            Ok(DistMsg::Error { error, .. }) => {
                // the worker is alive but cannot serve this shard; leave
                // the shard to leader recovery and keep the worker
                obs::warn(
                    "dist.leader",
                    &format!("worker failed a shard ({error}); leader will recover it"),
                    &[("worker", conn.id.into()), ("shard", task.shard_id.into())],
                );
                failed.lock().expect("failed lock").push(task.shard_id);
            }
            Ok(_) => {
                abandon(task, "unexpected message");
                return false;
            }
            Err(e) => {
                abandon(task, &e);
                return false;
            }
        }
    }
}

fn read_reply(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    shard_timeout: Duration,
) -> Result<DistMsg, String> {
    match read_line_bounded(reader, buf, MAX_FRAME_BYTES, Some(shard_timeout)) {
        LineRead::Line => {}
        LineRead::Eof | LineRead::Gone => return Err("connection dropped".to_string()),
        LineRead::Idle => return Err("reply deadline passed".to_string()),
        LineRead::Overlong => return Err(format!("frame over {MAX_FRAME_BYTES} bytes")),
    }
    let line = std::str::from_utf8(buf).map_err(|_| "frame is not UTF-8".to_string())?;
    wire::parse_msg(line.trim())
}

/// The single reduction: merge buffered per-shard statistics in
/// ascending shard order (float addition is not order-invariant — fixed
/// order is what makes the distributed fit bit-identical to the
/// in-process one). Refuses to finalize unless exactly shards
/// `0..n_shards` are present and the merged row count matches: a fit
/// that silently lost rows would be a *wrong model*, not a slow one.
pub(crate) fn merge_in_shard_order(
    replies: &BTreeMap<usize, WireStats>,
    n_shards: usize,
    expected_rows: usize,
    f_dim: usize,
) -> Result<(RidgeStats, f64), String> {
    if replies.len() != n_shards || replies.keys().next_back() != Some(&(n_shards - 1)) {
        return Err(format!(
            "shard-count mismatch: have {} of {n_shards} shards; refusing to finalize",
            replies.len()
        ));
    }
    let mut merged = RidgeStats::new(f_dim);
    let mut featurize_secs_total = 0.0;
    for reply in replies.values() {
        merged.merge(&reply.stats);
        featurize_secs_total += reply.featurize_secs;
    }
    if merged.n != expected_rows {
        return Err(format!(
            "distributed fit absorbed {} of {expected_rows} rows; refusing to finalize",
            merged.n
        ));
    }
    Ok((merged, featurize_secs_total))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(sid: usize, rows: usize) -> WireStats {
        let mut stats = RidgeStats::new(2);
        stats.n = rows;
        stats.b = vec![sid as f64, 1.0];
        WireStats { shard_id: sid, worker_id: 0, featurize_secs: 0.5, tid: 0, stats }
    }

    #[test]
    fn merge_refuses_missing_shards_and_row_mismatch() {
        // complete set: merges, in order, with summed telemetry
        let mut replies = BTreeMap::new();
        for sid in 0..3 {
            replies.insert(sid, shard(sid, 10));
        }
        let (merged, secs) = merge_in_shard_order(&replies, 3, 30, 2).unwrap();
        assert_eq!(merged.n, 30);
        assert_eq!(merged.b, vec![3.0, 3.0]);
        assert!((secs - 1.5).abs() < 1e-12);

        // a missing shard: refuse (the dead-worker path must never
        // finalize a partial model)
        replies.remove(&1);
        let e = merge_in_shard_order(&replies, 3, 30, 2).unwrap_err();
        assert!(e.contains("shard-count mismatch"), "{e}");

        // a wrong shard id filling the count: still refused
        replies.insert(7, shard(7, 10));
        let e = merge_in_shard_order(&replies, 3, 30, 2).unwrap_err();
        assert!(e.contains("refusing to finalize"), "{e}");

        // right shards, wrong row total: refused
        let mut replies = BTreeMap::new();
        for sid in 0..3 {
            replies.insert(sid, shard(sid, 9));
        }
        let e = merge_in_shard_order(&replies, 3, 30, 2).unwrap_err();
        assert!(e.contains("27 of 30 rows"), "{e}");
    }
}
