//! Kernel ridge regression, in both forms the paper compares:
//!
//! * [`FeatureRidge`] — feature-space KRR on a random-feature matrix Z
//!   (n x F): w = (Z^T Z + lambda I)^{-1} Z^T y; O(n F^2 + F^3). This is
//!   what the coordinator's one-round protocol assembles from per-worker
//!   sufficient statistics.
//! * [`ExactKrr`] — ground truth: alpha = (K + lambda I)^{-1} y with the
//!   exact Gram matrix; O(n^3). Used by tests and the spectral validators.

use crate::exec::Pool;
use crate::kernels::Kernel;
use crate::linalg::{syrk_flat_into_p, Cholesky, Mat};

/// Sufficient statistics for feature-space ridge regression: G = Z^T Z,
/// b = Z^T y, n rows seen. Additive across shards/batches — the heart of
/// the one-round distributed protocol and the streaming path.
#[derive(Clone, Debug)]
pub struct RidgeStats {
    pub g: Mat,
    pub b: Vec<f64>,
    pub n: usize,
    /// running sum of squared targets (for residual diagnostics)
    pub yy: f64,
}

impl RidgeStats {
    pub fn new(f_dim: usize) -> Self {
        RidgeStats { g: Mat::zeros(f_dim, f_dim), b: vec![0.0; f_dim], n: 0, yy: 0.0 }
    }

    /// Absorb a featurized batch (rows of z paired with y), drawing the
    /// dominant `Z^T Z` update from the global pool.
    pub fn absorb(&mut self, z: &Mat, y: &[f64]) {
        self.absorb_with(z, y, &Pool::global());
    }

    /// [`absorb`](RidgeStats::absorb) on an explicit pool. The O(n F^2)
    /// SYRK runs as the blocked parallel kernel — bit-identical to serial
    /// at every thread count — while the O(n F) `Z^T y` and counter
    /// updates stay on the calling thread. Coordinator workers pass
    /// [`Pool::serial`] (they are already the parallel axis).
    pub fn absorb_with(&mut self, z: &Mat, y: &[f64], pool: &Pool) {
        assert_eq!(z.rows(), y.len());
        assert_eq!(z.cols(), self.b.len());
        self.absorb_flat_with(z.data(), y, pool);
    }

    /// [`absorb_with`](RidgeStats::absorb_with) over a flat row-major
    /// feature buffer (`z.len() == y.len() * F`) — the out-of-core chunk
    /// path folds its reused scratch slice directly, no `Mat` wrapper.
    /// Every accumulator (G, b, yy, n) advances in row-ascending order, so
    /// absorbing the same rows in **any** chunking yields bit-identical
    /// statistics — the chunk-invariance contract `data::pipeline` is
    /// built on (property-tested in `tests/source_props.rs`).
    pub fn absorb_flat_with(&mut self, z: &[f64], y: &[f64], pool: &Pool) {
        let f = self.b.len();
        assert_eq!(z.len(), y.len() * f, "absorb_flat_with: buffer/target mismatch");
        syrk_flat_into_p(z, f, &mut self.g, pool);
        for (row, &yi) in z.chunks_exact(f).zip(y) {
            for (bj, &zj) in self.b.iter_mut().zip(row) {
                *bj += zj * yi;
            }
            self.yy += yi * yi;
        }
        self.n += y.len();
    }

    /// Merge another shard's statistics (the one-round reduction).
    pub fn merge(&mut self, other: &RidgeStats) {
        self.g.add_assign(&other.g);
        for (a, &b) in self.b.iter_mut().zip(&other.b) {
            *a += b;
        }
        self.n += other.n;
        self.yy += other.yy;
    }

    /// Solve for the ridge weights at regularization lambda.
    pub fn solve(&self, lambda: f64) -> FeatureRidge {
        let mut g = self.g.clone();
        g.symmetrize_from_upper();
        g.add_diag(lambda);
        let (chol, jitter) = Cholesky::new_with_jitter(&g, 1e-10);
        let weights = chol.solve(&self.b);
        FeatureRidge { weights, lambda: lambda + jitter }
    }
}

/// Trained feature-space ridge model.
#[derive(Clone, Debug)]
pub struct FeatureRidge {
    pub weights: Vec<f64>,
    pub lambda: f64,
}

impl FeatureRidge {
    /// Fit directly from a feature matrix (convenience; the coordinator
    /// path goes through RidgeStats).
    pub fn fit(z: &Mat, y: &[f64], lambda: f64) -> Self {
        let mut stats = RidgeStats::new(z.cols());
        stats.absorb(z, y);
        stats.solve(lambda)
    }

    /// Predict from featurized inputs.
    pub fn predict(&self, z: &Mat) -> Vec<f64> {
        z.matvec(&self.weights)
    }

    /// [`predict`](FeatureRidge::predict) with row parallelism drawn from
    /// an explicit pool (bit-identical to the serial path).
    pub fn predict_with(&self, z: &Mat, pool: &Pool) -> Vec<f64> {
        z.matvec_p(&self.weights, pool)
    }

    pub fn predict_row(&self, z_row: &[f64]) -> f64 {
        z_row.iter().zip(&self.weights).map(|(&a, &b)| a * b).sum()
    }
}

/// Gaussian-process regression through random features (Appendix A of the
/// paper lists GPs among the downstream tasks; Theorem 10 licenses the
/// low-rank surrogate). Predictive mean equals feature-ridge; predictive
/// variance is sigma^2 * z(x)^T (Z^T Z + lambda I)^{-1} z(x).
pub struct FeatureGp {
    chol: Cholesky,
    weights: Vec<f64>,
    noise_var: f64,
}

impl FeatureGp {
    /// Fit from accumulated sufficient statistics (same inputs the
    /// coordinator's one-round reduction produces).
    pub fn fit(stats: &RidgeStats, lambda: f64, noise_var: f64) -> FeatureGp {
        let mut g = stats.g.clone();
        g.symmetrize_from_upper();
        g.add_diag(lambda);
        let (chol, _) = Cholesky::new_with_jitter(&g, 1e-10);
        let weights = chol.solve(&stats.b);
        FeatureGp { chol, weights, noise_var }
    }

    /// Predictive mean and variance for one featurized point.
    pub fn predict_row(&self, z_row: &[f64]) -> (f64, f64) {
        let mean: f64 = z_row.iter().zip(&self.weights).map(|(&a, &b)| a * b).sum();
        let sol = self.chol.solve(z_row);
        let quad: f64 = z_row.iter().zip(&sol).map(|(&a, &b)| a * b).sum();
        (mean, self.noise_var * quad.max(0.0))
    }

    /// Batch prediction: (means, variances).
    pub fn predict(&self, z: &Mat) -> (Vec<f64>, Vec<f64>) {
        let mut means = Vec::with_capacity(z.rows());
        let mut vars = Vec::with_capacity(z.rows());
        for i in 0..z.rows() {
            let (m, v) = self.predict_row(z.row(i));
            means.push(m);
            vars.push(v);
        }
        (means, vars)
    }
}

/// Exact kernel ridge regression (ground truth).
pub struct ExactKrr {
    kernel: Kernel,
    x_train: Mat,
    alpha: Vec<f64>,
}

impl ExactKrr {
    pub fn fit(kernel: Kernel, x_train: Mat, y: &[f64], lambda: f64) -> Self {
        let mut k = kernel.gram(&x_train);
        k.add_diag(lambda);
        let (chol, _) = Cholesky::new_with_jitter(&k, 1e-10);
        let alpha = chol.solve(y);
        ExactKrr { kernel, x_train, alpha }
    }

    pub fn predict(&self, x: &Mat) -> Vec<f64> {
        let kx = self.kernel.cross_gram(x, &self.x_train);
        kx.matvec(&self.alpha)
    }
}

/// Mean squared error.
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    pred.iter().zip(truth).map(|(&p, &t)| (p - t) * (p - t)).sum::<f64>() / pred.len() as f64
}

/// 2-fold cross-validation over a grid of lambdas on featurized data
/// (the paper tunes the ridge parameter this way).
pub fn cv_lambda(z: &Mat, y: &[f64], grid: &[f64]) -> f64 {
    let n = z.rows();
    let half = n / 2;
    let z1 = z.row_block(0, half);
    let z2 = z.row_block(half, n);
    let (y1, y2) = (&y[..half], &y[half..]);
    let mut best = (f64::INFINITY, grid[0]);
    for &lam in grid {
        let m1 = FeatureRidge::fit(&z1, y1, lam);
        let m2 = FeatureRidge::fit(&z2, y2, lam);
        let e = mse(&m1.predict(&z2), y2) + mse(&m2.predict(&z1), y1);
        if e < best.0 {
            best = (e, lam);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{Featurizer, GegenbauerFeatures, RadialTable};
    use crate::rng::Rng;

    #[test]
    fn ridge_recovers_linear_model() {
        // y = Z w* exactly, tiny lambda -> recover w*
        let mut rng = Rng::new(130);
        let z = Mat::from_fn(50, 5, |_, _| rng.normal());
        let w_star: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let y = z.matvec(&w_star);
        let model = FeatureRidge::fit(&z, &y, 1e-10);
        for (a, b) in model.weights.iter().zip(&w_star) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn stats_absorb_equals_direct() {
        let mut rng = Rng::new(131);
        let z = Mat::from_fn(30, 4, |_, _| rng.normal());
        let y: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        // two-batch absorb == one-shot fit
        let mut stats = RidgeStats::new(4);
        stats.absorb(&z.row_block(0, 13), &y[..13]);
        stats.absorb(&z.row_block(13, 30), &y[13..]);
        let m1 = stats.solve(0.1);
        let m2 = FeatureRidge::fit(&z, &y, 0.1);
        for (a, b) in m1.weights.iter().zip(&m2.weights) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn merge_is_partition_invariant() {
        let mut rng = Rng::new(132);
        let z = Mat::from_fn(24, 3, |_, _| rng.normal());
        let y: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let mut a = RidgeStats::new(3);
        a.absorb(&z, &y);
        let mut b = RidgeStats::new(3);
        for lo in (0..24).step_by(6) {
            let mut shard = RidgeStats::new(3);
            shard.absorb(&z.row_block(lo, lo + 6), &y[lo..lo + 6]);
            b.merge(&shard);
        }
        assert!(a.g.max_abs_diff(&b.g) < 1e-10);
        assert_eq!(a.n, b.n);
    }

    #[test]
    fn feature_krr_approaches_exact_krr() {
        // random Gegenbauer features + ridge ~ exact Gaussian KRR
        let mut rng = Rng::new(133);
        let n = 80;
        let x = Mat::from_fn(n, 3, |_, _| rng.normal() * 0.6);
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                (2.0 * r[0]).sin() + r[1] * r[2] + 0.01 * rng.normal()
            })
            .collect();
        let lam = 1e-2;
        let exact = ExactKrr::fit(Kernel::Gaussian { bandwidth: 1.0 }, x.clone(), &y, lam);
        let feat =
            GegenbauerFeatures::new(RadialTable::gaussian(3, 12, 4), 2048, 7);
        let z = feat.featurize(&x);
        let approx = FeatureRidge::fit(&z, &y, lam);
        // compare predictions on fresh points
        let xt = Mat::from_fn(20, 3, |_, _| rng.normal() * 0.6);
        let zt = feat.featurize(&xt);
        let pe = exact.predict(&xt);
        let pa = approx.predict(&zt);
        let diff = mse(&pa, &pe);
        assert!(diff < 5e-3, "{diff}");
    }

    #[test]
    fn cv_picks_reasonable_lambda() {
        let mut rng = Rng::new(134);
        let z = Mat::from_fn(100, 8, |_, _| rng.normal());
        let w_star: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let y: Vec<f64> = z.matvec(&w_star).iter().map(|v| v + 0.1 * rng.normal()).collect();
        let lam = cv_lambda(&z, &y, &[1e-6, 1e-3, 1e0, 1e3]);
        assert!(lam <= 1.0, "clean linear data should prefer small lambda, got {lam}");
    }

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(mse(&[1.0, 3.0], &[1.0, 1.0]), 2.0);
    }

    #[test]
    fn gp_mean_matches_ridge_and_variance_behaves() {
        let mut rng = Rng::new(135);
        let z = Mat::from_fn(60, 6, |_, _| rng.normal());
        let y: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let mut stats = RidgeStats::new(6);
        stats.absorb(&z, &y);
        let lam = 0.5;
        let gp = FeatureGp::fit(&stats, lam, 1.0);
        let ridge = stats.solve(lam);
        // mean == ridge prediction
        let (m0, v0) = gp.predict_row(z.row(0));
        assert!((m0 - ridge.predict_row(z.row(0))).abs() < 1e-10);
        assert!(v0 > 0.0);
        // variance shrinks with more data: refit with twice the rows
        let mut stats2 = stats.clone();
        stats2.absorb(&z, &y);
        let gp2 = FeatureGp::fit(&stats2, lam, 1.0);
        let (_, v2) = gp2.predict_row(z.row(0));
        assert!(v2 < v0, "{v2} !< {v0}");
        // variance is larger far from the data than on it
        let far = vec![50.0; 6];
        let (_, v_far) = gp.predict_row(&far);
        assert!(v_far > v0);
    }
}
