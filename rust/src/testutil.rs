//! Mini property-testing runner (proptest is not in the offline registry).
//!
//! `for_random_cases` draws `n` seeded cases from a generator and runs the
//! property; on failure it reports the seed so the case is reproducible.

use crate::rng::Rng;

/// Run `prop` on `n` random cases produced by `gen` from forked seeds.
/// Panics with the offending seed on the first failure.
pub fn for_random_cases<T>(
    base_seed: u64,
    n: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..n {
        let mut rng = Rng::new(base_seed).fork(case as u64);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!("property failed on case {case} (base_seed {base_seed}): {msg}");
        }
    }
}

/// Assert two slices are elementwise close.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        for_random_cases(1, 25, |rng| rng.uniform(), |&u| {
            if (0.0..1.0).contains(&u) {
                Ok(())
            } else {
                Err(format!("{u} out of range"))
            }
        });
        count += 25;
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        for_random_cases(2, 10, |rng| rng.uniform(), |&u| {
            if u < 0.5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn close_helper() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, "ok");
    }
}
