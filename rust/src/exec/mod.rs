//! The crate-wide parallel execution engine: one thread policy, one set of
//! scatter primitives, shared by every layer (DESIGN.md §"Execution
//! model").
//!
//! The paper's pitch is *scalable* kernel methods — featurization and the
//! `Z^T Z` reduction dominate end-to-end time — so parallelism is a
//! whole-system property, not a per-call-site hack. This module owns it:
//!
//! * [`Pool`] — the worker-pool handle. [`Pool::global`] is sized from the
//!   machine (`std::thread::available_parallelism`), overridable once per
//!   process via [`Pool::set_global_threads`] (the CLI's global
//!   `--threads N` flag) or the `GZK_THREADS` environment variable (how
//!   the CI matrix pins the test suite to 1 and 4 threads). Explicit pools
//!   ([`Pool::new`]) are for tests and benches that need a fixed width.
//! * [`Pool::par_chunks`] / [`Pool::scatter_rows`] — row-range scatter over
//!   a flat row-major buffer: each worker owns a disjoint block of whole
//!   rows, so no locks, no false-sharing hot spots, and — because every
//!   output cell is produced by exactly one worker running the exact
//!   serial inner loop — results are **bit-identical for every thread
//!   count**. That determinism is what lets `absorb`, `kmeans`, `kpca`
//!   and the featurizers adopt the pool without perturbing a single test.
//! * [`Pool::run_jobs`] — a bounded job queue for coarse tasks (the
//!   coordinator's worker-loop wave): at most `threads` jobs in flight,
//!   the calling thread participates, returns when the queue drains.
//!
//! Blocking discipline: pool workers must never block on channels or
//! I/O — they run compute to completion and exit the scoped region.
//! Long-lived *control* threads (the streaming consumer, the serving
//! batcher's service loop) stay dedicated `std::thread` spawns and draw
//! their **compute** from the pool instead of spawning their own helpers.
//!
//! Workers are scoped to each parallel region (`std::thread::scope`), so
//! borrowed inputs flow in without `'static` bounds or unsafe lifetime
//! erasure; the pool owns the *policy* — sizing, splitting, reduction
//! order — rather than long-lived OS threads. Spawn cost (~tens of µs) is
//! noise against the O(n·F) and O(n·F²) regions it amortizes, and
//! [`Pool::for_rows`] keeps it off the latency path for tiny batches.

use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};

/// A worker-pool handle: how many threads a parallel region may use.
/// Cheap to copy; every parallel kernel takes `&Pool`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

/// Process-wide thread budget, set at most once (first of: CLI
/// `--threads`, `GZK_THREADS`, `available_parallelism`).
static GLOBAL_THREADS: OnceLock<usize> = OnceLock::new();

fn default_threads() -> usize {
    if let Ok(v) = std::env::var("GZK_THREADS") {
        match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => return n,
            // a mistyped pin (GZK_THREADS=0, garbage, empty) must not
            // silently run at machine width — that would fake out e.g.
            // the CI matrix leg that pins the suite serial
            _ => crate::obs::warn(
                "exec",
                &format!("GZK_THREADS={v:?} is not a positive integer; using all cores"),
                &[(
                    "cores",
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).into(),
                )],
            ),
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Pool {
    /// Rows-per-worker floor used by [`Pool::for_rows`]: below this,
    /// thread-spawn latency is comparable to the work itself.
    pub const MIN_ROWS_PER_WORKER: usize = 16;

    /// An explicit pool of `threads` workers (clamped to >= 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// The single-thread pool: every primitive runs inline on the calling
    /// thread, spawning nothing.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    /// The process-wide pool. Sized from `GZK_THREADS` or the machine's
    /// available parallelism unless [`Pool::set_global_threads`] ran
    /// first.
    pub fn global() -> Pool {
        Pool { threads: *GLOBAL_THREADS.get_or_init(default_threads) }
    }

    /// Fix the global pool width (the CLI's `--threads N`). First caller
    /// wins — the width must be constant for the life of the process so
    /// artifact run metadata and bench telemetry are coherent. Returns
    /// `Err(current)` if the global pool was already sized.
    pub fn set_global_threads(threads: usize) -> Result<(), usize> {
        let threads = threads.max(1);
        GLOBAL_THREADS
            .set(threads)
            .map_err(|_| *GLOBAL_THREADS.get().expect("global pool already sized"))
    }

    /// The global pool clamped so each worker gets at least
    /// [`MIN_ROWS_PER_WORKER`](Pool::MIN_ROWS_PER_WORKER) rows — the
    /// latency-path policy (serving batches of a few rows stay inline,
    /// bulk batches fan out). Never changes results, only thread count.
    pub fn for_rows(rows: usize) -> Pool {
        let cap = (rows / Self::MIN_ROWS_PER_WORKER).max(1);
        Pool::new(Self::global().threads.min(cap))
    }

    /// Worker count of this pool (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Scatter the rows of a flat row-major buffer across the pool in
    /// contiguous, evenly-sized blocks and run `body(lo, hi, block)` on
    /// each, where `block` is the `[lo, hi)` row range of `data`. Blocks
    /// are disjoint, every row is covered exactly once, and a pool of one
    /// thread (or a single block) runs inline on the calling thread.
    ///
    /// `data.len()` must be a whole number of `rows` rows; the row width
    /// is derived as `data.len() / rows`.
    pub fn par_chunks<T, F>(&self, rows: usize, data: &mut [T], body: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        if rows == 0 {
            assert!(data.is_empty(), "par_chunks: rows = 0 with a non-empty buffer");
            return;
        }
        let workers = self.threads.min(rows);
        let chunk = rows.div_ceil(workers);
        let bounds: Vec<usize> = (0..=workers).map(|t| (t * chunk).min(rows)).collect();
        self.scatter_rows(&bounds, data, body);
    }

    /// [`par_chunks`](Pool::par_chunks) with explicit row boundaries:
    /// `bounds` is a non-decreasing sequence `[0, b1, .., rows]`; chunk
    /// `i` covers rows `bounds[i] .. bounds[i+1]`. One worker runs per
    /// non-empty chunk, and the chunk count must not exceed the pool
    /// width (asserted): callers derive `bounds` from
    /// [`threads`](Pool::threads), so a serial pool really does run
    /// inline — handing a serial pool a multi-chunk partition is a bug,
    /// not a request for threads.
    pub fn scatter_rows<T, F>(&self, bounds: &[usize], data: &mut [T], body: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        assert!(
            bounds.first() == Some(&0) && bounds.windows(2).all(|w| w[0] <= w[1]),
            "scatter_rows: bounds must be non-decreasing and start at 0"
        );
        let rows = *bounds.last().expect("scatter_rows: bounds are non-empty");
        if rows == 0 {
            return;
        }
        assert_eq!(data.len() % rows, 0, "scatter_rows: buffer is not a whole number of rows");
        let cols = data.len() / rows;
        // carve the buffer into one disjoint slice per chunk
        let mut slices: Vec<&mut [T]> = Vec::with_capacity(bounds.len() - 1);
        let mut rest: &mut [T] = data;
        for w in bounds.windows(2) {
            let (head, tail) = rest.split_at_mut((w[1] - w[0]) * cols);
            slices.push(head);
            rest = tail;
        }
        let mut work: Vec<(usize, usize, &mut [T])> = bounds
            .windows(2)
            .zip(slices)
            .filter(|(w, _)| w[0] < w[1])
            .map(|(w, s)| (w[0], w[1], s))
            .collect();
        assert!(
            work.len() <= self.threads,
            "scatter_rows: {} chunks exceed the pool width {}",
            work.len(),
            self.threads
        );
        if work.len() <= 1 {
            if let Some((lo, hi, block)) = work.pop() {
                body(lo, hi, block);
            }
            return;
        }
        let (last_lo, last_hi, last_block) = work.pop().expect("at least two chunks");
        std::thread::scope(|scope| {
            for (lo, hi, block) in work {
                let body = &body;
                scope.spawn(move || body(lo, hi, block));
            }
            // the calling thread takes the final chunk instead of idling
            body(last_lo, last_hi, last_block);
        });
    }

    /// Run a wave of coarse jobs to completion, at most `threads` in
    /// flight: the calling thread and up to `threads - 1` scoped workers
    /// pull from one queue until it drains. Used by the coordinator for
    /// its worker loops — jobs may own channels and run for the whole
    /// wave, which the row-scatter primitives must never do.
    pub fn run_jobs(&self, jobs: Vec<Box<dyn FnOnce() + Send + '_>>) {
        // one span + one counter bump per *wave*, not per job — waves are
        // coarse by contract, so this stays off the hot path
        let _span = crate::obs::span("exec", "jobs");
        crate::obs::counter("exec.jobs").add(jobs.len() as u64);
        if self.threads <= 1 || jobs.len() <= 1 {
            for job in jobs {
                job();
            }
            return;
        }
        let workers = self.threads.min(jobs.len());
        let queue = Mutex::new(VecDeque::from(jobs));
        let next = || queue.lock().expect("job queue poisoned").pop_front();
        std::thread::scope(|scope| {
            for _ in 1..workers {
                scope.spawn(|| {
                    while let Some(job) = next() {
                        job();
                    }
                });
            }
            while let Some(job) = next() {
                job();
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_sizing() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::new(7).threads(), 7);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::global().threads() >= 1);
        // the latency clamp: tiny batches stay serial, bulk batches fan out
        assert_eq!(Pool::for_rows(0).threads(), 1);
        assert_eq!(Pool::for_rows(Pool::MIN_ROWS_PER_WORKER - 1).threads(), 1);
        assert!(Pool::for_rows(1 << 20).threads() <= Pool::global().threads());
    }

    #[test]
    fn par_chunks_covers_every_row_exactly_once() {
        for threads in [1usize, 2, 3, 8, 64] {
            let pool = Pool::new(threads);
            let rows = 23;
            let cols = 4;
            let mut data = vec![-1.0f64; rows * cols];
            pool.par_chunks(rows, &mut data, |lo, hi, block| {
                assert_eq!(block.len(), (hi - lo) * cols);
                for (r, row) in block.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v = (lo + r) as f64;
                    }
                }
            });
            for (i, row) in data.chunks(cols).enumerate() {
                assert!(
                    row.iter().all(|&v| v == i as f64),
                    "threads {threads}: row {i} written wrongly: {row:?}"
                );
            }
        }
    }

    #[test]
    fn par_chunks_handles_degenerate_shapes() {
        let pool = Pool::new(4);
        // zero rows
        let mut empty: Vec<f64> = Vec::new();
        pool.par_chunks(0, &mut empty, |_, _, _| panic!("no chunks expected"));
        // fewer rows than threads: every row still covered once
        let mut data = vec![0usize; 3];
        pool.par_chunks(3, &mut data, |lo, hi, block| {
            for (r, v) in block.iter_mut().enumerate() {
                *v = lo + r + 1;
            }
            assert!(hi <= 3);
        });
        assert_eq!(data, vec![1, 2, 3]);
        // zero-width rows
        let mut thin: Vec<f64> = Vec::new();
        pool.par_chunks(5, &mut thin, |lo, hi, block| {
            assert!(block.is_empty() && lo < hi);
        });
    }

    #[test]
    fn scatter_rows_honors_explicit_bounds() {
        let pool = Pool::new(4);
        let mut data = vec![0usize; 10];
        // uneven chunks, one of them empty
        pool.scatter_rows(&[0, 1, 1, 7, 10], &mut data, |lo, hi, block| {
            assert_eq!(block.len(), hi - lo);
            for v in block.iter_mut() {
                *v = lo * 100 + hi;
            }
        });
        assert_eq!(data[0], 1);
        assert!(data[1..7].iter().all(|&v| v == 107), "{data:?}");
        assert!(data[7..].iter().all(|&v| v == 710), "{data:?}");
    }

    #[test]
    fn run_jobs_runs_every_job_at_any_width() {
        for threads in [1usize, 2, 3, 16] {
            let hits = AtomicUsize::new(0);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..17)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            Pool::new(threads).run_jobs(jobs);
            assert_eq!(hits.load(Ordering::SeqCst), 17, "threads {threads}");
        }
    }

    #[test]
    fn run_jobs_empty_wave_is_a_noop() {
        Pool::new(4).run_jobs(Vec::new());
    }
}
