//! Spectral-approximation validators — the certificates behind Theorems 9,
//! 10, 11, 12 and the Table-1 bound formulas.

mod bounds;
mod leverage;

pub use bounds::{table1_bounds, BoundRow};
pub use leverage::{lemma7_bound, leverage_score, phi_w, theorem9_feature_count};

use crate::linalg::{sym_eigen, Cholesky, Mat};

/// The smallest epsilon such that Z^T Z + lambda I is an (eps, lambda)
/// spectral approximation of K + lambda I (paper Eq. 1):
///
///   (K + lI)/(1+e) <= Z^T Z + lI <= (K + lI)/(1-e)
///
/// Computed from the generalized eigenvalues mu of
/// (K+lI)^{-1/2} (Z^T Z + lI) (K+lI)^{-1/2}: eps = max(1/mu_min - 1,
/// 1 - 1/mu_max). Returns +inf when the approximation fails entirely.
pub fn spectral_epsilon(k: &Mat, zt_z: &Mat, lambda: f64) -> f64 {
    let n = k.rows();
    assert_eq!(zt_z.rows(), n);
    let mut k_reg = k.clone();
    k_reg.add_diag(lambda);
    let (chol, _) = Cholesky::new_with_jitter(&k_reg, 1e-12);
    let mut z_reg = zt_z.clone();
    z_reg.add_diag(lambda);
    // M = L^{-1} (Z^T Z + l I) L^{-T}
    let li_z = chol.whiten(&z_reg); // L^{-1} A
    let m = chol.whiten(&li_z.transpose()); // L^{-1} A^T L^{-T} = (L^{-1} A L^{-T})^T; symmetric
    let mut msym = m.clone();
    // enforce symmetry against roundoff
    for i in 0..n {
        for j in 0..i {
            let v = 0.5 * (msym[(i, j)] + msym[(j, i)]);
            msym[(i, j)] = v;
            msym[(j, i)] = v;
        }
    }
    let (mu, _) = sym_eigen(&msym);
    let mu_max = mu[0];
    let mu_min = mu[n - 1];
    if mu_min <= 0.0 {
        return f64::INFINITY;
    }
    let eps_low = 1.0 / mu_min - 1.0; // from lower PSD bound
    let eps_high = 1.0 - 1.0 / mu_max; // from upper PSD bound
    eps_low.max(eps_high).max(0.0)
}

/// Statistical dimension s_lambda = Tr(K (K + lambda I)^{-1}).
pub fn statistical_dimension(k: &Mat, lambda: f64) -> f64 {
    let (evals, _) = sym_eigen(k);
    evals.iter().map(|&l| (l.max(0.0)) / (l.max(0.0) + lambda)).sum()
}

/// Projection-cost preservation check (Theorem 10): for the rank-r
/// eigenprojector P of K, compare Tr(K - P K P) against
/// Tr(Z^T Z - P Z^T Z P). Returns (exact_cost, approx_cost, rel_err).
pub fn projection_cost_check(k: &Mat, zt_z: &Mat, r: usize) -> (f64, f64, f64) {
    let n = k.rows();
    let (evals, vecs) = sym_eigen(k);
    // P = V_r V_r^T
    let mut vr = Mat::zeros(n, r);
    for j in 0..r {
        for i in 0..n {
            vr[(i, j)] = vecs[(i, j)];
        }
    }
    // Tr(K - P K P) = Tr(K) - Tr(V_r^T K V_r) = sum_{i>r} lambda_i
    let exact: f64 = evals.iter().skip(r).sum();
    // Tr(Z^T Z) - Tr(V_r^T Z^T Z V_r)
    let tr_z: f64 = (0..n).map(|i| zt_z[(i, i)]).sum();
    let zv = zt_z.matmul(&vr);
    let vzv = vr.matmul_tn(&zv);
    let tr_pz: f64 = (0..r).map(|i| vzv[(i, i)]).sum();
    let approx = tr_z - tr_pz;
    let rel = (approx - exact).abs() / exact.abs().max(1e-12);
    (exact, approx, rel)
}

/// Empirical-risk bound ingredients for approximate KRR (Lemma 13):
/// risk(f~) <= risk(f)/(1-eps) + eps/(1+eps) * rank(Z)/n * sigma^2.
pub fn krr_risk_bound(base_risk: f64, eps: f64, rank_z: usize, n: usize, sigma2: f64) -> f64 {
    base_risk / (1.0 - eps) + eps / (1.0 + eps) * rank_z as f64 / n as f64 * sigma2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{Featurizer, GegenbauerFeatures, RadialTable};
    use crate::kernels::Kernel;
    use crate::rng::Rng;

    #[test]
    fn epsilon_zero_for_exact() {
        let mut rng = Rng::new(150);
        let x = Mat::from_fn(16, 3, |_, _| rng.normal() * 0.6);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let eps = spectral_epsilon(&k, &k, 0.1);
        assert!(eps < 1e-8, "{eps}");
    }

    #[test]
    fn epsilon_detects_scaling() {
        // Z^T Z = c K with c = 1.25 -> eps must reflect ~25% deviation on
        // the top of the spectrum
        let mut rng = Rng::new(151);
        let x = Mat::from_fn(12, 3, |_, _| rng.normal() * 0.6);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let mut k2 = k.clone();
        k2.scale(1.25);
        let eps = spectral_epsilon(&k, &k2, 1e-6);
        assert!(eps > 0.15 && eps < 0.35, "{eps}");
    }

    #[test]
    fn epsilon_decreases_with_more_features() {
        let mut rng = Rng::new(152);
        let x = Mat::from_fn(24, 3, |_, _| rng.normal() * 0.5);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let table = RadialTable::gaussian(3, 12, 4);
        let lambda = 0.1;
        let mut prev = f64::INFINITY;
        for (m, seed) in [(64usize, 1u64), (512, 2), (4096, 3)] {
            let feat = GegenbauerFeatures::new(table.clone(), m, seed);
            let z = feat.featurize(&x);
            let eps = spectral_epsilon(&k, &z.matmul_nt(&z), lambda);
            assert!(eps < prev * 1.5, "m={m}: eps={eps}, prev={prev}");
            prev = eps;
        }
        assert!(prev < 0.3, "final eps {prev}");
    }

    #[test]
    fn stat_dim_limits() {
        let mut rng = Rng::new(153);
        let x = Mat::from_fn(10, 3, |_, _| rng.normal());
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        // lambda -> 0: s_lambda -> rank ~ n; lambda -> inf: -> 0
        let s_small = statistical_dimension(&k, 1e-12);
        let s_big = statistical_dimension(&k, 1e12);
        assert!(s_small > 9.0, "{s_small}");
        assert!(s_big < 1e-6, "{s_big}");
        // monotone in lambda
        let s1 = statistical_dimension(&k, 0.01);
        let s2 = statistical_dimension(&k, 0.1);
        assert!(s1 > s2);
    }

    #[test]
    fn projection_cost_exact_for_k_itself() {
        let mut rng = Rng::new(154);
        let x = Mat::from_fn(14, 3, |_, _| rng.normal() * 0.7);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let (e, a, rel) = projection_cost_check(&k, &k, 3);
        assert!(rel < 1e-8, "exact={e} approx={a} rel={rel}");
    }

    #[test]
    fn projection_cost_preserved_by_features() {
        let mut rng = Rng::new(155);
        let x = Mat::from_fn(24, 3, |_, _| rng.normal() * 0.5);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let feat = GegenbauerFeatures::new(RadialTable::gaussian(3, 12, 4), 4096, 5);
        let z = feat.featurize(&x);
        let (_, _, rel) = projection_cost_check(&k, &z.matmul_nt(&z), 4);
        assert!(rel < 0.25, "{rel}");
    }

    #[test]
    fn risk_bound_degenerates_correctly() {
        // eps = 0 -> bound equals base risk
        assert!((krr_risk_bound(0.5, 0.0, 100, 1000, 1.0) - 0.5).abs() < 1e-12);
        // larger eps -> larger bound
        assert!(krr_risk_bound(0.5, 0.5, 100, 1000, 1.0) > 0.5);
    }
}
