//! Table 1 of the paper: feature-dimension bounds of each Gaussian-kernel
//! approximation method for an (eps, lambda)-spectral guarantee, evaluated
//! as formulas (log-domain to survive the exponents).

use crate::special::lgamma;

/// One row of the reproduced Table 1.
#[derive(Clone, Debug)]
pub struct BoundRow {
    pub method: &'static str,
    /// log10 of the feature-dimension bound
    pub log10_features: f64,
}

fn log_binomf(n: f64, k: f64) -> f64 {
    lgamma(n + 1.0) - lgamma(k + 1.0) - lgamma(n - k + 1.0)
}

/// Evaluate every method's feature-dimension bound (Table 1, constants as
/// printed in the paper; log n factors dropped exactly as the paper does).
pub fn table1_bounds(n: f64, lambda: f64, r: f64, d: f64, s_lambda: f64) -> Vec<BoundRow> {
    let ln10 = std::f64::consts::LN_10;
    let nl = (n / lambda).ln(); // log(n/lambda)

    // Fourier [RR09]: n / lambda
    let fourier = (n / lambda).ln() / ln10;

    // Modified Fourier [AKM+17]:
    // (248 r)^d (log n/l)^{d/2} + (200 log n/l)^{2d}, over Gamma(d/2+1)
    let t1 = d * (248.0 * r).ln() + 0.5 * d * nl.max(1.0).ln();
    let t2 = 2.0 * d * (200.0 * nl.max(1.0)).ln();
    let mf = (log_add(t1, t2) - lgamma(d / 2.0 + 1.0)) / ln10;

    // Nystrom [MM17]: s_lambda
    let nystrom = s_lambda.ln() / ln10;

    // PolySketch [AKK+20]: r^10 s_lambda
    let poly = (10.0 * r.ln() + s_lambda.ln()) / ln10;

    // Adaptive sketch [WZ20]: s_lambda
    let adaptive = s_lambda.ln() / ln10;

    // Gegenbauer (this work): ((2 log n/l)^d + (1.93 r)^{2d}) / (d-1)!
    let g1 = d * (2.0 * nl.max(1.0)).ln();
    let g2 = 2.0 * d * (1.93 * r).ln();
    let geg = (log_add(g1, g2) - lgamma(d)) / ln10;

    // Theorem-12 exact bound: m = (5 q^2 / 4 eps^2) C(q+d-1, q) log(16 s_l/delta)
    let eps = 0.5;
    let delta = 0.1;
    let q = (3.7 * r * r)
        .max(d / 2.0 * (2.8 * (r * r + nl.max(1.0) + d) / d).ln() + nl.max(1.0))
        .max(2.0);
    let thm12 = ((5.0 * q * q / (4.0 * eps * eps)).ln()
        + log_binomf(q + d - 1.0, q)
        + (16.0 * s_lambda / delta).ln().max(1.0).ln())
        / ln10;

    vec![
        BoundRow { method: "fourier", log10_features: fourier },
        BoundRow { method: "modified-fourier", log10_features: mf },
        BoundRow { method: "nystrom", log10_features: nystrom },
        BoundRow { method: "polysketch", log10_features: poly },
        BoundRow { method: "adaptive-sketch", log10_features: adaptive },
        BoundRow { method: "gegenbauer", log10_features: geg },
        BoundRow { method: "gegenbauer-thm12", log10_features: thm12 },
    ]
}

fn log_add(a: f64, b: f64) -> f64 {
    let (hi, lo) = if a > b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(rows: &[BoundRow], m: &str) -> f64 {
        rows.iter().find(|r| r.method == m).unwrap().log10_features
    }

    #[test]
    fn gegenbauer_beats_fourier_in_low_dim() {
        // the paper's headline: for d = o(log n/lambda), Gegenbauer's bound
        // is sub-polynomial in n/lambda while Fourier is linear
        let rows = table1_bounds(1e6, 1e-6, 1.0, 3.0, 1e3);
        assert!(get(&rows, "gegenbauer") < get(&rows, "fourier"));
        assert!(get(&rows, "gegenbauer") < get(&rows, "modified-fourier"));
    }

    #[test]
    fn gegenbauer_beats_polysketch_at_large_radius() {
        // r^10 hurts PolySketch at moderate radius, small d
        let rows = table1_bounds(1e5, 1e-3, 6.0, 3.0, 1e2);
        assert!(get(&rows, "gegenbauer-thm12") < get(&rows, "polysketch") + 10.0);
        assert!(get(&rows, "polysketch") > get(&rows, "nystrom"));
    }

    #[test]
    fn gegenbauer_degrades_in_high_dim() {
        // the paper's own caveat (and Tables 2/3): the bound explodes with d
        let low = get(&table1_bounds(1e5, 1e-3, 1.0, 3.0, 1e2), "gegenbauer");
        let high = get(&table1_bounds(1e5, 1e-3, 1.0, 40.0, 1e2), "gegenbauer");
        assert!(high > low);
    }

    #[test]
    fn all_rows_finite() {
        for rows in [
            table1_bounds(1e4, 1e-2, 0.5, 2.0, 10.0),
            table1_bounds(1e8, 1e-8, 10.0, 64.0, 1e5),
        ] {
            for r in rows {
                assert!(r.log10_features.is_finite(), "{}", r.method);
            }
        }
    }
}
