//! Ridge leverage scores of the GZK feature operator (paper §4) and the
//! Lemma-7 uniform upper bound — the quantity that drives every sample-
//! complexity theorem in the paper.
//!
//! For a direction w on S^{d-1}, Definition 6 gives
//!
//!   tau_lambda(w) = Tr( Phi_w^T (K + lambda I)^{-1} Phi_w ),
//!
//! where Phi_w in R^{n x s} stacks phi_{x_j}(w) and K is the (truncated)
//! GZK Gram matrix. Lemma 7 bounds it uniformly by
//!
//!   sum_l alpha_{l,d} * min( pi^2 (l+1)^2 / (6 lambda) * sum_j ||h_l(|x_j|)||^2 , s ),
//!
//! and Eq. (18) says E_w[tau_lambda(w)] equals the statistical dimension.

use crate::features::RadialTable;
use crate::linalg::{Cholesky, Mat};
use crate::special::{alpha_dim, gegenbauer_all};

/// Phi_w in R^{n x s}: the w-th "row" of the feature operator (Eq. 16).
/// Unlike Def. 8's Z this carries NO 1/sqrt(m) scaling.
pub fn phi_w(table: &RadialTable, x: &Mat, w: &[f64]) -> Mat {
    let n = x.rows();
    let (q, s) = (table.q, table.s);
    let mut out = Mat::zeros(n, s);
    for j in 0..n {
        let xr = x.row(j);
        let norm = xr.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        let cos =
            (xr.iter().zip(w).map(|(&a, &b)| a * b).sum::<f64>() / norm).clamp(-1.0, 1.0);
        let r = table.values(&[norm]);
        let p = gegenbauer_all(q, table.d, &[cos]);
        for i in 0..s {
            let mut acc = 0.0;
            for l in 0..=q {
                acc += r[l * s + i] * p[l];
            }
            out[(j, i)] = acc;
        }
    }
    out
}

/// Exact ridge leverage score tau_lambda(w) (Definition 6), computed
/// against the truncated-GZK Gram matrix.
pub fn leverage_score(table: &RadialTable, x: &Mat, w: &[f64], lambda: f64) -> f64 {
    let mut k = table.gzk_gram(x);
    k.add_diag(lambda);
    let (chol, _) = Cholesky::new_with_jitter(&k, 1e-12);
    let phi = phi_w(table, x, w);
    // Tr(Phi^T (K+lI)^{-1} Phi) = sum_i phi_i^T solve(phi_i)
    let mut tau = 0.0;
    let mut col = vec![0.0; x.rows()];
    for i in 0..table.s {
        for j in 0..x.rows() {
            col[j] = phi[(j, i)];
        }
        let sol = chol.solve(&col);
        tau += col.iter().zip(&sol).map(|(&a, &b)| a * b).sum::<f64>();
    }
    tau
}

/// The Lemma-7 uniform upper bound on tau_lambda(w).
pub fn lemma7_bound(table: &RadialTable, x: &Mat, lambda: f64) -> f64 {
    let n = x.rows();
    let s = table.s as f64;
    // sum_j ||h_l(|x_j|)||^2 per degree l
    let mut energy = vec![0.0; table.q + 1];
    for j in 0..n {
        let norm = x.row(j).iter().map(|v| v * v).sum::<f64>().sqrt();
        for (l, e) in table.degree_energy(norm).into_iter().enumerate() {
            energy[l] += e;
        }
    }
    let pi2_6 = std::f64::consts::PI * std::f64::consts::PI / 6.0;
    (0..=table.q)
        .map(|l| {
            let variance_term = pi2_6 * ((l + 1) * (l + 1)) as f64 / lambda * energy[l];
            alpha_dim(l, table.d) * variance_term.min(s)
        })
        .sum()
}

/// Theorem-9 feature-count bound m >= (8 / 3 eps^2) log(16 s_lambda / delta) * Lemma7.
pub fn theorem9_feature_count(
    table: &RadialTable,
    x: &Mat,
    lambda: f64,
    eps: f64,
    delta: f64,
    s_lambda: f64,
) -> f64 {
    8.0 / (3.0 * eps * eps) * (16.0 * s_lambda / delta).ln().max(1.0) * lemma7_bound(table, x, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::spectral::statistical_dimension;

    fn setup(n: usize, d: usize, scale: f64) -> (RadialTable, Mat, Rng) {
        let mut rng = Rng::new(170);
        let x = Mat::from_fn(n, d, |_, _| rng.normal() * scale);
        (RadialTable::gaussian(d, 10, 3), x, rng)
    }

    #[test]
    fn gzk_gram_matches_gaussian_at_high_truncation() {
        let mut rng = Rng::new(171);
        let x = Mat::from_fn(8, 3, |_, _| rng.normal() * 0.5);
        let table = RadialTable::gaussian(3, 18, 9);
        let kg = table.gzk_gram(&x);
        let ke = crate::kernels::Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        assert!(kg.max_abs_diff(&ke) < 1e-6, "{}", kg.max_abs_diff(&ke));
    }

    #[test]
    fn leverage_bounded_by_lemma7() {
        let (table, x, mut rng) = setup(20, 3, 0.6);
        let lambda = 0.1;
        let bound = lemma7_bound(&table, &x, lambda);
        let mut w = vec![0.0; 3];
        for _ in 0..25 {
            rng.sphere(&mut w);
            let tau = leverage_score(&table, &x, &w, lambda);
            assert!(tau <= bound * (1.0 + 1e-9), "tau {tau} > bound {bound}");
            assert!(tau >= 0.0);
        }
    }

    #[test]
    fn average_leverage_equals_statistical_dimension() {
        // Eq. (18): E_w[tau_lambda(w)] = s_lambda, Monte-Carlo check
        let (table, x, mut rng) = setup(12, 3, 0.5);
        let lambda = 0.2;
        let k = table.gzk_gram(&x);
        let s_lam = statistical_dimension(&k, lambda);
        let mut w = vec![0.0; 3];
        let n_mc = 600;
        let mean: f64 = (0..n_mc)
            .map(|_| {
                rng.sphere(&mut w);
                leverage_score(&table, &x, &w, lambda)
            })
            .sum::<f64>()
            / n_mc as f64;
        assert!(
            (mean - s_lam).abs() < 0.15 * s_lam.max(1.0),
            "E[tau] = {mean} vs s_lambda = {s_lam}"
        );
    }

    #[test]
    fn bound_tightens_with_lambda() {
        let (table, x, _) = setup(16, 3, 0.5);
        let b1 = lemma7_bound(&table, &x, 0.01);
        let b2 = lemma7_bound(&table, &x, 1.0);
        assert!(b2 <= b1);
    }

    #[test]
    fn theorem9_count_scales_with_eps() {
        let (table, x, _) = setup(16, 3, 0.5);
        let k = table.gzk_gram(&x);
        let s_lam = statistical_dimension(&k, 0.1);
        let m_half = theorem9_feature_count(&table, &x, 0.1, 0.5, 0.1, s_lam);
        let m_tenth = theorem9_feature_count(&table, &x, 0.1, 0.1, 0.1, s_lam);
        assert!((m_tenth / m_half - 25.0).abs() < 1e-6, "1/eps^2 scaling");
    }

    #[test]
    fn phi_w_reproduces_kernel_in_expectation() {
        // Lemma 5: E_w[<phi_x(w), phi_y(w)>] = k(x, y)
        let (table, x, mut rng) = setup(6, 3, 0.5);
        let k = table.gzk_gram(&x);
        let n_mc = 4000;
        let mut acc = Mat::zeros(6, 6);
        let mut w = vec![0.0; 3];
        for _ in 0..n_mc {
            rng.sphere(&mut w);
            let phi = phi_w(&table, &x, &w);
            let pp = phi.matmul_nt(&phi);
            acc.add_assign(&pp);
        }
        acc.scale(1.0 / n_mc as f64);
        let err = acc.max_abs_diff(&k);
        assert!(err < 0.05, "{err}");
    }
}
