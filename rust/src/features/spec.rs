//! The spec-driven featurizer registry: one serializable description —
//! `(kernel, method, m, seed)` — constructs *any* featurizer in the crate.
//!
//! The paper's one-round distributed protocol (§5) works because a
//! featurizer is fully determined by a small spec: broadcast the spec, and
//! every holder derives a bit-identical feature map. The Gegenbauer path
//! always had that property; this module extends it to every baseline so
//! experiments, benches, the CLI and the coordinator share one
//! construction API:
//!
//! * [`KernelSpec`] — which kernel is being approximated;
//! * [`Method`] — which approximation constructs the features (the
//!   registry: [`Method::registry`] enumerates every implementation);
//! * [`FeatureSpec`] — kernel + method + feature budget `m` + seed. Builds
//!   a boxed [`Featurizer`] via [`FeatureSpec::build`], reports its feature
//!   dimension without construction, and round-trips through JSON
//!   ([`FeatureSpec::to_json`] / [`FeatureSpec::from_json`]) for wire/CLI
//!   use;
//! * [`BoundSpec`] — a `FeatureSpec` bound to an input dimension `d`: the
//!   complete broadcast message of the coordinator protocol
//!   (re-exported there as `coordinator::FeatureSpec`).
//!
//! Built featurizers consume **raw** inputs for every method: Gaussian
//! bandwidth folding (the GZK convention of scaling inputs by 1/sigma) is
//! wrapped into the returned featurizer, so call sites never special-case
//! the Gegenbauer path.

use super::polysketch::sketch_size;
use super::radial::RadialTable;
use super::{
    FastFoodFeatures, Featurizer, FourierFeatures, GegenbauerFeatures, MaclaurinFeatures,
    NystromFeatures, PolySketchFeatures,
};
use crate::exec::Pool;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::runtime::Json;

/// Serializable kernel selector (mirrors [`Kernel`], which stays the
/// evaluation type; this is the description type).
#[derive(Clone, Debug, PartialEq)]
pub enum KernelSpec {
    /// exp(-||x-y||^2 / (2 sigma^2))
    Gaussian { bandwidth: f64 },
    /// exp(gamma <x,y>)
    Exponential { gamma: f64 },
    /// (<x,y> + c)^p — exact GZK of degree p (q/s are derived from p)
    Polynomial { p: usize, c: f64 },
    /// depth-L ReLU NTK
    Ntk { depth: usize },
}

impl KernelSpec {
    pub fn name(&self) -> &'static str {
        match self {
            KernelSpec::Gaussian { .. } => "gaussian",
            KernelSpec::Exponential { .. } => "exponential",
            KernelSpec::Polynomial { .. } => "polynomial",
            KernelSpec::Ntk { .. } => "ntk",
        }
    }

    /// The exact kernel this spec describes (ground truth / Nystrom input).
    pub fn to_kernel(&self) -> Kernel {
        match *self {
            KernelSpec::Gaussian { bandwidth } => Kernel::Gaussian { bandwidth },
            KernelSpec::Exponential { gamma } => Kernel::Exponential { gamma },
            KernelSpec::Polynomial { p, c } => Kernel::Polynomial { p: p as u32, c },
            KernelSpec::Ntk { depth } => Kernel::Ntk { depth },
        }
    }

    /// Multiplicative input preprocessing implied by the family: the GZK
    /// tables are unit-bandwidth, so Gaussian inputs are scaled by 1/sigma.
    pub fn input_scale(&self) -> f64 {
        match *self {
            KernelSpec::Gaussian { bandwidth } => 1.0 / bandwidth,
            _ => 1.0,
        }
    }

    /// Input preprocessing implied by the family (bandwidth folding).
    pub fn scale_inputs(&self, x: &Mat) -> Mat {
        let mut y = x.clone();
        let sc = self.input_scale();
        if sc != 1.0 {
            y.scale(sc);
        }
        y
    }

    /// Effective Gegenbauer truncation for this kernel: the polynomial
    /// family fixes (q, s) = (p, p/2 + 1) exactly and the NTK tables are
    /// single-channel; other families use the requested knobs.
    pub fn gegenbauer_order(&self, q: usize, s: usize) -> (usize, usize) {
        match *self {
            KernelSpec::Polynomial { p, .. } => (p, p / 2 + 1),
            KernelSpec::Ntk { .. } => (q, 1),
            _ => (q, s),
        }
    }

    /// The radial-factor table of the GZK expansion of this kernel.
    pub fn radial_table(&self, d: usize, q: usize, s: usize) -> RadialTable {
        match *self {
            KernelSpec::Gaussian { .. } => RadialTable::gaussian(d, q, s),
            KernelSpec::Exponential { gamma } => RadialTable::exponential(d, q, s, gamma),
            KernelSpec::Polynomial { p, c } => RadialTable::polynomial(d, p, c),
            KernelSpec::Ntk { depth } => RadialTable::ntk(d, q, depth),
        }
    }

    fn to_json(&self) -> String {
        match *self {
            KernelSpec::Gaussian { bandwidth } => {
                format!(r#"{{"family":"gaussian","bandwidth":{bandwidth:?}}}"#)
            }
            KernelSpec::Exponential { gamma } => {
                format!(r#"{{"family":"exponential","gamma":{gamma:?}}}"#)
            }
            KernelSpec::Polynomial { p, c } => {
                format!(r#"{{"family":"polynomial","p":{p},"c":{c:?}}}"#)
            }
            KernelSpec::Ntk { depth } => format!(r#"{{"family":"ntk","depth":{depth}}}"#),
        }
    }

    fn from_json_value(j: &Json) -> Result<KernelSpec, String> {
        let family = j
            .get("family")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "kernel spec: missing \"family\"".to_string())?;
        match family {
            "gaussian" => Ok(KernelSpec::Gaussian { bandwidth: req_f64(j, "bandwidth")? }),
            "exponential" => Ok(KernelSpec::Exponential { gamma: req_f64(j, "gamma")? }),
            "polynomial" => {
                Ok(KernelSpec::Polynomial { p: req_usize(j, "p")?, c: req_f64(j, "c")? })
            }
            "ntk" => Ok(KernelSpec::Ntk { depth: req_usize(j, "depth")? }),
            other => Err(format!("kernel spec: unknown family {other:?}")),
        }
    }
}

/// Which approximation method constructs the feature map. Tuning knobs that
/// belong to the method (not the kernel or the budget) live here, so a
/// `Method` value is everything the registry needs besides `(m, seed, d)`.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// The paper's random Gegenbauer features (Def. 8); truncation degree
    /// `q`, radial order `s`.
    Gegenbauer { q: usize, s: usize },
    /// Random Fourier features [RR09] (Gaussian kernel only).
    Fourier,
    /// FastFood structured Fourier features [LSS+13] (Gaussian only).
    FastFood,
    /// Random Maclaurin features [KK12] (Gaussian only).
    Maclaurin,
    /// TensorSketch of the Taylor expansion [AKK+20] (Gaussian only).
    PolySketch { degree: usize },
    /// Data-DEPENDENT Nystrom with leverage-score landmarks [MM17]; needs
    /// training rows at build time (any kernel).
    Nystrom { lambda: f64 },
}

impl Method {
    pub const GEGENBAUER: &'static str = "gegenbauer";
    pub const FOURIER: &'static str = "fourier";
    pub const FASTFOOD: &'static str = "fastfood";
    pub const MACLAURIN: &'static str = "maclaurin";
    pub const POLYSKETCH: &'static str = "polysketch";
    pub const NYSTROM: &'static str = "nystrom";

    pub fn name(&self) -> &'static str {
        match self {
            Method::Gegenbauer { .. } => Self::GEGENBAUER,
            Method::Fourier => Self::FOURIER,
            Method::FastFood => Self::FASTFOOD,
            Method::Maclaurin => Self::MACLAURIN,
            Method::PolySketch { .. } => Self::POLYSKETCH,
            Method::Nystrom { .. } => Self::NYSTROM,
        }
    }

    /// Look a method up by registry name, with default tuning knobs.
    pub fn from_name(name: &str) -> Result<Method, String> {
        match name {
            Self::GEGENBAUER => Ok(Method::Gegenbauer { q: 10, s: 2 }),
            Self::FOURIER => Ok(Method::Fourier),
            Self::FASTFOOD => Ok(Method::FastFood),
            Self::MACLAURIN => Ok(Method::Maclaurin),
            Self::POLYSKETCH => Ok(Method::PolySketch { degree: 6 }),
            Self::NYSTROM => Ok(Method::Nystrom { lambda: 1e-3 }),
            other => Err(format!(
                "unknown method {other:?}; registered: {}",
                Self::registry().iter().map(|m| m.name()).collect::<Vec<_>>().join(", ")
            )),
        }
    }

    /// Every registered method, with default tuning. Experiments, benches
    /// and tests iterate this list so a newly registered featurizer is
    /// picked up everywhere without touching call sites.
    pub fn registry() -> Vec<Method> {
        vec![
            Method::Gegenbauer { q: 10, s: 2 },
            Method::Fourier,
            Method::FastFood,
            Method::Nystrom { lambda: 1e-3 },
            Method::PolySketch { degree: 6 },
            Method::Maclaurin,
        ]
    }

    /// Re-parameterize the data-geometry tuning knobs (Gegenbauer's q/s),
    /// keeping the method identity — used when sweeping the registry with
    /// per-dataset truncation choices.
    pub fn tuned(self, q: usize, s: usize) -> Method {
        match self {
            Method::Gegenbauer { .. } => Method::Gegenbauer { q, s },
            other => other,
        }
    }

    /// Data-oblivious methods can be built from the spec alone (and hence
    /// broadcast by the coordinator); data-dependent ones need rows.
    pub fn is_oblivious(&self) -> bool {
        !matches!(self, Method::Nystrom { .. })
    }

    fn to_json(&self) -> String {
        match *self {
            Method::Gegenbauer { q, s } => {
                format!(r#"{{"name":"gegenbauer","q":{q},"s":{s}}}"#)
            }
            Method::Fourier => r#"{"name":"fourier"}"#.to_string(),
            Method::FastFood => r#"{"name":"fastfood"}"#.to_string(),
            Method::Maclaurin => r#"{"name":"maclaurin"}"#.to_string(),
            Method::PolySketch { degree } => {
                format!(r#"{{"name":"polysketch","degree":{degree}}}"#)
            }
            Method::Nystrom { lambda } => format!(r#"{{"name":"nystrom","lambda":{lambda:?}}}"#),
        }
    }

    fn from_json_value(j: &Json) -> Result<Method, String> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "method spec: missing \"name\"".to_string())?;
        match name {
            Self::GEGENBAUER => {
                Ok(Method::Gegenbauer { q: req_usize(j, "q")?, s: req_usize(j, "s")? })
            }
            Self::POLYSKETCH => Ok(Method::PolySketch { degree: req_usize(j, "degree")? }),
            Self::NYSTROM => Ok(Method::Nystrom { lambda: req_f64(j, "lambda")? }),
            other => Method::from_name(other),
        }
    }
}

/// Everything needed to reconstruct a feature map anywhere: the value type
/// of the registry. `m` is the **feature budget** — the target output
/// dimension. The Gegenbauer method spends it as `m / s` directions of `s`
/// radial channels each; every other method emits `~m` features directly
/// (see [`FeatureSpec::feature_dim`] for the exact count).
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureSpec {
    pub kernel: KernelSpec,
    pub method: Method,
    /// feature budget (target output dimension)
    pub m: usize,
    pub seed: u64,
}

impl FeatureSpec {
    pub fn new(kernel: KernelSpec, method: Method, m: usize, seed: u64) -> FeatureSpec {
        FeatureSpec { kernel, method, m, seed }
    }

    /// Bind to an input dimension, producing the coordinator's wire form.
    pub fn bind(self, d: usize) -> BoundSpec {
        BoundSpec { spec: self, d }
    }

    /// Exact output dimension of [`build`](FeatureSpec::build), derived
    /// without constructing the featurizer. (For the data-dependent Nystrom
    /// method this is the nominal landmark count; a fit on fewer than `m`
    /// training rows caps it at the row count.)
    pub fn feature_dim(&self) -> usize {
        match self.method {
            Method::Gegenbauer { q, s } => {
                let (_, s) = self.kernel.gegenbauer_order(q, s);
                (self.m / s).max(1) * s
            }
            Method::Fourier | Method::FastFood | Method::Maclaurin => self.m,
            Method::PolySketch { degree } => 1 + degree * sketch_size(self.m, degree),
            Method::Nystrom { .. } => self.m,
        }
    }

    /// The single construction registry: every featurizer in the crate is
    /// built here and nowhere else. `x_train` is consulted only by
    /// data-dependent methods (Nystrom); oblivious methods ignore it.
    pub fn try_build(
        &self,
        d: usize,
        x_train: Option<&Mat>,
    ) -> Result<Box<dyn Featurizer>, String> {
        match self.method {
            Method::Gegenbauer { .. } => {
                let feat = self.build_gegenbauer(d).expect("method is gegenbauer");
                let scale = self.kernel.input_scale();
                if scale != 1.0 {
                    Ok(Box::new(InputScaled { inner: feat, scale }))
                } else {
                    Ok(Box::new(feat))
                }
            }
            Method::Fourier => {
                let bw = self.gaussian_bandwidth()?;
                Ok(Box::new(FourierFeatures::new(d, self.m, bw, self.seed)))
            }
            Method::FastFood => {
                let bw = self.gaussian_bandwidth()?;
                Ok(Box::new(FastFoodFeatures::new(d, self.m, bw, self.seed)))
            }
            Method::Maclaurin => {
                let bw = self.gaussian_bandwidth()?;
                Ok(Box::new(MaclaurinFeatures::new_gaussian(d, self.m, bw, self.seed)))
            }
            Method::PolySketch { degree } => {
                let bw = self.gaussian_bandwidth()?;
                Ok(Box::new(PolySketchFeatures::new(d, self.m, degree, bw, self.seed)))
            }
            Method::Nystrom { .. } => {
                let x = x_train.ok_or_else(|| {
                    "nystrom is data-dependent: pass training rows (build_with_data)".to_string()
                })?;
                Ok(Box::new(self.build_nystrom(d, x)?))
            }
        }
    }

    /// Build a data-oblivious featurizer for inputs of dimension `d`.
    /// Every holder of the same spec builds a bit-identical map — the
    /// broadcast property the one-round protocol relies on. Panics for
    /// data-dependent methods and unsupported kernel/method pairs; use
    /// [`try_build`](FeatureSpec::try_build) to handle those gracefully.
    pub fn build(&self, d: usize) -> Box<dyn Featurizer> {
        self.try_build(d, None).unwrap_or_else(|e| panic!("FeatureSpec::build: {e}"))
    }

    /// Build any featurizer, fitting data-dependent methods on `x_train`
    /// (`d` is taken from the training rows).
    pub fn build_with_data(&self, x_train: &Mat) -> Box<dyn Featurizer> {
        self.try_build(x_train.cols(), Some(x_train))
            .unwrap_or_else(|e| panic!("FeatureSpec::build_with_data: {e}"))
    }

    /// The concrete (unscaled) Gegenbauer featurizer of this spec, if its
    /// method is Gegenbauer — the single place the direction budget is
    /// spent (`try_build` wraps this; the PJRT backend reads its raw
    /// direction set).
    pub fn build_gegenbauer(&self, d: usize) -> Option<GegenbauerFeatures> {
        let table = self.radial_table(d)?;
        let dirs = (self.m / table.s).max(1);
        Some(GegenbauerFeatures::new(table, dirs, self.seed))
    }

    /// The concrete Nystrom featurizer of this spec fitted on in-memory
    /// training rows — [`build_nystrom_source`](FeatureSpec::build_nystrom_source)
    /// over a borrowed `MatSource` (`try_build` wraps this).
    pub fn build_nystrom(&self, d: usize, x_train: &Mat) -> Result<NystromFeatures, String> {
        self.build_nystrom_source(d, &crate::data::MatSource::unlabeled(x_train))
    }

    /// The concrete Nystrom featurizer of this spec fitted from any
    /// [`DataSource`](crate::data::DataSource) — the **single place** the
    /// data-dependent baseline is constructed: `try_build`,
    /// `build_with_data` and `model::FittedMap::fit_source` all route
    /// here, so the in-memory and out-of-core Nystrom fits can never
    /// diverge. (The model artifact codec reads the fitted landmarks for
    /// persistence and rebuilds from them on load.)
    pub fn build_nystrom_source(
        &self,
        d: usize,
        src: &dyn crate::data::DataSource,
    ) -> Result<NystromFeatures, String> {
        let lambda = match self.method {
            Method::Nystrom { lambda } => lambda,
            _ => {
                return Err(format!(
                    "build_nystrom on method {:?}",
                    self.method.name()
                ))
            }
        };
        if src.dim() != d {
            return Err(format!(
                "nystrom: training rows have d={}, spec bound to d={d}",
                src.dim()
            ));
        }
        NystromFeatures::fit_source(self.kernel.to_kernel(), src, self.m, lambda, self.seed)
    }

    /// The radial table the Gegenbauer path of this spec uses (independent
    /// of `m`/`seed`); `None` for non-Gegenbauer methods.
    pub fn radial_table(&self, d: usize) -> Option<RadialTable> {
        match self.method {
            Method::Gegenbauer { q, s } => {
                let (q, s) = self.kernel.gegenbauer_order(q, s);
                Some(self.kernel.radial_table(d, q, s))
            }
            _ => None,
        }
    }

    fn gaussian_bandwidth(&self) -> Result<f64, String> {
        match &self.kernel {
            KernelSpec::Gaussian { bandwidth } => Ok(*bandwidth),
            other => Err(format!(
                "method {:?} supports only the gaussian kernel, got {}",
                self.method.name(),
                other.name()
            )),
        }
    }

    /// Serialize for the wire / CLI. The seed is a decimal *string* so the
    /// full u64 range survives the f64-backed JSON number type.
    pub fn to_json(&self) -> String {
        format!(
            r#"{{"kernel":{},"method":{},"m":{},"seed":"{}"}}"#,
            self.kernel.to_json(),
            self.method.to_json(),
            self.m,
            self.seed
        )
    }

    pub fn from_json(text: &str) -> Result<FeatureSpec, String> {
        Self::from_json_value(&Json::parse(text)?)
    }

    /// Decode from an already-parsed JSON value (the model artifact codec
    /// embeds specs inside a larger document).
    pub(crate) fn from_json_value(j: &Json) -> Result<FeatureSpec, String> {
        let kernel = KernelSpec::from_json_value(
            j.get("kernel").ok_or_else(|| "spec json: missing \"kernel\"".to_string())?,
        )?;
        let method = Method::from_json_value(
            j.get("method").ok_or_else(|| "spec json: missing \"method\"".to_string())?,
        )?;
        let m = req_usize(j, "m")?;
        let seed = j
            .get("seed")
            .and_then(|v| v.as_str())
            .ok_or_else(|| "spec json: missing string \"seed\"".to_string())?
            .parse::<u64>()
            .map_err(|e| format!("spec json: bad seed: {e}"))?;
        Ok(FeatureSpec { kernel, method, m, seed })
    }
}

/// A [`FeatureSpec`] bound to an input dimension `d` — the complete,
/// serializable broadcast message of the one-round protocol (a few bytes,
/// for *any* registered method). Re-exported as `coordinator::FeatureSpec`.
#[derive(Clone, Debug, PartialEq)]
pub struct BoundSpec {
    pub spec: FeatureSpec,
    pub d: usize,
}

impl BoundSpec {
    pub fn feature_dim(&self) -> usize {
        self.spec.feature_dim()
    }

    /// Build the featurizer. Every holder of the same spec builds a
    /// bit-identical map (tested in `determinism_across_builders`).
    pub fn build(&self) -> Box<dyn Featurizer> {
        self.spec.build(self.d)
    }

    /// The concrete Gegenbauer featurizer, if applicable (PJRT backend).
    pub fn build_gegenbauer(&self) -> Option<GegenbauerFeatures> {
        self.spec.build_gegenbauer(self.d)
    }

    /// Input preprocessing implied by the kernel family (bandwidth
    /// folding). Built featurizers already apply this internally; only the
    /// PJRT path, which bypasses [`build`](BoundSpec::build), needs it.
    pub fn scale_inputs(&self, x: &Mat) -> Mat {
        self.spec.kernel.scale_inputs(x)
    }

    pub fn kernel_name(&self) -> &'static str {
        self.spec.kernel.name()
    }

    pub fn to_json(&self) -> String {
        format!(
            r#"{{"d":{},"kernel":{},"method":{},"m":{},"seed":"{}"}}"#,
            self.d,
            self.spec.kernel.to_json(),
            self.spec.method.to_json(),
            self.spec.m,
            self.spec.seed
        )
    }

    pub fn from_json(text: &str) -> Result<BoundSpec, String> {
        Self::from_json_value(&Json::parse(text)?)
    }

    /// Decode from an already-parsed JSON value (the model artifact codec
    /// embeds bound specs inside a larger document).
    pub(crate) fn from_json_value(j: &Json) -> Result<BoundSpec, String> {
        let d = req_usize(j, "d")?;
        Ok(BoundSpec { spec: FeatureSpec::from_json_value(j)?, d })
    }
}

/// Bandwidth folding wrapper: scales inputs by `scale` before delegating to
/// the unit-bandwidth inner featurizer. Keeps every registry-built
/// featurizer raw-input-compatible.
struct InputScaled<F: Featurizer> {
    inner: F,
    scale: f64,
}

impl<F: Featurizer> InputScaled<F> {
    fn scaled(&self, x: &Mat) -> Mat {
        let mut y = x.clone();
        y.scale(self.scale);
        y
    }
}

impl<F: Featurizer> Featurizer for InputScaled<F> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn featurize_into(&self, x: &Mat, out: &mut [f64]) {
        self.inner.featurize_into(&self.scaled(x), out)
    }

    fn featurize_par_into(&self, x: &Mat, out: &mut [f64], pool: &Pool) {
        self.inner.featurize_par_into(&self.scaled(x), out, pool)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

fn req_f64(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("spec json: missing number {key:?}"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| format!("spec json: missing integer {key:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::test_support::check_gram_approx;
    use crate::rng::Rng;

    fn gaussian(bandwidth: f64) -> KernelSpec {
        KernelSpec::Gaussian { bandwidth }
    }

    #[test]
    fn registry_names_roundtrip() {
        for method in Method::registry() {
            let back = Method::from_name(method.name()).unwrap();
            assert_eq!(back.name(), method.name());
        }
        assert!(Method::from_name("no-such-method").is_err());
    }

    #[test]
    fn registry_gram_concentration() {
        // every registered method approximates the Gaussian Gram matrix;
        // per-method tolerances reflect their known variance (Tables 2/3:
        // maclaurin is the weak method, polysketch mid, the rest strong).
        let (n, d, scale, seed) = (12usize, 3usize, 0.5f64, 65u64);
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.normal() * scale);
        for method in Method::registry() {
            let (budget, tol) = match method.name() {
                Method::MACLAURIN => (16384, 0.6),
                Method::POLYSKETCH => (8192, 0.3),
                Method::FASTFOOD => (8192, 0.2),
                Method::NYSTROM => (8192, 0.05), // m >= n: near-exact
                _ => (8192, 0.25),
            };
            let spec = FeatureSpec::new(gaussian(1.0), method.tuned(14, 6), budget, 99);
            let feat = spec.build_with_data(&x);
            check_gram_approx(feat.as_ref(), &spec.kernel.to_kernel(), n, d, scale, seed, tol);
        }
    }

    #[test]
    fn trait_defaults_match_featurize_for_every_method() {
        // featurize, featurize_into and featurize_par must agree
        // bit-for-bit for every registered method (derived impls +
        // overrides); featurize_into writes into a caller slice, so also
        // check a scratch buffer reused across calls
        let d = 3;
        let mut rng = Rng::new(200);
        let x = Mat::from_fn(31, d, |_, _| rng.normal() * 0.6);
        for method in Method::registry() {
            // bandwidth != 1 exercises the InputScaled wrapper
            let spec = FeatureSpec::new(gaussian(1.3), method, 96, 7);
            let feat = spec.build_with_data(&x);
            let z = feat.featurize(&x);
            assert_eq!(z.cols(), feat.dim(), "{}", feat.name());
            let mut scratch = vec![f64::NAN; x.rows() * feat.dim()];
            feat.featurize_into(&x, &mut scratch);
            assert_eq!(z.data(), &scratch[..], "{}: featurize_into differs", feat.name());
            for threads in [2usize, 3, 5, 64] {
                // 64 > n: an explicit pool wider than the row count must
                // still be honored (and still agree bit for bit)
                let zp = feat.featurize_par(&x, &Pool::new(threads));
                assert_eq!(z, zp, "{}: featurize_par({threads}) differs", feat.name());
                scratch.fill(f64::NAN);
                feat.featurize_par_into(&x, &mut scratch, &Pool::new(threads));
                assert_eq!(
                    z.data(),
                    &scratch[..],
                    "{}: featurize_par_into({threads}) differs",
                    feat.name()
                );
            }
        }
    }

    #[test]
    fn feature_dim_matches_built_dim() {
        let mut rng = Rng::new(201);
        let x = Mat::from_fn(300, 4, |_, _| rng.normal());
        for method in Method::registry() {
            let spec = FeatureSpec::new(gaussian(1.0), method, 256, 3);
            let feat = spec.build_with_data(&x);
            assert_eq!(spec.feature_dim(), feat.dim(), "{}", feat.name());
        }
    }

    #[test]
    fn build_is_deterministic_per_spec() {
        let mut rng = Rng::new(202);
        let x = Mat::from_fn(7, 3, |_, _| rng.normal());
        for method in Method::registry().into_iter().filter(|m| m.is_oblivious()) {
            let spec = FeatureSpec::new(gaussian(0.8), method, 64, 11);
            let z1 = spec.build(3).featurize(&x);
            let z2 = spec.build(3).featurize(&x);
            assert_eq!(z1, z2, "{:?}", spec.method.name());
        }
    }

    #[test]
    fn scaled_wrapper_equals_manual_bandwidth_folding() {
        // gegenbauer at bandwidth sigma == unit-bandwidth gegenbauer on
        // inputs scaled by 1/sigma (the old call-site convention)
        let mut rng = Rng::new(203);
        let x = Mat::from_fn(9, 3, |_, _| rng.normal());
        let spec = FeatureSpec::new(gaussian(2.0), Method::Gegenbauer { q: 8, s: 2 }, 64, 5);
        let z = spec.build(3).featurize(&x);
        let unit = FeatureSpec::new(gaussian(1.0), Method::Gegenbauer { q: 8, s: 2 }, 64, 5);
        let mut xs = x.clone();
        xs.scale(0.5);
        let z_manual = unit.build(3).featurize(&xs);
        assert_eq!(z, z_manual);
    }

    #[test]
    fn polynomial_kernel_overrides_gegenbauer_order() {
        let spec = FeatureSpec::new(
            KernelSpec::Polynomial { p: 3, c: 0.5 },
            Method::Gegenbauer { q: 12, s: 2 },
            64,
            1,
        );
        // s_eff = p/2 + 1 = 2, q_eff = 3
        let table = spec.radial_table(4).unwrap();
        assert_eq!((table.q, table.s), (3, 2));
        assert_eq!(spec.feature_dim(), (64 / 2) * 2);
    }

    #[test]
    fn unsupported_pairs_and_missing_data_error() {
        let exp = KernelSpec::Exponential { gamma: 1.0 };
        let spec = FeatureSpec::new(exp, Method::Fourier, 32, 1);
        assert!(spec.try_build(3, None).is_err());
        let ny = FeatureSpec::new(gaussian(1.0), Method::Nystrom { lambda: 1e-3 }, 32, 1);
        assert!(ny.try_build(3, None).is_err());
        assert!(!Method::Nystrom { lambda: 1e-3 }.is_oblivious());
    }

    #[test]
    fn json_roundtrip_all_methods() {
        for method in Method::registry() {
            let spec = FeatureSpec::new(gaussian(1.5), method, 128, u64::MAX - 12345);
            let back = FeatureSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back);
        }
        for kernel in [
            gaussian(0.7),
            KernelSpec::Exponential { gamma: 0.4 },
            KernelSpec::Polynomial { p: 3, c: 1.0 },
            KernelSpec::Ntk { depth: 2 },
        ] {
            let spec = FeatureSpec::new(kernel, Method::Gegenbauer { q: 7, s: 3 }, 96, 42);
            let bound = spec.bind(5);
            let back = BoundSpec::from_json(&bound.to_json()).unwrap();
            assert_eq!(bound, back);
        }
    }

    #[test]
    fn json_rejects_malformed_specs() {
        assert!(FeatureSpec::from_json("{}").is_err());
        assert!(FeatureSpec::from_json("not json").is_err());
        let no_seed = r#"{"kernel":{"family":"gaussian","bandwidth":1.0},"method":{"name":"fourier"},"m":8}"#;
        assert!(FeatureSpec::from_json(no_seed).is_err());
        let bad_family = r#"{"kernel":{"family":"sobolev"},"method":{"name":"fourier"},"m":8,"seed":"1"}"#;
        assert!(FeatureSpec::from_json(bad_family).is_err());
    }
}
