//! Radial-factor tables h_l(t) for the GZK family — exact rust mirror of
//! `python/compile/radial.py` (paper Eqs. 12, 22, 23; Lemma 16).
//!
//! `coef[l][i]` folds both the sqrt(alpha_{l,d}) of the feature map
//! (Eq. 13) and the Mercer coefficient of h_l; radial values are
//!
//!   R[x][l, i] = coef[l,i] * ||x||^expo[l,i] * (e^{-||x||^2/2} if decay).

use crate::special::{alpha_dim, gegenbauer_series_coeffs, lgamma, log_alpha_dim};
use crate::kernels::ntk_kappa;

const LOG_SQRT_PI: f64 = 0.572_364_942_924_700_1; // 0.5 * ln(pi)

/// Truncated radial weights for one GZK family in dimension d.
#[derive(Clone, Debug)]
pub struct RadialTable {
    pub family: &'static str,
    pub d: usize,
    pub q: usize,
    pub s: usize,
    /// (q+1) x s linear-domain weights, row-major
    pub coef: Vec<f64>,
    /// (q+1) x s exponents of ||x||
    pub expo: Vec<f64>,
    /// multiply by exp(-||x||^2 / 2)?
    pub decay: bool,
}

fn base_log_coef(l: usize, i: usize, d: usize) -> f64 {
    let la = log_alpha_dim(l, d);
    la - 0.5 * l as f64 * std::f64::consts::LN_2
        + 0.5
            * (lgamma(d as f64 / 2.0) - LOG_SQRT_PI - lgamma(2.0 * i as f64 + 1.0)
                + lgamma(i as f64 + 0.5)
                - lgamma(i as f64 + l as f64 + d as f64 / 2.0))
}

impl RadialTable {
    /// Unit-bandwidth Gaussian kernel e^{-||x-y||^2/2} (Eq. 23). For other
    /// bandwidths rescale the inputs by 1/sigma.
    pub fn gaussian(d: usize, q: usize, s: usize) -> RadialTable {
        let mut coef = vec![0.0; (q + 1) * s];
        let mut expo = vec![0.0; (q + 1) * s];
        for l in 0..=q {
            for i in 0..s {
                coef[l * s + i] = base_log_coef(l, i, d).exp();
                expo[l * s + i] = (l + 2 * i) as f64;
            }
        }
        RadialTable { family: "gaussian", d, q, s, coef, expo, decay: true }
    }

    /// Dot-product kernel kappa(t) = exp(gamma t) (Eq. 12 with
    /// kappa^(j)(0) = gamma^j).
    pub fn exponential(d: usize, q: usize, s: usize, gamma: f64) -> RadialTable {
        assert!(gamma > 0.0);
        let mut coef = vec![0.0; (q + 1) * s];
        let mut expo = vec![0.0; (q + 1) * s];
        for l in 0..=q {
            for i in 0..s {
                let lg = base_log_coef(l, i, d) + 0.5 * (l + 2 * i) as f64 * gamma.ln();
                coef[l * s + i] = lg.exp();
                expo[l * s + i] = (l + 2 * i) as f64;
            }
        }
        RadialTable { family: "exponential", d, q, s, coef, expo, decay: false }
    }

    /// Dot-product kernel kappa(t) = (t + c)^p, exact at q = p.
    pub fn polynomial(d: usize, p: usize, c: f64) -> RadialTable {
        assert!(c >= 0.0, "Schoenberg PSD condition requires c >= 0");
        let q = p;
        let s = p / 2 + 1;
        let mut coef = vec![0.0; (q + 1) * s];
        let mut expo = vec![0.0; (q + 1) * s];
        for l in 0..=q {
            for i in 0..s {
                let j = l + 2 * i;
                if j > p {
                    continue;
                }
                // kappa^(j)(0) = p!/(p-j)! c^{p-j}
                let mut lk = lgamma(p as f64 + 1.0) - lgamma((p - j) as f64 + 1.0);
                if c > 0.0 {
                    lk += (p - j) as f64 * c.ln();
                } else if j != p {
                    continue;
                }
                coef[l * s + i] = (base_log_coef(l, i, d) + 0.5 * lk).exp();
                expo[l * s + i] = j as f64;
            }
        }
        RadialTable { family: "polynomial", d, q, s, coef, expo, decay: false }
    }

    /// Depth-`depth` ReLU NTK as a GZK (Lemma 16): h_l(t) = sqrt(c_l) t.
    pub fn ntk(d: usize, q: usize, depth: usize) -> RadialTable {
        let c = gegenbauer_series_coeffs(|t| ntk_kappa(t, depth), q, d, 512);
        let mut coef = vec![0.0; q + 1];
        for l in 0..=q {
            let cl = c[l].max(0.0); // clip quadrature noise
            coef[l] = (alpha_dim(l, d) * cl).sqrt();
        }
        RadialTable { family: "ntk", d, q, s: 1, coef, expo: vec![1.0; q + 1], decay: false }
    }

    /// Radial values for a batch of norms: (n, (q+1)*s) row-major.
    pub fn values(&self, norms: &[f64]) -> Vec<f64> {
        let width = (self.q + 1) * self.s;
        let mut out = vec![0.0; norms.len() * width];
        for (j, &nrm) in norms.iter().enumerate() {
            self.values_into(nrm, &mut out[j * width..(j + 1) * width]);
        }
        out
    }

    /// Radial values for one norm into a caller-provided buffer of length
    /// (q+1)*s — the allocation-free hot-path variant.
    pub fn values_into(&self, norm: f64, row: &mut [f64]) {
        debug_assert_eq!(row.len(), (self.q + 1) * self.s);
        let t = norm.max(1e-30);
        let lt = t.ln();
        let env = if self.decay { (-0.5 * t * t).exp() } else { 1.0 };
        for (k, out) in row.iter_mut().enumerate() {
            *out = if self.coef[k] == 0.0 {
                0.0
            } else {
                self.coef[k] * (self.expo[k] * lt).exp() * env
            };
        }
    }

    /// Energy sum_i coef-weighted |h_l|^2 at a given norm, per degree l —
    /// the quantity the Lemma-7 leverage bound depends on.
    pub fn degree_energy(&self, norm: f64) -> Vec<f64> {
        let vals = self.values(&[norm]);
        (0..=self.q)
            .map(|l| {
                (0..self.s)
                    .map(|i| {
                        let v = vals[l * self.s + i];
                        // undo the folded sqrt(alpha) to get |h_l|^2
                        v * v / alpha_dim(l, self.d)
                    })
                    .sum()
            })
            .collect()
    }

    /// Exact truncated-GZK kernel value k_{q,s}(x, y) per Definition 3:
    /// sum_l <h_l(|x|), h_l(|y|)> P_d^l(cos). This is the kernel the random
    /// features are unbiased FOR (the Theorem-11/12 approximand).
    pub fn gzk_eval(&self, x: &[f64], y: &[f64]) -> f64 {
        let nx = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        let ny = y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        let cos =
            (x.iter().zip(y).map(|(&a, &b)| a * b).sum::<f64>() / (nx * ny)).clamp(-1.0, 1.0);
        let rx = self.values(&[nx]);
        let ry = self.values(&[ny]);
        let p = crate::special::gegenbauer_all(self.q, self.d, &[cos]);
        let mut total = 0.0;
        for l in 0..=self.q {
            let mut dot = 0.0;
            for i in 0..self.s {
                dot += rx[l * self.s + i] * ry[l * self.s + i];
            }
            // values() folds sqrt(alpha) into each factor; divide one back out
            total += dot / alpha_dim(l, self.d) * p[l];
        }
        total
    }

    /// Gram matrix of the truncated GZK on a point set.
    pub fn gzk_gram(&self, x: &crate::linalg::Mat) -> crate::linalg::Mat {
        let n = x.rows();
        let mut k = crate::linalg::Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.gzk_eval(x.row(i), x.row(j));
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        k
    }
}

/// Theorem-12-style truncation degree for the Gaussian kernel.
pub fn suggest_q(r: f64, d: usize, n: usize, lam: f64, eps: f64) -> usize {
    let t = (n as f64 / (eps * lam)).max(std::f64::consts::E).ln();
    let df = d as f64;
    let q = (3.7 * r * r).max(df / 2.0 * (2.8 * (r * r + t + df) / df).ln() + t);
    (q.ceil() as usize).max(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::special::gegenbauer_eval;

    /// Evaluate the truncated GZK k_{q,s}(x, y) directly from Def. 3.
    fn gzk_kernel(table: &RadialTable, x: &[f64], y: &[f64]) -> f64 {
        let nx = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        let ny = y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        let cos = (x.iter().zip(y).map(|(&a, &b)| a * b).sum::<f64>() / (nx * ny)).clamp(-1.0, 1.0);
        let rx = table.values(&[nx]);
        let ry = table.values(&[ny]);
        let mut total = 0.0;
        for l in 0..=table.q {
            let mut dot = 0.0;
            for i in 0..table.s {
                dot += rx[l * table.s + i] * ry[l * table.s + i];
            }
            total += dot / alpha_dim(l, table.d) * gegenbauer_eval(l, table.d, cos);
        }
        total
    }

    #[test]
    fn gaussian_reconstruction() {
        let table = RadialTable::gaussian(4, 20, 10);
        let mut rng = crate::rng::Rng::new(60);
        for _ in 0..20 {
            let x: Vec<f64> = (0..4).map(|_| rng.normal() * 0.7).collect();
            let y: Vec<f64> = (0..4).map(|_| rng.normal() * 0.7).collect();
            let exact =
                (-0.5 * x.iter().zip(&y).map(|(&a, &b)| (a - b) * (a - b)).sum::<f64>()).exp();
            let got = gzk_kernel(&table, &x, &y);
            assert!((got - exact).abs() < 1e-6, "{got} vs {exact}");
        }
    }

    #[test]
    fn exponential_reconstruction() {
        let table = RadialTable::exponential(3, 22, 11, 0.8);
        let mut rng = crate::rng::Rng::new(61);
        for _ in 0..20 {
            let x: Vec<f64> = (0..3).map(|_| rng.normal() * 0.6).collect();
            let y: Vec<f64> = (0..3).map(|_| rng.normal() * 0.6).collect();
            let exact = (0.8 * x.iter().zip(&y).map(|(&a, &b)| a * b).sum::<f64>()).exp();
            let got = gzk_kernel(&table, &x, &y);
            assert!((got - exact).abs() < 1e-5 * exact.max(1.0), "{got} vs {exact}");
        }
    }

    #[test]
    fn polynomial_exact() {
        for (p, c) in [(2usize, 1.0), (3, 0.5), (4, 1.0), (3, 0.0)] {
            let table = RadialTable::polynomial(4, p, c);
            let mut rng = crate::rng::Rng::new(62);
            for _ in 0..10 {
                let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
                let y: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
                let exact =
                    (x.iter().zip(&y).map(|(&a, &b)| a * b).sum::<f64>() + c).powi(p as i32);
                let got = gzk_kernel(&table, &x, &y);
                assert!(
                    (got - exact).abs() < 1e-8 * exact.abs().max(1.0),
                    "p={p} c={c}: {got} vs {exact}"
                );
            }
        }
    }

    #[test]
    fn ntk_reconstruction_on_sphere() {
        let table = RadialTable::ntk(4, 40, 2);
        let mut rng = crate::rng::Rng::new(63);
        let mut x = vec![0.0; 4];
        let mut y = vec![0.0; 4];
        for _ in 0..10 {
            rng.sphere(&mut x);
            rng.sphere(&mut y);
            let cos = x.iter().zip(&y).map(|(&a, &b)| a * b).sum::<f64>().clamp(-1.0, 1.0);
            let exact = ntk_kappa(cos, 2);
            let got = gzk_kernel(&table, &x, &y);
            assert!((got - exact).abs() < 5e-3, "{got} vs {exact}");
        }
    }

    #[test]
    fn energy_decays_in_degree() {
        let table = RadialTable::gaussian(4, 16, 4);
        let e = table.degree_energy(1.5);
        assert!(e[12] < e[2] * 1e-4, "{:?}", e);
    }

    #[test]
    fn matches_python_values() {
        // spot values computed by python/compile/radial.py (gaussian d=3,q=2,s=2)
        // python: radial.gaussian_table(3,2,2).coef
        let t = RadialTable::gaussian(3, 2, 2);
        // coef[0,0] = exp(base_log_coef(0,0,3)); check internal consistency
        // against the closed form sqrt(alpha) * sqrt(alpha * G(1.5)*G(0.5)/
        // (sqrt(pi)*1*G(1.5)))  = sqrt(G(0.5)/sqrt(pi)) = 1
        assert!((t.coef[0] - 1.0).abs() < 1e-12, "{}", t.coef[0]);
        // l=1: alpha=3; coef = 3^1 * sqrt(2^-1 * G(1.5) G(0.5) / (sqrt(pi) G(2.5)))
        let expect = 3.0
            * (0.5 * (lgamma(1.5) + lgamma(0.5) - 0.5 * std::f64::consts::PI.ln()
                - lgamma(2.5)
                - std::f64::consts::LN_2))
                .exp();
        assert!((t.coef[2] - expect).abs() < 1e-12, "{} vs {expect}", t.coef[2]);
    }

    #[test]
    fn suggest_q_monotone() {
        let q1 = suggest_q(1.0, 3, 1000, 1e-3, 0.5);
        let q2 = suggest_q(2.0, 3, 1000, 1e-3, 0.5);
        let q3 = suggest_q(1.0, 3, 100_000, 1e-6, 0.5);
        assert!(q2 >= q1 && q3 >= q1);
    }
}
