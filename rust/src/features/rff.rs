//! Random Fourier features [RR09] for the Gaussian kernel — the classic
//! baseline of Tables 1-3.
//!
//! z(x) = sqrt(2/F) [cos(w_1^T x + b_1), ..., cos(w_F^T x + b_F)],
//! w ~ N(0, I/sigma^2), b ~ U[0, 2pi). E[z(x)^T z(y)] = k(x, y).

use super::Featurizer;
use crate::linalg::Mat;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct FourierFeatures {
    /// frequencies (F x d)
    w: Mat,
    /// phases (F)
    b: Vec<f64>,
}

impl FourierFeatures {
    pub fn new(d: usize, f_dim: usize, bandwidth: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fork(0xF0F);
        let w = Mat::from_fn(f_dim, d, |_, _| rng.normal() / bandwidth);
        let b = (0..f_dim).map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI)).collect();
        FourierFeatures { w, b }
    }
}

impl Featurizer for FourierFeatures {
    fn dim(&self) -> usize {
        self.w.rows()
    }

    /// Writes each row directly into the caller's buffer: per output cell
    /// one w_k^T x dot (accumulated in the same ascending order as
    /// `matmul_nt`) followed by the phase-shifted cosine — no intermediate
    /// projection matrix.
    fn featurize_into(&self, x: &Mat, out: &mut [f64]) {
        let f_dim = self.w.rows();
        assert_eq!(x.cols(), self.w.cols(), "fourier: input dim mismatch");
        assert_eq!(out.len(), x.rows() * f_dim, "fourier: featurize_into size");
        let scale = (2.0 / f_dim as f64).sqrt();
        for (i, orow) in out.chunks_exact_mut(f_dim).enumerate() {
            let xr = x.row(i);
            for (k, v) in orow.iter_mut().enumerate() {
                let wk = self.w.row(k);
                let mut acc = 0.0;
                for t in 0..xr.len() {
                    acc += xr[t] * wk[t];
                }
                *v = scale * (acc + self.b[k]).cos();
            }
        }
    }

    fn name(&self) -> &'static str {
        "fourier"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::test_support::check_gram_approx;
    use crate::kernels::Kernel;

    #[test]
    fn gram_concentrates() {
        let feat = FourierFeatures::new(3, 8192, 1.0, 1);
        check_gram_approx(&feat, &Kernel::Gaussian { bandwidth: 1.0 }, 16, 3, 0.8, 80, 0.1);
    }

    #[test]
    fn bandwidth_respected() {
        let feat = FourierFeatures::new(2, 16384, 2.0, 2);
        check_gram_approx(&feat, &Kernel::Gaussian { bandwidth: 2.0 }, 10, 2, 1.2, 81, 0.1);
    }

    #[test]
    fn diagonal_is_near_one() {
        let feat = FourierFeatures::new(4, 4096, 1.0, 3);
        let mut rng = crate::rng::Rng::new(82);
        let x = Mat::from_fn(8, 4, |_, _| rng.normal());
        let z = feat.featurize(&x);
        for i in 0..8 {
            let d: f64 = z.row(i).iter().map(|v| v * v).sum();
            assert!((d - 1.0).abs() < 0.1, "{d}");
        }
    }

    #[test]
    fn deterministic() {
        let f1 = FourierFeatures::new(3, 64, 1.0, 7);
        let f2 = FourierFeatures::new(3, 64, 1.0, 7);
        let mut rng = crate::rng::Rng::new(83);
        let x = Mat::from_fn(4, 3, |_, _| rng.normal());
        assert_eq!(f1.featurize(&x), f2.featurize(&x));
    }
}
