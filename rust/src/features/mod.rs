//! Feature maps: the paper's random Gegenbauer features plus every baseline
//! in Tables 2/3, all constructed through one spec-driven registry.
//!
//! All featurizers implement [`Featurizer`]: map a batch of raw points
//! (n x d) to a feature matrix Z (n x F) such that Z Z^T approximates the
//! target kernel's Gram matrix. A featurizer is *described* by a
//! [`FeatureSpec`] — `(kernel, method, m, seed)` — and every construction
//! site in the crate (experiments, coordinator, CLI, benches) goes through
//! [`FeatureSpec::build`] rather than naming concrete types; see [`spec`].

mod fastfood;
mod gegenbauer;
mod maclaurin;
mod nystrom;
mod polysketch;
pub mod radial;
mod rff;
pub mod spec;

pub use fastfood::FastFoodFeatures;
pub use gegenbauer::GegenbauerFeatures;
pub use maclaurin::MaclaurinFeatures;
pub use nystrom::NystromFeatures;
pub use polysketch::PolySketchFeatures;
pub use radial::RadialTable;
pub use rff::FourierFeatures;
pub use spec::{BoundSpec, FeatureSpec, KernelSpec, Method};

use crate::exec::Pool;
use crate::linalg::Mat;

/// A (possibly random) finite-dimensional feature map for a kernel.
///
/// `Send + Sync` is part of the contract: featurizers are broadcast to
/// worker threads by the coordinator and shared across chunk-parallel
/// featurization, so every implementation must be freely shareable.
///
/// The two batch variants have default implementations in terms of
/// [`featurize`](Featurizer::featurize), so a new featurizer only has to
/// supply the per-batch map; implementations with a cheaper path (e.g. the
/// Gegenbauer hot loop) override them.
pub trait Featurizer: Send + Sync {
    /// Output feature dimension F.
    fn dim(&self) -> usize;

    /// Map points (n x d) to features (n x F).
    fn featurize(&self, x: &Mat) -> Mat;

    /// Zero-copy variant: featurize into a preallocated (n x F) buffer.
    fn featurize_into(&self, x: &Mat, out: &mut Mat) {
        let z = self.featurize(x);
        assert_eq!(out.rows(), z.rows(), "{}: featurize_into row mismatch", self.name());
        assert_eq!(out.cols(), z.cols(), "{}: featurize_into col mismatch", self.name());
        out.data_mut().copy_from_slice(z.data());
    }

    /// Chunk-parallel batch featurization: scatters row ranges across the
    /// pool ([`Pool::par_chunks`]). Bit-identical to the sequential path
    /// because every featurizer maps rows independently.
    ///
    /// An explicit pool is **always honored**: there is no small-`n`
    /// fallback that silently serializes (a pool of `t` threads on `n < t`
    /// rows simply runs `n` workers), so pool bugs cannot hide behind
    /// small test inputs. Only a single-thread pool takes the serial
    /// path — which is the same computation by construction.
    fn featurize_par(&self, x: &Mat, pool: &Pool) -> Mat {
        let n = x.rows();
        if pool.threads() <= 1 || n <= 1 {
            return self.featurize(x);
        }
        let mut out = Mat::zeros(n, self.dim());
        pool.par_chunks(n, out.data_mut(), |lo, hi, block| {
            let z = self.featurize(&x.row_block(lo, hi));
            block.copy_from_slice(z.data());
        });
        out
    }

    /// Human-readable method name (bench tables, registry lookups).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::kernels::Kernel;
    use crate::rng::Rng;

    /// Shared concentration check: max |Z Z^T - K| / max |K| below tol.
    pub fn check_gram_approx(
        feat: &dyn Featurizer,
        kernel: &Kernel,
        n: usize,
        d: usize,
        scale: f64,
        seed: u64,
        tol: f64,
    ) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.normal() * scale);
        let z = feat.featurize(&x);
        assert_eq!(z.rows(), n);
        assert_eq!(z.cols(), feat.dim());
        let k_hat = z.matmul_nt(&z);
        let k = kernel.gram(&x);
        let kmax = k.data().iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let err = k_hat.max_abs_diff(&k) / kmax;
        assert!(
            err < tol,
            "{}: relative gram error {err:.4} >= {tol}",
            feat.name()
        );
    }
}
