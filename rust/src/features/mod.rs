//! Feature maps: the paper's random Gegenbauer features plus every baseline
//! in Tables 2/3.
//!
//! All featurizers implement [`Featurizer`]: map a batch of raw points
//! (n x d) to a feature matrix Z (n x F) such that Z Z^T approximates the
//! target kernel's Gram matrix.

mod fastfood;
mod gegenbauer;
mod maclaurin;
mod nystrom;
mod polysketch;
pub mod radial;
mod rff;

pub use fastfood::FastFoodFeatures;
pub use gegenbauer::GegenbauerFeatures;
pub use maclaurin::MaclaurinFeatures;
pub use nystrom::NystromFeatures;
pub use polysketch::PolySketchFeatures;
pub use radial::RadialTable;
pub use rff::FourierFeatures;

use crate::linalg::Mat;

/// A (possibly random) finite-dimensional feature map for a kernel.
pub trait Featurizer {
    /// Output feature dimension F.
    fn dim(&self) -> usize;
    /// Map points (n x d) to features (n x F).
    fn featurize(&self, x: &Mat) -> Mat;
    /// Human-readable method name (bench tables).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::kernels::Kernel;
    use crate::rng::Rng;

    /// Shared concentration check: max |Z Z^T - K| / max |K| below tol.
    pub fn check_gram_approx(
        feat: &dyn Featurizer,
        kernel: &Kernel,
        n: usize,
        d: usize,
        scale: f64,
        seed: u64,
        tol: f64,
    ) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.normal() * scale);
        let z = feat.featurize(&x);
        assert_eq!(z.rows(), n);
        assert_eq!(z.cols(), feat.dim());
        let k_hat = z.matmul_nt(&z);
        let k = kernel.gram(&x);
        let kmax = k.data().iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let err = k_hat.max_abs_diff(&k) / kmax;
        assert!(
            err < tol,
            "{}: relative gram error {err:.4} >= {tol}",
            feat.name()
        );
    }
}
