//! Feature maps: the paper's random Gegenbauer features plus every baseline
//! in Tables 2/3, all constructed through one spec-driven registry.
//!
//! All featurizers implement [`Featurizer`]: map a batch of raw points
//! (n x d) to a feature matrix Z (n x F) such that Z Z^T approximates the
//! target kernel's Gram matrix. A featurizer is *described* by a
//! [`FeatureSpec`] — `(kernel, method, m, seed)` — and every construction
//! site in the crate (experiments, coordinator, CLI, benches) goes through
//! [`FeatureSpec::build`] rather than naming concrete types; see [`spec`].

mod fastfood;
mod gegenbauer;
mod maclaurin;
mod nystrom;
mod polysketch;
pub mod radial;
mod rff;
pub mod spec;

pub use fastfood::FastFoodFeatures;
pub use gegenbauer::GegenbauerFeatures;
pub use maclaurin::MaclaurinFeatures;
pub use nystrom::NystromFeatures;
pub use polysketch::PolySketchFeatures;
pub use radial::RadialTable;
pub use rff::FourierFeatures;
pub use spec::{BoundSpec, FeatureSpec, KernelSpec, Method};

use crate::exec::Pool;
use crate::linalg::Mat;

/// A (possibly random) finite-dimensional feature map for a kernel.
///
/// `Send + Sync` is part of the contract: featurizers are broadcast to
/// worker threads by the coordinator and shared across chunk-parallel
/// featurization, so every implementation must be freely shareable.
///
/// The **required** batch method is [`featurize_into`]: write the feature
/// rows straight into a caller-owned buffer. That direction matters — the
/// out-of-core pipeline (`data::pipeline`) streams chunks of the dataset
/// through one chunk-sized scratch buffer, so the per-method impls must
/// not materialize an intermediate n x F matrix of their own.
/// [`featurize`](Featurizer::featurize) and the parallel variants are
/// derived from it.
///
/// [`featurize_into`]: Featurizer::featurize_into
pub trait Featurizer: Send + Sync {
    /// Output feature dimension F.
    fn dim(&self) -> usize;

    /// Write the features of the n rows of `x` into `out`, row-major —
    /// `out.len()` must equal `n * dim()`. This is the one method a
    /// featurizer must implement, and the chunk hot path: no intermediate
    /// feature matrix may be allocated.
    fn featurize_into(&self, x: &Mat, out: &mut [f64]);

    /// Map points (n x d) to features (n x F). Derived: allocates the
    /// output and delegates to [`featurize_into`](Featurizer::featurize_into).
    fn featurize(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows(), self.dim());
        self.featurize_into(x, out.data_mut());
        out
    }

    /// Chunk-parallel [`featurize_into`](Featurizer::featurize_into):
    /// scatters row ranges across the pool ([`Pool::par_chunks`]), each
    /// worker writing its block of `out` directly. Bit-identical to the
    /// sequential path because every featurizer maps rows independently.
    ///
    /// An explicit pool is **always honored**: there is no small-`n`
    /// fallback that silently serializes (a pool of `t` threads on `n < t`
    /// rows simply runs `n` workers), so pool bugs cannot hide behind
    /// small test inputs. Only a single-thread pool takes the serial
    /// path — which is the same computation by construction.
    fn featurize_par_into(&self, x: &Mat, out: &mut [f64], pool: &Pool) {
        let n = x.rows();
        assert_eq!(out.len(), n * self.dim(), "{}: featurize_par_into size", self.name());
        if pool.threads() <= 1 || n <= 1 {
            self.featurize_into(x, out);
            return;
        }
        pool.par_chunks(n, out, |lo, hi, block| {
            self.featurize_into(&x.row_block(lo, hi), block);
        });
    }

    /// Allocating variant of
    /// [`featurize_par_into`](Featurizer::featurize_par_into).
    fn featurize_par(&self, x: &Mat, pool: &Pool) -> Mat {
        let mut out = Mat::zeros(x.rows(), self.dim());
        self.featurize_par_into(x, out.data_mut(), pool);
        out
    }

    /// Human-readable method name (bench tables, registry lookups).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::kernels::Kernel;
    use crate::rng::Rng;

    /// Shared concentration check: max |Z Z^T - K| / max |K| below tol.
    pub fn check_gram_approx(
        feat: &dyn Featurizer,
        kernel: &Kernel,
        n: usize,
        d: usize,
        scale: f64,
        seed: u64,
        tol: f64,
    ) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, d, |_, _| rng.normal() * scale);
        let z = feat.featurize(&x);
        assert_eq!(z.rows(), n);
        assert_eq!(z.cols(), feat.dim());
        let k_hat = z.matmul_nt(&z);
        let k = kernel.gram(&x);
        let kmax = k.data().iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let err = k_hat.max_abs_diff(&k) / kmax;
        assert!(
            err < tol,
            "{}: relative gram error {err:.4} >= {tol}",
            feat.name()
        );
    }
}
