//! The paper's contribution: random Gegenbauer features for GZKs (Def. 8).
//!
//! Sample m i.i.d. directions w_k ~ U(S^{d-1}) and emit
//!
//!   Z[j, k*s + i] = (1/sqrt(m)) sum_l R[x_j][l, i] * P_d^l(<x_j, w_k>/||x_j||)
//!
//! where R folds the radial factors h_l and sqrt(alpha_{l,d}) (see
//! [`RadialTable`]). The column order (direction-major, radial-minor)
//! matches the L1 Pallas kernel so the PJRT path and this native path are
//! interchangeable bit-for-bit up to f32 rounding.
//!
//! This file is the native (pure-rust) hot path used by coordinator
//! workers; the AOT/PJRT path lives in `runtime`.

use super::radial::RadialTable;
use super::Featurizer;
use crate::exec::Pool;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::special::recurrence_coeffs;

/// Random Gegenbauer featurizer (the paper's Definition 8).
#[derive(Clone, Debug)]
pub struct GegenbauerFeatures {
    table: RadialTable,
    /// directions, row-major (m x d)
    w: Mat,
    /// recurrence coefficient arrays
    rec_a: Vec<f64>,
    rec_b: Vec<f64>,
}

impl GegenbauerFeatures {
    /// Sample `m` directions on S^{d-1} from `seed`. The same (table, m,
    /// seed) always produces the same feature map — the data-oblivious
    /// property the one-round distributed protocol relies on.
    pub fn new(table: RadialTable, m: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fork(0x6E6);
        let d = table.d;
        let w = Mat::from_vec(m, d, rng.sphere_matrix(m, d));
        let (rec_a, rec_b) = recurrence_coeffs(table.q, d);
        GegenbauerFeatures { table, w, rec_a, rec_b }
    }

    /// Build around explicit directions (used by tests and the PJRT parity
    /// harness).
    pub fn with_directions(table: RadialTable, w: Mat) -> Self {
        assert_eq!(w.cols(), table.d);
        let (rec_a, rec_b) = recurrence_coeffs(table.q, table.d);
        GegenbauerFeatures { table, w, rec_a, rec_b }
    }

    pub fn directions(&self) -> &Mat {
        &self.w
    }

    pub fn table(&self) -> &RadialTable {
        &self.table
    }

    pub fn num_directions(&self) -> usize {
        self.w.rows()
    }

    /// Featurize one point into `z_row` (length m*s). `t_buf` is scratch of
    /// length m; `r_buf` of length (q+1)*s.
    fn featurize_row(&self, x: &[f64], z_row: &mut [f64], t_buf: &mut [f64], r_buf: &mut [f64]) {
        let m = self.w.rows();
        let d = self.table.d;
        let q = self.table.q;
        let s = self.table.s;
        let inv_sqrt_m = 1.0 / (m as f64).sqrt();

        let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        self.table.values_into(norm, r_buf); // (q+1)*s, allocation-free
        let r = &*r_buf;

        // t_k = <x, w_k> / ||x||
        let inv = 1.0 / norm;
        for k in 0..m {
            let wrow = self.w.row(k);
            let mut acc = 0.0;
            for j in 0..d {
                acc += x[j] * wrow[j];
            }
            t_buf[k] = (acc * inv).clamp(-1.0, 1.0);
        }

        // Perf notes (EXPERIMENTS.md §Perf, three iterations):
        //  v1 (l-outer): streamed the (m*s) output row q times — memory
        //     bound, 0.39x of the equal-flop matmul roofline.
        //  v2 (k-outer, recurrence in registers): each z cell written once,
        //     but the three-term recurrence is a serial FMA chain per k —
        //     latency bound, no better.
        //  v3 (this): 8-lane chunks over directions; the recurrence runs on
        //     [f64; 8] lanes so the FMA chain has 8-way ILP and the
        //     compiler vectorizes it.
        const LANES: usize = 8;
        let rs = r;
        let aq = &self.rec_a;
        let bq = &self.rec_b;
        assert!(s <= 16, "radial order s > 16 not supported on the fast path");
        let mut k0 = 0;
        while k0 < m {
            let lanes = LANES.min(m - k0);
            let mut t = [0.0f64; LANES];
            t[..lanes].copy_from_slice(&t_buf[k0..k0 + lanes]);
            let mut pm1 = [1.0f64; LANES];
            let mut pc = t;
            // acc[i] holds the s radial channels, each on 8 lanes
            let mut acc = [[0.0f64; LANES]; 16];
            for (i, a) in acc.iter_mut().enumerate().take(s) {
                *a = [rs[i]; LANES]; // l = 0, P_0 = 1
            }
            for l in 1..=q {
                for i in 0..s {
                    let ri = rs[l * s + i];
                    if ri != 0.0 {
                        for j in 0..LANES {
                            acc[i][j] += ri * pc[j];
                        }
                    }
                }
                if l < q {
                    let (a, b) = (aq[l + 1], bq[l + 1]);
                    for j in 0..LANES {
                        let nxt = a * t[j] * pc[j] + b * pm1[j];
                        pm1[j] = pc[j];
                        pc[j] = nxt;
                    }
                }
            }
            for j in 0..lanes {
                for (i, a) in acc.iter().enumerate().take(s) {
                    z_row[(k0 + j) * s + i] = a[j] * inv_sqrt_m;
                }
            }
            k0 += lanes;
        }
    }
}

impl Featurizer for GegenbauerFeatures {
    fn dim(&self) -> usize {
        self.w.rows() * self.table.s
    }

    /// The primary batch map: streams rows through the shared scratch
    /// buffers straight into the caller's buffer (the chunk hot path never
    /// materializes an intermediate matrix).
    fn featurize_into(&self, x: &Mat, out: &mut [f64]) {
        let cols = self.dim();
        assert_eq!(x.cols(), self.table.d);
        assert_eq!(out.len(), x.rows() * cols);
        let mut t_buf = vec![0.0; self.w.rows()];
        let mut r_buf = vec![0.0; (self.table.q + 1) * self.table.s];
        for (i, z_row) in out.chunks_exact_mut(cols).enumerate() {
            self.featurize_row(x.row(i), z_row, &mut t_buf, &mut r_buf);
        }
    }

    /// Override of the chunk-parallel default: per-worker scratch buffers
    /// write straight into the shared output without even the row-block
    /// copy of `x` the default makes. Bit-identical to the sequential
    /// path — each row is independent — and, like the default, an explicit
    /// pool is always honored (no small-`n` serial fallback).
    fn featurize_par_into(&self, x: &Mat, out: &mut [f64], pool: &Pool) {
        let n = x.rows();
        let cols = self.dim();
        assert_eq!(x.cols(), self.table.d);
        assert_eq!(out.len(), n * cols);
        if pool.threads() <= 1 || n <= 1 {
            self.featurize_into(x, out);
            return;
        }
        pool.par_chunks(n, out, |lo, hi, block| {
            let mut t_buf = vec![0.0; self.w.rows()];
            let mut r_buf = vec![0.0; (self.table.q + 1) * self.table.s];
            for (r, i) in (lo..hi).enumerate() {
                self.featurize_row(
                    x.row(i),
                    &mut block[r * cols..(r + 1) * cols],
                    &mut t_buf,
                    &mut r_buf,
                );
            }
        });
    }

    fn name(&self) -> &'static str {
        "gegenbauer"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::test_support::check_gram_approx;
    use crate::kernels::Kernel;
    use crate::special::{alpha_dim, gegenbauer_eval};

    #[test]
    fn single_entry_formula() {
        // Z[j, k*s+i] must equal the scalar-by-scalar Def.-8 evaluation
        let table = RadialTable::gaussian(3, 5, 2);
        let feat = GegenbauerFeatures::new(table.clone(), 4, 9);
        let mut rng = crate::rng::Rng::new(64);
        let x = Mat::from_fn(3, 3, |_, _| rng.normal() * 0.8);
        let z = feat.featurize(&x);
        let (j, k, i) = (2usize, 3usize, 1usize);
        let xr = x.row(j);
        let norm = xr.iter().map(|v| v * v).sum::<f64>().sqrt();
        let r = table.values(&[norm]);
        let t: f64 =
            xr.iter().zip(feat.directions().row(k)).map(|(&a, &b)| a * b).sum::<f64>() / norm;
        let mut expect = 0.0;
        for l in 0..=table.q {
            expect += r[l * table.s + i] * gegenbauer_eval(l, 3, t);
        }
        expect /= (4.0f64).sqrt();
        assert!((z[(j, k * table.s + i)] - expect).abs() < 1e-12);
    }

    #[test]
    fn gram_concentrates_gaussian() {
        let table = RadialTable::gaussian(3, 14, 6);
        let feat = GegenbauerFeatures::new(table, 4096, 11);
        check_gram_approx(&feat, &Kernel::Gaussian { bandwidth: 1.0 }, 16, 3, 0.6, 65, 0.12);
    }

    #[test]
    fn gram_concentrates_exponential() {
        let table = RadialTable::exponential(3, 14, 6, 1.0);
        let feat = GegenbauerFeatures::new(table, 4096, 12);
        check_gram_approx(&feat, &Kernel::Exponential { gamma: 1.0 }, 12, 3, 0.6, 66, 0.15);
    }

    #[test]
    fn gram_concentrates_ntk_on_sphere() {
        let table = RadialTable::ntk(4, 24, 2);
        let feat = GegenbauerFeatures::new(table, 4096, 13);
        // points on the sphere: use scale trick then normalize inside check
        let mut rng = crate::rng::Rng::new(67);
        let mut x = Mat::zeros(10, 4);
        for i in 0..10 {
            rng.sphere(x.row_mut(i));
        }
        let z = feat.featurize(&x);
        let k_hat = z.matmul_nt(&z);
        let k = Kernel::Ntk { depth: 2 }.gram(&x);
        let err = k_hat.max_abs_diff(&k) / 2.0; // kappa(1) = 2 is the scale
        assert!(err < 0.12, "{err}");
    }

    #[test]
    fn unbiasedness_in_expectation() {
        // average Z Z^T over many seeds approaches K much closer than any
        // single draw: variance shrinks, bias stays (truncation only)
        let mut rng = crate::rng::Rng::new(68);
        let x = Mat::from_fn(6, 3, |_, _| rng.normal() * 0.5);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let table = RadialTable::gaussian(3, 12, 5);
        let mut mean = Mat::zeros(6, 6);
        let reps = 48;
        for rep in 0..reps {
            let feat = GegenbauerFeatures::new(table.clone(), 256, 1000 + rep);
            let z = feat.featurize(&x);
            mean.add_assign(&z.matmul_nt(&z));
        }
        mean.scale(1.0 / reps as f64);
        assert!(mean.max_abs_diff(&k) < 0.03, "{}", mean.max_abs_diff(&k));
    }

    #[test]
    fn deterministic_from_seed() {
        let table = RadialTable::gaussian(4, 8, 2);
        let f1 = GegenbauerFeatures::new(table.clone(), 64, 5);
        let f2 = GegenbauerFeatures::new(table, 64, 5);
        assert_eq!(f1.directions(), f2.directions());
        let mut rng = crate::rng::Rng::new(69);
        let x = Mat::from_fn(5, 4, |_, _| rng.normal());
        assert_eq!(f1.featurize(&x), f2.featurize(&x));
    }

    #[test]
    fn polynomial_features_match_exact_kernel_tightly() {
        // polynomial GZK truncation is exact, so only MC error remains —
        // and with enough directions ZZ^T -> K
        let table = RadialTable::polynomial(4, 2, 1.0);
        let feat = GegenbauerFeatures::new(table, 8192, 14);
        check_gram_approx(&feat, &Kernel::Polynomial { p: 2, c: 1.0 }, 10, 4, 0.8, 70, 0.1);
    }

    #[test]
    fn chebyshev_d2_path() {
        // d = 2 exercises the Chebyshev recurrence special case
        let table = RadialTable::gaussian(2, 12, 5);
        let feat = GegenbauerFeatures::new(table, 4096, 15);
        check_gram_approx(&feat, &Kernel::Gaussian { bandwidth: 1.0 }, 10, 2, 0.6, 71, 0.12);
    }

    #[test]
    fn parallel_featurize_bit_identical() {
        let table = RadialTable::gaussian(3, 10, 2);
        let feat = GegenbauerFeatures::new(table, 128, 17);
        let mut rng = crate::rng::Rng::new(73);
        let x = Mat::from_fn(101, 3, |_, _| rng.normal()); // odd row count
        let seq = feat.featurize(&x);
        for threads in [2usize, 3, 4, 8] {
            let par = feat.featurize_par(&x, &Pool::new(threads));
            assert_eq!(seq, par, "threads = {threads}");
        }
        // a pool wider than the row count is honored, not silently serialized
        let tiny = x.row_block(0, 3);
        assert_eq!(feat.featurize(&tiny), feat.featurize_par(&tiny, &Pool::new(8)));
    }

    #[test]
    fn zonal_rotation_invariance() {
        // on the sphere the Gaussian kernel is zonal: K(Rx, Ry) = K(x, y).
        // the feature gram (with the SAME directions) is only invariant in
        // expectation, so compare gram errors against the exact kernel.
        let mut rng = crate::rng::Rng::new(74);
        let n = 10;
        let mut x = Mat::zeros(n, 3);
        for i in 0..n {
            rng.sphere(x.row_mut(i));
        }
        // a rotation: orthonormalize a random 3x3 via Gram-Schmidt
        let mut rot = Mat::from_fn(3, 3, |_, _| rng.normal());
        for i in 0..3 {
            for j in 0..i {
                let dot: f64 = (0..3).map(|k| rot[(i, k)] * rot[(j, k)]).sum();
                for k in 0..3 {
                    rot[(i, k)] -= dot * rot[(j, k)];
                }
            }
            let norm: f64 = (0..3).map(|k| rot[(i, k)] * rot[(i, k)]).sum::<f64>().sqrt();
            for k in 0..3 {
                rot[(i, k)] /= norm;
            }
        }
        let xr = x.matmul_nt(&rot);
        let k = Kernel::Gaussian { bandwidth: 1.0 };
        // exact kernel invariant
        assert!(k.gram(&x).max_abs_diff(&k.gram(&xr)) < 1e-10);
        // feature gram errors comparable before/after rotation
        let feat = GegenbauerFeatures::new(RadialTable::gaussian(3, 10, 1), 4096, 75);
        let e1 = feat.featurize(&x).matmul_nt(&feat.featurize(&x)).max_abs_diff(&k.gram(&x));
        let e2 = feat.featurize(&xr).matmul_nt(&feat.featurize(&xr)).max_abs_diff(&k.gram(&xr));
        assert!(e1 < 0.25 && e2 < 0.25, "{e1} {e2}");
        assert!((e1 - e2).abs() < 0.1, "invariance broken: {e1} vs {e2}");
    }

    #[test]
    fn alpha_energy_sanity() {
        // feature column norms relate to alpha-weighted radial energy; just
        // assert all entries are finite and the scale is sane
        let table = RadialTable::gaussian(3, 10, 3);
        let feat = GegenbauerFeatures::new(table, 128, 16);
        let mut rng = crate::rng::Rng::new(72);
        let x = Mat::from_fn(4, 3, |_, _| rng.normal());
        let z = feat.featurize(&x);
        assert!(z.data().iter().all(|v| v.is_finite()));
        assert!(alpha_dim(2, 3) > 0.0);
    }
}
