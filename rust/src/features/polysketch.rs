//! PolySketch-style features [AKK+20]: sketch the Taylor expansion of the
//! Gaussian kernel degree by degree with TensorSketch [PP13].
//!
//! e^{<x,y>} = sum_j <x,y>^j / j!  and  <x^{tensor j}, y^{tensor j}> =
//! <x,y>^j, so concatenating sqrt(1/j!) * TS_j(x) over j = 0..deg (plus the
//! radial envelope e^{-|x|^2/2}) gives an unbiased sketch of the Gaussian
//! kernel truncated at degree `deg`. TS_j is the FFT-composed CountSketch
//! of the j-fold tensor power.

use super::Featurizer;
use crate::linalg::{fft_inplace, ifft_inplace, Mat};
use crate::rng::Rng;

#[derive(Clone, Debug)]
struct CountSketch {
    /// hash bucket per input coordinate
    h: Vec<usize>,
    /// sign per input coordinate
    s: Vec<f64>,
}

impl CountSketch {
    fn new(rng: &mut Rng, d: usize, m: usize) -> Self {
        CountSketch {
            h: (0..d).map(|_| rng.below(m)).collect(),
            s: (0..d).map(|_| rng.rademacher()).collect(),
        }
    }

    fn apply(&self, x: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for (j, &v) in x.iter().enumerate() {
            out[self.h[j]] += self.s[j] * v;
        }
    }
}

/// Sketch size per degree for a target feature budget: split the budget
/// (minus the constant coordinate) evenly across degrees and round down to
/// a power of two for the FFT composition. Shared with
/// `FeatureSpec::feature_dim` so the output dimension is derivable from a
/// spec without construction.
pub(crate) fn sketch_size(f_dim: usize, deg: usize) -> usize {
    let per = (f_dim.saturating_sub(1) / deg).max(2);
    if per.is_power_of_two() {
        per
    } else {
        per.next_power_of_two() / 2
    }
}

#[derive(Clone, Debug)]
pub struct PolySketchFeatures {
    d: usize,
    /// Taylor truncation degree
    deg: usize,
    /// sketch size per degree (power of two)
    m_per: usize,
    bandwidth: f64,
    /// sketches[j] holds the j CountSketches composing TS_j (degree j >= 1)
    sketches: Vec<Vec<CountSketch>>,
    /// sqrt(1/j!) scalings
    coeff: Vec<f64>,
}

impl PolySketchFeatures {
    pub fn new(d: usize, f_dim: usize, deg: usize, bandwidth: f64, seed: u64) -> Self {
        assert!(deg >= 1);
        let mut rng = Rng::new(seed).fork(0x9017);
        // degree 0 uses a single constant coordinate; split the rest evenly
        // and round down to a power of two for the FFT composition
        let m_per = sketch_size(f_dim, deg);
        let mut sketches = Vec::with_capacity(deg);
        for j in 1..=deg {
            sketches.push((0..j).map(|_| CountSketch::new(&mut rng, d, m_per)).collect());
        }
        let mut coeff = vec![1.0];
        let mut log_fact = 0.0;
        for j in 1..=deg {
            log_fact += (j as f64).ln();
            coeff.push((-0.5 * log_fact).exp());
        }
        PolySketchFeatures { d, deg, m_per, bandwidth, sketches, coeff }
    }

    /// TS_j(x): FFT-domain product of the j CountSketches.
    fn tensor_sketch(&self, j: usize, x: &[f64], scratch: &mut SketchScratch) -> Vec<f64> {
        let m = self.m_per;
        let cs = &self.sketches[j - 1];
        // accumulate product in FFT domain
        let (ar, ai) = (&mut scratch.acc_re, &mut scratch.acc_im);
        let (br, bi) = (&mut scratch.buf_re, &mut scratch.buf_im);
        cs[0].apply(x, ar);
        ai.fill(0.0);
        fft_inplace(ar, ai);
        for sketch in cs.iter().skip(1) {
            sketch.apply(x, br);
            bi.fill(0.0);
            fft_inplace(br, bi);
            for k in 0..m {
                let (r, i) = (ar[k] * br[k] - ai[k] * bi[k], ar[k] * bi[k] + ai[k] * br[k]);
                ar[k] = r;
                ai[k] = i;
            }
        }
        let mut out_re = ar.clone();
        let mut out_im = ai.clone();
        ifft_inplace(&mut out_re, &mut out_im);
        out_re
    }
}

struct SketchScratch {
    acc_re: Vec<f64>,
    acc_im: Vec<f64>,
    buf_re: Vec<f64>,
    buf_im: Vec<f64>,
}

impl Featurizer for PolySketchFeatures {
    fn dim(&self) -> usize {
        1 + self.deg * self.m_per
    }

    fn featurize_into(&self, x: &Mat, out: &mut [f64]) {
        assert_eq!(x.cols(), self.d);
        let f_dim = self.dim();
        assert_eq!(out.len(), x.rows() * f_dim, "polysketch: featurize_into size");
        let inv_bw = 1.0 / self.bandwidth;
        let mut scratch = SketchScratch {
            acc_re: vec![0.0; self.m_per],
            acc_im: vec![0.0; self.m_per],
            buf_re: vec![0.0; self.m_per],
            buf_im: vec![0.0; self.m_per],
        };
        let mut xs = vec![0.0; self.d];
        for (i, orow) in out.chunks_exact_mut(f_dim).enumerate() {
            let xr = x.row(i);
            let mut sq = 0.0;
            for (j, &v) in xr.iter().enumerate() {
                xs[j] = v * inv_bw;
                sq += xs[j] * xs[j];
            }
            let env = (-0.5 * sq).exp();
            // degree 0: constant 1 coordinate
            orow[0] = env * self.coeff[0];
            for j in 1..=self.deg {
                let ts = self.tensor_sketch(j, &xs, &mut scratch);
                let base = 1 + (j - 1) * self.m_per;
                let c = env * self.coeff[j];
                for (k, &v) in ts.iter().enumerate() {
                    orow[base + k] = c * v;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "polysketch"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;

    #[test]
    fn tensorsketch_degree2_unbiased() {
        // E[<TS_2(x), TS_2(y)>] = <x,y>^2; average over independent sketches
        let d = 6;
        let mut rng = crate::rng::Rng::new(110);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let exact = x.iter().zip(&y).map(|(&a, &b)| a * b).sum::<f64>().powi(2);
        let mut est = 0.0;
        let reps = 600;
        for rep in 0..reps {
            let ps = PolySketchFeatures::new(d, 65, 2, 1.0, 2000 + rep);
            let mut scratch = SketchScratch {
                acc_re: vec![0.0; ps.m_per],
                acc_im: vec![0.0; ps.m_per],
                buf_re: vec![0.0; ps.m_per],
                buf_im: vec![0.0; ps.m_per],
            };
            let tx = ps.tensor_sketch(2, &x, &mut scratch);
            let ty = ps.tensor_sketch(2, &y, &mut scratch);
            est += tx.iter().zip(&ty).map(|(&a, &b)| a * b).sum::<f64>();
        }
        est /= reps as f64;
        assert!((est - exact).abs() < 0.15 * exact.abs().max(1.0), "{est} vs {exact}");
    }

    #[test]
    fn gram_concentrates() {
        let feat = PolySketchFeatures::new(3, 8193, 6, 1.0, 13);
        let mut rng = crate::rng::Rng::new(111);
        let x = Mat::from_fn(10, 3, |_, _| rng.normal() * 0.5);
        let z = feat.featurize(&x);
        let k_hat = z.matmul_nt(&z);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let err = k_hat.max_abs_diff(&k);
        assert!(err < 0.2, "{err}");
    }

    #[test]
    fn dims_and_determinism() {
        let f1 = PolySketchFeatures::new(4, 257, 4, 1.0, 14);
        assert!(f1.dim() <= 257 + 64);
        let f2 = PolySketchFeatures::new(4, 257, 4, 1.0, 14);
        let mut rng = crate::rng::Rng::new(112);
        let x = Mat::from_fn(3, 4, |_, _| rng.normal());
        assert_eq!(f1.featurize(&x), f2.featurize(&x));
    }
}
