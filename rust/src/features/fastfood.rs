//! FastFood [LSS+13]: structured random Fourier features in O(F log d)
//! per point via Hadamard transforms instead of a dense Gaussian matrix.
//!
//! Per stacked block of size dp = 2^ceil(log2 d):
//!   V = (1/(sigma sqrt(dp))) * S H G Pi H B
//! with B, G diagonal (Rademacher / Gaussian), Pi a permutation, S a
//! chi-rescaling making row norms match a Gaussian matrix. Features are
//! cos(Vx + b) with the RFF scaling.

use super::Featurizer;
use crate::linalg::{fwht_inplace, Mat};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct FastFoodFeatures {
    d: usize,
    /// padded block size (power of two >= d)
    dp: usize,
    /// number of stacked blocks
    blocks: usize,
    f_dim: usize,
    bandwidth: f64,
    /// per block (one row each, blocks x dp): rademacher B, gaussian G,
    /// chi-rescaling S — flat matrices instead of vec-of-vecs so the
    /// whole parameter set is three contiguous buffers
    b_diag: Mat,
    g_diag: Mat,
    s_diag: Mat,
    /// permutations Pi, row-major (blocks x dp) in one flat buffer
    perm: Vec<usize>,
    phases: Vec<f64>,
}

impl FastFoodFeatures {
    pub fn new(d: usize, f_dim: usize, bandwidth: f64, seed: u64) -> Self {
        let dp = d.next_power_of_two();
        let blocks = f_dim.div_ceil(dp);
        let mut rng = Rng::new(seed).fork(0xFA57);
        let mut b_diag = Mat::zeros(blocks, dp);
        let mut g_diag = Mat::zeros(blocks, dp);
        let mut s_diag = Mat::zeros(blocks, dp);
        let mut perm = vec![0usize; blocks * dp];
        for blk in 0..blocks {
            for v in b_diag.row_mut(blk) {
                *v = rng.rademacher();
            }
            let g = g_diag.row_mut(blk);
            for v in g.iter_mut() {
                *v = rng.normal();
            }
            let g_frob: f64 = g.iter().map(|v| v * v).sum();
            let p = &mut perm[blk * dp..(blk + 1) * dp];
            for (i, v) in p.iter_mut().enumerate() {
                *v = i;
            }
            rng.shuffle(p);
            // S rescales each row to a chi_dp-distributed norm, matching an
            // i.i.d. Gaussian matrix row: s_i = chi_dp / ||G||_F
            for v in s_diag.row_mut(blk) {
                *v = rng.chi(dp) / g_frob.sqrt();
            }
        }
        let phases = (0..blocks * dp)
            .map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI))
            .collect();
        FastFoodFeatures { d, dp, blocks, f_dim, bandwidth, b_diag, g_diag, s_diag, perm, phases }
    }

    /// Apply the structured matrix of `block` to the padded input `buf`
    /// (length dp), in place.
    fn apply_block(&self, block: usize, buf: &mut [f64]) {
        let dp = self.dp;
        for (v, &b) in buf.iter_mut().zip(self.b_diag.row(block)) {
            *v *= b;
        }
        fwht_inplace(buf);
        // Pi
        let mut tmp = vec![0.0; dp];
        for (i, &p) in self.perm[block * dp..(block + 1) * dp].iter().enumerate() {
            tmp[i] = buf[p];
        }
        buf.copy_from_slice(&tmp);
        for (v, &g) in buf.iter_mut().zip(self.g_diag.row(block)) {
            *v *= g;
        }
        fwht_inplace(buf);
        let norm = 1.0 / (self.bandwidth * (dp as f64).sqrt());
        for (v, &s) in buf.iter_mut().zip(self.s_diag.row(block)) {
            *v *= s * norm;
        }
    }
}

impl Featurizer for FastFoodFeatures {
    fn dim(&self) -> usize {
        self.f_dim
    }

    fn featurize_into(&self, x: &Mat, out: &mut [f64]) {
        assert_eq!(x.cols(), self.d);
        assert_eq!(out.len(), x.rows() * self.f_dim, "fastfood: featurize_into size");
        let scale = (2.0 / self.f_dim as f64).sqrt();
        let mut buf = vec![0.0; self.dp];
        for (i, orow) in out.chunks_exact_mut(self.f_dim).enumerate() {
            let xr = x.row(i);
            for blk in 0..self.blocks {
                buf.fill(0.0);
                buf[..self.d].copy_from_slice(xr);
                self.apply_block(blk, &mut buf);
                for j in 0..self.dp {
                    let col = blk * self.dp + j;
                    if col < self.f_dim {
                        orow[col] = scale * (buf[j] + self.phases[col]).cos();
                    }
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "fastfood"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::test_support::check_gram_approx;
    use crate::kernels::Kernel;

    #[test]
    fn gram_concentrates() {
        // structured features have somewhat higher variance than dense RFF
        let feat = FastFoodFeatures::new(3, 8192, 1.0, 4);
        check_gram_approx(&feat, &Kernel::Gaussian { bandwidth: 1.0 }, 12, 3, 0.8, 90, 0.15);
    }

    #[test]
    fn projection_rows_look_gaussian() {
        // V x for x = e_1 should have mean 0 and variance 1/sigma^2 across rows
        let d = 8;
        let feat = FastFoodFeatures::new(d, 4096, 1.0, 5);
        let mut x = Mat::zeros(1, d);
        x[(0, 0)] = 1.0;
        // reach into apply_block via featurize on a zero-phase trick is
        // awkward; instead check the fourier feature diagonal: z.z ~ 1
        let z = feat.featurize(&x);
        let nrm: f64 = z.row(0).iter().map(|v| v * v).sum();
        assert!((nrm - 1.0).abs() < 0.1, "{nrm}");
    }

    #[test]
    fn non_power_of_two_dim() {
        let feat = FastFoodFeatures::new(9, 1000, 1.0, 6);
        assert_eq!(feat.dim(), 1000);
        let mut rng = crate::rng::Rng::new(91);
        let x = Mat::from_fn(5, 9, |_, _| rng.normal() * 0.5);
        let z = feat.featurize(&x);
        assert_eq!(z.cols(), 1000);
        assert!(z.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic() {
        let f1 = FastFoodFeatures::new(4, 256, 1.0, 8);
        let f2 = FastFoodFeatures::new(4, 256, 1.0, 8);
        let mut rng = crate::rng::Rng::new(92);
        let x = Mat::from_fn(3, 4, |_, _| rng.normal());
        assert_eq!(f1.featurize(&x), f2.featurize(&x));
    }
}
