//! Random Maclaurin features [KK12] for dot-product kernels, with the
//! standard Gaussian-kernel adaptation via the radial factorization
//! exp(-|x-y|^2/2) = e^{-|x|^2/2} e^{-|y|^2/2} e^{<x,y>}.
//!
//! Per output coordinate: sample degree N with P[N] = p^{-(N+1)} (p = 2),
//! then z(x) = sqrt(a_N p^{N+1}) prod_{k<=N} (w_k^T x) with Rademacher w_k,
//! where a_N is the kernel's Maclaurin coefficient (1/N! for exp).

use super::Featurizer;
use crate::linalg::Mat;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct MaclaurinFeatures {
    d: usize,
    /// for each feature: its degree and the packed Rademacher vectors
    degrees: Vec<usize>,
    /// flat Rademacher stack: feature f's `degrees[f]` vectors occupy
    /// `omega[omega_off[f] .. omega_off[f] + degrees[f] * d]` (degrees are
    /// ragged, so a single flat buffer + offsets replaces the old
    /// vec-of-vecs — one allocation, cache-linear scans)
    omega: Vec<f64>,
    omega_off: Vec<usize>,
    coeffs: Vec<f64>,
    /// Gaussian-kernel mode: multiply by e^{-|x|^2/(2 sigma^2)} and scale
    /// inputs by 1/sigma
    bandwidth: f64,
    max_degree: usize,
}

impl MaclaurinFeatures {
    /// Features for the Gaussian kernel of given bandwidth.
    pub fn new_gaussian(d: usize, f_dim: usize, bandwidth: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fork(0x3AC1);
        let p = 2.0f64;
        let max_degree = 24;
        let mut degrees = Vec::with_capacity(f_dim);
        let mut omega = Vec::new();
        let mut omega_off = Vec::with_capacity(f_dim);
        let mut coeffs = Vec::with_capacity(f_dim);
        // Maclaurin coefficients of exp: a_N = 1/N!
        let mut log_fact = vec![0.0f64; max_degree + 1];
        for k in 1..=max_degree {
            log_fact[k] = log_fact[k - 1] + (k as f64).ln();
        }
        for _ in 0..f_dim {
            // geometric degree: P[N] = 2^{-(N+1)}
            let mut n_deg = 0usize;
            while n_deg < max_degree && rng.next_u64() & 1 == 0 {
                n_deg += 1;
            }
            omega_off.push(omega.len());
            omega.extend((0..n_deg * d).map(|_| rng.rademacher()));
            // sqrt(a_N p^{N+1}) = sqrt(2^{N+1} / N!)
            let c = (0.5 * ((n_deg as f64 + 1.0) * p.ln() - log_fact[n_deg])).exp();
            degrees.push(n_deg);
            coeffs.push(c);
        }
        MaclaurinFeatures { d, degrees, omega, omega_off, coeffs, bandwidth, max_degree }
    }
}

impl Featurizer for MaclaurinFeatures {
    fn dim(&self) -> usize {
        self.degrees.len()
    }

    fn featurize_into(&self, x: &Mat, out: &mut [f64]) {
        assert_eq!(x.cols(), self.d);
        let f_dim = self.dim();
        assert_eq!(out.len(), x.rows() * f_dim, "maclaurin: featurize_into size");
        let inv_sqrt_f = 1.0 / (f_dim as f64).sqrt();
        let inv_bw = 1.0 / self.bandwidth;
        let mut xs = vec![0.0; self.d];
        for (i, orow) in out.chunks_exact_mut(f_dim).enumerate() {
            // scale by bandwidth and compute the Gaussian envelope
            let xr = x.row(i);
            let mut sq = 0.0;
            for (j, &v) in xr.iter().enumerate() {
                xs[j] = v * inv_bw;
                sq += xs[j] * xs[j];
            }
            let env = (-0.5 * sq).exp();
            for (f, orow_f) in orow.iter_mut().enumerate() {
                let deg = self.degrees[f];
                let off = self.omega_off[f];
                let omega = &self.omega[off..off + deg * self.d];
                let mut prod = 1.0;
                for k in 0..deg {
                    let mut dot = 0.0;
                    let wk = &omega[k * self.d..(k + 1) * self.d];
                    for j in 0..self.d {
                        dot += wk[j] * xs[j];
                    }
                    prod *= dot;
                }
                *orow_f = env * self.coeffs[f] * prod * inv_sqrt_f;
            }
        }
        let _ = self.max_degree;
    }

    fn name(&self) -> &'static str {
        "maclaurin"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;

    #[test]
    fn gram_concentrates_loosely() {
        // Maclaurin features are high-variance (the paper's Tables 2/3 show
        // it as the weakest method) — test with a generous tolerance
        let feat = MaclaurinFeatures::new_gaussian(3, 16384, 1.0, 9);
        let mut rng = crate::rng::Rng::new(100);
        let x = Mat::from_fn(10, 3, |_, _| rng.normal() * 0.5);
        let z = feat.featurize(&x);
        let k_hat = z.matmul_nt(&z);
        let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
        let err = k_hat.max_abs_diff(&k);
        assert!(err < 0.35, "{err}");
    }

    #[test]
    fn degree_distribution_geometric() {
        let feat = MaclaurinFeatures::new_gaussian(2, 20000, 1.0, 10);
        let zero = feat.degrees.iter().filter(|&&d| d == 0).count() as f64;
        let one = feat.degrees.iter().filter(|&&d| d == 1).count() as f64;
        assert!((zero / 20000.0 - 0.5).abs() < 0.02);
        assert!((one / 20000.0 - 0.25).abs() < 0.02);
    }

    #[test]
    fn deterministic() {
        let f1 = MaclaurinFeatures::new_gaussian(3, 128, 1.0, 11);
        let f2 = MaclaurinFeatures::new_gaussian(3, 128, 1.0, 11);
        let mut rng = crate::rng::Rng::new(101);
        let x = Mat::from_fn(4, 3, |_, _| rng.normal());
        assert_eq!(f1.featurize(&x), f2.featurize(&x));
    }

    #[test]
    fn finite_output() {
        let feat = MaclaurinFeatures::new_gaussian(5, 512, 2.0, 12);
        let mut rng = crate::rng::Rng::new(102);
        let x = Mat::from_fn(6, 5, |_, _| rng.normal() * 2.0);
        let z = feat.featurize(&x);
        assert!(z.data().iter().all(|v| v.is_finite()));
    }
}
