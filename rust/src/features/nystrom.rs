//! Nystrom features with recursive ridge-leverage-score landmark sampling
//! [MM17, WS01] — the data-*dependent* baseline the paper contrasts its
//! data-oblivious features against.
//!
//! Landmarks L of size m; Z(x) = Lchol^{-1} k_L(x) with K_LL = Lchol
//! Lchol^T, so Z Z^T = K_nL K_LL^{-1} K_Ln — the classic Nystrom
//! approximation. Landmarks are drawn uniformly, then refined one level by
//! approximate ridge leverage scores (the two-level core of MM17's
//! recursive scheme).

use super::Featurizer;
use crate::data::{gather_rows, DataSource, MatSource};
use crate::kernels::Kernel;
use crate::linalg::{Cholesky, Mat};
use crate::rng::Rng;

pub struct NystromFeatures {
    kernel: Kernel,
    /// landmark points (m x d)
    landmarks: Mat,
    /// Cholesky factor of K_LL (+ jitter)
    chol: Cholesky,
}

impl NystromFeatures {
    /// Fit on an in-memory training set: delegates to
    /// [`fit_source`](NystromFeatures::fit_source) over a borrowed
    /// [`MatSource`] — the in-memory and out-of-core fits are the same
    /// code path (and therefore bit-identical for the same rows).
    pub fn fit(kernel: Kernel, x_train: &Mat, m: usize, lambda: f64, seed: u64) -> Self {
        Self::fit_source(kernel, &MatSource::unlabeled(x_train), m, lambda, seed)
            .expect("in-memory source reads cannot fail")
    }

    /// Fit from any [`DataSource`]: two-level approximate
    /// ridge-leverage-score sampling (the core step of MM17's recursive
    /// scheme). Leverage scores are estimated on a candidate pool of
    /// min(n, 4m) uniform rows against a pilot of min(n, m) — the
    /// recursive-halving trick applied once. Only the candidate and pilot
    /// rows are ever materialized (O(m · d)), so a Nystrom fit over an
    /// out-of-core source never holds the n x d dataset.
    pub fn fit_source(
        kernel: Kernel,
        src: &dyn DataSource,
        m: usize,
        lambda: f64,
        seed: u64,
    ) -> Result<Self, String> {
        let n = src.len();
        if n == 0 {
            return Err("nystrom: cannot fit on an empty source".to_string());
        }
        let mut rng = Rng::new(seed).fork(0x9957);
        let m = m.min(n);

        // candidate pool (what we will sample landmarks from)
        let n_cand = (4 * m).min(n);
        let cand_idx = rng.sample_indices(n, n_cand);

        // level 0: uniform pilot of size min(n, m)
        let m0 = m.min(n);
        let idx0 = rng.sample_indices(n, m0);

        // the only rows the fit touches: candidates + pilot, O(m) of them
        let cand = gather_rows(src, &cand_idx)?;
        let pilot = gather_rows(src, &idx0)?;

        // approximate ridge leverage scores of the candidates against the
        // pilot: tau_i ~ (1/lambda)(k(x_i,x_i) - k_i^T (K_pp + l I)^{-1} k_i)
        let mut kpp = kernel.gram(&pilot);
        kpp.add_diag(lambda.max(1e-10));
        let (chol_p, _) = Cholesky::new_with_jitter(&kpp, 1e-10);
        let mut scores = Vec::with_capacity(n_cand);
        let mut ki = vec![0.0; m0];
        for c in 0..n_cand {
            for (j, kij) in ki.iter_mut().enumerate() {
                *kij = kernel.eval(cand.row(c), pilot.row(j));
            }
            let sol = chol_p.solve(&ki);
            let quad: f64 = ki.iter().zip(&sol).map(|(&a, &b)| a * b).sum();
            let kii = kernel.eval(cand.row(c), cand.row(c));
            scores.push(((kii - quad) / lambda.max(1e-10)).max(1e-12));
        }

        // level 1: sample m landmarks proportional to leverage scores
        let total: f64 = scores.iter().sum();
        let mut chosen = Vec::with_capacity(m);
        let mut used = vec![false; n_cand];
        while chosen.len() < m {
            let mut u = rng.uniform() * total;
            let mut pick = n_cand - 1;
            for (i, &sc) in scores.iter().enumerate() {
                if u < sc {
                    pick = i;
                    break;
                }
                u -= sc;
            }
            if !used[pick] {
                used[pick] = true;
                chosen.push(pick);
            }
        }
        let mut landmarks = Mat::zeros(m, src.dim());
        for (r, &i) in chosen.iter().enumerate() {
            landmarks.row_mut(r).copy_from_slice(cand.row(i));
        }

        Ok(Self::from_landmarks(kernel, landmarks))
    }

    /// Reconstruct the featurizer from its landmark set alone — the model
    /// artifact path: `fit` ends here too, so a featurizer rebuilt from
    /// persisted landmarks is bit-identical to the freshly fitted one
    /// (K_LL and its Cholesky are deterministic functions of the
    /// landmarks).
    pub fn from_landmarks(kernel: Kernel, landmarks: Mat) -> Self {
        let kll = kernel.gram(&landmarks);
        let (chol, _) = Cholesky::new_with_jitter(&kll, 1e-8);
        NystromFeatures { kernel, landmarks, chol }
    }

    pub fn landmarks(&self) -> &Mat {
        &self.landmarks
    }
}

impl Featurizer for NystromFeatures {
    fn dim(&self) -> usize {
        self.landmarks.rows()
    }

    fn featurize_into(&self, x: &Mat, out: &mut [f64]) {
        let m = self.landmarks.rows();
        assert_eq!(out.len(), x.rows() * m, "nystrom: featurize_into size");
        let mut k_row = vec![0.0; m];
        for (i, orow) in out.chunks_exact_mut(m).enumerate() {
            for (j, kij) in k_row.iter_mut().enumerate() {
                *kij = self.kernel.eval(x.row(i), self.landmarks.row(j));
            }
            let z = self.chol.solve_lower(&k_row);
            orow.copy_from_slice(&z);
        }
    }

    fn name(&self) -> &'static str {
        "nystrom"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_when_m_equals_n() {
        // with all points as landmarks, Z Z^T = K exactly
        let mut rng = crate::rng::Rng::new(120);
        let x = Mat::from_fn(20, 3, |_, _| rng.normal() * 0.7);
        let k = Kernel::Gaussian { bandwidth: 1.0 };
        let feat = NystromFeatures::fit(k.clone(), &x, 20, 1e-6, 1);
        let z = feat.featurize(&x);
        let k_hat = z.matmul_nt(&z);
        let kg = k.gram(&x);
        assert!(k_hat.max_abs_diff(&kg) < 1e-4, "{}", k_hat.max_abs_diff(&kg));
    }

    #[test]
    fn good_approximation_with_few_landmarks() {
        // smooth kernel + clustered data -> low effective rank
        let mut rng = crate::rng::Rng::new(121);
        let x = Mat::from_fn(100, 2, |_, _| rng.normal() * 0.4);
        let k = Kernel::Gaussian { bandwidth: 1.0 };
        let feat = NystromFeatures::fit(k.clone(), &x, 30, 1e-4, 2);
        let z = feat.featurize(&x);
        let k_hat = z.matmul_nt(&z);
        let kg = k.gram(&x);
        assert!(k_hat.max_abs_diff(&kg) < 0.05, "{}", k_hat.max_abs_diff(&kg));
    }

    #[test]
    fn nystrom_never_overestimates_diagonal() {
        // K - Z Z^T is PSD for Nystrom; check diagonal entries
        let mut rng = crate::rng::Rng::new(122);
        let x = Mat::from_fn(40, 3, |_, _| rng.normal());
        let k = Kernel::Gaussian { bandwidth: 1.0 };
        let feat = NystromFeatures::fit(k.clone(), &x, 10, 1e-4, 3);
        let z = feat.featurize(&x);
        for i in 0..40 {
            let zi: f64 = z.row(i).iter().map(|v| v * v).sum();
            assert!(zi <= 1.0 + 1e-6, "diag {zi}");
        }
    }

    #[test]
    fn rebuild_from_landmarks_is_bit_identical() {
        // the artifact round-trip invariant: fitting and rebuilding from
        // the fitted landmarks produce the same feature map exactly
        let mut rng = crate::rng::Rng::new(124);
        let x = Mat::from_fn(35, 3, |_, _| rng.normal() * 0.8);
        let k = Kernel::Gaussian { bandwidth: 1.0 };
        let fitted = NystromFeatures::fit(k.clone(), &x, 12, 1e-4, 9);
        let rebuilt = NystromFeatures::from_landmarks(k, fitted.landmarks().clone());
        assert_eq!(fitted.featurize(&x), rebuilt.featurize(&x));
        assert_eq!(fitted.dim(), rebuilt.dim());
    }

    #[test]
    fn deterministic() {
        let mut rng = crate::rng::Rng::new(123);
        let x = Mat::from_fn(30, 3, |_, _| rng.normal());
        let k = Kernel::Gaussian { bandwidth: 1.0 };
        let f1 = NystromFeatures::fit(k.clone(), &x, 8, 1e-4, 4);
        let f2 = NystromFeatures::fit(k, &x, 8, 1e-4, 4);
        assert_eq!(f1.featurize(&x), f2.featurize(&x));
    }
}
