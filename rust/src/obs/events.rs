//! Leveled structured event log: one newline-JSON record per event,
//! written to stderr by default or to the file installed by
//! [`set_log_file`] (the `--log-file` flag).
//!
//! Record schema (one object per line, fields after `msg` are
//! event-specific):
//!
//! ```text
//! {"ts":1754555555.123456,"level":"warn","target":"dist.leader",
//!  "msg":"worker 3 abandoned on shard 7 (died); reassigning",
//!  "worker":3,"shard":7}
//! ```
//!
//! `ts` is unix seconds with fractional part; `target` is a dotted
//! component path mirroring the registry naming scheme. The threshold
//! defaults to [`Level::Info`] and is set from `--log-level` or the
//! `GZK_LOG` env var. Emission must never take the process down: write
//! errors (closed stderr, full disk) are swallowed.

use std::fs::File;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity; ordered so a threshold admits itself and everything
/// more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a `--log-level` / `GZK_LOG` value (case-insensitive).
    pub fn parse(s: &str) -> Result<Level, String> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => {
                Err(format!("unknown log level {other:?}; known: error, warn, info, debug"))
            }
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

static THRESHOLD: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the emission threshold: events strictly less severe are dropped.
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

/// Default `--log-file` rotation cap (bytes); override per process with
/// [`set_log_file_capped`] (the `--log-cap-bytes` flag).
pub const DEFAULT_LOG_CAP_BYTES: u64 = 64 << 20;

/// The installed `--log-file` sink with its size-capped rotation state.
struct LogSink {
    file: File,
    path: String,
    written: u64,
    cap: u64,
}

impl LogSink {
    /// Write one event line, rotating first if it would push the file
    /// past the cap: the current file moves to `<path>.1` (replacing any
    /// previous `.1`) and the triggering line lands in the fresh file —
    /// rotation never loses the rotating write.
    fn write_line(&mut self, line: &str) {
        let len = line.len() as u64 + 1;
        if self.written + len > self.cap && self.written > 0 {
            let _ = self.file.flush();
            let _ = std::fs::rename(&self.path, format!("{}.1", self.path));
            match File::create(&self.path) {
                Ok(f) => {
                    self.file = f;
                    self.written = 0;
                }
                Err(_) => {
                    // keep writing to the renamed handle rather than
                    // dropping the event
                }
            }
        }
        if writeln!(self.file, "{line}").is_ok() {
            self.written += len;
        }
    }
}

static SINK: Mutex<Option<LogSink>> = Mutex::new(None);

/// Route all events to `path` (created/truncated) instead of stderr,
/// rotating at the default cap.
pub fn set_log_file(path: &str) -> Result<(), String> {
    set_log_file_capped(path, DEFAULT_LOG_CAP_BYTES)
}

/// [`set_log_file`] with an explicit rotation cap in bytes: once the
/// file would exceed it, it is renamed to `<path>.1` and a fresh file
/// takes over (one generation of history is kept).
pub fn set_log_file_capped(path: &str, cap: u64) -> Result<(), String> {
    if cap == 0 {
        return Err("log rotation cap must be >= 1 byte".to_string());
    }
    let file = File::create(path).map_err(|e| format!("open log file {path:?}: {e}"))?;
    *SINK.lock().expect("log sink lock") =
        Some(LogSink { file, path: path.to_string(), written: 0, cap });
    Ok(())
}

/// A typed event field value; call sites build them through the `From`
/// impls (`("shard", shard_id.into())`).
pub enum Field {
    U(u64),
    I(i64),
    F(f64),
    B(bool),
    S(String),
}

impl Field {
    fn to_json(&self) -> String {
        match self {
            Field::U(v) => v.to_string(),
            Field::I(v) => v.to_string(),
            Field::F(v) if v.is_finite() => format!("{v:?}"),
            Field::F(_) => "null".to_string(),
            Field::B(v) => v.to_string(),
            Field::S(v) => json_string(v),
        }
    }
}

impl From<u64> for Field {
    fn from(v: u64) -> Field {
        Field::U(v)
    }
}

impl From<u32> for Field {
    fn from(v: u32) -> Field {
        Field::U(u64::from(v))
    }
}

impl From<usize> for Field {
    fn from(v: usize) -> Field {
        Field::U(v as u64)
    }
}

impl From<i64> for Field {
    fn from(v: i64) -> Field {
        Field::I(v)
    }
}

impl From<f64> for Field {
    fn from(v: f64) -> Field {
        Field::F(v)
    }
}

impl From<bool> for Field {
    fn from(v: bool) -> Field {
        Field::B(v)
    }
}

impl From<&str> for Field {
    fn from(v: &str) -> Field {
        Field::S(v.to_string())
    }
}

impl From<String> for Field {
    fn from(v: String) -> Field {
        Field::S(v)
    }
}

/// Emit one event record if `level` clears the threshold.
pub fn event(level: Level, target: &str, msg: &str, fields: &[(&str, Field)]) {
    if (level as u8) > THRESHOLD.load(Ordering::Relaxed) {
        return;
    }
    let ts = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let mut line = format!(
        "{{\"ts\":{ts:.6},\"level\":\"{}\",\"target\":{},\"msg\":{}",
        level.name(),
        json_string(target),
        json_string(msg)
    );
    for (key, value) in fields {
        line.push(',');
        line.push_str(&json_string(key));
        line.push(':');
        line.push_str(&value.to_json());
    }
    line.push('}');
    // every emitted event also lands in the crash flight recorder; an
    // error-level event additionally triggers its on-error dump
    super::flightrec::record(&line);
    if level == Level::Error {
        super::flightrec::dump_on_error();
    }
    let mut sink = SINK.lock().expect("log sink lock");
    match sink.as_mut() {
        Some(sink) => sink.write_line(&line),
        None => {
            let _ = writeln!(std::io::stderr().lock(), "{line}");
        }
    }
}

pub fn error(target: &str, msg: &str, fields: &[(&str, Field)]) {
    event(Level::Error, target, msg, fields);
}

pub fn warn(target: &str, msg: &str, fields: &[(&str, Field)]) {
    event(Level::Warn, target, msg, fields);
}

pub fn info(target: &str, msg: &str, fields: &[(&str, Field)]) {
    event(Level::Info, target, msg, fields);
}

pub fn debug(target: &str, msg: &str, fields: &[(&str, Field)]) {
    event(Level::Debug, target, msg, fields);
}

/// Minimal JSON string escaper. Deliberately duplicated from the model
/// artifact codec: obs sits below every other layer and must not
/// depend upward.
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 || (c as u32) > 0x7e => {
                let cp = if (c as u32) > 0xFFFF { 0xFFFD } else { c as u32 };
                out.push_str(&format!("\\u{cp:04x}"));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("error").unwrap(), Level::Error);
        assert_eq!(Level::parse("WARN").unwrap(), Level::Warn);
        assert_eq!(Level::parse("Info").unwrap(), Level::Info);
        assert_eq!(Level::parse("debug").unwrap(), Level::Debug);
        let err = Level::parse("loud").unwrap_err();
        assert!(err.contains("known: error, warn, info, debug"), "{err}");
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn log_rotation_never_loses_the_rotating_write() {
        // drive a LogSink directly (the global SINK is process-wide and
        // other tests' events would interleave): a tiny cap forces many
        // rotations, and every recent line must survive in the live file
        // or the .1 generation — in particular the write that triggered
        // each rotation lands in the fresh file, never in the void
        let path = std::env::temp_dir()
            .join(format!("gzk-events-rotate-{}.log", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let rotated = format!("{path_s}.1");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&rotated);
        let mut sink = LogSink {
            file: File::create(&path_s).unwrap(),
            path: path_s.clone(),
            written: 0,
            cap: 200,
        };
        let total = 40;
        for i in 0..total {
            sink.write_line(&format!("{{\"msg\":\"rotate line {i:03}\"}}"));
        }
        drop(sink);
        let live = std::fs::read_to_string(&path).unwrap_or_default();
        let old = std::fs::read_to_string(&rotated).unwrap_or_default();
        assert!(
            live.len() as u64 <= 200,
            "live log {} bytes exceeds the 200-byte cap",
            live.len()
        );
        assert!(!old.is_empty(), "a 40-line run at cap 200 must have rotated");
        // the write that triggered the last rotation is the first line of
        // the fresh file — present, not lost
        assert!(
            live.contains(&format!("rotate line {:03}", total - 1)),
            "the final (rotating) write must land in the fresh file: {live:?}"
        );
        // survivors form a contiguous tail of the sequence: rotation
        // drops only the oldest generation, never a line in the middle
        let both = format!("{old}{live}");
        let survivors: Vec<usize> = (0..total)
            .filter(|i| both.contains(&format!("rotate line {i:03}")))
            .collect();
        let oldest = survivors[0];
        assert_eq!(
            survivors,
            (oldest..total).collect::<Vec<_>>(),
            "rotation lost a line in the middle of the tail"
        );
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&rotated);
    }

    #[test]
    fn fields_serialize_as_json_values() {
        assert_eq!(Field::from(7u64).to_json(), "7");
        assert_eq!(Field::from(-3i64).to_json(), "-3");
        assert_eq!(Field::from(1.5f64).to_json(), "1.5");
        assert_eq!(Field::from(f64::NAN).to_json(), "null");
        assert_eq!(Field::from(true).to_json(), "true");
        assert_eq!(Field::from("a\"b").to_json(), "\"a\\\"b\"");
    }
}
