//! `gzk trace-merge`: stitch per-process `--trace-out` files into one
//! Perfetto timeline, joined on the distributed trace IDs.
//!
//! Each process writes trace timestamps in microseconds since its own
//! monotonic origin, plus the wall-clock micros at which that origin
//! was pinned (`origin_unix_us` — see [`super::trace`]). Merging is a
//! two-step clock normalization:
//!
//! 1. **Baseline**: file *k*'s spans are shifted by
//!    `origin_unix_us[k] − origin_unix_us[0]`, which places every file
//!    on file 0's clock up to wall-clock error (NTP skew, coarse clock
//!    granularity — often hundreds of µs, which is visible at request
//!    timescales).
//! 2. **Trace-ID refinement** (the ping-round-trip rule): a request
//!    span on the client/proxy side *encloses* the matching server-side
//!    span for the same trace ID, and the enclosing minus the enclosed
//!    duration is the network round-trip. Assuming the two legs are
//!    symmetric — exactly the assumption behind normalizing clocks
//!    against a ping RTT — the midpoints of the two spans coincide in
//!    true time. For every trace ID shared with already-placed files
//!    the midpoint misalignment is computed and the **median** over all
//!    shared IDs is applied as the file's clock correction (median, so
//!    a straggling outlier request cannot skew the alignment).
//!
//! The merged document gives each input file its own `pid` (with a
//! `process_name` metadata record naming the source process and file),
//! shifts every timestamp so the earliest span sits at 0, and keeps the
//! `args.trace` join keys — load it in Perfetto and a traced predict
//! shows as nested spans across proxy and replica rows.
//!
//! This is an offline tool over trace files, not instrumentation, so —
//! unlike the recording half of the obs layer — it may lean on the
//! runtime JSON parser.

use crate::runtime::Json;
use std::path::Path;

use super::events::json_string;

/// One span parsed back out of a trace file.
struct Ev {
    name: String,
    cat: String,
    tid: u64,
    trace: Option<u64>,
    /// µs since the owning file's origin (f64: merged values are shifted
    /// by wall-clock deltas that need not be integral)
    ts: f64,
    dur: f64,
}

/// One parsed input file.
struct TraceFile {
    label: String,
    origin_unix_us: f64,
    events: Vec<Ev>,
    /// correction applied to place this file on the common clock
    shift: f64,
}

fn parse_file(path: &Path) -> Result<TraceFile, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("parse {path:?}: {e}"))?;
    let origin_unix_us =
        doc.get("origin_unix_us").and_then(Json::as_f64).unwrap_or(0.0);
    let pname = doc
        .get("process_name")
        .and_then(Json::as_str)
        .unwrap_or("gzk")
        .to_string();
    let pid = doc.get("process_pid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let file_name =
        path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    let label = format!("{pname} [{pid}] ({file_name})");
    let raw = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path:?}: no traceEvents array"))?;
    let mut events = Vec::with_capacity(raw.len());
    for e in raw {
        // only complete spans participate; metadata records are rebuilt
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        events.push(Ev {
            name: e.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            cat: e.get("cat").and_then(Json::as_str).unwrap_or("").to_string(),
            tid: e.get("tid").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            trace: e
                .get("args")
                .and_then(|a| a.get("trace"))
                .and_then(Json::as_str)
                .and_then(|s| s.parse::<u64>().ok()),
            ts: e.get("ts").and_then(Json::as_f64).unwrap_or(0.0),
            dur: e.get("dur").and_then(Json::as_f64).unwrap_or(0.0),
        });
    }
    Ok(TraceFile { label, origin_unix_us, events, shift: 0.0 })
}

/// For every trace ID, the midpoint of its longest already-placed span
/// (the longest span for an ID is the outermost — the enclosing side).
fn midpoints_by_trace(files: &[TraceFile]) -> std::collections::BTreeMap<u64, (f64, f64)> {
    let mut out: std::collections::BTreeMap<u64, (f64, f64)> = std::collections::BTreeMap::new();
    for f in files {
        for e in &f.events {
            let Some(t) = e.trace else { continue };
            let mid = e.ts + f.shift + e.dur / 2.0;
            match out.get(&t) {
                Some(&(_, dur)) if dur >= e.dur => {}
                _ => {
                    out.insert(t, (mid, e.dur));
                }
            }
        }
    }
    out
}

/// Merge `inputs` into one Chrome trace-event document (returned as a
/// string; the CLI writes it to `--out`).
pub fn merge_traces(inputs: &[std::path::PathBuf]) -> Result<String, String> {
    if inputs.len() < 2 {
        return Err("trace-merge needs at least two --inputs files".to_string());
    }
    let mut files = Vec::with_capacity(inputs.len());
    for path in inputs {
        files.push(parse_file(path)?);
    }
    let base = files[0].origin_unix_us;
    for k in 1..files.len() {
        // step 1: wall-clock baseline
        files[k].shift = files[k].origin_unix_us - base;
        // step 2: median midpoint correction over trace IDs shared with
        // the files already placed (file 0 is the reference clock)
        let placed = midpoints_by_trace(&files[..k]);
        let mut corrections: Vec<f64> = Vec::new();
        for e in &files[k].events {
            let Some(t) = e.trace else { continue };
            let Some(&(ref_mid, _)) = placed.get(&t) else { continue };
            let own_mid = e.ts + files[k].shift + e.dur / 2.0;
            corrections.push(ref_mid - own_mid);
        }
        if !corrections.is_empty() {
            corrections.sort_by(|a, b| a.partial_cmp(b).expect("finite corrections"));
            files[k].shift += corrections[corrections.len() / 2];
        }
    }
    // rebase so the earliest span lands at ts = 0
    let t0 = files
        .iter()
        .flat_map(|f| f.events.iter().map(move |e| e.ts + f.shift))
        .fold(f64::INFINITY, f64::min);
    let t0 = if t0.is_finite() { t0 } else { 0.0 };

    let mut out: Vec<String> = Vec::new();
    for (k, f) in files.iter().enumerate() {
        let pid = k + 1;
        out.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"args\":{{\"name\":{}}}}}",
            json_string(&f.label)
        ));
        for e in &f.events {
            let args = match e.trace {
                Some(t) => format!(",\"args\":{{\"trace\":\"{t}\"}}"),
                None => String::new(),
            };
            out.push(format!(
                "{{\"name\":{},\"cat\":{},\"ph\":\"X\",\"ts\":{:.1},\"dur\":{:.1},\"pid\":{pid},\"tid\":{}{}}}",
                json_string(&e.name),
                json_string(&e.cat),
                (e.ts + f.shift - t0).max(0.0),
                e.dur,
                e.tid,
                args
            ));
        }
    }
    Ok(format!("{{\"traceEvents\":[{}]}}\n", out.join(",")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_trace(
        tag: &str,
        origin_unix_us: u64,
        name: &str,
        spans: &[(&str, u64, u64, u64)], // (name, trace, ts, dur)
    ) -> std::path::PathBuf {
        let events: Vec<String> = spans
            .iter()
            .map(|(n, trace, ts, dur)| {
                let args = if *trace != 0 {
                    format!(",\"args\":{{\"trace\":\"{trace}\"}}")
                } else {
                    String::new()
                };
                format!(
                    "{{\"name\":\"{n}\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":{ts},\"dur\":{dur},\"pid\":1,\"tid\":1{args}}}"
                )
            })
            .collect();
        let doc = format!(
            "{{\"origin_unix_us\":{origin_unix_us},\"process_pid\":7,\"process_name\":\"{name}\",\"traceEvents\":[{}]}}",
            events.join(",")
        );
        let path = std::env::temp_dir()
            .join(format!("gzk-merge-unit-{}-{tag}.json", std::process::id()));
        std::fs::write(&path, doc).unwrap();
        path
    }

    #[test]
    fn shared_trace_ids_align_midpoints_across_skewed_clocks() {
        // proxy: a 1000µs request span for trace 42 starting at ts=100.
        // server: the matching 600µs span — its true midpoint equals the
        // proxy span's midpoint (symmetric legs), but the server's file
        // carries a wall-clock origin that is 500µs off true. The merge
        // must recover the alignment from the trace ID, not the origins.
        let proxy = write_trace("proxy", 1_000_000, "gzk proxy", &[("forward", 42, 100, 1000)]);
        // true server origin: proxy origin + 1000µs; the file lies by +500
        // (origin_unix_us = 1_001_500). In server-local time the span
        // midpoint is at 300µs (ts=0, dur=600) → true midpoint should be
        // proxy ts 600 (=100+1000/2).
        let server = write_trace("server", 1_001_500, "gzk server", &[("predict", 42, 0, 600)]);
        let merged = merge_traces(&[proxy.clone(), server.clone()]).unwrap();
        let doc = Json::parse(&merged).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let find = |name: &str| -> (f64, f64) {
            let e = events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("no {name} span in the merge"));
            (
                e.get("ts").and_then(Json::as_f64).unwrap(),
                e.get("dur").and_then(Json::as_f64).unwrap(),
            )
        };
        let (p_ts, p_dur) = find("forward");
        let (s_ts, s_dur) = find("predict");
        let p_mid = p_ts + p_dur / 2.0;
        let s_mid = s_ts + s_dur / 2.0;
        assert!(
            (p_mid - s_mid).abs() < 1e-6,
            "midpoints must align: proxy {p_mid} vs server {s_mid}"
        );
        // the server span nests inside the proxy span on the timeline
        assert!(s_ts >= p_ts && s_ts + s_dur <= p_ts + p_dur, "span must nest");
        // both files kept their trace join key and got distinct pids
        let pids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("pid").and_then(Json::as_f64).unwrap() as u64)
            .collect();
        assert_eq!(pids.len(), 2, "each input file gets its own pid");
        let _ = std::fs::remove_file(&proxy);
        let _ = std::fs::remove_file(&server);
    }

    #[test]
    fn merge_without_shared_traces_falls_back_to_wall_clock() {
        let a = write_trace("wc-a", 2_000_000, "gzk a", &[("alpha", 0, 0, 100)]);
        let b = write_trace("wc-b", 2_000_300, "gzk b", &[("beta", 0, 0, 100)]);
        let merged = merge_traces(&[a.clone(), b.clone()]).unwrap();
        let doc = Json::parse(&merged).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let ts_of = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .and_then(|e| e.get("ts").and_then(Json::as_f64))
                .unwrap()
        };
        // b's origin is 300µs later, so beta sits 300µs after alpha
        assert!((ts_of("beta") - ts_of("alpha") - 300.0).abs() < 1e-6);
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    #[test]
    fn merge_rejects_a_single_input() {
        let a = write_trace("single", 1, "gzk", &[]);
        let err = merge_traces(std::slice::from_ref(&a)).unwrap_err();
        assert!(err.contains("at least two"), "{err}");
        let _ = std::fs::remove_file(&a);
    }
}
