//! Crash/error flight recorder: a fixed-size ring of the most recent
//! event lines, per process.
//!
//! Every event record that clears the log threshold is also appended
//! here (see [`super::events::event`]); the ring keeps the last
//! [`SLOTS`] of them so that when something goes wrong the process can
//! answer "what happened just before?" without debug-level logging
//! having been on. Two ways out:
//!
//! * **on demand** — the wire `flightrec` command (answered locally by
//!   both `gzk server` and `gzk proxy`, like `metrics`) returns
//!   [`dump_json`];
//! * **on error** — when an error-level event fires and a dump path was
//!   installed ([`set_dump_path`], the `--flightrec <path>` flag), the
//!   ring is dumped there (latest error wins — the file is a snapshot
//!   of the moments before the most recent error).
//!
//! Writers are wait-free: a slot index is claimed with one atomic
//! fetch-add and the slot is filled under a `try_lock` — a contended
//! slot (another writer mid-replace, or a dump mid-read) drops the
//! record and counts it in `dropped` rather than blocking the event
//! path. std has no lock-free box swap, so per-slot mutexes with
//! try-lock-skip are the honest std-only approximation: no caller ever
//! waits, at the cost of a counted drop under contention.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Ring capacity: the last this-many event lines are kept.
pub const SLOTS: usize = 256;

static HEAD: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static DUMP_PATH: OnceLock<String> = OnceLock::new();

struct Slot {
    seq: u64,
    line: String,
}

fn ring() -> &'static Vec<Mutex<Option<Slot>>> {
    static RING: OnceLock<Vec<Mutex<Option<Slot>>>> = OnceLock::new();
    RING.get_or_init(|| (0..SLOTS).map(|_| Mutex::new(None)).collect())
}

/// Append one already-formatted event line (a JSON object) to the ring.
/// Wait-free; drops (and counts) the record if the slot is contended.
pub fn record(line: &str) {
    let seq = HEAD.fetch_add(1, Ordering::Relaxed);
    let slot = &ring()[(seq % SLOTS as u64) as usize];
    match slot.try_lock() {
        Ok(mut s) => *s = Some(Slot { seq, line: line.to_string() }),
        Err(_) => {
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Install the on-error dump path (first caller wins; the CLI's
/// `--flightrec <path>` flag). Without it, error-level events trigger
/// no dump and the ring is reachable only over the wire.
pub fn set_dump_path(path: &str) {
    let _ = DUMP_PATH.set(path.to_string());
}

/// Dump the ring to the installed path, if any — called by the event
/// layer on every error-level event. Write errors are swallowed (the
/// recorder must never take the process down with it).
pub fn dump_on_error() {
    if let Some(path) = DUMP_PATH.get() {
        let _ = std::fs::write(path, dump_json() + "\n");
    }
}

/// The ring as one JSON document: recent event lines in append order,
/// plus the global sequence cursor and the contended-drop count.
///
/// ```text
/// {"next_seq":412,"dropped":0,"events":[{...},{...}, ...]}
/// ```
pub fn dump_json() -> String {
    let mut entries: Vec<(u64, String)> = Vec::with_capacity(SLOTS);
    for slot in ring() {
        // try_lock on the read side too: skipping a slot a writer holds
        // beats stalling it
        if let Ok(s) = slot.try_lock() {
            if let Some(rec) = s.as_ref() {
                entries.push((rec.seq, rec.line.clone()));
            }
        }
    }
    entries.sort_by_key(|(seq, _)| *seq);
    let lines: Vec<String> = entries.into_iter().map(|(_, line)| line).collect();
    format!(
        "{{\"next_seq\":{},\"dropped\":{},\"events\":[{}]}}",
        HEAD.load(Ordering::Relaxed),
        DROPPED.load(Ordering::Relaxed),
        lines.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Json;

    #[test]
    fn ring_keeps_the_most_recent_records_in_order() {
        // other tests share the global ring, so assert only about our
        // own markers: write more than SLOTS of them, then the dump must
        // hold a contiguous, ordered suffix ending at the newest
        let total = SLOTS + 40;
        for i in 0..total {
            record(&format!("{{\"marker\":\"flightrec-{i:04}\"}}"));
        }
        let dump = dump_json();
        let doc = Json::parse(&dump).expect("dump is one valid JSON document");
        assert!(doc.get("next_seq").and_then(Json::as_f64).is_some());
        let events = doc.get("events").and_then(Json::as_arr).expect("events array");
        let ours: Vec<usize> = events
            .iter()
            .filter_map(|e| e.get("marker").and_then(Json::as_str))
            .filter_map(|m| m.strip_prefix("flightrec-")?.parse().ok())
            .collect();
        assert!(!ours.is_empty(), "ring lost every marker");
        assert!(
            ours.contains(&(total - 1)),
            "the newest marker must be in the ring: {ours:?}"
        );
        let mut sorted = ours.clone();
        sorted.sort_unstable();
        assert_eq!(ours, sorted, "dump must present records in append order");
        assert!(ours.len() <= SLOTS, "ring exceeded its capacity");
    }

    #[test]
    fn concurrent_writers_never_block_and_the_dump_stays_valid() {
        std::thread::scope(|scope| {
            for t in 0..4 {
                scope.spawn(move || {
                    for i in 0..500 {
                        record(&format!("{{\"w\":{t},\"i\":{i}}}"));
                    }
                });
            }
            for _ in 0..10 {
                let dump = dump_json();
                Json::parse(&dump).unwrap_or_else(|e| panic!("mid-flight dump invalid: {e}"));
            }
        });
        Json::parse(&dump_json()).expect("final dump valid");
    }
}
