//! Global lock-free metrics registry.
//!
//! Handles ([`Counter`], [`Gauge`], [`Hist`]) wrap `Arc`'d atomic
//! cells: an update is one relaxed atomic RMW, no lock. The name →
//! handle map sits behind a `Mutex` that is touched only at
//! registration and snapshot time, never on a hot path — call
//! [`counter`]/[`gauge`]/[`hist`] once and keep the handle. With the
//! registry disabled ([`set_enabled`]) every update costs exactly one
//! relaxed atomic load (the bench's instrumentation-overhead section
//! measures both states on the featurize+absorb hot path).
//!
//! Naming scheme: dotted lowercase paths, `<layer>.<thing>[.<detail>]`
//! — e.g. `exec.jobs`, `pipeline.rows`, `dist.leader.shards_reassigned`,
//! `proxy.replica.127.0.0.1:7711.ejections`, `serve.requests`. Dynamic
//! segments (replica addresses) are allowed, which is why names are
//! `String`s rather than `&'static str`.
//!
//! Histograms record **seconds** on the shared 1-2-5 log ladder
//! ([`LADDER_BOUNDS`], 1 µs … 50 s plus one overflow cell) — the ladder
//! PR 5 introduced for serving latency, hoisted here so every histogram
//! in the process is offline-comparable bucket for bucket.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::events::json_string;

/// Histogram bucket upper bounds in seconds: {1, 2, 5} × 10^e for e in
/// -6..=1.
pub const LADDER_BOUNDS: [f64; 24] = [
    1e-6, 2e-6, 5e-6, 1e-5, 2e-5, 5e-5, 1e-4, 2e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2, 2e-2, 5e-2,
    1e-1, 2e-1, 5e-1, 1.0, 2.0, 5.0, 1e1, 2e1, 5e1,
];

/// Cells per histogram: one per ladder bound plus one overflow cell.
pub const LADDER_CELLS: usize = LADDER_BOUNDS.len() + 1;

/// The ladder cell `v` (seconds) falls in; the last cell is overflow.
pub fn ladder_bucket(v: f64) -> usize {
    LADDER_BOUNDS.iter().position(|&b| v <= b).unwrap_or(LADDER_BOUNDS.len())
}

/// Shared quantile semantics for ladder histograms: the `q`-quantile
/// (`0.0 < q <= 1.0`) resolves to the **upper bound** of the bucket the
/// target rank lands in (≤ one ladder step of error); 0.0 when nothing
/// was recorded, and the overflow cell reports 2× the last bound.
pub fn quantile_of(counts: &[u64; LADDER_CELLS], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return if i < LADDER_BOUNDS.len() {
                LADDER_BOUNDS[i]
            } else {
                2.0 * LADDER_BOUNDS[LADDER_BOUNDS.len() - 1]
            };
        }
    }
    unreachable!("cumulative count reaches total")
}

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Whether updates are recorded — one relaxed load, the hot-path gate.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Globally enable/disable recording. Snapshots keep working while
/// disabled; the handles simply stop counting.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Monotonic counter; clone freely, updates are relaxed atomic adds.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value-wins signed gauge (queue depths, fleet sizes).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    pub fn add(&self, delta: i64) {
        if enabled() {
            self.0.fetch_add(delta, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistCells {
    counts: [AtomicU64; LADDER_CELLS],
}

/// Fixed-bucket histogram of seconds on the shared 1-2-5 ladder;
/// recording is one relaxed atomic add into the value's cell.
#[derive(Clone)]
pub struct Hist(Arc<HistCells>);

impl Default for Hist {
    fn default() -> Hist {
        Hist(Arc::new(HistCells { counts: std::array::from_fn(|_| AtomicU64::new(0)) }))
    }
}

impl Hist {
    /// Count one observation of `secs` into its ladder cell.
    pub fn record(&self, secs: f64) {
        if enabled() {
            self.0.counts[ladder_bucket(secs)].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A point-in-time copy of the cell counts.
    pub fn counts(&self) -> [u64; LADDER_CELLS] {
        std::array::from_fn(|i| self.0.counts[i].load(Ordering::Relaxed))
    }

    /// Total observations recorded so far.
    pub fn total(&self) -> u64 {
        self.counts().iter().sum()
    }

    /// Bucket-upper-bound quantile — see [`quantile_of`].
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_of(&self.counts(), q)
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Hist(Hist),
}

fn metrics() -> &'static Mutex<BTreeMap<String, Metric>> {
    static METRICS: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    METRICS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Register-or-fetch the counter named `name`. A name already taken by
/// a different metric kind yields a detached (unregistered) handle —
/// the caller still counts, the snapshot just cannot show it.
pub fn counter(name: &str) -> Counter {
    let mut map = metrics().lock().expect("metrics registry lock");
    match map.entry(name.to_string()).or_insert_with(|| Metric::Counter(Counter::default())) {
        Metric::Counter(c) => c.clone(),
        _ => Counter::default(),
    }
}

/// Register-or-fetch the gauge named `name` (see [`counter`]).
pub fn gauge(name: &str) -> Gauge {
    let mut map = metrics().lock().expect("metrics registry lock");
    match map.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Gauge::default())) {
        Metric::Gauge(g) => g.clone(),
        _ => Gauge::default(),
    }
}

/// Register-or-fetch the histogram named `name` (see [`counter`]).
pub fn hist(name: &str) -> Hist {
    let mut map = metrics().lock().expect("metrics registry lock");
    match map.entry(name.to_string()).or_insert_with(|| Metric::Hist(Hist::default())) {
        Metric::Hist(h) => h.clone(),
        _ => Hist::default(),
    }
}

/// One consistent JSON document of every registered metric: the map
/// lock is held for the whole walk, so a registration cannot interleave
/// with the snapshot (individual cells are relaxed loads — exact once
/// the writers have quiesced, tested by the 8-thread property test).
///
/// Shape:
/// `{"enabled":true,"ladder_bounds_s":[...],"counters":{...},
///   "gauges":{...},"hists":{"name":{"total":N,"p50_s":...,"p95_s":...,
///   "p99_s":...,"counts":[...]}}}`
pub fn snapshot_json() -> String {
    let map = metrics().lock().expect("metrics registry lock");
    let mut counters = Vec::new();
    let mut gauges = Vec::new();
    let mut hists = Vec::new();
    for (name, metric) in map.iter() {
        match metric {
            Metric::Counter(c) => counters.push(format!("{}:{}", json_string(name), c.get())),
            Metric::Gauge(g) => gauges.push(format!("{}:{}", json_string(name), g.get())),
            Metric::Hist(h) => {
                let counts = h.counts();
                let total: u64 = counts.iter().sum();
                let cells: Vec<String> = counts.iter().map(u64::to_string).collect();
                hists.push(format!(
                    "{}:{{\"total\":{},\"p50_s\":{:?},\"p95_s\":{:?},\"p99_s\":{:?},\"counts\":[{}]}}",
                    json_string(name),
                    total,
                    quantile_of(&counts, 0.5),
                    quantile_of(&counts, 0.95),
                    quantile_of(&counts, 0.99),
                    cells.join(",")
                ));
            }
        }
    }
    let bounds: Vec<String> = LADDER_BOUNDS.iter().map(|b| format!("{b:?}")).collect();
    format!(
        "{{\"enabled\":{},\"ladder_bounds_s\":[{}],\"counters\":{{{}}},\"gauges\":{{{}}},\"hists\":{{{}}}}}",
        enabled(),
        bounds.join(","),
        counters.join(","),
        gauges.join(","),
        hists.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `set_enabled` is process-global, so the tests that flip it or
    /// assert exact counts must not interleave.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().expect("registry test lock")
    }

    #[test]
    fn ladder_edges_are_exact() {
        // 1 µs is the FIRST bucket (bounds are inclusive upper bounds)
        assert_eq!(ladder_bucket(1e-6), 0);
        assert_eq!(ladder_bucket(1.5e-6), 1);
        // 50 s is the last bounded bucket; anything beyond overflows
        assert_eq!(ladder_bucket(5e1), LADDER_BOUNDS.len() - 1);
        assert_eq!(ladder_bucket(50.0001), LADDER_BOUNDS.len());
        assert_eq!(ladder_bucket(f64::INFINITY), LADDER_BOUNDS.len());
        // zero and negative land in the first cell, never panic
        assert_eq!(ladder_bucket(0.0), 0);
        assert_eq!(ladder_bucket(-1.0), 0);
    }

    #[test]
    fn hist_quantiles_match_the_serving_semantics() {
        let _guard = test_lock();
        let h = hist("test.registry.hist_quantiles");
        assert_eq!(h.quantile(0.5), 0.0, "empty hist reports 0");
        for _ in 0..90 {
            h.record(1.5e-6); // -> 2 µs bucket
        }
        for _ in 0..10 {
            h.record(0.3); // -> 0.5 s bucket
        }
        assert_eq!(h.total(), 100);
        assert_eq!(h.quantile(0.5), 2e-6);
        assert_eq!(h.quantile(0.99), 0.5);
        h.record(1e4); // overflow reports 2x the last bound
        assert_eq!(h.quantile(1.0), 100.0);
    }

    #[test]
    fn disabled_registry_stops_counting_but_keeps_snapshotting() {
        let _guard = test_lock();
        let c = counter("test.registry.disabled_counter");
        c.add(3);
        set_enabled(false);
        c.add(100);
        set_enabled(true);
        c.inc();
        assert_eq!(c.get(), 4);
        assert!(snapshot_json().contains("\"test.registry.disabled_counter\":4"));
    }

    #[test]
    fn same_name_returns_the_same_cell_and_kind_clash_detaches() {
        let _guard = test_lock();
        let a = counter("test.registry.shared");
        let b = counter("test.registry.shared");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        // a gauge under a counter's name must not panic or corrupt it
        let g = gauge("test.registry.shared");
        g.set(-7);
        assert_eq!(a.get(), 2);
        assert!(snapshot_json().contains("\"test.registry.shared\":2"));
    }
}
