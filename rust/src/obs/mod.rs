//! Observability layer (L0): a global metrics registry, leveled
//! structured events, distributed-trace spans, and a crash flight
//! recorder — shared by every layer from the exec-pool job waves to the
//! dist fleet.
//!
//! Independent channels, all std-only and all **read-only with respect
//! to results**: instrumentation never touches the data being computed,
//! so the bit-identity contracts of the parallel, chunked and
//! distributed fits hold with everything enabled (tested in
//! `tests/obs_props.rs` and `tests/trace_e2e.rs`).
//!
//! - [`registry`] — named counters, gauges and fixed-bucket histograms
//!   behind lock-free atomic cells; one consistent JSON snapshot backs
//!   the wire `metrics` command on `gzk server` and `gzk proxy` (and so
//!   the `gzk top` fleet monitor). A disabled registry costs one
//!   relaxed atomic load per update.
//! - [`events`] — leveled (error/warn/info/debug) newline-JSON records
//!   to stderr or the `--log-file` target (size-capped rotation to
//!   `<path>.1`), replacing bare `eprintln` diagnostics so
//!   worker-death/reassignment and replica-ejection stories are
//!   machine-parseable. Threshold via `--log-level` or `GZK_LOG`
//!   (default `info`).
//! - [`trace`] — RAII spans recorded into per-thread buffers and dumped
//!   as Chrome trace-event JSON by `--trace-out`, now carrying a
//!   distributed request/trace ID minted at ingress so per-process
//!   files stitch into one fleet timeline via `gzk trace-merge`
//!   ([`merge`]).
//! - [`flightrec`] — a fixed-size wait-free ring of the most recent
//!   event lines, dumped as JSON on error-level events and on demand
//!   via the wire `flightrec` command.

pub mod events;
pub mod flightrec;
pub mod merge;
pub mod registry;
pub mod trace;

pub use events::{debug, error, info, warn, Field, Level};
pub use registry::{counter, gauge, hist, Counter, Gauge, Hist};
pub use trace::{span, Span};
