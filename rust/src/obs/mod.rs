//! Observability layer (L0): a global metrics registry, leveled
//! structured events, and scoped trace spans — shared by every layer
//! from the exec-pool job waves to the dist fleet.
//!
//! Three independent channels, all std-only and all **read-only with
//! respect to results**: instrumentation never touches the data being
//! computed, so the bit-identity contracts of the parallel, chunked and
//! distributed fits hold with everything enabled (tested in
//! `tests/obs_props.rs`).
//!
//! - [`registry`] — named counters, gauges and fixed-bucket histograms
//!   behind lock-free atomic cells; one consistent JSON snapshot backs
//!   the wire `metrics` command on `gzk server` and `gzk proxy`. A
//!   disabled registry costs one relaxed atomic load per update.
//! - [`events`] — leveled (error/warn/info/debug) newline-JSON records
//!   to stderr or the `--log-file` target, replacing bare `eprintln`
//!   diagnostics so worker-death/reassignment and replica-ejection
//!   stories are machine-parseable. Threshold via `--log-level` or
//!   `GZK_LOG` (default `info`).
//! - [`trace`] — RAII spans recorded into per-thread buffers and dumped
//!   as Chrome trace-event JSON by `--trace-out` (load the file in
//!   `chrome://tracing` or Perfetto to see featurize/absorb/solve/
//!   chunk-I/O/scatter/merge stages on a timeline).

pub mod events;
pub mod registry;
pub mod trace;

pub use events::{debug, error, info, warn, Field, Level};
pub use registry::{counter, gauge, hist, Counter, Gauge, Hist};
pub use trace::{span, Span};
