//! Scoped trace spans → Chrome trace-event JSON, with distributed
//! request/trace IDs for cross-process stitching.
//!
//! A [`Span`] is an RAII timer: created via [`span`], it records
//! (name, category, thread, trace, start, duration) into a
//! **per-thread** buffer when dropped — no locking on the hot path.
//! Buffers drain into a global list when their thread exits, when they
//! grow past [`DRAIN_SPANS`] records, or after [`DRAIN_INTERVAL`] since
//! the last drain (so `--trace-out` is usable on a long-lived server
//! whose event-loop threads never exit), or when [`write_chrome_trace`]
//! flushes the calling thread explicitly.
//!
//! **Trace IDs.** [`mint_trace_id`] returns a compact u64 request ID:
//! random high 32 bits (drawn once per process from the crate [`Rng`]
//! seeded off the clock and pid, so two processes minting concurrently
//! collide with probability ~2⁻³²) | a per-process counter in the low
//! 32 bits. 0 means "untraced". The ID is minted at ingress (loadgen or
//! the proxy), carried request-direction-only on the wire (JSON `"tid"`
//! field / GZF2 frame header slot / dist `"tid"` fields — replies never
//! carry it, so traced replies stay byte-identical to untraced ones),
//! and attached to spans two ways: explicitly via [`record_since`], or
//! ambiently via [`with_trace`] — an RAII guard that sets the calling
//! thread's current trace so nested spans (a worker's featurize/absorb
//! under its shard) inherit it. `gzk trace-merge` joins the per-process
//! trace files on these IDs.
//!
//! Tracing is off by default: until [`enable`] is called (the CLI does
//! so for `--trace-out`), creating a span costs one relaxed atomic load
//! and allocates nothing. The written file is the Chrome trace-event
//! format — open it in `chrome://tracing` or Perfetto:
//!
//! ```text
//! {"origin_unix_us":1754555555123456,"process_pid":4242,
//!  "process_name":"gzk server",
//!  "traceEvents":[{"name":"featurize","cat":"pipeline","ph":"X",
//!                  "ts":1234,"dur":567,"pid":1,"tid":2,
//!                  "args":{"trace":"81985529216486895"}}, ...]}
//! ```
//!
//! `origin_unix_us` (wall-clock micros when the monotonic origin was
//! pinned) and the process fields are what `gzk trace-merge` uses to
//! place files from different processes on one timeline.
//!
//! Span naming convention: short stage verbs scoped by category —
//! `cat:"pipeline"` for `chunk.read`/`featurize`/`absorb`/`eval`,
//! `cat:"fit"` for `scatter`/`merge`/`solve`/`recover`, `cat:"dist"`
//! for `register`/`scatter`/`shard N`/`recover`, `cat:"exec"` for
//! `jobs`, `cat:"serve"`/`cat:"proxy"` for per-request predict spans.
//!
//! [`Rng`]: crate::rng::Rng

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use super::events::json_string;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ORIGIN: OnceLock<Instant> = OnceLock::new();
/// Wall-clock micros at the instant ORIGIN was pinned — the cross-file
/// baseline for `gzk trace-merge`.
static ORIGIN_UNIX_US: OnceLock<u64> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DONE: Mutex<Vec<SpanRec>> = Mutex::new(Vec::new());
static PROCESS_NAME: OnceLock<String> = OnceLock::new();

/// Drain a thread's span buffer once it holds this many records.
pub const DRAIN_SPANS: usize = 128;
/// ... or once this long has passed since its last drain, whichever
/// comes first (checked at span-record time — no timer thread).
pub const DRAIN_INTERVAL_US: u64 = 1_000_000;

/// High 32 bits of every trace ID this process mints.
static TRACE_HIGH: OnceLock<u64> = OnceLock::new();
/// Low-32-bit per-process mint counter (starts at 1 so the first ID is
/// never 0 even under an all-zero random draw).
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

struct SpanRec {
    name: String,
    cat: &'static str,
    tid: u64,
    /// distributed request/trace ID; 0 = untraced
    trace: u64,
    ts_us: u64,
    dur_us: u64,
}

/// Turn span collection on (idempotent). The first call pins the
/// timeline origin; all `ts` values are microseconds since it.
pub fn enable() {
    ORIGIN.get_or_init(|| {
        ORIGIN_UNIX_US.get_or_init(|| {
            SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_micros() as u64)
                .unwrap_or(0)
        });
        Instant::now()
    });
    ENABLED.store(true, Ordering::Relaxed);
}

/// Whether spans are being collected — one relaxed load.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Name this process in the written trace (the CLI passes its
/// subcommand); first caller wins.
pub fn set_process_name(name: &str) {
    let _ = PROCESS_NAME.set(name.to_string());
}

/// Mint a new nonzero request/trace ID: random high 32 bits (fixed per
/// process) | per-process counter low 32 bits.
pub fn mint_trace_id() -> u64 {
    let high = *TRACE_HIGH.get_or_init(|| {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let seed = nanos ^ (u64::from(std::process::id()) << 32) ^ 0x6765_676b_5f74_6964;
        crate::rng::Rng::new(seed).next_u64() & 0xffff_ffff_0000_0000
    });
    let low = NEXT_TRACE.fetch_add(1, Ordering::Relaxed) & 0xffff_ffff;
    let id = high | low;
    if id == 0 {
        1
    } else {
        id
    }
}

thread_local! {
    /// The trace ID ambient spans on this thread inherit; 0 = none.
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
}

/// RAII guard from [`with_trace`]; restores the previous ambient trace
/// ID on drop (guards nest).
pub struct TraceCtx {
    prev: u64,
}

/// Set the calling thread's ambient trace ID until the guard drops —
/// spans opened inside inherit it (a dist worker wraps its shard work
/// in the job's trace this way).
pub fn with_trace(trace: u64) -> TraceCtx {
    let prev = CURRENT_TRACE.with(|c| c.replace(trace));
    TraceCtx { prev }
}

impl Drop for TraceCtx {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT_TRACE.with(|c| c.set(prev));
    }
}

/// The calling thread's ambient trace ID (0 = none).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(Cell::get)
}

struct LocalBuf {
    tid: u64,
    recs: Vec<SpanRec>,
    /// `ts_us` of the last drain, for the periodic-drain policy
    last_drain_us: u64,
}

impl LocalBuf {
    fn push(&mut self, rec: SpanRec) {
        let now_us = rec.ts_us.saturating_add(rec.dur_us);
        self.recs.push(rec);
        if self.recs.len() >= DRAIN_SPANS
            || now_us.saturating_sub(self.last_drain_us) >= DRAIN_INTERVAL_US
        {
            if let Ok(mut done) = DONE.lock() {
                done.append(&mut self.recs);
            }
            self.last_drain_us = now_us;
        }
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        if !self.recs.is_empty() {
            if let Ok(mut done) = DONE.lock() {
                done.append(&mut self.recs);
            }
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        recs: Vec::new(),
        last_drain_us: 0,
    });
}

/// An in-flight scoped timer; recording happens on drop. With tracing
/// disabled this is `None` — no allocation, no clock read.
pub struct Span(Option<OpenSpan>);

struct OpenSpan {
    name: String,
    cat: &'static str,
    trace: u64,
    start: Instant,
}

/// Open a span; it records when the returned guard drops. The span
/// carries the thread's ambient trace ID (see [`with_trace`]).
pub fn span(cat: &'static str, name: &str) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(OpenSpan {
        name: name.to_string(),
        cat,
        trace: current_trace(),
        start: Instant::now(),
    }))
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(open) = self.0.take() else {
            return;
        };
        let origin = *ORIGIN.get().expect("tracing enabled implies an origin");
        let rec = SpanRec {
            name: open.name,
            cat: open.cat,
            tid: 0, // assigned below from the thread-local
            trace: open.trace,
            ts_us: open.start.duration_since(origin).as_micros() as u64,
            dur_us: open.start.elapsed().as_micros() as u64,
        };
        LOCAL.with(|local| {
            let mut buf = local.borrow_mut();
            let tid = buf.tid;
            buf.push(SpanRec { tid, ..rec });
        });
    }
}

/// Record a completed span from an explicit start instant and trace ID
/// — for paths where a request's start and completion happen in
/// different stack frames (the mux event loop opens no RAII guard; it
/// remembers the dispatch instant and records here when the reply
/// pumps out). No-op with tracing disabled.
pub fn record_since(cat: &'static str, name: &str, trace: u64, start: Instant) {
    if !enabled() {
        return;
    }
    let origin = *ORIGIN.get().expect("tracing enabled implies an origin");
    let rec = SpanRec {
        name: name.to_string(),
        cat,
        tid: 0,
        trace,
        ts_us: start
            .checked_duration_since(origin)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0),
        dur_us: start.elapsed().as_micros() as u64,
    };
    LOCAL.with(|local| {
        let mut buf = local.borrow_mut();
        let tid = buf.tid;
        buf.push(SpanRec { tid, ..rec });
    });
}

/// Drain the calling thread's buffer into the global list (scoped
/// worker threads drain automatically at exit; the main thread calls
/// this through [`write_chrome_trace`]).
pub fn flush_thread() {
    LOCAL.with(|local| {
        let mut buf = local.borrow_mut();
        if !buf.recs.is_empty() {
            if let Ok(mut done) = DONE.lock() {
                done.append(&mut buf.recs);
            }
        }
    });
}

/// Write everything collected so far as one Chrome trace-event JSON
/// document at `path`, with the wall-clock origin and process identity
/// `gzk trace-merge` joins on.
pub fn write_chrome_trace(path: &str) -> Result<(), String> {
    flush_thread();
    let mut done = DONE.lock().map_err(|_| "trace buffer poisoned".to_string())?;
    done.sort_by_key(|r| (r.ts_us, r.tid));
    let events: Vec<String> = done
        .iter()
        .map(|r| {
            let args = if r.trace != 0 {
                format!(",\"args\":{{\"trace\":\"{}\"}}", r.trace)
            } else {
                String::new()
            };
            format!(
                "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}{}}}",
                json_string(&r.name),
                r.cat,
                r.ts_us,
                r.dur_us,
                r.tid,
                args
            )
        })
        .collect();
    let origin_unix_us = ORIGIN_UNIX_US.get().copied().unwrap_or(0);
    let name = PROCESS_NAME.get().map(String::as_str).unwrap_or("gzk");
    let doc = format!(
        "{{\"origin_unix_us\":{origin_unix_us},\"process_pid\":{},\"process_name\":{},\"traceEvents\":[{}]}}\n",
        std::process::id(),
        json_string(name),
        events.join(",")
    );
    std::fs::write(path, doc).map_err(|e| format!("write trace {path:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_free_and_record_nothing() {
        // tracing starts disabled in the test process unless another
        // test enabled it; either way a dropped span must never panic
        let s = span("test", "noop");
        drop(s);
    }

    #[test]
    fn minted_trace_ids_are_nonzero_and_unique() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b, "consecutive mints must differ in the counter bits");
        assert_eq!(a >> 32, b >> 32, "one process keeps one random high half");
    }

    #[test]
    fn ambient_trace_ctx_nests_and_restores() {
        assert_eq!(current_trace(), 0);
        {
            let _outer = with_trace(7);
            assert_eq!(current_trace(), 7);
            {
                let _inner = with_trace(9);
                assert_eq!(current_trace(), 9);
            }
            assert_eq!(current_trace(), 7);
        }
        assert_eq!(current_trace(), 0);
    }

    #[test]
    fn spans_record_and_the_trace_is_valid_json() {
        enable();
        {
            let _g = with_trace(0x1234);
            let _outer = span("test", "trace.outer");
            let _inner = span("test", "trace.inner");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        std::thread::scope(|scope| {
            scope.spawn(|| {
                let _s = span("test", "trace.worker");
            });
        });
        record_since("test", "trace.since", 0x1234, Instant::now());
        let path = std::env::temp_dir()
            .join(format!("gzk-trace-unit-{}.json", std::process::id()));
        write_chrome_trace(path.to_str().expect("utf-8 temp path")).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::runtime::Json::parse(&text).unwrap();
        assert!(doc.get("origin_unix_us").and_then(|v| v.as_f64()).is_some());
        assert!(doc.get("process_pid").and_then(|v| v.as_f64()).is_some());
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        let names: Vec<&str> =
            events.iter().filter_map(|e| e.get("name").and_then(|n| n.as_str())).collect();
        for want in ["trace.outer", "trace.inner", "trace.worker", "trace.since"] {
            assert!(names.contains(&want), "missing span {want:?} in {names:?}");
        }
        for e in events {
            assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
            assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
            assert!(e.get("dur").and_then(|d| d.as_f64()).is_some());
            if e.get("name").and_then(|n| n.as_str()) == Some("trace.outer") {
                let trace = e
                    .get("args")
                    .and_then(|a| a.get("trace"))
                    .and_then(|t| t.as_str())
                    .expect("traced span carries args.trace");
                assert_eq!(trace, "4660"); // 0x1234 as decimal string
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn buffers_drain_before_thread_exit_once_past_the_size_trigger() {
        enable();
        // a long-lived thread records DRAIN_SPANS spans and parks; the
        // spans must be visible in the global list while it still lives
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
        let h = std::thread::spawn(move || {
            for i in 0..DRAIN_SPANS {
                let _s = span("test", &format!("drain.{i}"));
            }
            ready_tx.send(()).unwrap();
            rx.recv().unwrap(); // park until the assertion ran
        });
        ready_rx.recv().unwrap();
        {
            let done = DONE.lock().unwrap();
            let drained = done.iter().filter(|r| r.name.starts_with("drain.")).count();
            assert!(
                drained >= DRAIN_SPANS,
                "only {drained} of {DRAIN_SPANS} spans drained while the thread lives"
            );
        }
        tx.send(()).unwrap();
        h.join().unwrap();
    }
}
