//! Scoped trace spans → Chrome trace-event JSON.
//!
//! A [`Span`] is an RAII timer: created via [`span`], it records
//! (name, category, thread, start, duration) into a **per-thread**
//! buffer when dropped — no locking on the hot path. Buffers drain into
//! a global list when their thread exits (every compute thread in this
//! crate is scoped, so all spans are collected before a fit returns) or
//! when [`write_chrome_trace`] flushes the calling thread explicitly.
//!
//! Tracing is off by default: until [`enable`] is called (the CLI does
//! so for `--trace-out`), creating a span costs one relaxed atomic load
//! and allocates nothing. The written file is the Chrome trace-event
//! format — open it in `chrome://tracing` or Perfetto:
//!
//! ```text
//! {"traceEvents":[{"name":"featurize","cat":"pipeline","ph":"X",
//!                  "ts":1234,"dur":567,"pid":1,"tid":2}, ...]}
//! ```
//!
//! Span naming convention: short stage verbs scoped by category —
//! `cat:"pipeline"` for `chunk.read`/`featurize`/`absorb`/`eval`,
//! `cat:"fit"` for `scatter`/`merge`/`solve`/`recover`, `cat:"dist"`
//! for `register`/`scatter`/`shard N`/`recover`, `cat:"exec"` for
//! `jobs`.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::events::json_string;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ORIGIN: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DONE: Mutex<Vec<SpanRec>> = Mutex::new(Vec::new());

struct SpanRec {
    name: String,
    cat: &'static str,
    tid: u64,
    ts_us: u64,
    dur_us: u64,
}

/// Turn span collection on (idempotent). The first call pins the
/// timeline origin; all `ts` values are microseconds since it.
pub fn enable() {
    ORIGIN.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Whether spans are being collected — one relaxed load.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

struct LocalBuf {
    tid: u64,
    recs: Vec<SpanRec>,
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        if !self.recs.is_empty() {
            if let Ok(mut done) = DONE.lock() {
                done.append(&mut self.recs);
            }
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        recs: Vec::new(),
    });
}

/// An in-flight scoped timer; recording happens on drop. With tracing
/// disabled this is `None` — no allocation, no clock read.
pub struct Span(Option<OpenSpan>);

struct OpenSpan {
    name: String,
    cat: &'static str,
    start: Instant,
}

/// Open a span; it records when the returned guard drops.
pub fn span(cat: &'static str, name: &str) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(OpenSpan { name: name.to_string(), cat, start: Instant::now() }))
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(open) = self.0.take() else {
            return;
        };
        let origin = *ORIGIN.get().expect("tracing enabled implies an origin");
        let rec = SpanRec {
            name: open.name,
            cat: open.cat,
            tid: 0, // assigned below from the thread-local
            ts_us: open.start.duration_since(origin).as_micros() as u64,
            dur_us: open.start.elapsed().as_micros() as u64,
        };
        LOCAL.with(|local| {
            let mut buf = local.borrow_mut();
            let tid = buf.tid;
            buf.recs.push(SpanRec { tid, ..rec });
        });
    }
}

/// Drain the calling thread's buffer into the global list (scoped
/// worker threads drain automatically at exit; the main thread calls
/// this through [`write_chrome_trace`]).
pub fn flush_thread() {
    LOCAL.with(|local| {
        let mut buf = local.borrow_mut();
        if !buf.recs.is_empty() {
            if let Ok(mut done) = DONE.lock() {
                done.append(&mut buf.recs);
            }
        }
    });
}

/// Write everything collected so far as one Chrome trace-event JSON
/// document at `path`.
pub fn write_chrome_trace(path: &str) -> Result<(), String> {
    flush_thread();
    let mut done = DONE.lock().map_err(|_| "trace buffer poisoned".to_string())?;
    done.sort_by_key(|r| (r.ts_us, r.tid));
    let events: Vec<String> = done
        .iter()
        .map(|r| {
            format!(
                "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
                json_string(&r.name),
                r.cat,
                r.ts_us,
                r.dur_us,
                r.tid
            )
        })
        .collect();
    let doc = format!("{{\"traceEvents\":[{}]}}\n", events.join(","));
    std::fs::write(path, doc).map_err(|e| format!("write trace {path:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_free_and_record_nothing() {
        // tracing starts disabled in the test process unless another
        // test enabled it; either way a dropped span must never panic
        let s = span("test", "noop");
        drop(s);
    }

    #[test]
    fn spans_record_and_the_trace_is_valid_json() {
        enable();
        {
            let _outer = span("test", "trace.outer");
            let _inner = span("test", "trace.inner");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        std::thread::scope(|scope| {
            scope.spawn(|| {
                let _s = span("test", "trace.worker");
            });
        });
        let path = std::env::temp_dir()
            .join(format!("gzk-trace-unit-{}.json", std::process::id()));
        write_chrome_trace(path.to_str().expect("utf-8 temp path")).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::runtime::Json::parse(&text).unwrap();
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        let names: Vec<&str> =
            events.iter().filter_map(|e| e.get("name").and_then(|n| n.as_str())).collect();
        for want in ["trace.outer", "trace.inner", "trace.worker"] {
            assert!(names.contains(&want), "missing span {want:?} in {names:?}");
        }
        for e in events {
            assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
            assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
            assert!(e.get("dur").and_then(|d| d.as_f64()).is_some());
        }
        let _ = std::fs::remove_file(&path);
    }
}
