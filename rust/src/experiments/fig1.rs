//! Figure 1: max-error of polynomial approximations on [-1, 1] vs degree,
//! comparing the Taylor expansion (d = infinity) against Gegenbauer series
//! with d in {2, 4, 8, 32} (d = 2 being the Chebyshev series), for
//! kappa(x) = exp(2x) and the two-layer ReLU NTK.

use crate::bench::Table;
use crate::kernels::ntk_kappa;
use crate::linalg::Mat;
use crate::special::series::{exp_maclaurin, ntk_maclaurin};
use crate::special::{gegenbauer_all, gegenbauer_series_coeffs};

pub const DIMS: [usize; 4] = [2, 4, 8, 32];

/// Error curves for one target function.
pub struct Fig1Curves {
    pub function: &'static str,
    /// taylor[q] = max error of the degree-q Maclaurin truncation
    pub taylor: Vec<f64>,
    /// (DIMS.len() x (max_degree + 1)) flat matrix: row di holds the
    /// Gegenbauer-series errors for DIMS[di], one column per degree
    pub gegenbauer: Mat,
}

fn max_err_poly(coeffs: &[f64], d: usize, f: &dyn Fn(f64) -> f64, grid: &[f64]) -> f64 {
    let q = coeffs.len() - 1;
    let p = gegenbauer_all(q, d, grid);
    let mut max_err: f64 = 0.0;
    for (j, &t) in grid.iter().enumerate() {
        let approx: f64 = (0..=q).map(|l| coeffs[l] * p[l * grid.len() + j]).sum();
        max_err = max_err.max((approx - f(t)).abs());
    }
    max_err
}

fn max_err_taylor(coeffs: &[f64], f: &dyn Fn(f64) -> f64, grid: &[f64]) -> f64 {
    let mut max_err: f64 = 0.0;
    for &t in grid {
        let mut acc = 0.0;
        for &c in coeffs.iter().rev() {
            acc = acc * t + c;
        }
        max_err = max_err.max((acc - f(t)).abs());
    }
    max_err
}

/// Compute the Fig.-1 curves up to `max_degree` for both target functions.
pub fn run(max_degree: usize) -> Vec<Fig1Curves> {
    let grid: Vec<f64> = (0..2001).map(|i| -1.0 + 2.0 * i as f64 / 2000.0).collect();
    let targets: Vec<(&'static str, Box<dyn Fn(f64) -> f64>, Vec<f64>)> = vec![
        ("exp(2x)", Box::new(|t: f64| (2.0 * t).exp()), exp_maclaurin(2.0, max_degree + 1).c),
        // the paper's two-layer ReLU NTK a1(a1(x)) + (a1(x)+x a0(x)) a0(a1(x))
        // is depth = 3 in our kappa indexing (two nested a1 applications)
        ("ntk-2layer", Box::new(|t: f64| ntk_kappa(t, 3)), ntk_maclaurin(3, max_degree + 1).c),
    ];
    let mut out = Vec::new();
    for (name, f, taylor_coef) in targets {
        let mut taylor = Vec::with_capacity(max_degree + 1);
        for q in 0..=max_degree {
            taylor.push(max_err_taylor(&taylor_coef[..=q], f.as_ref(), &grid));
        }
        let mut geg = Mat::zeros(DIMS.len(), max_degree + 1);
        for (di, &d) in DIMS.iter().enumerate() {
            let coeffs = gegenbauer_series_coeffs(|t| f(t), max_degree, d, 512);
            for q in 0..=max_degree {
                geg[(di, q)] = max_err_poly(&coeffs[..=q], d, f.as_ref(), &grid);
            }
        }
        out.push(Fig1Curves { function: name, taylor, gegenbauer: geg });
    }
    out
}

/// Print the curves as a table (degree x method), the textual Fig. 1.
pub fn print(curves: &[Fig1Curves]) {
    for c in curves {
        println!("\nFigure 1 — {} : max error on [-1,1]", c.function);
        let mut headers = vec!["degree".to_string(), "taylor".to_string()];
        for &d in DIMS.iter() {
            headers.push(if d == 2 { "geg d=2 (cheb)".into() } else { format!("geg d={d}") });
        }
        let mut table = Table::new(headers);
        for q in 0..c.taylor.len() {
            let mut row = vec![q.to_string(), format!("{:.2e}", c.taylor[q])];
            for di in 0..DIMS.len() {
                row.push(format!("{:.2e}", c.gegenbauer[(di, q)]));
            }
            table.row(row);
        }
        table.print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chebyshev_beats_taylor_for_exp() {
        // the figure's headline: at degree 15, Chebyshev (d=2) crushes
        // Taylor, and the Gegenbauer family interpolates between them
        let curves = run(15);
        let exp = &curves[0];
        let cheb = exp.gegenbauer[(0, 15)];
        let taylor = exp.taylor[15];
        assert!(cheb < taylor * 1e-2, "cheb {cheb} vs taylor {taylor}");
        // interpolation: error at d=4 between d=2 and taylor
        let d4 = exp.gegenbauer[(1, 15)];
        assert!(cheb <= d4 * 10.0 && d4 <= taylor, "{cheb} {d4} {taylor}");
    }

    #[test]
    fn errors_decrease_with_degree() {
        let curves = run(12);
        for c in &curves {
            for di in 0..DIMS.len() {
                assert!(
                    c.gegenbauer[(di, 12)] <= c.gegenbauer[(di, 2)] + 1e-12,
                    "{} d={}",
                    c.function,
                    DIMS[di]
                );
            }
        }
    }

    #[test]
    fn ntk_taylor_is_poor() {
        // NTK is non-analytic at |t| = 1 -> Taylor converges slowly there
        let curves = run(15);
        let ntk = &curves[1];
        assert!(ntk.taylor[15] > 1e-3, "{}", ntk.taylor[15]);
        // Chebyshev still improves markedly over Taylor
        assert!(ntk.gegenbauer[(0, 15)] < ntk.taylor[15]);
    }
}
