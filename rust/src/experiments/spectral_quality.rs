//! Spectral-approximation quality sweep (the empirical content of
//! Theorems 9/12 and Eq. 1): smallest eps achieved vs feature count, per
//! method, on a dataset small enough to eigendecompose exactly.

use crate::bench::Table;
use crate::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::spectral::{spectral_epsilon, statistical_dimension};

pub struct SpectralRow {
    pub method: &'static str,
    pub m: usize,
    pub eps: f64,
}

pub fn run(n: usize, d: usize, lambda: f64, seed: u64) -> (f64, Vec<SpectralRow>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.6);
    let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
    let s_lambda = statistical_dimension(&k, lambda);
    let kernel = KernelSpec::Gaussian { bandwidth: 1.0 };
    // the paper's three-way comparison: the oblivious pair plus the
    // data-dependent Nystrom reference (fit with the sweep's lambda)
    let methods =
        [Method::Gegenbauer { q: 12, s: 2 }, Method::Fourier, Method::Nystrom { lambda }];
    let mut rows = Vec::new();
    for &m in &[64usize, 128, 256, 512, 1024, 2048] {
        for method in &methods {
            let spec = FeatureSpec::new(kernel.clone(), method.clone(), m, seed + m as u64);
            let feat = spec.try_build(d, Some(&x)).expect("spectral sweep build");
            let z = feat.featurize(&x);
            rows.push(SpectralRow {
                method: feat.name(),
                m: feat.dim(),
                eps: spectral_epsilon(&k, &z.matmul_nt(&z), lambda),
            });
        }
    }
    (s_lambda, rows)
}

pub fn print(s_lambda: f64, rows: &[SpectralRow]) {
    println!("\nSpectral quality (Eq. 1) — smallest eps vs feature count");
    println!("(statistical dimension s_lambda = {s_lambda:.1})\n");
    let mut t = Table::new(vec!["method", "m", "eps"]);
    for r in rows {
        t.row(vec![r.method.to_string(), r.m.to_string(), format!("{:.4}", r.eps)]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eps_improves_with_m_for_each_method() {
        let (_, rows) = run(40, 3, 0.3, 17);
        let mut methods: Vec<&'static str> = rows.iter().map(|r| r.method).collect();
        methods.sort_unstable();
        methods.dedup();
        assert_eq!(methods.len(), 3);
        for method in methods {
            let eps: Vec<f64> =
                rows.iter().filter(|r| r.method == method).map(|r| r.eps).collect();
            let first = eps.first().copied().unwrap();
            let last = eps.last().copied().unwrap();
            assert!(
                last <= first * 1.2 + 1e-9 && last.is_finite(),
                "{method}: {first} -> {last}"
            );
        }
    }
}
