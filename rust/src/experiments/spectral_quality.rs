//! Spectral-approximation quality sweep (the empirical content of
//! Theorems 9/12 and Eq. 1): smallest eps achieved vs feature count, per
//! method, on a dataset small enough to eigendecompose exactly.

use crate::bench::Table;
use crate::features::{Featurizer, FourierFeatures, GegenbauerFeatures, NystromFeatures, RadialTable};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::spectral::{spectral_epsilon, statistical_dimension};

pub struct SpectralRow {
    pub method: &'static str,
    pub m: usize,
    pub eps: f64,
}

pub fn run(n: usize, d: usize, lambda: f64, seed: u64) -> (f64, Vec<SpectralRow>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.6);
    let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
    let s_lambda = statistical_dimension(&k, lambda);
    let table = RadialTable::gaussian(d, 12, 2);
    let mut rows = Vec::new();
    for &m in &[64usize, 128, 256, 512, 1024, 2048] {
        let zg = GegenbauerFeatures::new(table.clone(), m / 2, seed + m as u64).featurize(&x);
        rows.push(SpectralRow {
            method: "gegenbauer",
            m,
            eps: spectral_epsilon(&k, &zg.matmul_nt(&zg), lambda),
        });
        let zf = FourierFeatures::new(d, m, 1.0, seed + m as u64).featurize(&x);
        rows.push(SpectralRow {
            method: "fourier",
            m,
            eps: spectral_epsilon(&k, &zf.matmul_nt(&zf), lambda),
        });
        let zn = NystromFeatures::fit(
            Kernel::Gaussian { bandwidth: 1.0 },
            &x,
            m.min(n),
            lambda,
            seed + m as u64,
        )
        .featurize(&x);
        rows.push(SpectralRow {
            method: "nystrom",
            m: m.min(n),
            eps: spectral_epsilon(&k, &zn.matmul_nt(&zn), lambda),
        });
    }
    (s_lambda, rows)
}

pub fn print(s_lambda: f64, rows: &[SpectralRow]) {
    println!("\nSpectral quality (Eq. 1) — smallest eps vs feature count");
    println!("(statistical dimension s_lambda = {s_lambda:.1})\n");
    let mut t = Table::new(vec!["method", "m", "eps"]);
    for r in rows {
        t.row(vec![r.method.to_string(), r.m.to_string(), format!("{:.4}", r.eps)]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eps_improves_with_m_for_each_method() {
        let (_, rows) = run(40, 3, 0.3, 17);
        for method in ["gegenbauer", "fourier", "nystrom"] {
            let eps: Vec<f64> =
                rows.iter().filter(|r| r.method == method).map(|r| r.eps).collect();
            let first = eps.first().copied().unwrap();
            let last = eps.last().copied().unwrap();
            assert!(
                last <= first * 1.2 + 1e-9 && last.is_finite(),
                "{method}: {first} -> {last}"
            );
        }
    }
}
