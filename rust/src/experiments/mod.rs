//! Experiment drivers shared by the CLI (`gzk <exp>`) and the bench
//! binaries (`cargo bench`). One function per paper table/figure; each
//! returns structured rows so benches and EXPERIMENTS.md stay in sync.

pub mod fig1;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod spectral_quality;
