//! Table 3: kernel k-means objective with the Gaussian kernel on the six
//! UCI-geometry clustering datasets at feature dimension m = 512.
//!
//! Inputs are l2-normalized (the paper's preprocessing), so all points live
//! on S^{d-1} and the Gaussian kernel becomes a zonal kernel — the
//! best-case regime for Gegenbauer features at low d.
//!
//! Methods come from [`Method::registry`], each built through
//! [`FeatureSpec::build_with_data`].

use crate::bench::Table;
use crate::data::{clustering_dataset, ClusteringSpec, CLUSTERING_SPECS};
use crate::exec::Pool;
use crate::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use crate::kmeans::kmeans;
use std::time::Instant;

pub struct Table3Row {
    pub dataset: &'static str,
    pub method: &'static str,
    pub objective: f64,
    pub secs: f64,
}

pub fn run_dataset(
    spec: ClusteringSpec,
    scale: f64,
    m_features: usize,
    seed: u64,
) -> Vec<Table3Row> {
    let scaled = ClusteringSpec {
        name: spec.name,
        n: ((spec.n as f64 * scale) as usize).max(50 * spec.k),
        d: spec.d,
        k: spec.k,
    };
    let ds = clustering_dataset(scaled, seed);
    let d = spec.d;
    // unit-norm inputs; the paper uses a fixed unit-bandwidth Gaussian
    let kernel = KernelSpec::Gaussian { bandwidth: 1.0 };
    let s = if d > 16 { 1 } else { 2 };
    // points on the sphere: radius exactly 1 -> modest q suffices
    let q = (d / 2 + 6).min(12);

    let mut rows = Vec::new();
    for (i, method) in Method::registry().into_iter().enumerate() {
        let fspec =
            FeatureSpec::new(kernel.clone(), method.tuned(q, s), m_features, seed + 1 + i as u64);
        let feat = fspec.build_with_data(&ds.x);
        let t0 = Instant::now();
        // featurize + Lloyd scans draw from the global pool (bit-identical
        // to serial, so the reported objective is thread-count independent)
        let z = feat.featurize_par(&ds.x, &Pool::global());
        let res = kmeans(&z, spec.k, 50, seed ^ 0xB00);
        rows.push(Table3Row {
            dataset: spec.name,
            method: feat.name(),
            objective: res.objective,
            secs: t0.elapsed().as_secs_f64(),
        });
    }
    rows
}

pub fn run_all(scale: f64, m_features: usize, seed: u64) -> Vec<Table3Row> {
    let mut rows = Vec::new();
    for spec in CLUSTERING_SPECS {
        eprintln!("table3: running {} (scale {scale}) ...", spec.name);
        rows.extend(run_dataset(spec, scale, m_features, seed));
    }
    rows
}

pub fn print(rows: &[Table3Row]) {
    println!("\nTable 3 — kernel k-means objective with the Gaussian kernel\n");
    let mut t = Table::new(vec!["dataset", "method", "objective", "time"]);
    for r in rows {
        t.row(vec![
            r.dataset.to_string(),
            r.method.to_string(),
            format!("{:.4}", r.objective),
            format!("{:.2}s", r.secs),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abalone_small_runs_all_registered_methods() {
        let spec = CLUSTERING_SPECS[0]; // abalone, d=8
        let rows = run_dataset(spec, 0.1, 128, 11);
        assert_eq!(rows.len(), Method::registry().len());
        for r in &rows {
            assert!(r.objective.is_finite() && r.objective >= 0.0, "{}", r.method);
        }
        // the strong methods (gegenbauer / nystrom / fourier) should not be
        // far worse than the weakest
        let get = |m: &str| rows.iter().find(|r| r.method == m).unwrap().objective;
        assert!(get(Method::GEGENBAUER) <= get(Method::MACLAURIN) * 2.0 + 0.1);
    }
}
