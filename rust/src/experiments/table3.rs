//! Table 3: kernel k-means objective with the Gaussian kernel on the six
//! UCI-geometry clustering datasets at feature dimension m = 512.
//!
//! Inputs are l2-normalized (the paper's preprocessing), so all points live
//! on S^{d-1} and the Gaussian kernel becomes a zonal kernel — the
//! best-case regime for Gegenbauer features at low d.
//!
//! The experiment is a consumer of the chunked data pipeline: rows come
//! from a lazily generated [`SyntheticSource`] and the fit is
//! `data::pipeline::kmeans_chunked` (reservoir init + streaming absorb +
//! a streamed objective pass), so neither the n x d dataset nor the n x m
//! feature matrix is ever materialized. The reported objective is the
//! average squared distance to the assigned centroid — the quantity of
//! the paper's Table 3 — for the streaming fit.
//!
//! Methods come from [`Method::registry`], each fitted through
//! [`FittedMap::fit_source`].

use crate::bench::Table;
use crate::data::{pipeline, SyntheticSource, CLUSTERING_SPECS};
use crate::exec::Pool;
use crate::features::{FeatureSpec, KernelSpec, Method};
use crate::model::FittedMap;
use std::time::Instant;

/// Chunk height used by the streamed fits below.
const CHUNK_ROWS: usize = 8192;

pub struct Table3Row {
    pub dataset: &'static str,
    pub method: &'static str,
    pub objective: f64,
    pub secs: f64,
}

pub fn run_dataset(
    spec: crate::data::ClusteringSpec,
    scale: f64,
    m_features: usize,
    seed: u64,
) -> Vec<Table3Row> {
    let n = ((spec.n as f64 * scale) as usize).max(50 * spec.k);
    let src = SyntheticSource::clustering(spec.name, n, spec.d, spec.k, seed);
    let d = spec.d;
    // unit-norm inputs; the paper uses a fixed unit-bandwidth Gaussian
    let kernel = KernelSpec::Gaussian { bandwidth: 1.0 };
    let s = if d > 16 { 1 } else { 2 };
    // points on the sphere: radius exactly 1 -> modest q suffices
    let q = (d / 2 + 6).min(12);

    let mut rows = Vec::new();
    for (i, method) in Method::registry().into_iter().enumerate() {
        let fspec =
            FeatureSpec::new(kernel.clone(), method.tuned(q, s), m_features, seed + 1 + i as u64)
                .bind(d);
        let map = FittedMap::fit_source(fspec, &src).expect("registry method fits");
        let method_name = map.featurizer().name();
        let t0 = Instant::now();
        // per-chunk featurize + absorb draw from the global pool
        // (bit-identical to serial, so the reported objective is
        // thread-count independent)
        let (res, _) = pipeline::kmeans_chunked(
            map.featurizer(),
            &src,
            spec.k,
            CHUNK_ROWS,
            seed ^ 0xB00,
            &Pool::global(),
        )
        .expect("streamed kmeans fit");
        rows.push(Table3Row {
            dataset: spec.name,
            method: method_name,
            objective: res.objective,
            secs: t0.elapsed().as_secs_f64(),
        });
    }
    rows
}

pub fn run_all(scale: f64, m_features: usize, seed: u64) -> Vec<Table3Row> {
    let mut rows = Vec::new();
    for spec in CLUSTERING_SPECS {
        eprintln!("table3: running {} (scale {scale}) ...", spec.name);
        rows.extend(run_dataset(spec, scale, m_features, seed));
    }
    rows
}

pub fn print(rows: &[Table3Row]) {
    println!("\nTable 3 — kernel k-means objective with the Gaussian kernel (streamed fit)\n");
    let mut t = Table::new(vec!["dataset", "method", "objective", "time"]);
    for r in rows {
        t.row(vec![
            r.dataset.to_string(),
            r.method.to_string(),
            format!("{:.4}", r.objective),
            format!("{:.2}s", r.secs),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abalone_small_runs_all_registered_methods() {
        let spec = CLUSTERING_SPECS[0]; // abalone, d=8
        let rows = run_dataset(spec, 0.1, 128, 11);
        assert_eq!(rows.len(), Method::registry().len());
        for r in &rows {
            assert!(r.objective.is_finite() && r.objective >= 0.0, "{}", r.method);
        }
        // the strong methods (gegenbauer / nystrom / fourier) should not be
        // far worse than the weakest
        let get = |m: &str| rows.iter().find(|r| r.method == m).unwrap().objective;
        assert!(get(Method::GEGENBAUER) <= get(Method::MACLAURIN) * 2.0 + 0.1);
    }
}
