//! Table 1: feature-dimension bounds of each Gaussian-kernel approximation
//! for an (eps, lambda)-spectral guarantee, evaluated over a grid of
//! problem geometries, plus an *empirical* companion: the measured feature
//! count each random method needs to reach eps <= 0.5 on a small dataset.

use crate::bench::Table;
use crate::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::spectral::{spectral_epsilon, statistical_dimension, table1_bounds, BoundRow};

/// The analytic half: print the bound formulas across geometries.
pub fn run_bounds() -> Vec<(String, Vec<BoundRow>)> {
    let geoms = [
        (1e5f64, 1e-3f64, 1.0f64, 3.0f64),
        (1e6, 1e-6, 1.0, 3.0),
        (1e6, 1e-6, 4.0, 3.0),
        (1e6, 1e-6, 1.0, 8.0),
        (1e6, 1e-6, 1.0, 24.0),
    ];
    let mut out = Vec::new();
    for (n, lam, r, d) in geoms {
        // s_lambda estimate for a Gaussian kernel at this geometry: use the
        // paper's sub-poly proxy min(n, (log(n/lam))^d / d!)
        let s_est = ((n / lam).ln().powf(d) / (1..=(d as usize)).map(|k| k as f64).product::<f64>())
            .min(n);
        let rows = table1_bounds(n, lam, r, d, s_est.max(2.0));
        out.push((format!("n={n:.0e} lam={lam:.0e} r={r} d={d}"), rows));
    }
    out
}

pub fn print_bounds(rows: &[(String, Vec<BoundRow>)]) {
    println!("\nTable 1 — log10(feature-dimension bound) per method\n");
    let methods: Vec<&str> = rows[0].1.iter().map(|r| r.method).collect();
    let mut headers = vec!["geometry".to_string()];
    headers.extend(methods.iter().map(|m| m.to_string()));
    let mut t = Table::new(headers);
    for (geom, brs) in rows {
        let mut row = vec![geom.clone()];
        row.extend(brs.iter().map(|b| format!("{:.1}", b.log10_features)));
        t.row(row);
    }
    t.print();
}

/// The empirical half: measured features needed for eps <= target on a
/// small synthetic set, Gegenbauer vs Fourier (the two oblivious methods).
pub struct EmpiricalRow {
    pub method: &'static str,
    pub m_needed: Option<usize>,
    pub final_eps: f64,
}

pub fn run_empirical(n: usize, d: usize, lambda: f64, eps_target: f64, seed: u64) -> Vec<EmpiricalRow> {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.6);
    let k = Kernel::Gaussian { bandwidth: 1.0 }.gram(&x);
    let s_lam = statistical_dimension(&k, lambda);
    println!("  (statistical dimension s_lambda = {s_lam:.1})");
    let kernel = KernelSpec::Gaussian { bandwidth: 1.0 };
    let mut out = Vec::new();
    // the two data-oblivious contenders of the paper's empirical half
    for method in [Method::Gegenbauer { q: 12, s: 3 }, Method::Fourier] {
        let mut m_needed = None;
        let mut final_eps = f64::INFINITY;
        for &m in &[64usize, 128, 256, 512, 1024, 2048, 4096] {
            let spec = FeatureSpec::new(kernel.clone(), method.clone(), m, seed + m as u64);
            let z = spec.build(d).featurize(&x);
            let eps = spectral_epsilon(&k, &z.matmul_nt(&z), lambda);
            final_eps = eps;
            if eps <= eps_target {
                m_needed = Some(m);
                break;
            }
        }
        out.push(EmpiricalRow { method: method.name(), m_needed, final_eps });
    }
    out
}

pub fn print_empirical(rows: &[EmpiricalRow], eps_target: f64) {
    println!("\nTable 1 (empirical) — features needed for eps <= {eps_target}\n");
    let mut t = Table::new(vec!["method", "m needed", "eps at stop"]);
    for r in rows {
        t.row(vec![
            r.method.to_string(),
            r.m_needed.map(|m| m.to_string()).unwrap_or_else(|| ">4096".into()),
            format!("{:.3}", r.final_eps),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_table_has_shape() {
        let rows = run_bounds();
        assert_eq!(rows.len(), 5);
        for (_, brs) in &rows {
            assert_eq!(brs.len(), 7);
        }
    }

    #[test]
    fn empirical_both_methods_converge() {
        let rows = run_empirical(48, 3, 0.5, 0.6, 3);
        for r in &rows {
            assert!(
                r.m_needed.is_some() || r.final_eps < 1.0,
                "{}: eps {}",
                r.method,
                r.final_eps
            );
        }
    }
}
