//! Table 2: kernel ridge regression with the Gaussian kernel on the four
//! regression datasets (Elevation, CO2, Climate, Protein), comparing every
//! method in the featurizer registry at feature dimension m = 1024.
//!
//! Reported per (dataset, method): test MSE and featurization wall time —
//! the same two columns as the paper. Datasets are the lazily generated
//! synthetic stand-ins of [`SyntheticSource`] (DESIGN.md §6), and the
//! whole experiment is a consumer of the chunked `data::pipeline`: train
//! statistics, validation-based lambda selection and test MSE all stream
//! chunk by chunk, so the n x d dataset and the n x m feature matrix are
//! **never materialized** — this is the out-of-core path that lets
//! `--scale 1` run the full climate set (n = 223,656). `scale` subsamples
//! each dataset to scale * n_paper rows to keep default bench wall time
//! sane.
//!
//! Methods come from [`Method::registry`], each fitted through
//! [`FittedMap::fit_source`] — registering a new featurizer adds a row to
//! this table with no changes here (the data-dependent Nystrom baseline
//! gathers its landmark sample by random access).

use crate::bench::Table;
use crate::data::{gather_rows, pipeline, DataSource, SourceSlice, SyntheticSource};
use crate::exec::Pool;
use crate::features::{FeatureSpec, KernelSpec, Method};
use crate::krr::FeatureRidge;
use crate::linalg::Mat;
use crate::model::FittedMap;

/// Chunk height used by the streamed fits below.
const CHUNK_ROWS: usize = 8192;

pub struct Table2Row {
    pub dataset: &'static str,
    pub method: &'static str,
    pub mse: f64,
    pub featurize_secs: f64,
    pub fit_secs: f64,
}

/// Dataset geometry of the paper's Table 2 (n before scaling).
pub const PAPER_SIZES: [(&str, usize); 4] = crate::data::REGRESSION_SIZES;

/// The lazy source for one Table-2 dataset at `scale` of its paper size.
pub fn make_source(name: &str, scale: f64, seed: u64) -> SyntheticSource {
    let n_full = PAPER_SIZES.iter().find(|(n, _)| *n == name).expect("dataset").1;
    let n = ((n_full as f64 * scale) as usize).max(500);
    SyntheticSource::by_name(name, n, seed).expect("registered regression dataset")
}

/// A uniform probe sample of up to 500 rows — the only rows this
/// experiment ever gathers (bandwidth heuristic + Gegenbauer tuning).
fn probe_rows(src: &dyn DataSource, seed: u64) -> Mat {
    let n = src.len();
    let n_probe = n.min(500);
    let mut rng = crate::rng::Rng::new(seed);
    let idx = rng.sample_indices(n, n_probe);
    gather_rows(src, &idx).expect("probe rows")
}

/// Bandwidth heuristic: median pairwise distance on the probe sample.
pub fn median_bandwidth(probe: &Mat) -> f64 {
    let n = probe.rows();
    let mut d2 = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in 0..i {
            let (a, b) = (probe.row(i), probe.row(j));
            d2.push(a.iter().zip(b).map(|(&u, &v)| (u - v) * (u - v)).sum::<f64>());
        }
    }
    d2.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (d2[d2.len() / 2]).sqrt().max(1e-6)
}

const LAMBDA_GRID: [f64; 5] = [1e-6, 1e-4, 1e-2, 1e0, 1e2];

/// Gegenbauer truncation knobs for a dataset: enough degrees for the
/// bandwidth-scaled data radius (estimated on the probe sample), s = 2
/// radial channels at moderate d.
fn gegenbauer_tuning(probe: &Mat, n_total: usize, bw: f64) -> (usize, usize) {
    let d = probe.cols();
    let r_max = (0..probe.rows())
        .map(|i| probe.row(i).iter().map(|v| v * v).sum::<f64>().sqrt() / bw)
        .fold(0.0f64, f64::max);
    let s = if d > 16 { 1 } else { 2 };
    let q = crate::features::radial::suggest_q(r_max.min(3.0), d, n_total, 1e-3, 0.5)
        .min(16)
        .max(4);
    (q, s)
}

/// Streamed fit + eval for one fitted map: single-pass sufficient
/// statistics over the fit rows, lambda selection on a streamed
/// validation slice, a final absorb of that slice, then streamed test
/// MSE. Returns (mse, featurize_secs, fit_secs). Peak feature memory:
/// `CHUNK_ROWS x F`.
fn fit_eval_streamed(
    map: &FittedMap,
    train: &SourceSlice<'_>,
    test: &SourceSlice<'_>,
) -> Result<(f64, f64, f64), String> {
    let t0 = std::time::Instant::now();
    let pool = Pool::global();
    let n = train.len();
    let n_val = (n / 10).max(1);
    let n_fit = n - n_val;
    let fit_slice = SourceSlice::new(train, 0, n_fit);
    let val_slice = SourceSlice::new(train, n_fit, n);

    let f_dim = map.feature_dim();
    let (mut stats, info) =
        pipeline::ridge_stats(map.featurizer(), &fit_slice, CHUNK_ROWS, &pool)?;
    let mut featurize_secs = info.featurize_secs;

    // one model per grid lambda, all evaluated in a single streamed pass
    // over the validation slice (the shared pipeline chunk loop — same
    // reused scratch as the fit, no per-chunk feature matrix)
    let models: Vec<FeatureRidge> =
        LAMBDA_GRID.iter().map(|&lam| stats.solve(lam * n_fit as f64 / 1000.0)).collect();
    let mut val_err = [0.0f64; LAMBDA_GRID.len()];
    let vinfo =
        pipeline::for_each_chunk(map.featurizer(), &val_slice, CHUNK_ROWS, &pool, |_, y, z| {
            for (m, err) in models.iter().zip(val_err.iter_mut()) {
                for (row, &t) in z.chunks_exact(f_dim).zip(y) {
                    let p = m.predict_row(row);
                    *err += (p - t) * (p - t);
                }
            }
            // absorb the validation rows for the final refit while the
            // features are still in hand
            stats.absorb_flat_with(z, y, &pool);
        })?;
    featurize_secs += vinfo.featurize_secs;
    let best = LAMBDA_GRID
        .iter()
        .zip(val_err)
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(&lam, _)| lam)
        .unwrap();
    let model = stats.solve(best * n as f64 / 1000.0);
    let fit_secs = t0.elapsed().as_secs_f64() - featurize_secs;

    // streamed test MSE through the same chunk loop. Its featurize time
    // is deliberately NOT folded into the reported column: that column
    // has always meant *training* featurization (the paper's comparison),
    // so cross-PR bench tracking stays comparable.
    let mut test_sq = 0.0;
    pipeline::for_each_chunk(map.featurizer(), test, CHUNK_ROWS, &pool, |_, y, z| {
        for (row, &t) in z.chunks_exact(f_dim).zip(y) {
            let p = model.predict_row(row);
            test_sq += (p - t) * (p - t);
        }
    })?;
    Ok((test_sq / test.len() as f64, featurize_secs, fit_secs.max(0.0)))
}

/// Run one dataset through every registered method at feature budget
/// `m_features`, fully streamed.
pub fn run_dataset(name: &'static str, scale: f64, m_features: usize, seed: u64) -> Vec<Table2Row> {
    let src = make_source(name, scale, seed);
    let n = src.len();
    let n_test = (n / 10).max(1);
    let train = SourceSlice::new(&src, 0, n - n_test);
    let test = SourceSlice::new(&src, n - n_test, n);
    let probe = probe_rows(&train, seed ^ 0x5EED);
    let bw = median_bandwidth(&probe);
    let kernel = KernelSpec::Gaussian { bandwidth: bw };
    let (q, s) = gegenbauer_tuning(&probe, train.len(), bw);

    let mut rows = Vec::new();
    for (i, method) in Method::registry().into_iter().enumerate() {
        let spec =
            FeatureSpec::new(kernel.clone(), method.tuned(q, s), m_features, seed + 1 + i as u64)
                .bind(src.dim());
        let map = FittedMap::fit_source(spec, &train).expect("registry method fits");
        let method_name = map.featurizer().name();
        let (err, featurize_secs, fit_secs) =
            fit_eval_streamed(&map, &train, &test).expect("streamed fit");
        rows.push(Table2Row {
            dataset: name,
            method: method_name,
            mse: err,
            featurize_secs,
            fit_secs,
        });
    }
    rows
}

pub fn run_all(scale: f64, m_features: usize, seed: u64) -> Vec<Table2Row> {
    let mut rows = Vec::new();
    for (name, _) in PAPER_SIZES {
        eprintln!("table2: running {name} (scale {scale}) ...");
        rows.extend(run_dataset(name, scale, m_features, seed));
    }
    rows
}

pub fn print(rows: &[Table2Row]) {
    println!("\nTable 2 — KRR with the Gaussian kernel (test MSE / featurize time)\n");
    let mut t = Table::new(vec!["dataset", "method", "mse", "featurize", "fit"]);
    for r in rows {
        t.row(vec![
            r.dataset.to_string(),
            r.method.to_string(),
            format!("{:.4}", r.mse),
            format!("{:.2}s", r.featurize_secs),
            format!("{:.2}s", r.fit_secs),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elevation_small_scale_covers_registry() {
        // every registered method produces a row, and the paper's shape on
        // S^2 data holds: gegenbauer is no worse than the weak maclaurin
        let rows = run_dataset("elevation", 0.02, 256, 7);
        assert_eq!(rows.len(), Method::registry().len());
        let get = |m: &str| rows.iter().find(|r| r.method == m).unwrap().mse;
        let geg = get(Method::GEGENBAUER);
        let mac = get(Method::MACLAURIN);
        assert!(geg.is_finite() && mac.is_finite());
        assert!(geg <= mac * 1.5, "gegenbauer {geg} vs maclaurin {mac}");
    }

    #[test]
    fn bandwidth_heuristic_positive() {
        let src = make_source("protein", 0.02, 1);
        let probe = probe_rows(&src, 1);
        let bw = median_bandwidth(&probe);
        assert!(bw > 0.1 && bw < 100.0, "{bw}");
    }
}
