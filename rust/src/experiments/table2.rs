//! Table 2: kernel ridge regression with the Gaussian kernel on the four
//! regression datasets (Elevation, CO2, Climate, Protein), comparing every
//! method in the featurizer registry at feature dimension m = 1024.
//!
//! Reported per (dataset, method): test MSE and featurization wall time —
//! the same two columns as the paper. Datasets are the synthetic
//! stand-ins of `data::synthetic` (DESIGN.md §6); `scale` subsamples each
//! dataset to scale * n_paper rows to keep bench wall time sane.
//!
//! Methods come from [`Method::registry`], each built through
//! [`FeatureSpec::build_with_data`] — registering a new featurizer adds a
//! row to this table with no changes here.

use crate::bench::Table;
use crate::data::{self, Dataset};
use crate::exec::Pool;
use crate::features::{FeatureSpec, Featurizer, KernelSpec, Method};
use crate::krr::{mse, RidgeStats};
use crate::linalg::Mat;
use std::time::Instant;

pub struct Table2Row {
    pub dataset: &'static str,
    pub method: &'static str,
    pub mse: f64,
    pub featurize_secs: f64,
    pub fit_secs: f64,
}

/// Dataset geometry of the paper's Table 2 (n before scaling).
pub const PAPER_SIZES: [(&str, usize); 4] =
    [("elevation", 64_800), ("co2", 146_040), ("climate", 223_656), ("protein", 45_730)];

pub fn make_dataset(name: &str, scale: f64, seed: u64) -> Dataset {
    let n_full = PAPER_SIZES.iter().find(|(n, _)| *n == name).expect("dataset").1;
    let n = ((n_full as f64 * scale) as usize).max(500);
    match name {
        "elevation" => data::elevation(n, seed),
        "co2" => data::co2(n, seed),
        "climate" => data::climate(n, seed),
        "protein" => data::protein(n, seed),
        _ => unreachable!(),
    }
}

/// Bandwidth heuristic: median pairwise distance on a probe subsample.
pub fn median_bandwidth(x: &Mat, seed: u64) -> f64 {
    let mut rng = crate::rng::Rng::new(seed);
    let n = x.rows().min(500);
    let idx = rng.sample_indices(x.rows(), n);
    let mut d2 = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in 0..i {
            let (a, b) = (x.row(idx[i]), x.row(idx[j]));
            d2.push(a.iter().zip(b).map(|(&u, &v)| (u - v) * (u - v)).sum::<f64>());
        }
    }
    d2.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (d2[d2.len() / 2]).sqrt().max(1e-6)
}

const LAMBDA_GRID: [f64; 5] = [1e-6, 1e-4, 1e-2, 1e0, 1e2];

/// Fit on train (with lambda chosen on a validation split), evaluate MSE on
/// test. Returns (mse, fit_secs).
fn fit_eval(z_tr: &Mat, y_tr: &[f64], z_te: &Mat, y_te: &[f64]) -> (f64, f64) {
    let t0 = Instant::now();
    let n = z_tr.rows();
    let n_val = (n / 10).max(1);
    let n_fit = n - n_val;
    let mut stats_fit = RidgeStats::new(z_tr.cols());
    stats_fit.absorb(&z_tr.row_block(0, n_fit), &y_tr[..n_fit]);
    let z_val = z_tr.row_block(n_fit, n);
    let mut best = (f64::INFINITY, LAMBDA_GRID[0]);
    for &lam in &LAMBDA_GRID {
        let model = stats_fit.solve(lam * n_fit as f64 / 1000.0);
        let e = mse(&model.predict(&z_val), &y_tr[n_fit..]);
        if e < best.0 {
            best = (e, lam);
        }
    }
    // refit on all training rows at the chosen lambda
    let mut stats = stats_fit;
    stats.absorb(&z_val, &y_tr[n_fit..]);
    let model = stats.solve(best.1 * n as f64 / 1000.0);
    let fit_secs = t0.elapsed().as_secs_f64();
    (mse(&model.predict(z_te), y_te), fit_secs)
}

/// Gegenbauer truncation knobs for a dataset: enough degrees for the
/// bandwidth-scaled data radius, s = 2 radial channels at moderate d.
fn gegenbauer_tuning(x_tr: &Mat, bw: f64) -> (usize, usize) {
    let d = x_tr.cols();
    let r_max = (0..x_tr.rows())
        .map(|i| x_tr.row(i).iter().map(|v| v * v).sum::<f64>().sqrt() / bw)
        .fold(0.0f64, f64::max);
    let s = if d > 16 { 1 } else { 2 };
    let q = crate::features::radial::suggest_q(r_max.min(3.0), d, x_tr.rows(), 1e-3, 0.5)
        .min(16)
        .max(4);
    (q, s)
}

/// Run one dataset through every registered method at feature budget
/// `m_features`.
pub fn run_dataset(name: &'static str, scale: f64, m_features: usize, seed: u64) -> Vec<Table2Row> {
    let ds = make_dataset(name, scale, seed);
    let (x_tr, y_tr, x_te, y_te) = data::split(&ds.x, &ds.y, 0.1, seed ^ 0x5EED);
    let bw = median_bandwidth(&x_tr, seed);
    let kernel = KernelSpec::Gaussian { bandwidth: bw };
    let (q, s) = gegenbauer_tuning(&x_tr, bw);

    let mut rows = Vec::new();
    for (i, method) in Method::registry().into_iter().enumerate() {
        let spec =
            FeatureSpec::new(kernel.clone(), method.tuned(q, s), m_features, seed + 1 + i as u64);
        let feat = spec.build_with_data(&x_tr);
        // bulk featurization draws from the global pool (bit-identical to
        // serial, so the reported MSE is thread-count independent)
        let pool = Pool::global();
        let t0 = Instant::now();
        let z_tr = feat.featurize_par(&x_tr, &pool);
        let featurize_secs = t0.elapsed().as_secs_f64();
        let z_te = feat.featurize_par(&x_te, &pool);
        let (err, fit_secs) = fit_eval(&z_tr, &y_tr, &z_te, &y_te);
        rows.push(Table2Row {
            dataset: name,
            method: feat.name(),
            mse: err,
            featurize_secs,
            fit_secs,
        });
    }
    rows
}

pub fn run_all(scale: f64, m_features: usize, seed: u64) -> Vec<Table2Row> {
    let mut rows = Vec::new();
    for (name, _) in PAPER_SIZES {
        eprintln!("table2: running {name} (scale {scale}) ...");
        rows.extend(run_dataset(name, scale, m_features, seed));
    }
    rows
}

pub fn print(rows: &[Table2Row]) {
    println!("\nTable 2 — KRR with the Gaussian kernel (test MSE / featurize time)\n");
    let mut t = Table::new(vec!["dataset", "method", "mse", "featurize", "fit"]);
    for r in rows {
        t.row(vec![
            r.dataset.to_string(),
            r.method.to_string(),
            format!("{:.4}", r.mse),
            format!("{:.2}s", r.featurize_secs),
            format!("{:.2}s", r.fit_secs),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elevation_small_scale_covers_registry() {
        // every registered method produces a row, and the paper's shape on
        // S^2 data holds: gegenbauer is no worse than the weak maclaurin
        let rows = run_dataset("elevation", 0.02, 256, 7);
        assert_eq!(rows.len(), Method::registry().len());
        let get = |m: &str| rows.iter().find(|r| r.method == m).unwrap().mse;
        let geg = get(Method::GEGENBAUER);
        let mac = get(Method::MACLAURIN);
        assert!(geg.is_finite() && mac.is_finite());
        assert!(geg <= mac * 1.5, "gegenbauer {geg} vs maclaurin {mac}");
    }

    #[test]
    fn bandwidth_heuristic_positive() {
        let ds = make_dataset("protein", 0.02, 1);
        let bw = median_bandwidth(&ds.x, 1);
        assert!(bw > 0.1 && bw < 100.0, "{bw}");
    }
}
