//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Grammar: `gzk <subcommand> [--flag value]... [--switch]...`
//!
//! Besides generic flag access, this module owns the shared featurizer
//! flag group — `--kernel/--method/--m/--seed` plus the per-kernel and
//! per-method tuning knobs — parsed once into a
//! [`FeatureSpec`](crate::features::FeatureSpec) by [`Args::feature_spec`],
//! so no subcommand re-implements featurizer construction.

use crate::features::{FeatureSpec, KernelSpec, Method};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut args = Args { subcommand, ..Default::default() };
        while let Some(tok) = it.next() {
            let name = tok
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {tok:?}"))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    args.flags.insert(name, it.next().unwrap());
                }
                _ => args.switches.push(name),
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Fallible core of the typed flag getters: `Err` names the flag and
    /// the expected type — a typo'd `--m 10k24` must not quietly run with
    /// m = 1024.
    fn try_parsed<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
        kind: &str,
    ) -> Result<T, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| format!("flag --{name}: cannot parse {v:?} as {kind}"))
            }
        }
    }

    /// Typed flag access for the CLI: malformed input is a *usage* error,
    /// not a crash — emit the flag-naming message as an error-level event
    /// and exit(2), never a panic backtrace.
    fn parsed<T: std::str::FromStr>(&self, name: &str, default: T, kind: &str) -> T {
        self.try_parsed(name, default, kind).unwrap_or_else(|e| {
            crate::obs::error("cli", &format!("argument error: {e}"), &[]);
            std::process::exit(2);
        })
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.parsed(name, default, "an unsigned integer")
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.parsed(name, default, "a number")
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.parsed(name, default, "an unsigned integer")
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// A comma-separated list of positive integers (`--clients 1,8`).
    /// Entries must be >= 1 — a zero-client trial or a zero-width sweep
    /// is always a usage mistake, and the error names the flag.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        let Some(v) = self.get(name) else {
            return Ok(default.to_vec());
        };
        let mut out = Vec::new();
        for part in v.split(',') {
            let n: usize = part.trim().parse().map_err(|_| {
                format!(
                    "flag --{name}: cannot parse {part:?} as an unsigned integer \
                     (expected a comma-separated list like \"1,8\")"
                )
            })?;
            if n == 0 {
                return Err(format!("flag --{name}: entries must be >= 1, got {v:?}"));
            }
            out.push(n);
        }
        Ok(out)
    }

    /// A comma-separated list of `host:port` addresses (`--replicas
    /// 127.0.0.1:7701,127.0.0.1:7702`), shared by `gzk proxy --replicas`
    /// and the loadgen replica sweep. Every entry must carry a non-empty
    /// host and a non-zero port — an address that "parses" but can never
    /// be connected to is a usage mistake, and the error names the flag.
    /// `Ok(empty)` when the flag is absent.
    pub fn get_addr_list(&self, name: &str) -> Result<Vec<String>, String> {
        let Some(v) = self.get(name) else {
            return Ok(Vec::new());
        };
        let mut out = Vec::new();
        for part in v.split(',') {
            let addr = part.trim();
            let Some((host, port)) = addr.rsplit_once(':') else {
                return Err(format!(
                    "flag --{name}: {part:?} is not host:port \
                     (expected a comma-separated list like \"127.0.0.1:7701,127.0.0.1:7702\")"
                ));
            };
            if host.is_empty() {
                return Err(format!("flag --{name}: {part:?} has an empty host"));
            }
            match port.parse::<u16>() {
                Ok(p) if p != 0 => {}
                _ => {
                    return Err(format!(
                        "flag --{name}: {part:?} needs a port in 1..=65535, got {port:?}"
                    ))
                }
            }
            out.push(addr.to_string());
        }
        Ok(out)
    }

    /// A comma-separated list of file paths (`gzk trace-merge --inputs
    /// proxy.json,server.json`). Entries must be non-empty — an empty
    /// segment is a typo, not a path; `Ok(empty)` when the flag is
    /// absent, so the caller owns the "how many are required" rule.
    pub fn get_path_list(&self, name: &str) -> Result<Vec<std::path::PathBuf>, String> {
        if self.has(name) {
            return Err(format!("flag --{name} requires a value (comma-separated file paths)"));
        }
        let Some(v) = self.get(name) else {
            return Ok(Vec::new());
        };
        let mut out = Vec::new();
        for part in v.split(',') {
            let p = part.trim();
            if p.is_empty() {
                return Err(format!("flag --{name}: empty path entry in {v:?}"));
            }
            out.push(std::path::PathBuf::from(p));
        }
        Ok(out)
    }

    /// The global `--threads N` flag: how many workers the process-wide
    /// [`exec::Pool`](crate::exec::Pool) uses for every parallel path
    /// (featurize, absorb, k-means, KPCA, the coordinator's worker wave).
    /// `Ok(None)` when absent — the pool then sizes itself from the
    /// machine. Applies to every subcommand, so it is parsed here rather
    /// than per command.
    pub fn threads(&self) -> Result<Option<usize>, String> {
        if self.has("threads") {
            return Err("flag --threads requires a value (e.g. --threads 4)".to_string());
        }
        match self.get("threads") {
            None => Ok(None),
            Some(v) => {
                let n: usize = v.parse().map_err(|_| {
                    format!("flag --threads: cannot parse {v:?} as an unsigned integer")
                })?;
                if n == 0 {
                    return Err("flag --threads: must be >= 1 (omit it to use all cores)".into());
                }
                Ok(Some(n))
            }
        }
    }

    /// The global `--log-level error|warn|info|debug` flag: the
    /// structured-event threshold (see [`obs::events`](crate::obs::events)).
    /// `Ok(None)` when absent — main then falls back to the `GZK_LOG`
    /// env var and finally `info`. Applies to every subcommand, so it is
    /// parsed here rather than per command.
    pub fn log_level(&self) -> Result<Option<crate::obs::Level>, String> {
        if self.has("log-level") {
            return Err("flag --log-level requires a value (e.g. --log-level debug)".to_string());
        }
        match self.get("log-level") {
            None => Ok(None),
            Some(v) => {
                crate::obs::Level::parse(v).map(Some).map_err(|e| format!("flag --log-level: {e}"))
            }
        }
    }

    /// A global flag that takes a file path (`--log-file`, `--trace-out`):
    /// `Ok(None)` when absent. The bare-switch form is a usage error —
    /// a path swallowed by the next `--flag` must not be silently
    /// dropped.
    pub fn path_flag(&self, name: &str) -> Result<Option<&str>, String> {
        if self.has(name) {
            return Err(format!("flag --{name} requires a value (a file path)"));
        }
        Ok(self.get(name))
    }

    /// The shared featurizer flag group, parsed once into a `FeatureSpec`:
    ///
    /// ```text
    /// --kernel gaussian|exponential|polynomial|ntk   (default gaussian)
    ///   --bandwidth F   Gaussian bandwidth            (default 1.0)
    ///   --gamma F       exponential rate              (default 1.0)
    ///   --poly-p N --poly-c F   polynomial degree/offset
    ///   --depth N       NTK depth                     (default 2)
    /// --method <registry name>                        (default gegenbauer)
    ///   --q N --s N     Gegenbauer truncation / radial order
    ///   --taylor-deg N  PolySketch Taylor degree      (default 6)
    ///   --nystrom-lambda F                            (default 1e-3)
    /// --m N             feature budget                (default per command)
    /// --seed N                                        (default per command)
    /// ```
    pub fn feature_spec(&self, default_m: usize, default_seed: u64) -> Result<FeatureSpec, String> {
        // kernel knobs must be finite (a NaN bandwidth would poison every
        // feature and only surface much later, e.g. in the artifact codec)
        let finite_pos = |name: &str, v: f64| -> Result<f64, String> {
            if v.is_finite() && v > 0.0 {
                Ok(v)
            } else {
                Err(format!("flag --{name}: must be a finite positive number, got {v}"))
            }
        };
        let kernel = match self.get("kernel").unwrap_or("gaussian") {
            "gaussian" => KernelSpec::Gaussian {
                bandwidth: finite_pos("bandwidth", self.get_f64("bandwidth", 1.0))?,
            },
            "exponential" => KernelSpec::Exponential {
                gamma: finite_pos("gamma", self.get_f64("gamma", 1.0))?,
            },
            "polynomial" => {
                let c = self.get_f64("poly-c", 1.0);
                if !c.is_finite() {
                    return Err(format!("flag --poly-c: must be a finite number, got {c}"));
                }
                KernelSpec::Polynomial { p: self.get_usize("poly-p", 2), c }
            }
            "ntk" => KernelSpec::Ntk { depth: self.get_usize("depth", 2) },
            other => return Err(format!("unknown --kernel {other:?}")),
        };
        let method = match Method::from_name(self.get("method").unwrap_or(Method::GEGENBAUER))? {
            Method::Gegenbauer { .. } => Method::Gegenbauer {
                q: self.get_usize("q", 12),
                s: self.get_usize("s", 2),
            },
            Method::PolySketch { .. } => {
                Method::PolySketch { degree: self.get_usize("taylor-deg", 6) }
            }
            Method::Nystrom { .. } => {
                let lambda = self.get_f64("nystrom-lambda", 1e-3);
                if !lambda.is_finite() || lambda < 0.0 {
                    return Err(format!(
                        "flag --nystrom-lambda: must be a finite non-negative number, got {lambda}"
                    ));
                }
                Method::Nystrom { lambda }
            }
            other => other,
        };
        Ok(FeatureSpec::new(
            kernel,
            method,
            self.get_usize("m", default_m),
            self.get_u64("seed", default_seed),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table2 --dataset elevation --m 1024 --fast");
        assert_eq!(a.subcommand, "table2");
        assert_eq!(a.get("dataset"), Some("elevation"));
        assert_eq!(a.get_usize("m", 0), 1024);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn defaults() {
        let a = parse("fig1");
        assert_eq!(a.get_usize("degree", 15), 15);
        assert_eq!(a.get_f64("lambda", 1e-3), 1e-3);
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse("x --shift -3.5");
        assert_eq!(a.get_f64("shift", 0.0), -3.5);
    }

    #[test]
    fn rejects_bare_positional() {
        assert!(Args::parse(vec!["cmd".into(), "oops".into()]).is_err());
    }

    #[test]
    fn malformed_flag_values_error_with_flag_name() {
        // the fallible helper behind every typed getter: the error names
        // the offending flag and echoes the bad value (the CLI surfaces it
        // via eprintln + exit(2), without a backtrace — see cli_e2e.rs)
        let a = parse("serve --m 10k24 --lambda o.1 --seed -3");
        let e = a.try_parsed::<usize>("m", 512, "an unsigned integer").unwrap_err();
        assert!(e.contains("flag --m") && e.contains("10k24"), "{e}");
        let e = a.try_parsed::<f64>("lambda", 0.1, "a number").unwrap_err();
        assert!(e.contains("flag --lambda"), "{e}");
        let e = a.try_parsed::<u64>("seed", 1, "an unsigned integer").unwrap_err();
        assert!(e.contains("flag --seed"), "{e}");
        // absent and well-formed flags still flow through the same helper
        assert_eq!(a.try_parsed::<usize>("absent", 7, "an unsigned integer").unwrap(), 7);
        let b = parse("serve --m 1024");
        assert_eq!(b.try_parsed::<usize>("m", 512, "an unsigned integer").unwrap(), 1024);
    }

    #[test]
    fn usize_list_flag_parses_and_rejects_nonsense() {
        // absent: the default; present: a comma list, spaces tolerated
        assert_eq!(parse("loadgen").get_usize_list("clients", &[1, 8]).unwrap(), vec![1, 8]);
        let a = parse("loadgen --clients 2,4,16");
        assert_eq!(a.get_usize_list("clients", &[1]).unwrap(), vec![2, 4, 16]);
        let a = parse("loadgen --clients 7");
        assert_eq!(a.get_usize_list("clients", &[1]).unwrap(), vec![7]);
        for bad in ["loadgen --clients 1,x", "loadgen --clients 1,,2", "loadgen --clients 0"] {
            let e = parse(bad).get_usize_list("clients", &[1]).unwrap_err();
            assert!(e.contains("--clients"), "{bad}: {e}");
        }
    }

    #[test]
    fn addr_list_flag_parses_and_rejects_nonsense() {
        assert!(parse("proxy").get_addr_list("replicas").unwrap().is_empty());
        let a = parse("proxy --replicas 127.0.0.1:7701,localhost:7702,[::1]:7703");
        assert_eq!(
            a.get_addr_list("replicas").unwrap(),
            vec!["127.0.0.1:7701", "localhost:7702", "[::1]:7703"]
        );
        for bad in [
            "proxy --replicas 127.0.0.1",      // no port
            "proxy --replicas :7701",          // empty host
            "proxy --replicas 127.0.0.1:",     // empty port
            "proxy --replicas 127.0.0.1:0",    // port 0
            "proxy --replicas 127.0.0.1:port", // non-numeric port
            "proxy --replicas a:1,,b:2",       // empty entry
            "proxy --replicas 127.0.0.1:70000",
        ] {
            let e = parse(bad).get_addr_list("replicas").unwrap_err();
            assert!(e.contains("--replicas"), "{bad}: {e}");
        }
    }

    #[test]
    fn path_list_flag_parses_and_rejects_nonsense() {
        assert!(parse("trace-merge").get_path_list("inputs").unwrap().is_empty());
        // one argv token; spaces around commas are trimmed
        let a = Args::parse(vec![
            "trace-merge".into(),
            "--inputs".into(),
            "a.json, b.json ,dir/c.json".into(),
        ])
        .unwrap();
        assert_eq!(
            a.get_path_list("inputs").unwrap(),
            vec![
                std::path::PathBuf::from("a.json"),
                std::path::PathBuf::from("b.json"),
                std::path::PathBuf::from("dir/c.json")
            ]
        );
        let e = parse("trace-merge --inputs a.json,,b.json").get_path_list("inputs").unwrap_err();
        assert!(e.contains("--inputs") && e.contains("empty"), "{e}");
        let e = parse("trace-merge --inputs --out m.json").get_path_list("inputs").unwrap_err();
        assert!(e.contains("requires a value"), "{e}");
    }

    #[test]
    fn threads_flag_parses_and_rejects_nonsense() {
        assert_eq!(parse("serve").threads().unwrap(), None);
        assert_eq!(parse("serve --threads 4").threads().unwrap(), Some(4));
        assert_eq!(parse("fit --threads 1 --m 64").threads().unwrap(), Some(1));
        for bad in ["serve --threads 0", "serve --threads four", "serve --threads -2"] {
            let e = parse(bad).threads().unwrap_err();
            assert!(e.contains("--threads"), "{bad}: {e}");
        }
        // a bare `--threads` (value swallowed by the next flag) is an error
        let e = parse("serve --threads --m 64").threads().unwrap_err();
        assert!(e.contains("requires a value"), "{e}");
    }

    #[test]
    fn log_level_flag_parses_and_rejects_nonsense() {
        assert_eq!(parse("fit").log_level().unwrap(), None);
        assert_eq!(
            parse("fit --log-level debug").log_level().unwrap(),
            Some(crate::obs::Level::Debug)
        );
        let e = parse("fit --log-level loud").log_level().unwrap_err();
        assert!(e.contains("--log-level") && e.contains("loud"), "{e}");
        let e = parse("fit --log-level --m 64").log_level().unwrap_err();
        assert!(e.contains("requires a value"), "{e}");
    }

    #[test]
    fn path_flags_require_a_value() {
        assert_eq!(parse("fit").path_flag("trace-out").unwrap(), None);
        assert_eq!(parse("fit --trace-out t.json").path_flag("trace-out").unwrap(), Some("t.json"));
        let e = parse("fit --log-file --m 64").path_flag("log-file").unwrap_err();
        assert!(e.contains("--log-file") && e.contains("requires a value"), "{e}");
    }

    #[test]
    fn feature_spec_defaults_to_gegenbauer_gaussian() {
        let a = parse("serve");
        let spec = a.feature_spec(512, 7).unwrap();
        assert_eq!(spec.kernel, KernelSpec::Gaussian { bandwidth: 1.0 });
        assert_eq!(spec.method, Method::Gegenbauer { q: 12, s: 2 });
        assert_eq!((spec.m, spec.seed), (512, 7));
    }

    #[test]
    fn feature_spec_parses_full_flag_group() {
        let a = parse("serve --kernel exponential --gamma 0.5 --method gegenbauer --q 9 --s 3 --m 256 --seed 11");
        let spec = a.feature_spec(512, 7).unwrap();
        assert_eq!(spec.kernel, KernelSpec::Exponential { gamma: 0.5 });
        assert_eq!(spec.method, Method::Gegenbauer { q: 9, s: 3 });
        assert_eq!((spec.m, spec.seed), (256, 11));
    }

    #[test]
    fn feature_spec_method_knobs() {
        let a = parse("x --method polysketch --taylor-deg 4");
        assert_eq!(a.feature_spec(64, 1).unwrap().method, Method::PolySketch { degree: 4 });
        let a = parse("x --method nystrom --nystrom-lambda 0.01");
        assert_eq!(a.feature_spec(64, 1).unwrap().method, Method::Nystrom { lambda: 0.01 });
    }

    #[test]
    fn feature_spec_rejects_unknown_names() {
        assert!(parse("x --kernel sobolev").feature_spec(64, 1).is_err());
        assert!(parse("x --method svm").feature_spec(64, 1).is_err());
    }

    #[test]
    fn feature_spec_rejects_non_finite_kernel_knobs() {
        // str::parse::<f64> accepts "nan"/"inf"; a NaN bandwidth would
        // poison every downstream value, so it must die at the flag group
        for bad in ["nan", "inf", "-1", "0"] {
            let e = parse(&format!("x --bandwidth {bad}")).feature_spec(64, 1).unwrap_err();
            assert!(e.contains("flag --bandwidth"), "{bad}: {e}");
        }
        assert!(parse("x --kernel exponential --gamma nan").feature_spec(64, 1).is_err());
        assert!(parse("x --kernel polynomial --poly-c inf").feature_spec(64, 1).is_err());
        // method knobs too: a NaN nystrom lambda would serialize as
        // invalid JSON in the model artifact
        assert!(parse("x --method nystrom --nystrom-lambda nan").feature_spec(64, 1).is_err());
        assert!(parse("x --method nystrom --nystrom-lambda -0.5").feature_spec(64, 1).is_err());
    }
}
