//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Grammar: `gzk <subcommand> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut args = Args { subcommand, ..Default::default() };
        while let Some(tok) = it.next() {
            let name = tok
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {tok:?}"))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    args.flags.insert(name, it.next().unwrap());
                }
                _ => args.switches.push(name),
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table2 --dataset elevation --m 1024 --fast");
        assert_eq!(a.subcommand, "table2");
        assert_eq!(a.get("dataset"), Some("elevation"));
        assert_eq!(a.get_usize("m", 0), 1024);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
    }

    #[test]
    fn defaults() {
        let a = parse("fig1");
        assert_eq!(a.get_usize("degree", 15), 15);
        assert_eq!(a.get_f64("lambda", 1e-3), 1e-3);
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse("x --shift -3.5");
        assert_eq!(a.get_f64("shift", 0.0), -3.5);
    }

    #[test]
    fn rejects_bare_positional() {
        assert!(Args::parse(vec!["cmd".into(), "oops".into()]).is_err());
    }
}
