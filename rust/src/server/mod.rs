//! L4 — the network serving subsystem: a std-only TCP front-end over the
//! model / batcher / exec stack (DESIGN.md §3c).
//!
//! ```text
//!   TcpListener ── accept loop ──► event loop 0..N  (poll(2) readiness;
//!        │          round-robin      each loop owns per-connection
//!        │                           state machines: rbuf → parse →
//!        │                           dispatch → ordered replies → wbuf)
//!        │            wire: newline-delimited JSON (predict / models /
//!        │                  stats / metrics / ping / shutdown), or —
//!        │                  after {"cmd":"binary"} — length-prefixed
//!        │                  binary frames (bit-exact raw LE f64)
//!        ▼                                  ▼
//!   router: name ──► ModelRoute { PredictionService, Admission }
//!        ▲               each route = the L3 dynamic batcher over one
//!        │               artifact; batch compute draws from exec::Pool;
//!        │               ready replies ring the owning loop's waker
//!   manifest poll: ModelStore/models.json fingerprints → hot-reload
//! ```
//!
//! * [`wire`] — the JSON request/response codec. Floats reuse the
//!   artifact convention (shortest round-trip formatting), so
//!   predictions cross the wire **bit-exactly** — `gzk loadgen` verifies
//!   replies against a local `Model::predict` with equality, not
//!   tolerance.
//! * [`frame`] — the optional binary frame codec (negotiated per
//!   connection): length-prefixed, little-endian raw f64 payloads, the
//!   same 1 MiB cap as the JSON line.
//! * [`router`] — multi-model routing over a [`ModelStore`] directory
//!   with manifest-poll hot-reload: persist a new artifact into the
//!   store (`gzk fit --out <store>`) and the running server serves it
//!   without restart.
//! * [`admission`] — bounded per-model queues; overload is answered with
//!   a `"retry":true` backpressure reply instead of an unbounded queue.
//! * [`listener`] — accept loop (connection budget, round-robin deal to
//!   the event loops) + the bounded line reader the dist layer shares.
//! * [`mux`] — the event loops: nonblocking sockets, `poll(2)`
//!   readiness via [`sys`], per-connection state machines, reply-ready
//!   doorbells. Thread count is O(event-loops), not O(connections).
//! * [`sys`] — the thin std-only FFI shim (`poll(2)`, `RLIMIT_NOFILE`).
//! * [`loadgen`] — the measurement harness behind `gzk loadgen`:
//!   concurrent clients over real sockets (JSON, binary, or both for
//!   cross-checking), bit-identity verification, `BENCH_serve.json` with
//!   throughput + latency percentiles per client count.
//! * [`top`] — the live fleet monitor behind `gzk top`: polls the wire
//!   `metrics` command across `--targets`, diffs counters into rates,
//!   renders per-model throughput / ladder percentiles / queue depth /
//!   admission rejects, optionally as machine-readable `--json-out`.
//!
//! [`ModelStore`]: crate::model::ModelStore

pub mod admission;
pub mod frame;
pub mod listener;
pub mod loadgen;
pub mod mux;
pub mod router;
pub mod sys;
pub mod top;
pub mod wire;

pub use loadgen::{ClientConn, LoadgenConfig, LoadgenReport, TrialResult, WireMode};
pub use router::{Router, RouterConfig};

use listener::Shared;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving knobs for [`Server::start`]. The defaults match the CLI's.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// largest dynamic batch per model (the batcher's `max_batch`)
    pub max_batch: usize,
    /// extra batching window for bursty low-rate clients (`max_wait`)
    pub max_wait: Duration,
    /// per-model bound on admitted-but-unanswered requests
    pub max_queue: usize,
    /// how often the store manifest is polled for hot-reload
    pub poll: Duration,
    /// connection budget; 0 = size from the pool policy (8× pool width)
    pub max_conns: usize,
    /// disconnect a connection after this long with no request bytes
    /// (releases its budget slot); `Duration::ZERO` disables the policy
    pub idle_timeout: Duration,
    /// honor the wire `shutdown` command from non-loopback peers; off by
    /// default so a non-loopback `--addr` is not a remote kill switch
    pub allow_remote_shutdown: bool,
    /// event-loop threads multiplexing the connections; 0 = size from
    /// the pool policy (pool width, clamped to [1, 4] — loops are
    /// I/O-bound, a handful multiplexes thousands of connections)
    pub event_loops: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_batch: 64,
            max_wait: Duration::ZERO,
            max_queue: 1024,
            poll: Duration::from_millis(200),
            max_conns: 0,
            idle_timeout: Duration::from_secs(300),
            allow_remote_shutdown: false,
            event_loops: 0,
        }
    }
}

/// A running TCP model server. Dropping the handle does NOT stop it —
/// call [`shutdown`](Server::shutdown) (or send the wire `shutdown`
/// command) and then [`wait`](Server::wait).
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_handle: Option<JoinHandle<()>>,
    poll_handle: Option<JoinHandle<()>>,
    loop_handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Open the store, load every model, bind `addr` (e.g.
    /// `127.0.0.1:7711`; port 0 picks an ephemeral port — see
    /// [`local_addr`](Server::local_addr)) and start serving.
    pub fn start(
        store_dir: impl Into<PathBuf>,
        addr: &str,
        cfg: ServerConfig,
    ) -> Result<Server, String> {
        let router = Router::open(
            store_dir,
            RouterConfig {
                max_batch: cfg.max_batch,
                max_wait: cfg.max_wait,
                max_queue: cfg.max_queue,
            },
        )?;
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local_addr =
            listener.local_addr().map_err(|e| format!("local addr of {addr}: {e}"))?;
        let max_conns = if cfg.max_conns > 0 {
            cfg.max_conns
        } else {
            8 * crate::exec::Pool::global().threads()
        };
        let n_loops = if cfg.event_loops > 0 {
            cfg.event_loops
        } else {
            crate::exec::Pool::global().threads().clamp(1, 4)
        };
        // the budget plus waker pairs, listener, store and slack; a
        // best-effort raise so a 1k–10k connection budget is actually
        // reachable past the usual 1024-fd soft default
        sys::raise_nofile_limit(max_conns as u64 + 64);
        let mut loops = Vec::with_capacity(n_loops);
        let mut wake_rxs = Vec::with_capacity(n_loops);
        for _ in 0..n_loops {
            let (handle, wake_rx) = mux::LoopHandle::new()?;
            loops.push(handle);
            wake_rxs.push(wake_rx);
        }
        let shared = Arc::new(Shared {
            router,
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            max_conns,
            addr: local_addr,
            idle_timeout: (cfg.idle_timeout > Duration::ZERO).then_some(cfg.idle_timeout),
            allow_remote_shutdown: cfg.allow_remote_shutdown,
            loops,
        });
        let loop_handles = wake_rxs
            .into_iter()
            .enumerate()
            .map(|(idx, wake_rx)| {
                let shared = Arc::clone(&shared);
                let handle = Arc::clone(&shared.loops[idx]);
                std::thread::spawn(move || mux::event_loop(idx, shared, handle, wake_rx))
            })
            .collect();
        let accept_shared = Arc::clone(&shared);
        let accept_handle =
            std::thread::spawn(move || listener::accept_loop(listener, accept_shared));
        let poll_shared = Arc::clone(&shared);
        let poll = cfg.poll.max(Duration::from_millis(1));
        let poll_handle = std::thread::spawn(move || poll_loop(&poll_shared, poll));
        Ok(Server {
            shared,
            local_addr,
            accept_handle: Some(accept_handle),
            poll_handle: Some(poll_handle),
            loop_handles,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Names of the models currently served.
    pub fn model_names(&self) -> Vec<String> {
        self.shared.router.model_names()
    }

    /// Ask the server to stop (same effect as the wire `shutdown`
    /// command); returns immediately — pair with [`wait`](Server::wait).
    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }

    /// Block until the server has shut down (wire `shutdown` command or
    /// [`shutdown`](Server::shutdown)), drain live connections (the
    /// event loops flush in-flight replies under a bounded grace
    /// period), and return the final per-model stats reply line.
    pub fn wait(mut self) -> String {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.poll_handle.take() {
            let _ = h.join();
        }
        for h in self.loop_handles.drain(..) {
            let _ = h.join();
        }
        // belt and braces: the loops already drained their connections
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.shared.active_conns.load(Ordering::Acquire) > 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.shared.router.stats_reply()
    }
}

/// Manifest poll: the hot-reload driver. Sleeps in short slices so
/// shutdown stays prompt even with long poll intervals; reload reports
/// are info-level obs events (the server's operational log).
fn poll_loop(shared: &Arc<Shared>, poll: Duration) {
    loop {
        let mut slept = Duration::ZERO;
        while slept < poll {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            let step = (poll - slept).min(Duration::from_millis(25));
            std::thread::sleep(step);
            slept += step;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        match shared.router.sync(false) {
            Ok(changes) => {
                for c in changes {
                    crate::obs::info("server.reload", &c, &[]);
                }
            }
            Err(e) => {
                crate::obs::warn("server.reload", &format!("store poll failed: {e}"), &[]);
            }
        }
    }
}
