//! Bounded admission for the serving front-end: each routed model admits
//! at most `max_queue` in-flight requests (admitted but not yet
//! answered). Beyond that the listener replies with a backpressure
//! error (`"retry":true`) **immediately** instead of letting the
//! batcher's unbounded mpsc queue absorb an arbitrary backlog — under
//! overload the server sheds load with bounded latency rather than
//! growing memory and tail latency without bound.
//!
//! The mechanism is a lock-free counter with RAII release: admission is
//! a CAS increment capped at `max_queue`, and the [`AdmissionGuard`]
//! decrements on drop — on every exit path, including a client that
//! disconnects mid-request.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Per-model admission state. Shared (`Arc`) between every connection
/// thread routing to the model and the `stats` reporter.
pub struct Admission {
    max_queue: usize,
    in_flight: AtomicUsize,
    rejects: AtomicU64,
    /// the obs-registry twin of `rejects`: `server.admission.<model>.
    /// rejected_total`. The registry counter is process-global and
    /// name-keyed, so unlike the per-route `rejects` field it survives
    /// hot-swaps (a reload builds a fresh `Admission` but resolves the
    /// same counter) and shows up in the wire `metrics` snapshot.
    rejected_total: crate::obs::registry::Counter,
    /// registry twin of `in_flight`: `server.admission.<model>.
    /// queue_depth` — the live depth `gzk top` reads off the wire
    /// `metrics` snapshot
    depth_gauge: crate::obs::registry::Gauge,
}

impl Admission {
    /// `name` is the model the bound belongs to; it keys the registry
    /// counter so rejects are attributable per model in `metrics`.
    pub fn new(name: &str, max_queue: usize) -> Arc<Admission> {
        assert!(max_queue >= 1, "admission needs room for at least one request");
        Arc::new(Admission {
            max_queue,
            in_flight: AtomicUsize::new(0),
            rejects: AtomicU64::new(0),
            rejected_total: crate::obs::counter(&format!(
                "server.admission.{name}.rejected_total"
            )),
            depth_gauge: crate::obs::gauge(&format!("server.admission.{name}.queue_depth")),
        })
    }

    /// Try to admit one request: `Some(guard)` reserves a queue slot
    /// until the guard drops; `None` means the queue is full (counted as
    /// a reject — the caller owes the client a backpressure reply).
    pub fn try_admit(self: &Arc<Admission>) -> Option<AdmissionGuard> {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_queue {
                self.rejects.fetch_add(1, Ordering::Relaxed);
                self.rejected_total.inc();
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.depth_gauge.set(cur as i64 + 1);
                    return Some(AdmissionGuard { admission: Arc::clone(self) });
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Requests currently admitted and not yet answered.
    pub fn depth(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// Requests turned away since the route was created.
    pub fn rejects(&self) -> u64 {
        self.rejects.load(Ordering::Relaxed)
    }
}

/// RAII queue slot: dropping it releases the admission.
pub struct AdmissionGuard {
    admission: Arc<Admission>,
}

impl Drop for AdmissionGuard {
    fn drop(&mut self) {
        let prev = self.admission.in_flight.fetch_sub(1, Ordering::AcqRel);
        self.admission.depth_gauge.set(prev as i64 - 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_the_cap_and_releases_on_drop() {
        let adm = Admission::new("adm-test-cap", 2);
        let obs_before = crate::obs::counter("server.admission.adm-test-cap.rejected_total").get();
        let a = adm.try_admit().expect("slot 1");
        let _b = adm.try_admit().expect("slot 2");
        assert_eq!(adm.depth(), 2);
        assert!(adm.try_admit().is_none(), "third admit must be rejected");
        assert_eq!(adm.rejects(), 1);
        drop(a);
        assert_eq!(adm.depth(), 1);
        let _c = adm.try_admit().expect("slot freed by the dropped guard");
        assert_eq!(adm.rejects(), 1, "successful admits are not rejects");
        // the registry twin counted the same reject under the model's name
        let obs_after = crate::obs::counter("server.admission.adm-test-cap.rejected_total").get();
        assert_eq!(obs_after - obs_before, 1);
    }

    #[test]
    fn concurrent_admission_never_exceeds_the_cap() {
        let adm = Admission::new("adm-test-concurrent", 4);
        let peak = AtomicUsize::new(0);
        let admitted = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..500 {
                        if let Some(guard) = adm.try_admit() {
                            admitted.fetch_add(1, Ordering::Relaxed);
                            let d = adm.depth();
                            peak.fetch_max(d, Ordering::Relaxed);
                            assert!(d <= 4, "depth {d} exceeded the cap");
                            drop(guard);
                        }
                    }
                });
            }
        });
        assert!(peak.load(Ordering::Relaxed) <= 4);
        assert_eq!(
            admitted.load(Ordering::Relaxed) as u64 + adm.rejects(),
            8 * 500,
            "every attempt either admitted or rejected"
        );
        assert_eq!(adm.depth(), 0, "all guards released");
    }
}
