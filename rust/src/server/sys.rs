//! Thin std-only FFI shim over the two OS facilities the event-driven
//! listener needs and std does not expose: readiness polling
//! (`poll(2)`) and the file-descriptor resource limit
//! (`getrlimit`/`setrlimit` with `RLIMIT_NOFILE`).
//!
//! This is deliberately the *whole* FFI surface of the serving tier: no
//! epoll/kqueue (poll is portable across unix and fine at the 1k–10k
//! connection scale the C10K bench targets — the per-call fd-array walk
//! is microseconds against network latencies), no pipes or eventfd (the
//! event loops wake each other through a loopback TCP socketpair built
//! entirely from std — see `mux::LoopHandle`), no fcntl (std's
//! `set_nonblocking` covers the sockets). Everything here is
//! `#[repr(C)]` structs + constants transcribed from POSIX, cfg-gated
//! where Linux and the BSD family (macOS) disagree (`nfds_t`,
//! `RLIMIT_NOFILE`).
//!
//! On non-unix targets the crate still compiles: [`poll_fds`] reports
//! `Unsupported` (the event-loop server is a unix subsystem; the rest of
//! the crate — fitting, artifacts, the dist layer's blocking sockets —
//! has no FFI at all).

/// One pollable descriptor: mirrors `struct pollfd`. `events` is what to
/// wait for, `revents` what the kernel reported.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

/// Data may be read without blocking.
pub const POLLIN: i16 = 0x001;
/// Data may be written without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (always reported, never requested).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (always reported, never requested).
pub const POLLHUP: i16 = 0x010;
/// The fd is not open (always reported, never requested).
pub const POLLNVAL: i16 = 0x020;

#[cfg(unix)]
mod imp {
    use super::PollFd;

    // `nfds_t`: `unsigned long` on Linux glibc/musl, `unsigned int` on
    // the BSD family (macOS included).
    #[cfg(target_os = "macos")]
    type Nfds = std::ffi::c_uint;
    #[cfg(not(target_os = "macos"))]
    type Nfds = std::ffi::c_ulong;

    // `RLIMIT_NOFILE`: 7 on Linux, 8 on the BSD family.
    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: std::ffi::c_int = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: std::ffi::c_int = 8;

    /// `struct rlimit`: `rlim_t` is `unsigned long` on the platforms we
    /// target (64-bit on every 64-bit unix).
    #[repr(C)]
    struct RLimit {
        cur: std::ffi::c_ulong,
        max: std::ffi::c_ulong,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: std::ffi::c_int) -> std::ffi::c_int;
        fn getrlimit(resource: std::ffi::c_int, rlim: *mut RLimit) -> std::ffi::c_int;
        fn setrlimit(resource: std::ffi::c_int, rlim: *const RLimit) -> std::ffi::c_int;
    }

    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                continue; // EINTR: retry with the same timeout
            }
            return Err(err);
        }
    }

    pub fn raise_nofile(want: u64) -> u64 {
        let mut lim = RLimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 0;
        }
        let cur = lim.cur as u64;
        let hard = lim.max as u64;
        if cur >= want {
            return cur; // already enough headroom
        }
        // unprivileged processes may raise the soft limit up to the hard
        // limit, no further — clamp instead of failing
        let target = want.min(hard);
        let req = RLimit { cur: target as std::ffi::c_ulong, max: lim.max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &req) } == 0 {
            target
        } else {
            cur
        }
    }
}

#[cfg(not(unix))]
mod imp {
    use super::PollFd;

    pub fn poll_fds(_fds: &mut [PollFd], _timeout_ms: i32) -> std::io::Result<usize> {
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "readiness polling requires a unix target",
        ))
    }

    pub fn raise_nofile(_want: u64) -> u64 {
        u64::MAX // no rlimit concept; report "plenty"
    }
}

/// Block until a descriptor in `fds` is ready, the timeout expires
/// (`Ok(0)`), or an error other than EINTR occurs. `timeout_ms < 0`
/// blocks indefinitely. EINTR is retried internally — callers never see
/// it.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    imp::poll_fds(fds, timeout_ms)
}

/// Best-effort: raise the soft `RLIMIT_NOFILE` to at least `want`
/// descriptors (clamped to the hard limit — unprivileged processes
/// cannot exceed it). Returns the soft limit after the attempt; both the
/// server (sized from its connection budget) and loadgen (sized from the
/// largest client count) call this so a 1k–10k connection sweep does not
/// die on the usual 1024-fd default.
pub fn raise_nofile_limit(want: u64) -> u64 {
    imp::raise_nofile(want)
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn poll_reports_readability_exactly_when_bytes_are_pending() {
        let (mut a, b) = pair();
        let mut fds = [PollFd { fd: b.as_raw_fd(), events: POLLIN, revents: 0 }];
        // nothing written yet: a short poll times out with 0 ready fds
        assert_eq!(poll_fds(&mut fds, 20).unwrap(), 0);
        assert_eq!(fds[0].revents, 0);
        a.write_all(b"x").unwrap();
        a.flush().unwrap();
        // readable now; a generous timeout returns promptly
        assert_eq!(poll_fds(&mut fds, 5_000).unwrap(), 1);
        assert_ne!(fds[0].revents & POLLIN, 0, "revents {:#x}", fds[0].revents);
        // an idle socket with send-buffer room is immediately writable
        let mut wfds = [PollFd { fd: b.as_raw_fd(), events: POLLOUT, revents: 0 }];
        assert_eq!(poll_fds(&mut wfds, 5_000).unwrap(), 1);
        assert_ne!(wfds[0].revents & POLLOUT, 0);
    }

    #[test]
    fn nofile_limit_raises_are_monotone_and_clamped() {
        let before = raise_nofile_limit(0); // read the current soft limit
        assert!(before > 0, "process must have a nonzero fd limit");
        let after = raise_nofile_limit(before); // no-op: already there
        assert!(after >= before);
        // an absurd request clamps to the hard limit instead of failing
        let clamped = raise_nofile_limit(u64::MAX);
        assert!(clamped >= after);
    }
}
