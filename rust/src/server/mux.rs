//! Event-driven connection multiplexing: the C10K half of the serving
//! tier (DESIGN.md §3c).
//!
//! The thread-per-connection listener capped realistic concurrency at
//! hundreds of clients (two OS threads per accept). Here a small fixed
//! pool of event-loop threads drives every connection through
//! nonblocking sockets and level-triggered readiness polling
//! ([`sys::poll_fds`]): the accept loop hands each new connection to a
//! loop round-robin, and the loop owns a [`Conn`] state machine per
//! connection — receive buffer, ordered reply queue, write buffer,
//! deadlines. Thread count is O(event-loops), independent of connection
//! count.
//!
//! Every hardening bound of the thread-per-connection design survives as
//! a state transition (the PR-5 invariants, re-verified by
//! `tests/server_e2e.rs`):
//!
//! * **line/frame cap** — a newline-free flood trips the
//!   [`MAX_LINE_BYTES`] check on the receive buffer (and a hostile frame
//!   length prefix is rejected from its header by [`frame::scan`]):
//!   error reply, then close. No way to resynchronize mid-line.
//! * **slow-loris** — `last_read` bounds the gap between reads and
//!   `assembly_start` bounds how long one request may take to assemble;
//!   either deadline queues the idle-timeout reply and closes.
//! * **flooder that never reads** — replies stop being *read* from the
//!   socket? The write buffer grows to its high-water mark, the loop
//!   stops polling the connection for readability (backpressure instead
//!   of memory growth), and a write side that makes no progress for the
//!   idle timeout is closed outright.
//! * **reply-queue bound** — at [`REPLY_QUEUE_BOUND`] dispatched-but-
//!   unwritten replies the connection also stops being read, the moral
//!   equivalent of the old reader thread blocking on its full
//!   `sync_channel`.
//! * **loopback-gated shutdown** — unchanged: the wire `shutdown` is
//!   honored only from loopback peers unless the server opted in.
//!
//! Replies stay **in request order**: dispatched predicts join a
//! per-connection [`VecDeque`] and only the *front* entry's channel is
//! polled; completed replies behind a still-pending head wait their
//! turn. A ready reply does not wait for a poll timeout either — every
//! dispatch carries a [`ReplyNotify`] doorbell that wakes the owning
//! loop (a byte through its loopback waker pair) the moment the batcher
//! sends the reply.

use super::admission::AdmissionGuard;
use super::frame;
use super::listener::{is_loopback_ip, Shared, MAX_LINE_BYTES};
use super::sys::{self, PollFd, POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};
use super::wire;
use super::router::Dispatch;
use crate::coordinator::ReplyNotify;
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-connection bound on dispatched-but-unwritten replies. Admission
/// bounds admitted predicts, but the cheap commands (ping/models/stats,
/// error replies) bypass admission — without this bound, a client that
/// floods commands and never reads its socket grows the reply queue
/// without limit. At the bound the connection stops being polled for
/// readability: backpressure, not memory growth.
pub(crate) const REPLY_QUEUE_BOUND: usize = 256;

/// Stop reading a connection whose unwritten reply bytes reach this
/// high-water mark (the buffered twin of the reply-queue bound, for
/// replies that are large rather than many).
const WBUF_HIGH_WATER: usize = MAX_LINE_BYTES;

/// How long a shutting-down loop keeps flushing in-flight replies
/// before closing whatever is left.
const DRAIN_GRACE: Duration = Duration::from_secs(5);

/// Largest poll timeout: the sweep tick that backstops deadlines and any
/// doorbell lost to a crashed service thread.
const MAX_POLL_MS: i32 = 250;

/// One event loop's mailbox: how the accept loop (new connections), the
/// batcher doorbells (ready replies) and shutdown reach a thread that is
/// parked inside `poll(2)`. The waker is a nonblocking loopback TCP pair
/// built entirely from std — the write end lives here, the read end is
/// fd 0 of the loop's poll set.
pub(crate) struct LoopHandle {
    inbox: Mutex<Vec<TcpStream>>,
    wake_tx: Mutex<TcpStream>,
}

impl LoopHandle {
    /// Build the handle and its waker pair; the returned stream is the
    /// read end the loop polls.
    pub(crate) fn new() -> Result<(Arc<LoopHandle>, TcpStream), String> {
        let (tx, rx) = loopback_pair()?;
        Ok((Arc::new(LoopHandle { inbox: Mutex::new(Vec::new()), wake_tx: Mutex::new(tx) }), rx))
    }

    /// Interrupt the loop's poll. One byte through the waker; a full
    /// send buffer (`WouldBlock`) means a wake is already pending, which
    /// is all a wake means — never block, never fail.
    pub(crate) fn wake(&self) {
        if let Ok(mut tx) = self.wake_tx.lock() {
            let _ = tx.write(&[1]);
        }
    }

    /// Hand a freshly accepted connection to this loop.
    pub(crate) fn enqueue_conn(&self, stream: TcpStream) {
        self.inbox.lock().expect("loop inbox lock").push(stream);
        self.wake();
    }
}

/// A connected nonblocking loopback pair. TCP instead of a pipe keeps
/// the crate std-only; accepting until the peer matches our connect's
/// local address guards against a foreign process racing onto the
/// ephemeral port.
fn loopback_pair() -> Result<(TcpStream, TcpStream), String> {
    let l = TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind waker: {e}"))?;
    let addr = l.local_addr().map_err(|e| format!("waker addr: {e}"))?;
    let tx = TcpStream::connect(addr).map_err(|e| format!("connect waker: {e}"))?;
    let local = tx.local_addr().map_err(|e| format!("waker local addr: {e}"))?;
    loop {
        let (rx, peer) = l.accept().map_err(|e| format!("accept waker: {e}"))?;
        if peer != local {
            continue; // someone else's connect; not our waker
        }
        tx.set_nonblocking(true).map_err(|e| format!("waker nonblocking: {e}"))?;
        rx.set_nonblocking(true).map_err(|e| format!("waker nonblocking: {e}"))?;
        return Ok((tx, rx));
    }
}

#[cfg(unix)]
fn raw_fd(s: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd(_s: &TcpStream) -> i32 {
    -1 // the poll shim reports Unsupported before the fd matters
}

/// One entry of a connection's ordered reply queue.
enum PendingOut {
    /// reply bytes ready to move into the write buffer
    Ready(Vec<u8>),
    /// an admitted predict: poll `rx`; the guard holds the admission
    /// slot until the reply is serialized. `binary` is the connection's
    /// mode *at dispatch time*, so predicts pipelined ahead of a
    /// `binary` upgrade still get the JSON replies they asked for.
    /// `tid` is the request's distributed trace ID (0 = untraced),
    /// `queued` when it was dispatched, and `hist` the route's latency
    /// histogram — all observability-only, none touch the reply bytes.
    Await {
        model: String,
        rx: Receiver<Vec<f64>>,
        guard: AdmissionGuard,
        binary: bool,
        tid: u64,
        queued: Instant,
        hist: crate::obs::Hist,
    },
    /// close once everything queued before this marker is flushed
    Close,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    fd: i32,
    peer_loopback: bool,
    /// negotiated frame mode (`{"cmd":"binary"}` flips it)
    binary: bool,
    /// bytes read but not yet parsed into a request
    rbuf: Vec<u8>,
    /// serialized replies not yet written; `wpos` marks the write cursor
    wbuf: Vec<u8>,
    wpos: usize,
    pending: VecDeque<PendingOut>,
    /// peer closed its write side (EOF); finish owed replies, then close
    read_closed: bool,
    /// a Close marker is queued: stop reading, drain, close
    close_queued: bool,
    /// reap this connection at the next sweep
    dead: bool,
    last_read: Instant,
    /// when the (incomplete) request at the head of `rbuf` started
    /// assembling — the slow-loris deadline
    assembly_start: Option<Instant>,
    last_write_progress: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> Result<Conn, String> {
        let _ = stream.set_nodelay(true); // request/reply lines, not bulk data
        stream.set_nonblocking(true).map_err(|e| format!("nonblocking: {e}"))?;
        let peer_loopback = stream.peer_addr().map(|a| is_loopback_ip(a.ip())).unwrap_or(false);
        let fd = raw_fd(&stream);
        let now = Instant::now();
        Ok(Conn {
            stream,
            fd,
            peer_loopback,
            binary: false,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            read_closed: false,
            close_queued: false,
            dead: false,
            last_read: now,
            assembly_start: None,
            last_write_progress: now,
        })
    }

    fn unwritten(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Queue one reply in order.
    fn queue(&mut self, bytes: Vec<u8>) {
        self.pending.push_back(PendingOut::Ready(bytes));
    }

    /// Queue a final reply followed by the close marker; reading stops.
    fn queue_last(&mut self, bytes: Vec<u8>) {
        if self.close_queued {
            return; // the first close wins; never stack duplicates
        }
        self.pending.push_back(PendingOut::Ready(bytes));
        self.pending.push_back(PendingOut::Close);
        self.close_queued = true;
    }

    /// An error reply in the connection's current wire mode.
    fn error_bytes(&self, msg: &str) -> Vec<u8> {
        if self.binary {
            frame::frame(&frame::status_payload(frame::ST_ERR, msg))
        } else {
            json_line(&wire::error_reply(msg))
        }
    }

    /// May this connection's socket be polled for readability?
    fn wants_read(&self) -> bool {
        !self.read_closed
            && !self.close_queued
            && self.pending.len() < REPLY_QUEUE_BOUND
            && self.unwritten() < WBUF_HIGH_WATER
    }

    fn poll_events(&self, shutting: bool) -> i16 {
        let mut ev = 0i16;
        if self.wants_read() && !shutting {
            ev |= POLLIN;
        }
        if self.unwritten() > 0 {
            ev |= POLLOUT;
        }
        ev // ERR/HUP/NVAL are reported even with no requested events
    }

    /// The soonest instant a deadline could fire for this connection.
    fn next_deadline(&self, idle: Duration) -> Option<Instant> {
        let mut soonest: Option<Instant> = None;
        let mut push = |t: Instant| {
            soonest = Some(match soonest {
                Some(s) if s <= t => s,
                _ => t,
            });
        };
        if !self.close_queued && !self.read_closed {
            push(self.last_read + idle);
            if let Some(t0) = self.assembly_start {
                push(t0 + idle);
            }
        }
        if self.unwritten() > 0 {
            push(self.last_write_progress + idle);
        }
        soonest
    }
}

fn json_line(line: &str) -> Vec<u8> {
    let mut b = Vec::with_capacity(line.len() + 1);
    b.extend_from_slice(line.as_bytes());
    b.push(b'\n');
    b
}

/// Convert an `Dispatch::Immediate` JSON reply (routing error, admission
/// overload, submit failure — a successful predict is always `Pending`)
/// into the equivalent reply frame, preserving the retry contract.
fn immediate_frame(line: &str) -> Vec<u8> {
    let payload = match wire::parse_reply(line) {
        Ok(r) => {
            let msg = r.error.unwrap_or_else(|| "server error".to_string());
            frame::status_payload(if r.retry { frame::ST_RETRY } else { frame::ST_ERR }, &msg)
        }
        Err(_) => frame::status_payload(frame::ST_ERR, "server error"),
    };
    frame::frame(&payload)
}

/// What one event loop carries into its per-connection helpers.
struct LoopCtx {
    shared: Arc<Shared>,
    /// the loop's doorbell, handed to every dispatched predict
    bell: ReplyNotify,
    binary_upgrades: crate::obs::registry::Counter,
    frames_in: crate::obs::registry::Counter,
}

/// One event loop: owns its connections start to finish. `idx` names the
/// loop's per-loop metrics; `wake_rx` is the read end of the waker pair.
pub(crate) fn event_loop(
    idx: usize,
    shared: Arc<Shared>,
    handle: Arc<LoopHandle>,
    mut wake_rx: TcpStream,
) {
    let conns_gauge = crate::obs::gauge(&format!("server.loop{idx}.conns"));
    let wakeups = crate::obs::counter(&format!("server.loop{idx}.wakeups"));
    let ctx = LoopCtx {
        shared: Arc::clone(&shared),
        bell: Arc::new({
            let h = Arc::clone(&handle);
            move || h.wake()
        }),
        binary_upgrades: crate::obs::counter("server.binary_upgrades"),
        frames_in: crate::obs::counter("server.frames.requests"),
    };
    let wake_fd = raw_fd(&wake_rx);
    let mut conns: Vec<Conn> = Vec::new();
    let mut shutdown_since: Option<Instant> = None;
    loop {
        // admit connections the accept loop queued
        let fresh: Vec<TcpStream> =
            handle.inbox.lock().expect("loop inbox lock").drain(..).collect();
        for s in fresh {
            match Conn::new(s) {
                Ok(c) => conns.push(c),
                Err(_) => {
                    // dead on arrival: release its budget slot
                    shared.active_conns.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }

        let shutting = shared.shutdown.load(Ordering::Acquire);
        if shutting && shutdown_since.is_none() {
            shutdown_since = Some(Instant::now());
        }
        let drain_expired = shutdown_since.map(|t| t.elapsed() > DRAIN_GRACE).unwrap_or(false);

        // sweep every connection: pump ready replies, flush, deadlines
        let now = Instant::now();
        for c in conns.iter_mut() {
            service(c, &ctx);
            enforce_deadlines(c, &shared, now);
            if shutting && (drain_expired || (c.pending.is_empty() && c.unwritten() == 0)) {
                c.dead = true; // drained (or out of grace): close
            }
        }
        conns.retain(|c| {
            if c.dead {
                shared.active_conns.fetch_sub(1, Ordering::AcqRel);
                false
            } else {
                true
            }
        });
        conns_gauge.set(conns.len() as i64);
        if shutting && conns.is_empty() {
            return;
        }

        // poll the waker + every connection (index i+1 = conns[i]; the
        // set is rebuilt each iteration, nothing mutates it mid-poll)
        let mut pfds = Vec::with_capacity(conns.len() + 1);
        pfds.push(PollFd { fd: wake_fd, events: POLLIN, revents: 0 });
        for c in &conns {
            pfds.push(PollFd { fd: c.fd, events: c.poll_events(shutting), revents: 0 });
        }
        let timeout = poll_timeout(&conns, &shared, shutting);
        match sys::poll_fds(&mut pfds, timeout) {
            Ok(0) => continue, // sweep tick: deadlines re-checked above
            Ok(_) => wakeups.inc(),
            Err(_) => {
                // unsupported target or transient failure: degrade to a
                // slow sweep instead of a busy loop
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        }
        if pfds[0].revents & POLLIN != 0 {
            drain_waker(&mut wake_rx);
        }
        for (c, pfd) in conns.iter_mut().zip(&pfds[1..]) {
            let re = pfd.revents;
            if re & (POLLERR | POLLNVAL) != 0 {
                c.dead = true;
                continue;
            }
            if re & POLLIN != 0 {
                read_ready(c, &ctx);
            } else if re & POLLHUP != 0 {
                // hangup with nothing left to read: flush what is owed
                c.read_closed = true;
            }
            if re & POLLOUT != 0 {
                flush(c);
            }
        }
    }
}

/// Swallow queued wake bytes; level-triggered poll would otherwise spin
/// on them forever.
fn drain_waker(wake_rx: &mut TcpStream) {
    let mut buf = [0u8; 64];
    loop {
        match wake_rx.read(&mut buf) {
            Ok(0) => return, // wake_tx outlives the loop; treat as spurious
            Ok(_) => continue,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            _ => return,
        }
    }
}

/// Smallest poll timeout that keeps every connection deadline honest,
/// clamped to `[1, MAX_POLL_MS]` ms.
fn poll_timeout(conns: &[Conn], shared: &Shared, shutting: bool) -> i32 {
    if shutting {
        return 10; // drain fast
    }
    let mut timeout = MAX_POLL_MS;
    if let Some(idle) = shared.idle_timeout {
        let now = Instant::now();
        for c in conns {
            if let Some(deadline) = c.next_deadline(idle) {
                let ms = deadline.saturating_duration_since(now).as_millis() as i32;
                timeout = timeout.min(ms.max(1));
            }
        }
    }
    timeout
}

/// Pump ready replies into the write buffer, flush, and — if
/// backpressure lifted — resume parsing bytes already buffered.
fn service(c: &mut Conn, ctx: &LoopCtx) {
    if c.dead {
        return;
    }
    pump(c);
    flush(c);
    if !c.dead && !c.close_queued && !c.rbuf.is_empty() && c.wants_read() {
        process_rbuf(c, ctx);
        pump(c);
        flush(c);
    }
    // EOF with a final unterminated JSON line still gets served (the
    // bounded line reader did the same at EOF); an incomplete frame at
    // EOF is just dropped
    if c.read_closed && !c.close_queued && !c.binary && !c.rbuf.is_empty() {
        let line = std::mem::take(&mut c.rbuf);
        c.assembly_start = None;
        handle_line(c, &line, ctx);
        pump(c);
        flush(c);
    }
    // nothing more will arrive and nothing is owed: close
    if c.read_closed && c.pending.is_empty() && c.unwritten() == 0 {
        c.dead = true;
    }
}

/// Apply the idle/assembly/write-stall deadlines (see the module doc's
/// hardening map).
fn enforce_deadlines(c: &mut Conn, shared: &Shared, now: Instant) {
    let Some(idle) = shared.idle_timeout else { return };
    if c.dead {
        return;
    }
    if !c.close_queued && !c.read_closed {
        let read_gap = now.duration_since(c.last_read) >= idle;
        let assembly =
            c.assembly_start.map(|t0| now.duration_since(t0) >= idle).unwrap_or(false);
        if read_gap || assembly {
            // tell the client why, then release the budget slot
            let reply = c.error_bytes("idle timeout; closing connection");
            c.queue_last(reply);
            pump(c);
            flush(c);
        }
    }
    if c.unwritten() > 0 && now.duration_since(c.last_write_progress) >= idle {
        c.dead = true; // the write twin: a stalled reader of our replies
    }
}

/// What [`pump`] decided to do with the queue head (computed first, so
/// the borrow of the head ends before the queue is mutated).
enum PumpAction {
    TakeReady,
    Reply(Vec<f64>),
    Reloaded,
}

/// Move completed replies, **in request order**, from the pending queue
/// into the write buffer. Only the head is ever polled; a completed
/// reply behind a pending head waits its turn.
fn pump(c: &mut Conn) {
    loop {
        let drained = c.unwritten() == 0;
        let action = match c.pending.front_mut() {
            None => return,
            Some(PendingOut::Ready(_)) => PumpAction::TakeReady,
            Some(PendingOut::Close) => {
                if drained {
                    c.dead = true; // final reply flushed: close now
                }
                return; // nothing after a Close marker matters
            }
            Some(PendingOut::Await { rx, .. }) => match rx.try_recv() {
                Err(TryRecvError::Empty) => return, // head still cooking
                Ok(y) => PumpAction::Reply(y),
                Err(TryRecvError::Disconnected) => PumpAction::Reloaded,
            },
        };
        if drained {
            // the stall clock measures progress on a non-empty buffer
            c.last_write_progress = Instant::now();
        }
        match (action, c.pending.pop_front()) {
            (PumpAction::TakeReady, Some(PendingOut::Ready(bytes))) => {
                c.wbuf.extend_from_slice(&bytes);
            }
            (
                PumpAction::Reply(y),
                Some(PendingOut::Await { model, guard, binary, tid, queued, hist }),
            ) => {
                hist.record(queued.elapsed().as_secs_f64());
                if tid != 0 {
                    // stitchable serve-side span: dispatch to reply-ready
                    crate::obs::trace::record_since("serve", "predict", tid, queued);
                }
                let bytes = if binary {
                    if y.iter().all(|v| v.is_finite()) {
                        frame::frame(&frame::ok_payload(&y))
                    } else {
                        frame::frame(&frame::status_payload(
                            frame::ST_ERR,
                            &format!("model {model:?} produced a non-finite prediction"),
                        ))
                    }
                } else {
                    json_line(
                        &wire::predict_reply(&model, &y).unwrap_or_else(|e| wire::error_reply(&e)),
                    )
                };
                c.wbuf.extend_from_slice(&bytes);
                drop(guard); // release the admission slot with the reply in hand
            }
            (PumpAction::Reloaded, Some(PendingOut::Await { model, guard, binary, .. })) => {
                // the route was swapped out mid-flight and its service
                // exited: rare, and retriable by contract
                let msg = format!("model {model:?} was reloaded mid-request; retry");
                let bytes = if binary {
                    frame::frame(&frame::status_payload(frame::ST_RETRY, &msg))
                } else {
                    json_line(&wire::overload_reply(&msg))
                };
                c.wbuf.extend_from_slice(&bytes);
                drop(guard);
            }
            _ => unreachable!("pump action computed from the same queue head"),
        }
    }
}

/// Write as much of the write buffer as the socket accepts right now.
fn flush(c: &mut Conn) {
    while c.wpos < c.wbuf.len() {
        match (&c.stream).write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => {
                c.wpos += n;
                c.last_write_progress = Instant::now();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    c.wbuf.clear();
    c.wpos = 0;
    if matches!(c.pending.front(), Some(PendingOut::Close)) {
        c.dead = true; // everything before the marker is on the wire
    }
}

/// The socket reported readable: pull a bounded number of chunks into
/// the receive buffer and parse. Bounded so one firehose connection
/// cannot monopolize its loop — fairness across the poll set.
fn read_ready(c: &mut Conn, ctx: &LoopCtx) {
    let mut buf = [0u8; 16 * 1024];
    for _ in 0..4 {
        match (&c.stream).read(&mut buf) {
            Ok(0) => {
                c.read_closed = true;
                break;
            }
            Ok(n) => {
                c.last_read = Instant::now();
                if c.rbuf.is_empty() {
                    c.assembly_start = Some(c.last_read);
                }
                c.rbuf.extend_from_slice(&buf[..n]);
                if n < buf.len() {
                    break; // drained the socket
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    process_rbuf(c, ctx);
    pump(c);
    flush(c);
}

/// Parse as many complete requests as the receive buffer holds,
/// honoring the caps and the backpressure bounds. Handles the
/// mid-buffer mode switch: bytes pipelined behind a `binary` upgrade
/// line are parsed as frames.
fn process_rbuf(c: &mut Conn, ctx: &LoopCtx) {
    loop {
        if c.dead || c.close_queued || c.pending.len() >= REPLY_QUEUE_BOUND {
            break; // backpressure: the rest of rbuf waits
        }
        if c.binary {
            match frame::scan(&c.rbuf) {
                frame::Scan::Incomplete => break,
                frame::Scan::BadMagic => {
                    let reply = c.error_bytes("bad frame magic; closing connection");
                    c.queue_last(reply);
                    break;
                }
                frame::Scan::Oversized(n) => {
                    let reply = c.error_bytes(&format!(
                        "frame payload of {n} bytes exceeds the {} cap; closing connection",
                        frame::MAX_FRAME_PAYLOAD
                    ));
                    c.queue_last(reply);
                    break;
                }
                frame::Scan::Frame { total, header, tid } => {
                    // liberal acceptance: a GZF2 frame is honored whether
                    // or not the upgrade ack negotiated v2 — the tid slot
                    // is pure metadata and the payload grammar is shared
                    let f: Vec<u8> = c.rbuf.drain(..total).collect();
                    ctx.frames_in.inc();
                    handle_frame(c, &f[header..], tid, ctx);
                }
            }
        } else {
            match c.rbuf.iter().position(|&b| b == b'\n') {
                Some(pos) if pos > MAX_LINE_BYTES => {
                    let reply = c.error_bytes(&format!(
                        "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
                    ));
                    c.queue_last(reply);
                    break;
                }
                Some(pos) => {
                    let line: Vec<u8> = c.rbuf.drain(..=pos).take(pos).collect();
                    handle_line(c, &line, ctx);
                }
                None => {
                    if c.rbuf.len() > MAX_LINE_BYTES {
                        // no way to resynchronize mid-line: reply, close
                        let reply = c.error_bytes(&format!(
                            "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
                        ));
                        c.queue_last(reply);
                    }
                    break;
                }
            }
        }
    }
    // the assembly deadline tracks the (incomplete) head request only
    c.assembly_start = if c.rbuf.is_empty() { None } else { c.assembly_start };
}

/// Dispatch one JSON request line — the same arms the thread-per-
/// connection reader had, plus the `binary` upgrade.
fn handle_line(c: &mut Conn, raw: &[u8], ctx: &LoopCtx) {
    let line = match std::str::from_utf8(raw) {
        Ok(l) => l.trim(),
        Err(_) => {
            c.queue(json_line(&wire::error_reply("request is not UTF-8")));
            return;
        }
    };
    if line.is_empty() {
        return;
    }
    let shared = &ctx.shared;
    match wire::parse_request(line) {
        Err(e) => c.queue(json_line(&wire::error_reply(&e))),
        Ok(wire::Request::Ping) => c.queue(json_line(&wire::ping_reply())),
        Ok(wire::Request::Models) => c.queue(json_line(&shared.router.models_reply())),
        Ok(wire::Request::Stats) => c.queue(json_line(&shared.router.stats_reply())),
        Ok(wire::Request::Metrics) => c.queue(json_line(&wire::metrics_reply())),
        Ok(wire::Request::Flightrec) => c.queue(json_line(&wire::flightrec_reply())),
        Ok(wire::Request::Binary { v2 }) => {
            // the ack is the LAST JSON line; every later byte is framed.
            // a v2 ask is acked with "v":2 — the client may then send
            // GZF2 trace-carrying frames
            c.queue(json_line(&if v2 { wire::binary_reply_v2() } else { wire::binary_reply() }));
            c.binary = true;
            ctx.binary_upgrades.inc();
        }
        Ok(wire::Request::Shutdown) => {
            if !c.peer_loopback && !shared.allow_remote_shutdown {
                crate::obs::warn(
                    "server.listener",
                    "shutdown refused from a non-loopback peer",
                    &[],
                );
                c.queue(json_line(&wire::error_reply(
                    "shutdown refused from a non-loopback peer (the server \
                     must opt in with --allow-remote-shutdown)",
                )));
            } else {
                crate::obs::info("server.listener", "wire shutdown accepted", &[]);
                c.queue_last(json_line(&wire::shutdown_reply()));
                shared.begin_shutdown();
            }
        }
        Ok(wire::Request::Predict { model, x, tid }) => {
            match shared.router.dispatch_predict_notify(
                model.as_deref(),
                &x,
                Some(Arc::clone(&ctx.bell)),
            ) {
                Dispatch::Immediate(reply) => c.queue(json_line(&reply)),
                Dispatch::Pending { model, rx, guard, hist } => {
                    c.pending.push_back(PendingOut::Await {
                        model,
                        rx,
                        guard,
                        binary: false,
                        tid,
                        queued: Instant::now(),
                        hist,
                    });
                }
            }
        }
    }
}

/// Dispatch one binary frame (`tid` from the GZF2 header slot, 0 for
/// GZF1). A malformed payload is an error frame and the connection
/// survives — parity with how a malformed JSON line is answered.
fn handle_frame(c: &mut Conn, payload: &[u8], tid: u64, ctx: &LoopCtx) {
    match frame::parse_request(payload) {
        Err(e) => {
            let reply = c.error_bytes(&e);
            c.queue(reply);
        }
        Ok(frame::FrameRequest::Ping) => c.queue(frame::frame(&frame::pong_payload())),
        Ok(frame::FrameRequest::Predict { model, x }) => {
            match ctx.shared.router.dispatch_predict_notify(
                model.as_deref(),
                &x,
                Some(Arc::clone(&ctx.bell)),
            ) {
                Dispatch::Immediate(reply) => c.queue(immediate_frame(&reply)),
                Dispatch::Pending { model, rx, guard, hist } => {
                    c.pending.push_back(PendingOut::Await {
                        model,
                        rx,
                        guard,
                        binary: true,
                        tid,
                        queued: Instant::now(),
                        hist,
                    });
                }
            }
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    #[test]
    fn waker_bytes_interrupt_a_poll_and_drain_clean() {
        let (handle, mut rx) = LoopHandle::new().unwrap();
        let fd = raw_fd(&rx);
        let mut fds = [PollFd { fd, events: POLLIN, revents: 0 }];
        assert_eq!(sys::poll_fds(&mut fds, 20).unwrap(), 0, "no wake pending yet");
        handle.wake();
        handle.wake(); // coalescing is fine; blocking is not
        assert_eq!(sys::poll_fds(&mut fds, 5_000).unwrap(), 1);
        drain_waker(&mut rx);
        fds[0].revents = 0;
        assert_eq!(sys::poll_fds(&mut fds, 20).unwrap(), 0, "drained: level low again");
    }

    #[test]
    fn enqueued_connections_arrive_with_a_wake() {
        let (handle, rx) = LoopHandle::new().unwrap();
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let c = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        handle.enqueue_conn(c);
        assert_eq!(handle.inbox.lock().unwrap().len(), 1);
        let fd = raw_fd(&rx);
        let mut fds = [PollFd { fd, events: POLLIN, revents: 0 }];
        assert_eq!(sys::poll_fds(&mut fds, 5_000).unwrap(), 1, "enqueue must wake the loop");
    }
}
