//! Wire codec for the TCP serving protocol: newline-delimited JSON, one
//! request object per line, replies in request order on the same
//! connection.
//!
//! Request grammar (the full protocol — see DESIGN.md §3c/§3e):
//!
//! ```text
//! {"cmd":"predict","x":[1.0,2.0,3.0],"model":"ridge"}   model optional when
//!                                                        exactly one is served
//!     ... ,"tid":"81985529216486895"}   optional distributed trace ID
//!                       (u64 as a decimal string — the dist-wire
//!                       convention, since the in-crate JSON number is
//!                       an f64 and exact only to 2^53); minted at
//!                       ingress, echoed into every span the request
//!                       touches, never echoed in the reply (replies
//!                       stay byte-identical traced or not)
//! {"cmd":"models"}      list served models (name, kind, d, output_dim)
//! {"cmd":"stats"}       per-model ServeMetrics + latency percentiles +
//!                       admission queue depth / rejects
//! {"cmd":"metrics"}     one consistent JSON snapshot of the process-wide
//!                       observability registry (counters, gauges,
//!                       latency histograms — see the `obs` module);
//!                       answered locally by both `gzk server` and
//!                       `gzk proxy`, never forwarded
//! {"cmd":"flightrec"}   dump the crash flight recorder ring (recent
//!                       event lines); answered locally, like metrics
//! {"cmd":"ping"}        liveness probe
//! {"cmd":"binary"}      switch THIS connection to length-prefixed
//!                       binary frames after the ack (see
//!                       [`super::frame`]); predict requests/replies
//!                       then skip JSON entirely while staying
//!                       bit-exact (raw little-endian f64 bytes).
//!                       "v":2 requests the GZF2 trace-carrying frame
//!                       header: a server that understands it acks with
//!                       "v":2 and the client may then send GZF2 frames;
//!                       an old server ignores the field and acks
//!                       without it, so the client sticks to GZF1 —
//!                       version negotiation keeps old and new peers
//!                       interoperable in both directions
//! {"cmd":"shutdown"}    stop the server after acking (honored from
//!                       loopback peers only, unless the server was
//!                       started with --allow-remote-shutdown)
//! ```
//!
//! Requests are untrusted: a line is capped at
//! [`listener::MAX_LINE_BYTES`](super::listener::MAX_LINE_BYTES) and the
//! JSON parser bounds nesting depth, so hostile framing degrades to an
//! error reply (or a closed connection), never a panic or a stack
//! overflow.
//!
//! Every reply is one JSON object with an `"ok"` field; errors carry
//! `"error"` and — for backpressure rejects, the one retriable failure —
//! `"retry":true`. Floats reuse the model-artifact convention
//! ([`artifact::fmt_f64`](crate::model::artifact::fmt_f64): shortest
//! round-trip `{:?}` formatting, parsed back via `str::parse::<f64>`), so
//! a prediction crosses the wire **bit-exactly** — the loadgen harness
//! checks replies against a local `Model::predict` with `==`, not a
//! tolerance.

use crate::model::artifact::{vec_from_json, vec_to_json};
use crate::runtime::Json;

/// One parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Predict one point; `model` routes between served models and may be
    /// omitted when the server serves exactly one. `tid` is the optional
    /// distributed trace ID (0 = untraced) — observability metadata only,
    /// it never changes routing, batching, or the reply bytes.
    Predict { model: Option<String>, x: Vec<f64>, tid: u64 },
    Models,
    Stats,
    Metrics,
    Flightrec,
    Ping,
    /// switch this connection to binary frame mode after the ack; `v2`
    /// means the client asked for GZF2 trace-carrying frames
    Binary { v2: bool },
    Shutdown,
}

/// Parse an optional `"tid"` field: a u64 as a decimal string. Absent →
/// 0 (untraced). Present-but-invalid is a hard error — a garbled trace
/// ID must surface at the sender, not silently drop tracing.
fn parse_tid(j: &Json) -> Result<u64, String> {
    match j.get("tid") {
        None => Ok(0),
        Some(Json::Str(s)) => {
            s.parse::<u64>().map_err(|_| format!("\"tid\" is not a u64 decimal string: {s:?}"))
        }
        Some(_) => Err("\"tid\" must be a u64 decimal string".to_string()),
    }
}

/// Parse one request line. Malformed input is an error *message* (the
/// listener turns it into an error reply and keeps the connection) —
/// never a panic, since every byte here is client-controlled.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| format!("malformed request: {e}"))?;
    let cmd = j
        .get("cmd")
        .and_then(|c| c.as_str())
        .ok_or_else(|| "request missing string field \"cmd\"".to_string())?;
    match cmd {
        "predict" => {
            let x = vec_from_json(
                j.get("x").ok_or_else(|| "predict request missing \"x\"".to_string())?,
            )
            .map_err(|_| "predict \"x\" must be an array of numbers".to_string())?;
            if x.is_empty() {
                return Err("predict \"x\" must not be empty".to_string());
            }
            // "1e999" parses to inf: refuse it here so a hostile request
            // can never push a non-finite value into the shared batch
            if !x.iter().all(|v| v.is_finite()) {
                return Err("predict \"x\" contains a non-finite value".to_string());
            }
            let model = match j.get("model") {
                None => None,
                Some(Json::Str(s)) => Some(s.clone()),
                Some(_) => {
                    // a non-string model must not silently fall back to
                    // single-model routing — that would mask a client bug
                    return Err("predict \"model\" must be a string".to_string());
                }
            };
            Ok(Request::Predict { model, x, tid: parse_tid(&j)? })
        }
        "models" => Ok(Request::Models),
        "stats" => Ok(Request::Stats),
        "metrics" => Ok(Request::Metrics),
        "flightrec" => Ok(Request::Flightrec),
        "ping" => Ok(Request::Ping),
        "binary" => {
            let v2 = match j.get("v") {
                None => false,
                Some(v) if v.as_f64() == Some(2.0) => true,
                Some(_) => {
                    return Err("binary \"v\" must be 2 (the only negotiable version)".to_string())
                }
            };
            Ok(Request::Binary { v2 })
        }
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!(
            "unknown cmd {other:?}; known: predict, models, stats, metrics, flightrec, ping, \
             binary, shutdown"
        )),
    }
}

/// Build a predict request line (the loadgen client side).
pub fn predict_request(model: Option<&str>, x: &[f64]) -> String {
    match model {
        Some(m) => {
            format!(r#"{{"cmd":"predict","model":{},"x":{}}}"#, json_string(m), vec_to_json(x))
        }
        None => format!(r#"{{"cmd":"predict","x":{}}}"#, vec_to_json(x)),
    }
}

/// [`predict_request`] carrying a distributed trace ID (`tid` 0 falls
/// back to the untraced line — the two must stay byte-identical so a
/// "traced" client with tracing disabled perturbs nothing).
pub fn predict_request_traced(model: Option<&str>, x: &[f64], tid: u64) -> String {
    let mut line = predict_request(model, x);
    if tid != 0 {
        line.truncate(line.len() - 1);
        line.push_str(&format!(r#","tid":"{tid}"}}"#));
    }
    line
}

/// Build an argument-less command line (`models` / `stats` / `ping` /
/// `shutdown`).
pub fn cmd_request(cmd: &str) -> String {
    format!(r#"{{"cmd":{}}}"#, json_string(cmd))
}

/// Successful predict reply. Errs (instead of panicking in the artifact
/// float formatter) if the model produced a non-finite value, so one
/// pathological prediction degrades to an error reply, not a dead
/// connection.
pub fn predict_reply(model: &str, y: &[f64]) -> Result<String, String> {
    if !y.iter().all(|v| v.is_finite()) {
        return Err(format!("model {model:?} produced a non-finite prediction"));
    }
    Ok(format!(r#"{{"ok":true,"model":{},"y":{}}}"#, json_string(model), vec_to_json(y)))
}

/// Non-retriable error reply.
pub fn error_reply(msg: &str) -> String {
    format!(r#"{{"ok":false,"error":{}}}"#, json_string(msg))
}

/// Backpressure reply: the admission queue (or the connection budget) is
/// full. `"retry":true` is the contract that THIS failure — alone — is
/// safe and sensible to retry after backoff.
pub fn overload_reply(msg: &str) -> String {
    format!(r#"{{"ok":false,"error":{},"retry":true}}"#, json_string(msg))
}

pub fn ping_reply() -> String {
    r#"{"ok":true,"pong":true}"#.to_string()
}

/// Ack for the `binary` upgrade: the LAST JSON line on the connection —
/// every byte after it is framed (see [`super::frame`]).
pub fn binary_reply() -> String {
    r#"{"ok":true,"binary":true}"#.to_string()
}

/// Ack for a `{"cmd":"binary","v":2}` upgrade from a server that speaks
/// GZF2: the echoed `"v":2` is the client's licence to send
/// trace-carrying frames.
pub fn binary_reply_v2() -> String {
    r#"{"ok":true,"binary":true,"v":2}"#.to_string()
}

/// The `binary` upgrade line requesting GZF2 frames.
pub fn binary_request_v2() -> String {
    r#"{"cmd":"binary","v":2}"#.to_string()
}

/// Reply to `metrics`: the process-wide registry snapshot, embedded
/// verbatim (it is already one consistent JSON object).
pub fn metrics_reply() -> String {
    format!(r#"{{"ok":true,"metrics":{}}}"#, crate::obs::registry::snapshot_json())
}

/// Reply to `flightrec`: the crash flight recorder ring, embedded
/// verbatim (already one JSON object — see
/// [`crate::obs::flightrec::dump_json`]).
pub fn flightrec_reply() -> String {
    format!(r#"{{"ok":true,"flightrec":{}}}"#, crate::obs::flightrec::dump_json())
}

pub fn shutdown_reply() -> String {
    r#"{"ok":true,"stopping":true}"#.to_string()
}

/// One parsed reply line (the loadgen client side).
#[derive(Clone, Debug)]
pub struct Reply {
    pub ok: bool,
    pub error: Option<String>,
    /// set on backpressure rejects: retry after backoff is safe
    pub retry: bool,
    /// the whole reply object, for command-specific fields
    pub body: Json,
    /// the reply line verbatim (the in-crate `Json` has no serializer;
    /// loadgen embeds server stats in its report as received)
    pub raw: String,
}

impl Reply {
    /// The prediction vector of a predict reply.
    pub fn y(&self) -> Result<Vec<f64>, String> {
        if !self.ok {
            return Err(self.error.clone().unwrap_or_else(|| "server error".to_string()));
        }
        vec_from_json(
            self.body.get("y").ok_or_else(|| "predict reply missing \"y\"".to_string())?,
        )
    }
}

/// Parse one reply line.
pub fn parse_reply(line: &str) -> Result<Reply, String> {
    let j = Json::parse(line).map_err(|e| format!("malformed reply: {e}"))?;
    let ok = match j.get("ok") {
        Some(Json::Bool(b)) => *b,
        _ => return Err("reply missing boolean field \"ok\"".to_string()),
    };
    let error = j.get("error").and_then(|e| e.as_str()).map(str::to_string);
    let retry = matches!(j.get("retry"), Some(Json::Bool(true)));
    Ok(Reply { ok, error, retry, body: j, raw: line.to_string() })
}

// Reply messages embed arbitrary error text (paths, debug-quoted
// names); the crate's one JSON string-literal writer lives next to the
// artifact codec.
pub(crate) use crate::model::artifact::json_string;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_round_trips_bit_exactly() {
        // awkward floats: subnormal, negative zero, many digits
        let x = vec![1.0 / 3.0, -0.0, 5e-324, 1.23456789012345e300];
        let line = predict_request(Some("ridge"), &x);
        match parse_request(&line).unwrap() {
            Request::Predict { model, x: got, tid } => {
                assert_eq!(model.as_deref(), Some("ridge"));
                assert_eq!(tid, 0, "no tid field parses as untraced");
                assert_eq!(x.len(), got.len());
                for (a, b) in x.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        let reply = predict_reply("ridge", &x).unwrap();
        let parsed = parse_reply(&reply).unwrap();
        assert!(parsed.ok && !parsed.retry);
        let y = parsed.y().unwrap();
        for (a, b) in x.iter().zip(&y) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn parse_request_rejects_garbage_without_panicking() {
        for bad in [
            "",
            "not json",
            "{}",
            r#"{"cmd":"predict"}"#,
            r#"{"cmd":"predict","x":[]}"#,
            r#"{"cmd":"predict","x":["a"]}"#,
            r#"{"cmd":"predict","x":[1e999]}"#,
            r#"{"cmd":"predict","x":[1],"model":5}"#,
            r#"{"cmd":"predict","x":[1],"tid":7}"#,
            r#"{"cmd":"predict","x":[1],"tid":"not-a-number"}"#,
            r#"{"cmd":"predict","x":[1],"tid":"-3"}"#,
            r#"{"cmd":"binary","v":3}"#,
            r#"{"cmd":"binary","v":"2"}"#,
            r#"{"cmd":"launch-missiles"}"#,
            r#"{"cmd":42}"#,
        ] {
            assert!(parse_request(bad).is_err(), "accepted {bad:?}");
        }
        assert_eq!(parse_request(r#"{"cmd":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(
            parse_request(r#"{"cmd":"binary"}"#).unwrap(),
            Request::Binary { v2: false }
        );
        assert_eq!(parse_request(&binary_request_v2()).unwrap(), Request::Binary { v2: true });
        assert_eq!(parse_request(&cmd_request("stats")).unwrap(), Request::Stats);
        assert_eq!(parse_request(&cmd_request("metrics")).unwrap(), Request::Metrics);
        assert_eq!(parse_request(&cmd_request("flightrec")).unwrap(), Request::Flightrec);
        assert_eq!(parse_request(&cmd_request("shutdown")).unwrap(), Request::Shutdown);
        // model omitted: route to the single served model
        match parse_request(r#"{"cmd":"predict","x":[1,2]}"#).unwrap() {
            Request::Predict { model: None, x, tid: 0 } => assert_eq!(x, vec![1.0, 2.0]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn traced_requests_carry_the_tid_and_untraced_lines_are_identical() {
        let x = [1.5, -2.5];
        // tid 0 → byte-identical to the untraced builder
        assert_eq!(predict_request_traced(Some("m"), &x, 0), predict_request(Some("m"), &x));
        let line = predict_request_traced(Some("m"), &x, 0x0123_4567_89ab_cdef);
        match parse_request(&line).unwrap() {
            Request::Predict { tid, .. } => assert_eq!(tid, 0x0123_4567_89ab_cdef),
            other => panic!("{other:?}"),
        }
        // a u64 above 2^53 survives the decimal-string convention exactly
        let big = u64::MAX;
        match parse_request(&predict_request_traced(None, &x, big)).unwrap() {
            Request::Predict { tid, .. } => assert_eq!(tid, big),
            other => panic!("{other:?}"),
        }
        // the flightrec reply embeds the ring dump as valid JSON
        let f = parse_reply(&flightrec_reply()).unwrap();
        assert!(f.ok);
        assert!(f.body.get("flightrec").and_then(|j| j.get("next_seq")).is_some());
    }

    #[test]
    fn error_replies_escape_arbitrary_text_and_carry_retry() {
        let e = error_reply("no model \"a\\b\"\nhave: c");
        let parsed = parse_reply(&e).unwrap();
        assert!(!parsed.ok && !parsed.retry);
        assert_eq!(parsed.error.as_deref(), Some("no model \"a\\b\"\nhave: c"));
        assert!(parsed.y().is_err());
        let o = parse_reply(&overload_reply("queue full")).unwrap();
        assert!(!o.ok && o.retry);
        // the metrics reply embeds the registry snapshot as valid JSON
        let m = parse_reply(&metrics_reply()).unwrap();
        assert!(m.ok);
        assert!(m.body.get("metrics").and_then(|j| j.get("counters")).is_some());
        // non-finite predictions degrade to an error, not a panic
        assert!(predict_reply("m", &[f64::NAN]).is_err());
        assert!(predict_reply("m", &[f64::INFINITY]).is_err());
    }
}
