//! `gzk top` — a live fleet monitor over the wire `metrics` command.
//!
//! Each tick polls every `--targets` address (servers and/or proxies),
//! pulls the registry snapshot the `metrics` command carries, and diffs
//! the counters against the previous tick to turn cumulative totals
//! into **rates**: per-model throughput (`server.predict.<model>.
//! requests_total`), admission rejects per second, live queue depth
//! (the `server.admission.<model>.queue_depth` gauge) and the ladder
//! p50/p95/p99 straight from the `server.predict.<model>.latency_s`
//! histogram. One row per (target, model) renders as a fixed-width
//! table; `--json-out` additionally rewrites a machine-readable
//! document after every tick (`{"format":1,"monitor":"top",...}` — the
//! CI smoke jobs assert its rate fields). `--once` takes exactly two
//! polls one interval apart, renders the single diff, and exits — the
//! scriptable mode; without it the monitor runs until interrupted.
//!
//! Like every observability surface in the crate, `top` is strictly
//! read-only: it sends only the `metrics` command, which mutates
//! nothing, so watching a fleet cannot perturb what it serves (beyond
//! servicing the poll itself). A target that fails to answer renders as
//! a `down` row and keeps its slot — a replica rebooting mid-watch
//! reappears on the next tick.

use super::loadgen::ClientConn;
use super::wire;
use crate::runtime::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Knobs for [`run_top`]; the defaults match the CLI's.
#[derive(Clone, Debug)]
pub struct TopConfig {
    /// addresses to poll (servers or proxies; each answers `metrics`
    /// about itself)
    pub targets: Vec<String>,
    /// time between polls (the rate window)
    pub interval: Duration,
    /// two polls, one rendered diff, exit (the scriptable mode)
    pub once: bool,
    /// rewrite a machine-readable snapshot here after every tick
    pub json_out: Option<std::path::PathBuf>,
}

impl Default for TopConfig {
    fn default() -> TopConfig {
        TopConfig {
            targets: Vec::new(),
            interval: Duration::from_secs(2),
            once: false,
            json_out: None,
        }
    }
}

/// One target's registry snapshot, flattened for diffing.
#[derive(Clone, Debug, Default)]
struct Snap {
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    /// name -> (total, p50_s, p95_s, p99_s)
    hists: BTreeMap<String, (f64, f64, f64, f64)>,
}

/// One rendered (target, model) row.
#[derive(Clone, Debug)]
struct ModelRow {
    model: String,
    requests_total: f64,
    rps: f64,
    p50_s: f64,
    p95_s: f64,
    p99_s: f64,
    queue_depth: f64,
    rejects_ps: f64,
}

fn num_map(j: Option<&Json>) -> BTreeMap<String, f64> {
    match j {
        Some(Json::Obj(m)) => {
            m.iter().filter_map(|(k, v)| Some((k.clone(), v.as_f64()?))).collect()
        }
        _ => BTreeMap::new(),
    }
}

fn parse_snapshot(body: &Json) -> Result<Snap, String> {
    let m = body.get("metrics").ok_or_else(|| "metrics reply missing snapshot".to_string())?;
    let hists = match m.get("hists") {
        Some(Json::Obj(h)) => h
            .iter()
            .filter_map(|(k, v)| {
                Some((
                    k.clone(),
                    (
                        v.get("total")?.as_f64()?,
                        v.get("p50_s")?.as_f64()?,
                        v.get("p95_s")?.as_f64()?,
                        v.get("p99_s")?.as_f64()?,
                    ),
                ))
            })
            .collect(),
        _ => BTreeMap::new(),
    };
    Ok(Snap { counters: num_map(m.get("counters")), gauges: num_map(m.get("gauges")), hists })
}

fn fetch_snapshot(addr: &str) -> Result<Snap, String> {
    let mut conn = ClientConn::connect(addr)?;
    let reply = conn.roundtrip(&wire::cmd_request("metrics"))?;
    if !reply.ok {
        return Err(reply.error.unwrap_or_else(|| "metrics command failed".to_string()));
    }
    parse_snapshot(&reply.body)
}

/// Diff two snapshots of one target into per-model rows. Models are
/// discovered from the `server.predict.<model>.requests_total` counter
/// namespace of the *current* snapshot (a model hot-loaded between
/// ticks appears with its full count as the delta).
fn model_rows(prev: &Snap, cur: &Snap, dt_s: f64) -> Vec<ModelRow> {
    const PREFIX: &str = "server.predict.";
    const SUFFIX: &str = ".requests_total";
    let dt = dt_s.max(1e-9);
    let mut rows = Vec::new();
    for (name, &total) in &cur.counters {
        let Some(model) = name.strip_prefix(PREFIX).and_then(|r| r.strip_suffix(SUFFIX)) else {
            continue;
        };
        let before = prev.counters.get(name).copied().unwrap_or(0.0);
        let rej_name = format!("server.admission.{model}.rejected_total");
        let rej_now = cur.counters.get(&rej_name).copied().unwrap_or(0.0);
        let rej_before = prev.counters.get(&rej_name).copied().unwrap_or(0.0);
        let (_, p50, p95, p99) = cur
            .hists
            .get(&format!("server.predict.{model}.latency_s"))
            .copied()
            .unwrap_or((0.0, 0.0, 0.0, 0.0));
        rows.push(ModelRow {
            model: model.to_string(),
            requests_total: total,
            rps: (total - before).max(0.0) / dt,
            p50_s: p50,
            p95_s: p95,
            p99_s: p99,
            queue_depth: cur
                .gauges
                .get(&format!("server.admission.{model}.queue_depth"))
                .copied()
                .unwrap_or(0.0),
            rejects_ps: (rej_now - rej_before).max(0.0) / dt,
        });
    }
    rows
}

/// Sum of the per-event-loop connection gauges (`server.loop<i>.conns`).
fn conns_of(snap: &Snap) -> f64 {
    snap.gauges
        .iter()
        .filter(|(k, _)| k.starts_with("server.loop") && k.ends_with(".conns"))
        .map(|(_, v)| v)
        .sum()
}

fn render_tick(
    out: &mut String,
    targets: &[String],
    polls: &[Result<Snap, String>],
    prevs: &[Result<Snap, String>],
    dt_s: f64,
) {
    out.push_str(&format!(
        "{:<22} {:<14} {:>10} {:>9} {:>9} {:>9} {:>6} {:>7} {:>6}\n",
        "target", "model", "rps", "p50_ms", "p95_ms", "p99_ms", "queue", "rej/s", "conns"
    ));
    for (i, addr) in targets.iter().enumerate() {
        let (cur, prev) = (&polls[i], &prevs[i]);
        let (cur, prev) = match (cur, prev) {
            (Ok(c), Ok(p)) => (c, p),
            (Ok(c), Err(_)) => (c, c), // just came up: rates unknown, show 0
            (Err(e), _) => {
                out.push_str(&format!("{addr:<22} down: {e}\n"));
                continue;
            }
        };
        let rows = model_rows(prev, cur, dt_s);
        if rows.is_empty() {
            out.push_str(&format!("{:<22} {:<14} (no served models)\n", addr, "-"));
            continue;
        }
        let conns = conns_of(cur);
        for r in rows {
            out.push_str(&format!(
                "{:<22} {:<14} {:>10.1} {:>9.2} {:>9.2} {:>9.2} {:>6.0} {:>7.1} {:>6.0}\n",
                addr,
                r.model,
                r.rps,
                r.p50_s * 1e3,
                r.p95_s * 1e3,
                r.p99_s * 1e3,
                r.queue_depth,
                r.rejects_ps,
                conns
            ));
        }
    }
}

fn tick_json(
    targets: &[String],
    polls: &[Result<Snap, String>],
    prevs: &[Result<Snap, String>],
    elapsed_s: f64,
    dt_s: f64,
) -> String {
    let per: Vec<String> = targets
        .iter()
        .enumerate()
        .map(|(i, addr)| {
            let addr_json = wire::json_string(addr);
            let (cur, prev) = match (&polls[i], &prevs[i]) {
                (Ok(c), Ok(p)) => (c, p),
                (Ok(c), Err(_)) => (c, c),
                (Err(e), _) => {
                    return format!(
                        r#"{{"addr":{addr_json},"ok":false,"error":{}}}"#,
                        wire::json_string(e)
                    );
                }
            };
            let models: Vec<String> = model_rows(prev, cur, dt_s)
                .iter()
                .map(|r| {
                    format!(
                        concat!(
                            r#"{{"model":{},"requests_total":{:.0},"rps":{:.2},"#,
                            r#""p50_s":{:?},"p95_s":{:?},"p99_s":{:?},"#,
                            r#""queue_depth":{:.0},"rejects_ps":{:.2}}}"#
                        ),
                        wire::json_string(&r.model),
                        r.requests_total,
                        r.rps,
                        r.p50_s,
                        r.p95_s,
                        r.p99_s,
                        r.queue_depth,
                        r.rejects_ps
                    )
                })
                .collect();
            format!(
                r#"{{"addr":{addr_json},"ok":true,"conns":{:.0},"models":[{}]}}"#,
                conns_of(cur),
                models.join(",")
            )
        })
        .collect();
    format!(
        r#"{{"elapsed_s":{elapsed_s:.3},"window_s":{dt_s:.3},"targets":[{}]}}"#,
        per.join(",")
    )
}

/// Drive the monitor; rendered ticks go to `print` (the CLI passes a
/// stdout printer — injected so tests capture output without a TTY).
/// Returns after one diff with `once`, else loops until the process is
/// interrupted.
pub fn run_top(cfg: &TopConfig, print: &mut dyn FnMut(&str)) -> Result<(), String> {
    if cfg.targets.is_empty() {
        return Err("top needs at least one --targets address".to_string());
    }
    if cfg.interval.is_zero() {
        return Err("top needs a nonzero --interval".to_string());
    }
    let t0 = Instant::now();
    let mut prevs: Vec<Result<Snap, String>> =
        cfg.targets.iter().map(|a| fetch_snapshot(a)).collect();
    let mut prev_at = Instant::now();
    let mut ticks: Vec<String> = Vec::new();
    loop {
        std::thread::sleep(cfg.interval);
        let polls: Vec<Result<Snap, String>> =
            cfg.targets.iter().map(|a| fetch_snapshot(a)).collect();
        let now = Instant::now();
        let dt_s = now.duration_since(prev_at).as_secs_f64();
        let mut text = String::new();
        render_tick(&mut text, &cfg.targets, &polls, &prevs, dt_s);
        print(&text);
        if let Some(path) = &cfg.json_out {
            ticks.push(tick_json(
                &cfg.targets,
                &polls,
                &prevs,
                t0.elapsed().as_secs_f64(),
                dt_s,
            ));
            // rewritten whole every tick so the file is always a complete
            // document, even when the monitor is killed mid-watch
            let doc = format!(
                r#"{{"format":1,"monitor":"top","interval_s":{:.3},"polls":[{}]}}"#,
                cfg.interval.as_secs_f64(),
                ticks.join(",")
            );
            std::fs::write(path, doc).map_err(|e| format!("write {path:?}: {e}"))?;
        }
        if cfg.once {
            return Ok(());
        }
        prevs = polls;
        prev_at = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(requests: f64, rejects: f64, depth: f64) -> Snap {
        let mut s = Snap::default();
        s.counters.insert("server.predict.elev.requests_total".to_string(), requests);
        s.counters.insert("server.admission.elev.rejected_total".to_string(), rejects);
        s.gauges.insert("server.admission.elev.queue_depth".to_string(), depth);
        s.gauges.insert("server.loop0.conns".to_string(), 3.0);
        s.gauges.insert("server.loop1.conns".to_string(), 2.0);
        s.hists.insert(
            "server.predict.elev.latency_s".to_string(),
            (requests, 2e-4, 1e-3, 2e-3),
        );
        s
    }

    #[test]
    fn counter_diffs_become_rates_and_hists_pass_through() {
        let rows = model_rows(&snap(100.0, 4.0, 1.0), &snap(350.0, 9.0, 2.0), 2.5);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.model, "elev");
        assert!((r.rps - 100.0).abs() < 1e-9, "Δ250 over 2.5 s, got {}", r.rps);
        assert!((r.rejects_ps - 2.0).abs() < 1e-9);
        assert_eq!(r.queue_depth, 2.0);
        assert_eq!((r.p50_s, r.p95_s, r.p99_s), (2e-4, 1e-3, 2e-3));
        assert_eq!(conns_of(&snap(0.0, 0.0, 0.0)), 5.0);

        // a model absent from the previous tick (hot-loaded) attributes
        // its whole count to the window rather than going negative
        let rows = model_rows(&Snap::default(), &snap(50.0, 0.0, 0.0), 1.0);
        assert!((rows[0].rps - 50.0).abs() < 1e-9);
    }

    #[test]
    fn tick_json_carries_rate_fields_and_down_targets() {
        let targets = vec!["a:1".to_string(), "b:2".to_string()];
        let polls = vec![Ok(snap(10.0, 0.0, 0.0)), Err("refused".to_string())];
        let prevs = vec![Ok(snap(0.0, 0.0, 0.0)), Err("refused".to_string())];
        let doc = tick_json(&targets, &polls, &prevs, 1.0, 1.0);
        let j = Json::parse(&doc).expect("tick json parses");
        let ts = j.get("targets").and_then(|t| t.as_arr()).expect("targets array");
        assert_eq!(ts.len(), 2);
        let m = ts[0].get("models").and_then(|m| m.as_arr()).expect("models array");
        assert_eq!(m[0].get("rps").and_then(Json::as_f64), Some(10.0));
        assert!(m[0].get("p95_s").and_then(Json::as_f64).is_some());
        assert_eq!(ts[1].get("ok"), Some(&Json::Bool(false)));

        // the rendered table shows the down target without panicking
        let mut text = String::new();
        render_tick(&mut text, &targets, &polls, &prevs, 1.0);
        assert!(text.contains("down: refused"), "{text}");
        assert!(text.contains("elev"), "{text}");
    }

    #[test]
    fn snapshot_parser_reads_the_registry_shape() {
        let body = Json::parse(concat!(
            r#"{"metrics":{"enabled":true,"counters":{"server.predict.m.requests_total":7},"#,
            r#""gauges":{"server.admission.m.queue_depth":1},"#,
            r#""hists":{"server.predict.m.latency_s":"#,
            r#"{"total":7,"p50_s":0.0002,"p95_s":0.001,"p99_s":0.002,"counts":[7]}}}}"#
        ))
        .expect("test body parses");
        let s = parse_snapshot(&body).expect("snapshot parses");
        assert_eq!(s.counters["server.predict.m.requests_total"], 7.0);
        assert_eq!(s.hists["server.predict.m.latency_s"].3, 0.002);
        let rows = model_rows(&Snap::default(), &s, 7.0);
        assert!((rows[0].rps - 1.0).abs() < 1e-9);
    }
}
