//! Multi-model routing over a [`ModelStore`] directory, with
//! manifest-poll hot-reload.
//!
//! Each manifest entry becomes a [`ModelRoute`]: the loaded model behind
//! its own [`PredictionService`] batcher (so dynamic batching,
//! pool-parallel featurization and bit-identical prediction all come from
//! the existing L3 machinery) plus its own [`Admission`] bound. Routing is
//! by model name; a request that names no model is routed to the single
//! served model, and is an error when several are served.
//!
//! **Hot-reload contract:** [`Router::sync`] re-reads the manifest and
//! compares each entry's artifact *fingerprint* (file name, byte length,
//! mtime). New entries start serving, changed entries are reloaded and
//! swapped in atomically (requests already in flight finish on the old
//! model — its service thread exits once its last reply is delivered),
//! and entries gone from the manifest stop serving. The store's
//! temp-file + rename write discipline means a poll never observes a
//! torn artifact, so `gzk fit --out <store>` against a live server is the
//! whole deployment story. A failed reload keeps the previous route
//! serving (and is reported, not fatal) — a bad deploy degrades to "old
//! model keeps serving", never to an outage.

use super::admission::{Admission, AdmissionGuard};
use super::wire;
use crate::coordinator::PredictionService;
use crate::model::{ModelKind, ModelStore};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant, SystemTime};

/// Per-route serving knobs (shared by every route the router builds).
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// largest batch the service loop drains per model iteration
    pub max_batch: usize,
    /// optional extra batching window for bursty low-rate clients
    pub max_wait: Duration,
    /// per-model bound on admitted-but-unanswered requests
    pub max_queue: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig { max_batch: 64, max_wait: Duration::ZERO, max_queue: 1024 }
    }
}

/// What identifies an artifact version on disk. `ModelStore` writes via
/// temp-file + rename, so any rewrite bumps the mtime (and, for model
/// artifacts, almost always the byte length); equality of fingerprints is
/// the router's "nothing to reload" test.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Fingerprint {
    file: String,
    len: u64,
    modified: Option<SystemTime>,
}

impl Fingerprint {
    fn of(file: &str, path: &Path) -> Result<Fingerprint, String> {
        let meta = std::fs::metadata(path).map_err(|e| format!("stat {path:?}: {e}"))?;
        Ok(Fingerprint { file: file.to_string(), len: meta.len(), modified: meta.modified().ok() })
    }
}

/// One served model: its batcher, its admission bound, and the identity
/// of the artifact it was loaded from. The registry handles survive
/// hot-swaps (the registry dedups by name, so a reloaded route gets the
/// same underlying cells and the counters stay cumulative).
struct ModelRoute {
    name: String,
    kind: ModelKind,
    d: usize,
    feature_dim: usize,
    output_dim: usize,
    svc: PredictionService,
    admission: Arc<Admission>,
    fingerprint: Fingerprint,
    /// `server.predict.<name>.requests_total` — admitted predicts; the
    /// `gzk top` monitor diffs it into a per-model throughput rate
    req_counter: crate::obs::Counter,
    /// `server.predict.<name>.latency_s` — dispatch-to-reply wall time
    /// on the ladder histogram, so the metrics snapshot (and `gzk top`)
    /// gets per-model p50/p95/p99
    lat_hist: crate::obs::Hist,
}

/// How the listener answers a predict request.
pub enum Dispatch {
    /// Admitted into a model's batcher: await `rx`, then reply. The guard
    /// holds the admission slot until the reply is written; `hist` is the
    /// route's latency histogram for the listener to record
    /// dispatch-to-reply time into.
    Pending {
        model: String,
        rx: Receiver<Vec<f64>>,
        guard: AdmissionGuard,
        hist: crate::obs::Hist,
    },
    /// Answered without touching a batcher (routing / validation /
    /// backpressure) — already a complete reply line.
    Immediate(String),
}

pub struct Router {
    store: ModelStore,
    cfg: RouterConfig,
    routes: RwLock<BTreeMap<String, Arc<ModelRoute>>>,
    /// Artifact versions that failed to stat (`None`) or load
    /// (`Some(fingerprint)`) during a non-strict sync — remembered so a
    /// bad deploy is reported ONCE and retried only when the file
    /// changes again, not re-parsed and re-logged on every poll tick.
    failed: std::sync::Mutex<BTreeMap<String, Option<Fingerprint>>>,
    /// when this router (≈ the server) came up; the `stats` wire reply
    /// reports it as `uptime_s`
    started: Instant,
    /// successful hot-swaps of an already-served route
    reloads: AtomicU64,
    /// admission rejects accumulated by *retired* route generations, per
    /// model. A hot-swap replaces the route — and with it the live
    /// [`Admission`] counter — so without this ledger every reload would
    /// silently zero the model's reject history; `stats` reports
    /// `total_rejects` = retired + live.
    retired_rejects: std::sync::Mutex<BTreeMap<String, u64>>,
}

impl Router {
    /// Open the store and load every manifest entry. Startup is strict:
    /// an empty store or any unloadable artifact is an error (fail fast
    /// at deploy time); only the *polling* resync tolerates bad entries.
    pub fn open(
        store_dir: impl Into<std::path::PathBuf>,
        cfg: RouterConfig,
    ) -> Result<Router, String> {
        if cfg.max_batch < 1 {
            return Err("router max_batch must be >= 1".to_string());
        }
        if cfg.max_queue < 1 {
            return Err("router max_queue must be >= 1".to_string());
        }
        let store = ModelStore::open_existing(store_dir)?;
        let router = Router {
            store,
            cfg,
            routes: RwLock::new(BTreeMap::new()),
            failed: std::sync::Mutex::new(BTreeMap::new()),
            started: Instant::now(),
            reloads: AtomicU64::new(0),
            retired_rejects: std::sync::Mutex::new(BTreeMap::new()),
        };
        router.sync(true)?;
        if router.routes.read().expect("routes lock").is_empty() {
            return Err(format!(
                "store {:?} has no models; run `gzk fit --out <dir>` first",
                router.store.dir()
            ));
        }
        Ok(router)
    }

    /// Reconcile the routes with the store manifest; returns one
    /// human-readable line per change. With `strict` (startup) any
    /// failure is `Err`; without (the poll loop) a failing entry is
    /// reported in the change list and the previous route keeps serving.
    pub fn sync(&self, strict: bool) -> Result<Vec<String>, String> {
        let entries = self.store.entries()?;
        let mut changes = Vec::new();
        // snapshot current fingerprints, then build replacement routes
        // OUTSIDE the lock (loading an artifact can be slow; requests
        // keep flowing to the old route meanwhile)
        let current: BTreeMap<String, Fingerprint> = {
            let routes = self.routes.read().expect("routes lock");
            routes.iter().map(|(n, r)| (n.clone(), r.fingerprint.clone())).collect()
        };
        let mut fresh: Vec<Arc<ModelRoute>> = Vec::new();
        for entry in &entries {
            let path = self.store.dir().join(&entry.file);
            let fp = match Fingerprint::of(&entry.file, &path) {
                Ok(fp) => fp,
                Err(e) => {
                    if strict {
                        return Err(e);
                    }
                    // report a missing/unstattable artifact once, not on
                    // every poll tick (`None` marks "stat kept failing")
                    let already = self
                        .failed
                        .lock()
                        .expect("failed-artifact lock")
                        .insert(entry.name.clone(), None)
                        == Some(None);
                    if !already {
                        changes.push(format!("route {:?}: skipped ({e})", entry.name));
                    }
                    continue;
                }
            };
            if current.get(&entry.name) == Some(&fp) {
                continue; // unchanged artifact: keep the live route
            }
            if self.failed.lock().expect("failed-artifact lock").get(&entry.name)
                == Some(&Some(fp.clone()))
            {
                continue; // this exact version already failed to load
            }
            match self.build_route(&entry.name, fp.clone()) {
                Ok(route) => {
                    self.failed.lock().expect("failed-artifact lock").remove(&route.name);
                    changes.push(format!(
                        "route {:?}: {} ({}, d={}, F={}, out={})",
                        route.name,
                        if current.contains_key(&route.name) {
                            "reloaded changed artifact"
                        } else {
                            "serving new artifact"
                        },
                        route.kind.name(),
                        route.d,
                        route.feature_dim,
                        route.output_dim
                    ));
                    fresh.push(Arc::new(route));
                }
                Err(e) => {
                    if strict {
                        return Err(format!("load model {:?}: {e}", entry.name));
                    }
                    // remember this exact version as bad: retry only when
                    // the file changes again
                    self.failed
                        .lock()
                        .expect("failed-artifact lock")
                        .insert(entry.name.clone(), Some(fp));
                    changes.push(format!(
                        "route {:?}: load failed, previous version keeps serving ({e})",
                        entry.name
                    ));
                }
            }
        }
        let manifest_names: std::collections::BTreeSet<&str> =
            entries.iter().map(|e| e.name.as_str()).collect();
        let mut routes = self.routes.write().expect("routes lock");
        for route in fresh {
            if let Some(old) = routes.insert(route.name.clone(), route) {
                self.reloads.fetch_add(1, Ordering::Relaxed);
                self.retire(&old);
            }
        }
        let stale: Vec<String> = routes
            .keys()
            .filter(|n| !manifest_names.contains(n.as_str()))
            .cloned()
            .collect();
        for name in stale {
            if let Some(old) = routes.remove(&name) {
                self.retire(&old);
            }
            changes.push(format!("route {name:?}: removed (no longer in the store manifest)"));
        }
        self.failed
            .lock()
            .expect("failed-artifact lock")
            .retain(|name, _| manifest_names.contains(name.as_str()));
        Ok(changes)
    }

    /// Bank a retired route generation's admission rejects so the
    /// cumulative `total_rejects` counter survives hot-swaps.
    fn retire(&self, old: &ModelRoute) {
        *self
            .retired_rejects
            .lock()
            .expect("retired-rejects lock")
            .entry(old.name.clone())
            .or_insert(0) += old.admission.rejects();
    }

    fn build_route(&self, name: &str, fingerprint: Fingerprint) -> Result<ModelRoute, String> {
        let model = self.store.load(name)?;
        let kind = model.kind();
        let d = model.feature_spec().d;
        let feature_dim = model.feature_spec().feature_dim();
        let output_dim = model.output_dim();
        let svc = PredictionService::serve(model, self.cfg.max_batch, self.cfg.max_wait);
        Ok(ModelRoute {
            name: name.to_string(),
            kind,
            d,
            feature_dim,
            output_dim,
            svc,
            admission: Admission::new(name, self.cfg.max_queue),
            fingerprint,
            req_counter: crate::obs::counter(&format!("server.predict.{name}.requests_total")),
            lat_hist: crate::obs::hist(&format!("server.predict.{name}.latency_s")),
        })
    }

    fn lookup(&self, name: Option<&str>) -> Result<Arc<ModelRoute>, String> {
        let routes = self.routes.read().expect("routes lock");
        match name {
            Some(n) => routes.get(n).cloned().ok_or_else(|| {
                let have: Vec<&str> = routes.keys().map(String::as_str).collect();
                format!(
                    "no model {n:?}; serving: {}",
                    if have.is_empty() { "none".to_string() } else { have.join(", ") }
                )
            }),
            None => match routes.len() {
                1 => Ok(routes.values().next().expect("len checked").clone()),
                0 => Err("no models are being served".to_string()),
                _ => Err(format!(
                    "multiple models served ({}); name one with \"model\"",
                    routes.keys().cloned().collect::<Vec<_>>().join(", ")
                )),
            },
        }
    }

    /// Route one predict request: resolve the model, validate the input
    /// dimension, admit against the model's queue bound, submit to its
    /// batcher. Never blocks — the listener's reader thread calls this,
    /// and only its *writer* thread awaits replies.
    pub fn dispatch_predict(&self, model: Option<&str>, x: &[f64]) -> Dispatch {
        self.dispatch_predict_notify(model, x, None)
    }

    /// [`dispatch_predict`](Router::dispatch_predict) with an optional
    /// reply doorbell, forwarded to the batcher: the event-loop listener
    /// passes a closure that wakes the loop owning the connection the
    /// moment its reply is ready.
    pub fn dispatch_predict_notify(
        &self,
        model: Option<&str>,
        x: &[f64],
        notify: Option<crate::coordinator::ReplyNotify>,
    ) -> Dispatch {
        let route = match self.lookup(model) {
            Ok(r) => r,
            Err(e) => return Dispatch::Immediate(wire::error_reply(&e)),
        };
        if x.len() != route.d {
            return Dispatch::Immediate(wire::error_reply(&format!(
                "input has {} values but model {:?} expects d = {}",
                x.len(),
                route.name,
                route.d
            )));
        }
        let Some(guard) = route.admission.try_admit() else {
            return Dispatch::Immediate(wire::overload_reply(&format!(
                "model {:?} queue is full ({} in flight); retry after backoff",
                route.name,
                route.admission.max_queue()
            )));
        };
        match route.svc.client().submit_notify(x, notify) {
            Ok(rx) => {
                route.req_counter.inc();
                Dispatch::Pending {
                    model: route.name.clone(),
                    rx,
                    guard,
                    hist: route.lat_hist.clone(),
                }
            }
            Err(e) => Dispatch::Immediate(wire::error_reply(&e)),
        }
    }

    /// Names of the currently served models (sorted).
    pub fn model_names(&self) -> Vec<String> {
        self.routes.read().expect("routes lock").keys().cloned().collect()
    }

    /// The `models` wire reply: one row per served model.
    pub fn models_reply(&self) -> String {
        let routes = self.routes.read().expect("routes lock");
        let rows: Vec<String> = routes
            .values()
            .map(|r| {
                format!(
                    r#"{{"name":{},"kind":"{}","d":{},"feature_dim":{},"output_dim":{}}}"#,
                    wire::json_string(&r.name),
                    r.kind.name(),
                    r.d,
                    r.feature_dim,
                    r.output_dim
                )
            })
            .collect();
        format!(r#"{{"ok":true,"models":[{}]}}"#, rows.join(","))
    }

    /// The `stats` wire reply: per-model [`ServeMetrics`] counters,
    /// latency percentiles from the fixed-bucket histogram, and the
    /// admission queue state.
    ///
    /// **Percentile semantics** (see also `loadgen::pct`, the client
    /// side): `p50_us`/`p95_us`/`p99_us` here come from the fixed 1-2-5
    /// bucket ladder ([`LADDER_BOUNDS`](crate::obs::registry::LADDER_BOUNDS))
    /// and resolve to the **upper bound of the bucket** the rank lands
    /// in — up to one ladder step (~2–2.5×) above the true order
    /// statistic, by design (O(1) recording, bounded memory, cheap
    /// snapshots). The loadgen harness instead keeps every sample and
    /// reports **exact** order statistics, so its percentiles are
    /// `<=` the server's for the same traffic; compare them knowing the
    /// server quantizes up. `BENCH_serve.json` records the ladder so the
    /// two are reconcilable offline.
    ///
    /// [`ServeMetrics`]: crate::coordinator::ServeMetrics
    pub fn stats_reply(&self) -> String {
        let routes = self.routes.read().expect("routes lock");
        let retired = self.retired_rejects.lock().expect("retired-rejects lock");
        let mut total_rejects = 0u64;
        let rows: Vec<String> = routes
            .values()
            .map(|r| {
                let m = r.svc.metrics();
                // cumulative across route generations: the live Admission
                // counter resets on every hot-swap, the ledger does not
                let model_total =
                    retired.get(&r.name).copied().unwrap_or(0) + r.admission.rejects();
                total_rejects += model_total;
                format!(
                    concat!(
                        r#"{{"model":{},"kind":"{}","requests":{},"batches":{},"max_batch_seen":{},"#,
                        r#""p50_us":{:.1},"p95_us":{:.1},"p99_us":{:.1},"#,
                        r#""queue_depth":{},"max_queue":{},"rejects":{},"total_rejects":{}}}"#
                    ),
                    wire::json_string(&r.name),
                    r.kind.name(),
                    m.requests,
                    m.batches,
                    m.max_batch_seen,
                    m.latency.quantile(0.5) * 1e6,
                    m.latency.quantile(0.95) * 1e6,
                    m.latency.quantile(0.99) * 1e6,
                    r.admission.depth(),
                    r.admission.max_queue(),
                    r.admission.rejects(),
                    model_total
                )
            })
            .collect();
        format!(
            r#"{{"ok":true,"uptime_s":{:.3},"reloads":{},"total_rejects":{},"stats":[{}]}}"#,
            self.started.elapsed().as_secs_f64(),
            self.reloads.load(Ordering::Relaxed),
            total_rejects,
            rows.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureSpec, KernelSpec, Method};
    use crate::linalg::Mat;
    use crate::model::{Model, RidgeModel};
    use crate::rng::Rng;

    fn fresh_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("gzk-router-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_ridge(seed: u64) -> RidgeModel {
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 5, s: 1 },
            16,
            seed,
        )
        .bind(2);
        let mut rng = Rng::new(seed ^ 0xF00);
        let x = Mat::from_fn(40, 2, |_, _| rng.normal() * 0.5);
        let y: Vec<f64> = (0..40).map(|i| x[(i, 0)] - x[(i, 1)]).collect();
        RidgeModel::fit(spec, &x, &y, 1e-3).unwrap()
    }

    fn recv_y(router: &Router, model: Option<&str>, x: &[f64]) -> Result<Vec<f64>, String> {
        match router.dispatch_predict(model, x) {
            Dispatch::Pending { rx, .. } => {
                rx.recv().map_err(|_| "service dropped request".to_string())
            }
            Dispatch::Immediate(line) => Err(line),
        }
    }

    #[test]
    fn routes_validate_and_predict_bit_identically() {
        let dir = fresh_dir("basic");
        let store = ModelStore::open(&dir).unwrap();
        let model = small_ridge(7);
        store.save("ridge", &model).unwrap();
        let router = Router::open(&dir, RouterConfig::default()).unwrap();
        assert_eq!(router.model_names(), vec!["ridge".to_string()]);

        let x = [0.3, -0.8];
        let expect = Model::predict(&model, &Mat::from_vec(1, 2, x.to_vec()));
        // named and unnamed routing agree, bit for bit
        for sel in [Some("ridge"), None] {
            let y = recv_y(&router, sel, &x).unwrap();
            assert_eq!(y.len(), 1);
            assert_eq!(y[0].to_bits(), expect[(0, 0)].to_bits());
        }
        // wrong dimension and unknown model are immediate error replies
        let e = recv_y(&router, None, &[1.0]).unwrap_err();
        assert!(e.contains("expects d = 2"), "{e}");
        let e = recv_y(&router, Some("nope"), &x).unwrap_err();
        assert!(e.contains("no model") && e.contains("ridge"), "{e}");
        // stats counts the two successful predictions
        let stats = router.stats_reply();
        assert!(stats.contains(r#""requests":2"#), "{stats}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sync_adds_reloads_and_removes_routes() {
        let dir = fresh_dir("sync");
        let store = ModelStore::open(&dir).unwrap();
        store.save("a", &small_ridge(1)).unwrap();
        let router = Router::open(&dir, RouterConfig::default()).unwrap();
        let x = [0.2, 0.4];
        let y1 = recv_y(&router, None, &x).unwrap();

        // a second model appears in the store: picked up by sync, and an
        // unnamed predict now requires a model name
        store.save("b", &small_ridge(2)).unwrap();
        let changes = router.sync(false).unwrap();
        assert_eq!(changes.len(), 1, "{changes:?}");
        assert!(changes[0].contains("serving new artifact"), "{changes:?}");
        assert_eq!(router.model_names(), vec!["a".to_string(), "b".to_string()]);
        let e = recv_y(&router, None, &x).unwrap_err();
        assert!(e.contains("multiple models"), "{e}");
        assert!(router.models_reply().contains(r#""name":"b""#));

        // an unchanged store is a no-op sync
        assert!(router.sync(false).unwrap().is_empty());

        // replacing "a"'s artifact hot-swaps the route: predictions change
        std::thread::sleep(Duration::from_millis(20)); // ensure a distinct mtime
        let replacement = small_ridge(99);
        store.save("a", &replacement).unwrap();
        let changes = router.sync(false).unwrap();
        assert!(
            changes.iter().any(|c| c.contains("reloaded changed artifact")),
            "{changes:?}"
        );
        let y2 = recv_y(&router, Some("a"), &x).unwrap();
        let expect = Model::predict(&replacement, &Mat::from_vec(1, 2, x.to_vec()));
        assert_eq!(y2[0].to_bits(), expect[(0, 0)].to_bits());
        assert_ne!(y1[0].to_bits(), y2[0].to_bits(), "swap must change the served model");

        // dropping "b" from the manifest stops serving it
        let manifest = std::fs::read_to_string(dir.join("models.json")).unwrap();
        let pruned = manifest.replace(r#",{"name":"b","kind":"ridge","file":"b.model.json"}"#, "");
        assert_ne!(manifest, pruned, "test must actually prune the manifest");
        std::fs::write(dir.join("models.json"), pruned).unwrap();
        let changes = router.sync(false).unwrap();
        assert!(changes.iter().any(|c| c.contains("removed")), "{changes:?}");
        assert_eq!(router.model_names(), vec!["a".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_is_strict_and_polling_is_not() {
        // empty store: startup refuses
        let dir = fresh_dir("strict");
        let _ = ModelStore::open(&dir).unwrap();
        let err = Router::open(&dir, RouterConfig::default()).unwrap_err();
        assert!(err.contains("no models"), "{err}");

        // a corrupt artifact: startup refuses ...
        let store = ModelStore::open(&dir).unwrap();
        store.save("ok", &small_ridge(3)).unwrap();
        let router = Router::open(&dir, RouterConfig::default()).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        std::fs::write(dir.join("ok.model.json"), "corrupt{").unwrap();
        assert!(Router::open(&dir, RouterConfig::default()).is_err());
        // ... but a live router keeps the previous route serving
        let changes = router.sync(false).unwrap();
        assert!(
            changes.iter().any(|c| c.contains("previous version keeps serving")),
            "{changes:?}"
        );
        // the bad version is remembered: the next poll is silent, not a
        // re-parse + re-report of the same broken artifact
        assert!(router.sync(false).unwrap().is_empty());
        assert!(recv_y(&router, None, &[0.1, 0.2]).is_ok());
        // a rewritten (changed) artifact is retried and swaps in
        std::thread::sleep(Duration::from_millis(20));
        let fixed = small_ridge(8);
        store.save("ok", &fixed).unwrap();
        let changes = router.sync(false).unwrap();
        assert!(changes.iter().any(|c| c.contains("reloaded")), "{changes:?}");
        let y = recv_y(&router, None, &[0.1, 0.2]).unwrap();
        let expect = Model::predict(&fixed, &Mat::from_vec(1, 2, vec![0.1, 0.2]));
        assert_eq!(y[0].to_bits(), expect[(0, 0)].to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_queue_is_a_retriable_overload_reply() {
        let dir = fresh_dir("overload");
        let store = ModelStore::open(&dir).unwrap();
        store.save("ridge", &small_ridge(5)).unwrap();
        let cfg = RouterConfig { max_queue: 1, ..RouterConfig::default() };
        let router = Router::open(&dir, cfg).unwrap();
        let x = [0.1, 0.2];
        // hold one admitted request un-awaited: the queue (bound 1) is full
        let first = router.dispatch_predict(None, &x);
        let Dispatch::Pending { rx, guard, .. } = first else {
            panic!("first request must be admitted");
        };
        match router.dispatch_predict(None, &x) {
            Dispatch::Immediate(line) => {
                let reply = wire::parse_reply(&line).unwrap();
                assert!(!reply.ok && reply.retry, "{line}");
                assert!(reply.error.unwrap().contains("queue is full"));
            }
            Dispatch::Pending { .. } => panic!("second request must be rejected"),
        }
        assert!(router.stats_reply().contains(r#""rejects":1"#));
        // releasing the slot re-admits
        let _ = rx.recv().unwrap();
        drop(guard);
        assert!(matches!(router.dispatch_predict(None, &x), Dispatch::Pending { .. }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_reports_uptime_reloads_and_swap_surviving_rejects() {
        let dir = fresh_dir("counters");
        let store = ModelStore::open(&dir).unwrap();
        store.save("ridge", &small_ridge(11)).unwrap();
        let cfg = RouterConfig { max_queue: 1, ..RouterConfig::default() };
        let router = Router::open(&dir, cfg).unwrap();
        let stats = router.stats_reply();
        assert!(stats.contains(r#""uptime_s":"#), "{stats}");
        assert!(stats.contains(r#""reloads":0"#), "{stats}");
        assert!(stats.contains(r#""total_rejects":0"#), "{stats}");

        // provoke one admission reject
        let x = [0.1, 0.2];
        let Dispatch::Pending { rx, guard, .. } = router.dispatch_predict(None, &x) else {
            panic!("first request must be admitted");
        };
        assert!(matches!(router.dispatch_predict(None, &x), Dispatch::Immediate(_)));
        let _ = rx.recv().unwrap();
        drop(guard);

        // hot-swap the route: the live Admission counter is recreated, but
        // the cumulative ledger keeps the reject history
        std::thread::sleep(Duration::from_millis(20));
        store.save("ridge", &small_ridge(12)).unwrap();
        let changes = router.sync(false).unwrap();
        assert!(changes.iter().any(|c| c.contains("reloaded")), "{changes:?}");
        let stats = router.stats_reply();
        assert!(stats.contains(r#""reloads":1"#), "{stats}");
        assert!(stats.contains(r#""rejects":0"#), "{stats}");
        assert!(stats.contains(r#""total_rejects":1"#), "{stats}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
