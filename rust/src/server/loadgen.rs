//! Concurrent load generator for a running `gzk server` — the
//! measurement harness behind `gzk loadgen`.
//!
//! For each requested client count it opens that many TCP connections,
//! fires `requests_per_client` predict requests per connection (rows
//! drawn deterministically from a [`SyntheticSource`] — row i is a pure
//! function of `(dataset, seed, i)`, so a run is reproducible), measures
//! per-request latency, and aggregates throughput plus p50/p95/p99 from
//! the raw samples (exact, unlike the server's fixed-bucket histogram —
//! comparing the two is itself a useful check). With a local `--store`
//! it also loads the same artifact and checks **every** reply
//! bit-identical to `Model::predict` — the wire codec's shortest
//! round-trip floats make that an equality test, not a tolerance.
//!
//! The [`WireMode`] picks the protocol: plain JSON lines, the binary
//! frame mode (each connection upgrades with `{"cmd":"binary"}` before
//! the measured window), or **compare** — a JSON trial and a binary
//! trial per client count, with every reply's raw bit pattern
//! cross-checked between the two (`cross_mismatches`), the direct proof
//! that frame mode changes latency but never a single output bit.
//!
//! Backpressure replies (`"retry":true` / `ST_RETRY` frames) are
//! retried after a short backoff and counted, so a run against a
//! saturated server degrades to honest numbers (slower, with a retry
//! count) rather than an error. After the direct trials the harness
//! also fetches the server's `metrics` snapshot and cross-checks the
//! per-model `server.admission.<model>.rejected_total` registry counter
//! against the `stats` reply's cumulative reject count.
//!
//! Results are emitted as `BENCH_serve.json` (same convention as the
//! hotpath bench's `BENCH_hotpath.json`; CI uploads it as an artifact).

use super::{frame, sys, wire};
use crate::data::{DataSource, SyntheticSource};
use crate::model::{Model, ModelStore};
use crate::runtime::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Barrier;
use std::time::{Duration, Instant};

/// Which protocol the measured requests use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// newline-delimited JSON (the default)
    Json,
    /// length-prefixed binary frames (each connection upgrades first)
    Binary,
    /// both, one trial each per client count, reply bits cross-checked
    Compare,
}

/// One blocking request/reply connection to a `gzk server`.
pub struct ClientConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ClientConn {
    pub fn connect(addr: &str) -> Result<ClientConn, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("connect to gzk server {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(
            stream.try_clone().map_err(|e| format!("clone connection to {addr}: {e}"))?,
        );
        Ok(ClientConn { reader, writer: stream })
    }

    /// Send one request line and read the matching reply line.
    pub fn roundtrip(&mut self, line: &str) -> Result<wire::Reply, String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.write_all(b"\n"))
            .map_err(|e| format!("send request: {e}"))?;
        let mut reply = String::new();
        match self.reader.read_line(&mut reply) {
            Ok(0) => Err("server closed the connection".to_string()),
            Ok(_) => wire::parse_reply(reply.trim_end()),
            Err(e) => Err(format!("read reply: {e}")),
        }
    }

    /// Negotiate the binary frame mode: after the ack, every byte on
    /// this connection is framed.
    pub fn upgrade_binary(&mut self) -> Result<(), String> {
        let reply = self.roundtrip(&wire::cmd_request("binary"))?;
        if reply.ok && matches!(reply.body.get("binary"), Some(Json::Bool(true))) {
            Ok(())
        } else {
            Err(reply
                .error
                .unwrap_or_else(|| "server did not ack the binary upgrade".to_string()))
        }
    }

    /// Negotiate binary frames while *offering* the GZF2 traced-frame
    /// header (`{"cmd":"binary","v":2}`). Returns whether the peer acked
    /// v2; an older peer ignores the offer, the connection stays GZF1,
    /// and the caller must not send GZF2 frames on it.
    pub fn upgrade_binary_v2(&mut self) -> Result<bool, String> {
        let reply = self.roundtrip(&wire::binary_request_v2())?;
        if reply.ok && matches!(reply.body.get("binary"), Some(Json::Bool(true))) {
            Ok(matches!(reply.body.get("v"), Some(Json::Num(v)) if *v == 2.0))
        } else {
            Err(reply
                .error
                .unwrap_or_else(|| "server did not ack the binary upgrade".to_string()))
        }
    }

    /// Write one complete frame (header included).
    pub fn send_frame(&mut self, frame_bytes: &[u8]) -> Result<(), String> {
        self.writer.write_all(frame_bytes).map_err(|e| format!("send frame: {e}"))
    }

    /// Read one complete reply frame.
    pub fn read_frame(&mut self) -> Result<Vec<u8>, String> {
        frame::read_frame(&mut self.reader)?
            .ok_or_else(|| "server closed the connection".to_string())
    }

    /// Send one frame and read the matching reply frame.
    pub fn roundtrip_frame(&mut self, frame_bytes: &[u8]) -> Result<Vec<u8>, String> {
        self.send_frame(frame_bytes)?;
        self.read_frame()
    }
}

/// What to run; see the `gzk loadgen` flags in `main.rs`.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// server (or proxy) to drive directly; empty = no direct target,
    /// `replica_sweep` only
    pub addr: String,
    /// client counts to sweep, one trial each (e.g. `[1, 8]`)
    pub clients: Vec<usize>,
    pub requests_per_client: usize,
    /// rows come from this synthetic dataset; `None` = the dataset
    /// recorded in the artifact (with `store`) or `elevation`
    pub dataset: Option<String>,
    /// model to target; `None` = the server's single model
    pub model: Option<String>,
    /// local copy of the server's store: enables bit-identity checking
    pub store: Option<PathBuf>,
    pub seed: u64,
    /// send the wire `shutdown` command after the last trial
    pub send_shutdown: bool,
    /// replica counts to sweep (e.g. `[1, 2, 4]`): for each count N,
    /// loadgen spins N in-process `gzk server` replicas over `store`
    /// (required) behind an in-process proxy, runs one trial at the
    /// largest client count through the proxy, and tears the tier down —
    /// the serving twin of the distributed-fit worker sweep
    pub replica_sweep: Vec<usize>,
    /// protocol for the measured requests
    pub wire: WireMode,
    /// mint a trace ID per request and carry it on the wire (`"tid"` on
    /// JSON lines, the GZF2 header in binary mode when the server acks
    /// v2) — replies are tid-free either way, so the bit-identity check
    /// runs unchanged
    pub traced: bool,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            addr: String::new(),
            clients: vec![1],
            requests_per_client: 100,
            dataset: None,
            model: None,
            store: None,
            seed: 1,
            send_shutdown: false,
            replica_sweep: Vec::new(),
            wire: WireMode::Json,
            traced: false,
        }
    }
}

/// One client-count trial, aggregated over all its connections.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub clients: usize,
    /// protocol this trial ran over: `"json"` or `"binary"`
    pub wire: &'static str,
    /// successful predictions (excludes retries)
    pub requests: usize,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// backpressure replies absorbed by retrying
    pub retries: usize,
    /// replies that were NOT bit-identical to the local model (0 unless
    /// verification found a real divergence)
    pub mismatches: usize,
    /// compare mode only: replies whose bit pattern differed from the
    /// matching request of this trial's JSON twin
    pub cross_mismatches: usize,
}

/// One replica-count entry of the scaling sweep.
#[derive(Clone, Debug)]
pub struct ReplicaTrial {
    pub replicas: usize,
    pub trial: TrialResult,
}

/// Serve-path tracing cost (format 5): median latency with per-request
/// trace IDs minted and spans recorded, against the same trial with
/// tracing off. Filled by the hotpath bench's obs-overhead section.
#[derive(Clone, Copy, Debug)]
pub struct TraceOverhead {
    pub p50_us_off: f64,
    pub p50_us_on: f64,
    /// `(on - off) / off` — the bench bounds this below 0.10
    pub overhead_frac: f64,
}

/// Everything a run produced; `write_json` emits `BENCH_serve.json`.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub addr: String,
    pub model: String,
    pub dataset: String,
    pub requests_per_client: usize,
    pub seed: u64,
    /// bit-identity checking was active (a local store was supplied)
    pub verified: bool,
    pub wire_mode: WireMode,
    /// requests carried per-request trace IDs (see `LoadgenConfig::traced`)
    pub traced: bool,
    pub trials: Vec<TrialResult>,
    /// replica-scaling trials (empty unless a sweep was requested)
    pub replica_trials: Vec<ReplicaTrial>,
    /// the server's `stats` reply captured after each trial (for sweep
    /// trials, one replica's stats fetched through the proxy — carrying
    /// the uptime / reload / cumulative-reject counters)
    pub server_stats: Vec<String>,
    /// the target model's `server.admission.<model>.rejected_total`
    /// registry counter, fetched over the wire `metrics` command after
    /// the direct trials and cross-checked against the `stats` reply
    /// (`None` when there was no direct target or the registry was off)
    pub admission_rejected_total: Option<u64>,
    /// tracing-on vs tracing-off serve latency (`None` unless the
    /// hotpath bench's obs-overhead section measured it)
    pub trace_overhead: Option<TraceOverhead>,
}

impl LoadgenReport {
    pub fn mismatches(&self) -> usize {
        self.trials.iter().map(|t| t.mismatches + t.cross_mismatches).sum::<usize>()
            + self
                .replica_trials
                .iter()
                .map(|r| r.trial.mismatches + r.trial.cross_mismatches)
                .sum::<usize>()
    }

    /// Machine-readable results (the CI serving-smoke artifact).
    /// Format 2 = format 1 plus the `replica_sweep` section; format 3
    /// adds `latency_semantics` — loadgen percentiles are exact order
    /// statistics, while the embedded `server_stats` percentiles are
    /// bucket upper bounds on the recorded `bucket_ladder_s` (see
    /// [`pct`] and `Router::stats_reply`); format 4 adds the per-trial
    /// `wire` / `cross_mismatches` fields (the JSON-vs-binary frame
    /// comparison) plus the top-level `wire_mode` and
    /// `admission_rejected_total`; format 5 adds the top-level `traced`
    /// flag and the `trace_overhead` section (tracing-on vs tracing-off
    /// serve p50, measured by the hotpath bench; `null` when unmeasured).
    pub fn write_json(&self, path: &std::path::Path) -> Result<(), String> {
        fn trial_json(t: &TrialResult, prefix: &str) -> String {
            format!(
                concat!(
                    r#"{{{}"clients":{},"wire":"{}","requests":{},"wall_secs":{:.4},"#,
                    r#""throughput_rps":{:.1},"#,
                    r#""p50_us":{:.2},"p95_us":{:.2},"p99_us":{:.2},"retries":{},"#,
                    r#""mismatches":{},"cross_mismatches":{}}}"#
                ),
                prefix,
                t.clients,
                t.wire,
                t.requests,
                t.wall_secs,
                t.throughput_rps,
                t.p50_us,
                t.p95_us,
                t.p99_us,
                t.retries,
                t.mismatches,
                t.cross_mismatches
            )
        }
        let trials: Vec<String> = self.trials.iter().map(|t| trial_json(t, "")).collect();
        let sweep: Vec<String> = self
            .replica_trials
            .iter()
            .map(|r| trial_json(&r.trial, &format!(r#""replicas":{},"#, r.replicas)))
            .collect();
        let ladder: Vec<String> = crate::obs::registry::LADDER_BOUNDS
            .iter()
            .map(|b| format!("{b:?}"))
            .collect();
        let wire_mode = match self.wire_mode {
            WireMode::Json => "json",
            WireMode::Binary => "binary",
            WireMode::Compare => "compare",
        };
        let rejected = match self.admission_rejected_total {
            Some(n) => n.to_string(),
            None => "null".to_string(),
        };
        let overhead = match &self.trace_overhead {
            Some(o) => format!(
                r#"{{"p50_us_off":{:.2},"p50_us_on":{:.2},"overhead_frac":{:.4}}}"#,
                o.p50_us_off, o.p50_us_on, o.overhead_frac
            ),
            None => "null".to_string(),
        };
        let text = format!(
            concat!(
                r#"{{"format":5,"bench":"serve","addr":{},"model":{},"dataset":{},"#,
                r#""requests_per_client":{},"seed":{},"verified":{},"wire_mode":"{}","#,
                r#""traced":{},"admission_rejected_total":{},"trace_overhead":{},"#,
                r#""latency_semantics":{{"trials":"exact order statistics","#,
                r#""server_stats":"bucket upper bound on bucket_ladder_s"}},"#,
                r#""bucket_ladder_s":[{}],"trials":[{}],"#,
                r#""replica_sweep":[{}]}}"#
            ),
            wire::json_string(&self.addr),
            wire::json_string(&self.model),
            wire::json_string(&self.dataset),
            self.requests_per_client,
            self.seed,
            self.verified,
            wire_mode,
            self.traced,
            rejected,
            overhead,
            ladder.join(","),
            trials.join(","),
            sweep.join(",")
        );
        std::fs::write(path, text).map_err(|e| format!("write {path:?}: {e}"))
    }
}

/// Row description of one served model from the `models` wire reply.
struct WireModel {
    name: String,
    d: usize,
}

fn served_models(conn: &mut ClientConn) -> Result<Vec<WireModel>, String> {
    let reply = conn.roundtrip(&wire::cmd_request("models"))?;
    if !reply.ok {
        return Err(reply.error.unwrap_or_else(|| "models command failed".to_string()));
    }
    let arr = reply
        .body
        .get("models")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| "models reply missing models[]".to_string())?;
    arr.iter()
        .map(|m| {
            Ok(WireModel {
                name: m
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| "models reply entry missing name".to_string())?
                    .to_string(),
                d: m.get("d")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| "models reply entry missing d".to_string())?,
            })
        })
        .collect()
}

/// `sorted[len * num/den]` with the house clamp (see `print_latency_summary`).
///
/// **Percentile semantics** (the counterpart of `Router::stats_reply`):
/// loadgen keeps every latency sample and reports the **exact** order
/// statistic — no bucketing. The server's `stats` percentiles come from
/// the fixed 1-2-5 ladder and quantize **up** to their bucket's upper
/// bound, so for the same traffic `server p50 >= loadgen p50` by up to
/// one ladder step (~2–2.5×). Both conventions, plus the ladder itself,
/// are recorded in `BENCH_serve.json` so the two reports reconcile.
fn pct(sorted: &[f64], num: usize, den: usize) -> f64 {
    sorted[(sorted.len() * num / den).min(sorted.len() - 1)]
}

/// Drive the sweep. Per trial: `clients` connections × `requests_per_client`
/// requests each, all clients released together (barrier) so throughput is
/// measured under the full concurrency.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport, String> {
    if cfg.clients.is_empty() || cfg.requests_per_client == 0 {
        return Err("loadgen needs at least one client count and one request".to_string());
    }
    let direct = !cfg.addr.is_empty();
    if !direct && cfg.replica_sweep.is_empty() {
        return Err("loadgen needs --addr, --replica-sweep, or both".to_string());
    }
    if !cfg.replica_sweep.is_empty() && cfg.store.is_none() {
        return Err(
            "the replica sweep spins its own servers and needs --store <model dir>".to_string()
        );
    }
    let max_clients = *cfg.clients.iter().max().expect("non-empty");
    // one socket per client plus the control conn and slack; doubled so
    // an in-process replica sweep (whose servers also hold fds) fits
    sys::raise_nofile_limit(2 * max_clients as u64 + 256);

    // resolve the target model: ask the live server when there is one,
    // else (sweep-only) read the store manifest the sweep will serve from
    let mut control = None;
    let (name, d) = if direct {
        let mut conn = ClientConn::connect(&cfg.addr)?;
        let served = served_models(&mut conn)?;
        let target = pick_target(&served, cfg.model.as_deref())?;
        let out = (target.name.clone(), target.d);
        control = Some(conn);
        out
    } else {
        let dir = cfg.store.as_ref().expect("checked above");
        let store = ModelStore::open_existing(dir)?;
        let served: Vec<WireModel> = store
            .entries()?
            .iter()
            .map(|e| {
                Ok(WireModel { name: e.name.clone(), d: store.load(&e.name)?.feature_spec().d })
            })
            .collect::<Result<_, String>>()?;
        let target = pick_target(&served, cfg.model.as_deref())?;
        (target.name.clone(), target.d)
    };

    // the local twin for bit-identity checking, plus the recorded
    // training dataset as the default row generator
    let (local, recorded_dataset): (Option<Box<dyn Model>>, Option<String>) = match &cfg.store {
        Some(dir) => {
            let store = ModelStore::open_existing(dir)?;
            let (model, meta) = store.load_with_meta(&name)?;
            if model.feature_spec().d != d {
                return Err(format!(
                    "local artifact {name:?} in {dir:?} has d = {} but the server's has d = {d} \
                     — different stores?",
                    model.feature_spec().d
                ));
            }
            (Some(model), meta.dataset)
        }
        None => (None, None),
    };
    let dataset = cfg
        .dataset
        .clone()
        .or_else(|| {
            // the artifact's recorded dataset, when it is one loadgen can
            // regenerate (a `file:` path is not)
            recorded_dataset.filter(|n| SyntheticSource::by_name(n, 1, cfg.seed).is_ok())
        })
        .unwrap_or_else(|| "elevation".to_string());
    let total_rows = max_clients * cfg.requests_per_client;
    let source = SyntheticSource::by_name(&dataset, total_rows, cfg.seed)?;
    if source.dim() != d {
        return Err(format!(
            "dataset {dataset:?} has input dimension {} but model {name:?} expects d = {d}; \
             pass a --dataset with matching dimension",
            source.dim()
        ));
    }

    let ctx =
        TrialCtx { cfg, model_name: &name, source: &source, local: local.as_deref() };
    let mut trials = Vec::with_capacity(cfg.clients.len());
    let mut server_stats = Vec::new();
    if let Some(control) = control.as_mut() {
        for &n_clients in &cfg.clients {
            match cfg.wire {
                WireMode::Json => {
                    let (t, _) = run_trial(&ctx, &cfg.addr, n_clients, false, false)?;
                    trials.push(t);
                }
                WireMode::Binary => {
                    let (t, _) = run_trial(&ctx, &cfg.addr, n_clients, true, false)?;
                    trials.push(t);
                }
                WireMode::Compare => {
                    // identical rows over both protocols; the reply bit
                    // patterns must agree request for request
                    let (tj, bits_json) = run_trial(&ctx, &cfg.addr, n_clients, false, true)?;
                    let (mut tb, bits_bin) = run_trial(&ctx, &cfg.addr, n_clients, true, true)?;
                    tb.cross_mismatches =
                        bits_json.iter().zip(&bits_bin).filter(|(a, b)| a != b).count();
                    trials.push(tj);
                    trials.push(tb);
                }
            }
            let stats = control.roundtrip(&wire::cmd_request("stats"))?;
            if !stats.ok {
                return Err(stats.error.unwrap_or_else(|| "stats command failed".to_string()));
            }
            server_stats.push(stats.raw);
        }
    }

    // admission-counter cross-check: the registry twin must cover what
    // the router's own stats report (see check_admission_counter)
    let mut admission_rejected_total = None;
    if let Some(control) = control.as_mut() {
        let last_stats = server_stats.last().map(String::as_str);
        admission_rejected_total = check_admission_counter(control, &name, last_stats)?;
    }

    // replica-scaling sweep: an in-process serving tier (N servers + a
    // proxy, all on loopback ephemeral ports) per requested count, driven
    // at the largest client count so the single-replica admission bound
    // is actually contended
    let sweep_binary = cfg.wire == WireMode::Binary;
    let mut replica_trials = Vec::with_capacity(cfg.replica_sweep.len());
    for &n_replicas in &cfg.replica_sweep {
        if n_replicas == 0 {
            return Err("replica sweep entries must be >= 1".to_string());
        }
        let store_dir = cfg.store.as_ref().expect("checked above");
        let mut servers = Vec::with_capacity(n_replicas);
        for _ in 0..n_replicas {
            servers.push(crate::server::Server::start(
                store_dir,
                "127.0.0.1:0",
                crate::server::ServerConfig::default(),
            )?);
        }
        let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
        let proxy =
            crate::dist::Proxy::start("127.0.0.1:0", addrs, crate::dist::ProxyConfig::default())?;
        let proxy_addr = proxy.local_addr().to_string();
        let trial = run_trial(&ctx, &proxy_addr, max_clients, sweep_binary, false);
        // capture one replica's stats through the proxy (uptime, reloads,
        // cumulative rejects) before tearing the tier down
        if let Ok((t, _)) = &trial {
            let stats = ClientConn::connect(&proxy_addr)
                .and_then(|mut c| c.roundtrip(&wire::cmd_request("stats")));
            if let Ok(stats) = stats {
                server_stats.push(stats.raw);
            }
            replica_trials.push(ReplicaTrial { replicas: n_replicas, trial: t.clone() });
        }
        proxy.shutdown();
        let _ = proxy.wait();
        for s in servers {
            s.shutdown();
            let _ = s.wait();
        }
        trial?; // after teardown: a failed sweep trial is still an error
    }

    if cfg.send_shutdown {
        if let Some(control) = control.as_mut() {
            let reply = control.roundtrip(&wire::cmd_request("shutdown"))?;
            if !reply.ok {
                return Err(reply
                    .error
                    .unwrap_or_else(|| "server refused the shutdown command".to_string()));
            }
        }
    }
    Ok(LoadgenReport {
        addr: cfg.addr.clone(),
        model: name,
        dataset,
        requests_per_client: cfg.requests_per_client,
        seed: cfg.seed,
        verified: local.is_some(),
        wire_mode: cfg.wire,
        traced: cfg.traced,
        trials,
        replica_trials,
        server_stats,
        admission_rejected_total,
        trace_overhead: None,
    })
}

/// Fetch `server.admission.<model>.rejected_total` from the wire
/// `metrics` snapshot and require it covers the cumulative reject count
/// the `stats` reply reports for the model. `Ok(None)` when the server's
/// registry is disabled (nothing to cross-check).
fn check_admission_counter(
    control: &mut ClientConn,
    model: &str,
    last_stats: Option<&str>,
) -> Result<Option<u64>, String> {
    let metrics = control.roundtrip(&wire::cmd_request("metrics"))?;
    if !metrics.ok {
        return Err(metrics.error.unwrap_or_else(|| "metrics command failed".to_string()));
    }
    let snapshot = metrics
        .body
        .get("metrics")
        .ok_or_else(|| "metrics reply missing the registry snapshot".to_string())?;
    if !matches!(snapshot.get("enabled"), Some(Json::Bool(true))) {
        return Ok(None);
    }
    let counter_name = format!("server.admission.{model}.rejected_total");
    let counter =
        snapshot.get("counters").and_then(|c| c.get(&counter_name)).and_then(|v| v.as_f64());
    // the stats reply's per-model cumulative count (retired + live)
    let stats_total = last_stats
        .and_then(|raw| Json::parse(raw).ok())
        .and_then(|j| {
            j.get("stats")?.as_arr()?.iter().find_map(|row| {
                (row.get("model")?.as_str()? == model)
                    .then(|| row.get("total_rejects")?.as_f64())
                    .flatten()
            })
        })
        .unwrap_or(0.0) as u64;
    let counter = match counter {
        Some(v) => v as u64,
        // a proxy answers `metrics` locally and its snapshot has no
        // server-side admission counters: absence is "nothing to
        // cross-check", not an error (the e2e tests and CI assert
        // presence where the target is known to be a server)
        None => return Ok(None),
    };
    // >= rather than ==: the registry is process-global, so other routers
    // for the same model name (an earlier in-process replica sweep, a
    // prior server in the same test process) add to the same counter
    if counter < stats_total {
        return Err(format!(
            "admission counter cross-check failed: registry {counter_name:?} = {counter} but \
             the stats reply counts {stats_total} rejects for model {model:?}"
        ));
    }
    Ok(Some(counter))
}

/// Resolve which served model to target: the named one, or the single
/// served model when unnamed.
fn pick_target<'a>(served: &'a [WireModel], want: Option<&str>) -> Result<&'a WireModel, String> {
    match want {
        Some(name) => served.iter().find(|m| m.name == name).ok_or_else(|| {
            let have: Vec<&str> = served.iter().map(|m| m.name.as_str()).collect();
            format!("server does not serve {name:?}; serving: {}", have.join(", "))
        }),
        None => match served.len() {
            1 => Ok(&served[0]),
            0 => Err("server serves no models".to_string()),
            _ => {
                let have: Vec<&str> = served.iter().map(|m| m.name.as_str()).collect();
                Err(format!(
                    "server serves several models ({}); pick one with --model",
                    have.join(", ")
                ))
            }
        },
    }
}

/// What every trial shares; bundled so [`run_trial`] stays callable with
/// the per-trial knobs (target address, client count, protocol) alone.
struct TrialCtx<'a> {
    cfg: &'a LoadgenConfig,
    model_name: &'a str,
    source: &'a SyntheticSource,
    local: Option<&'a dyn Model>,
}

/// What each client thread brings home.
struct ClientOut {
    latencies: Vec<f64>,
    retries: usize,
    mismatches: usize,
    /// reply bit patterns in request order (compare mode only)
    ys: Vec<Vec<u64>>,
}

/// One predict round-trip with the retry-on-backpressure loop, over
/// whichever protocol the connection runs. `tid == 0` builds the exact
/// untraced bytes (the traced builders degrade byte-identically at 0);
/// a nonzero tid rides the `"tid"` field / GZF2 header and closes a
/// `loadgen/predict` span on success.
fn predict_roundtrip(
    conn: &mut ClientConn,
    model_name: &str,
    x: &[f64],
    binary: bool,
    tid: u64,
    retries: &mut usize,
) -> Result<Vec<f64>, String> {
    let t0 = Instant::now();
    if binary {
        let req = frame::frame_traced(&frame::predict_payload(Some(model_name), x), tid);
        loop {
            let reply = conn.roundtrip_frame(&req)?;
            match frame::parse_reply(frame::payload(&reply))? {
                frame::FrameReply::Ok { y } => {
                    if tid != 0 {
                        crate::obs::trace::record_since("loadgen", "predict", tid, t0);
                    }
                    return Ok(y);
                }
                frame::FrameReply::Err { msg, retry } => {
                    if !retry || *retries >= 10_000 {
                        return Err(msg);
                    }
                    *retries += 1;
                    std::thread::sleep(Duration::from_micros(200));
                }
                frame::FrameReply::Pong => {
                    return Err("unexpected pong reply to a predict frame".to_string());
                }
            }
        }
    } else {
        let line = wire::predict_request_traced(Some(model_name), x, tid);
        loop {
            let reply = conn.roundtrip(&line)?;
            if reply.ok {
                if tid != 0 {
                    crate::obs::trace::record_since("loadgen", "predict", tid, t0);
                }
                return reply.y();
            }
            if !reply.retry || *retries >= 10_000 {
                return Err(reply.error.unwrap_or_else(|| "server error".to_string()));
            }
            *retries += 1;
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

/// One trial: `n_clients` connections × `requests_per_client` requests.
/// With `collect`, the second return value holds every reply's bit
/// pattern indexed `client * requests + request` — what compare mode
/// diffs across protocols.
fn run_trial(
    ctx: &TrialCtx<'_>,
    addr: &str,
    n_clients: usize,
    binary: bool,
    collect: bool,
) -> Result<(TrialResult, Vec<Vec<u64>>), String> {
    let requests = ctx.cfg.requests_per_client;
    let traced = ctx.cfg.traced;
    let (model_name, source, local) = (ctx.model_name, ctx.source, ctx.local);
    let barrier = Barrier::new(n_clients + 1);
    let mut outs: Vec<Result<ClientOut, String>> = Vec::with_capacity(n_clients);
    let mut wall = 0.0f64;
    std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(n_clients);
        for t in 0..n_clients {
            let barrier = &barrier;
            // small explicit stacks: a 1k–10k client sweep would reserve
            // gigabytes of address space on default 8 MiB stacks
            let join = std::thread::Builder::new()
                .stack_size(512 << 10)
                .spawn_scoped(scope, move || -> Result<ClientOut, String> {
                    // connect (and upgrade) before the barrier: setup cost
                    // is not load. EVERY thread must reach the barrier
                    // exactly once — even on a failed connect — or the
                    // whole trial deadlocks.
                    let conn = ClientConn::connect(addr).and_then(|mut c| {
                        let mut v2 = false;
                        if binary {
                            if traced {
                                // offer GZF2; a peer that declines keeps
                                // the connection GZF1 and this client's
                                // requests go out untraced (tid 0)
                                v2 = c.upgrade_binary_v2()?;
                            } else {
                                c.upgrade_binary()?;
                            }
                        }
                        Ok((c, v2))
                    });
                    barrier.wait();
                    let (mut conn, v2) = conn?;
                    let mint = traced && (!binary || v2);
                    let mut out = ClientOut {
                        latencies: Vec::with_capacity(requests),
                        retries: 0,
                        mismatches: 0,
                        ys: Vec::new(),
                    };
                    for r in 0..requests {
                        let row = t * requests + r;
                        let (x, _y) = source.read_range(row, row + 1)?;
                        let tid =
                            if mint { crate::obs::trace::mint_trace_id() } else { 0 };
                        let t0 = Instant::now();
                        let y = predict_roundtrip(
                            &mut conn,
                            model_name,
                            x.row(0),
                            binary,
                            tid,
                            &mut out.retries,
                        )?;
                        out.latencies.push(t0.elapsed().as_secs_f64());
                        if let Some(model) = local {
                            let expect = model.predict(&x);
                            let same = y.len() == expect.cols()
                                && y.iter()
                                    .zip(expect.row(0))
                                    .all(|(a, b)| a.to_bits() == b.to_bits());
                            if !same {
                                out.mismatches += 1;
                            }
                        }
                        if collect {
                            out.ys.push(y.iter().map(|v| v.to_bits()).collect());
                        }
                    }
                    Ok(out)
                })
                .map_err(|e| format!("spawn loadgen client thread: {e}"));
            match join {
                Ok(j) => joins.push(j),
                Err(e) => outs.push(Err(e)),
            }
        }
        // threads that failed to even spawn still owe the barrier a wait
        for _ in joins.len()..n_clients {
            barrier.wait();
        }
        barrier.wait();
        let t0 = Instant::now();
        for j in joins {
            outs.push(j.join().unwrap_or_else(|_| Err("client thread panicked".to_string())));
        }
        wall = t0.elapsed().as_secs_f64();
    });

    let mut latencies = Vec::with_capacity(n_clients * requests);
    let mut retries = 0;
    let mut mismatches = 0;
    let mut bits = Vec::new();
    for out in outs {
        let out = out?;
        latencies.extend(out.latencies);
        retries += out.retries;
        mismatches += out.mismatches;
        bits.extend(out.ys);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let total = latencies.len();
    let trial = TrialResult {
        clients: n_clients,
        wire: if binary { "binary" } else { "json" },
        requests: total,
        wall_secs: wall,
        throughput_rps: total as f64 / wall.max(1e-12),
        p50_us: pct(&latencies, 50, 100) * 1e6,
        p95_us: pct(&latencies, 95, 100) * 1e6,
        p99_us: pct(&latencies, 99, 100) * 1e6,
        retries,
        mismatches,
        cross_mismatches: 0,
    };
    Ok((trial, bits))
}
