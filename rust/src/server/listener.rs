//! TCP accept loop and per-connection reader/writer threads.
//!
//! Each accepted connection gets **two** threads: a reader that parses
//! request lines and dispatches them (routing, admission, batcher submit
//! — none of which block), and a writer that awaits each dispatched
//! reply **in request order** and writes it back. Splitting the two is
//! what makes the protocol pipelined: a client may write many requests
//! without waiting, and consecutive requests from one connection land in
//! the same dynamic batch — the same amortization the paper's recurrence
//! gets from batched rows.
//!
//! Concurrency is bounded in two places, both sized from the
//! [`exec::Pool`](crate::exec::Pool) policy by default: the connection
//! budget (`max_conns`, default 8× the pool width — beyond it a
//! connection gets one `"retry":true` line and is closed), and per-model
//! admission ([`super::admission`]). The batch *compute* itself draws
//! from the global pool inside `PredictionService`, so reader/writer
//! threads stay I/O-only — the blocking discipline of DESIGN.md §2b.
//!
//! Because every request byte is client-controlled, the connection
//! itself is bounded too: a request line may not exceed
//! [`MAX_LINE_BYTES`] (an overlong line gets an error reply and the
//! connection closes — there is no way to resynchronize mid-line); the
//! idle timeout bounds both the gap between reads *and* the assembly of
//! a single line (a byte-per-interval drip would never trip a plain
//! SO_RCVTIMEO), so half-open and slow-loris clients release their
//! `max_conns` slot; the reply queue is a bounded `sync_channel`
//! (admission bounds predicts, but ping/stats/error replies bypass it —
//! a flooder that never reads its socket now blocks the reader instead
//! of growing the queue) and the matching write timeout turns a
//! permanently-stalled writer into a closed connection. The wire
//! `shutdown` command is honored only from loopback peers (including
//! IPv4-mapped loopback on dual-stack binds) unless the server was
//! started with `allow_remote_shutdown`.

use super::router::{Dispatch, Router};
use super::wire;
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Longest accepted request line (1 MiB — orders of magnitude beyond any
/// legitimate predict request). Without a cap, a client that streams
/// bytes without ever sending a newline grows the line buffer without
/// bound, bypassing both the connection budget and per-model admission.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Per-connection bound on dispatched-but-unwritten replies. Admission
/// bounds admitted predicts, but the cheap commands (ping/models/stats,
/// error replies) bypass admission — without this bound, a client that
/// floods commands and never reads its socket grows the reply queue
/// without limit. When it fills, the reader blocks, which stops reading
/// the socket: backpressure, not memory growth.
const REPLY_QUEUE_BOUND: usize = 256;

/// State shared by the accept loop, every connection thread, the
/// hot-reload poller and the [`Server`](super::Server) handle.
pub(crate) struct Shared {
    pub router: Router,
    pub shutdown: AtomicBool,
    pub active_conns: AtomicUsize,
    pub max_conns: usize,
    pub addr: SocketAddr,
    /// close a connection after this long with no request bytes, so a
    /// silent half-open client cannot pin its reader thread and
    /// connection-budget slot forever; `None` disables the policy
    pub idle_timeout: Option<Duration>,
    /// honor the wire `shutdown` command from non-loopback peers (off by
    /// default: with `--addr` on a public interface, an unauthenticated
    /// shutdown would be a one-line remote kill switch)
    pub allow_remote_shutdown: bool,
}

impl Shared {
    /// Begin shutdown exactly once: flip the flag and unblock the
    /// blocking `accept` with a throwaway self-connection. A wildcard
    /// bind (`0.0.0.0` / `::`) is not connectable on every platform, so
    /// the probe targets the matching loopback instead.
    pub(crate) fn begin_shutdown(&self) {
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            let mut addr = self.addr;
            if addr.ip().is_unspecified() {
                addr.set_ip(match addr {
                    SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(addr);
        }
    }
}

/// Accept until shutdown. Runs on the server's accept thread.
pub(crate) fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue, // transient accept failure; keep serving
        };
        // connection budget: reply-and-close instead of stalling the
        // accept queue (a client that sees "retry":true may back off)
        if shared.active_conns.fetch_add(1, Ordering::AcqRel) >= shared.max_conns {
            crate::obs::counter("server.conns_rejected").inc();
            crate::obs::warn(
                "server.listener",
                "connection rejected: at the connection budget",
                &[("max_conns", shared.max_conns.into())],
            );
            let mut s = &stream;
            let _ = writeln!(
                s,
                "{}",
                wire::overload_reply(&format!(
                    "server is at its connection budget ({}); retry after backoff",
                    shared.max_conns
                ))
            );
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
            continue;
        }
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            handle_conn(stream, &shared);
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
        });
    }
}

/// What the reader hands the writer, one entry per request line, in
/// order.
enum Outgoing {
    /// a complete reply line
    Line(String),
    /// an admitted predict: await the batcher, then reply
    Reply { model: String, rx: Receiver<Vec<f64>>, guard: super::admission::AdmissionGuard },
    /// write the line, then close the connection (shutdown ack)
    Last(String),
}

fn handle_conn(stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true); // request/reply lines, not bulk data
    if let Some(idle) = shared.idle_timeout {
        // the write twin of the read-side idle policy: a client that
        // stops draining its socket stalls the writer; past the budget
        // the write errors, the writer exits, and the blocked reader's
        // send fails — the connection slot is released, not pinned
        let _ = stream.set_write_timeout(Some(idle));
    }
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = sync_channel::<Outgoing>(REPLY_QUEUE_BOUND);
    let reader_shared = Arc::clone(shared);
    let reader = std::thread::spawn(move || read_loop(reader_stream, &reader_shared, tx));
    write_loop(stream, rx);
    let _ = reader.join();
}

/// Loopback test for the shutdown gate that also recognizes IPv4-mapped
/// loopback (`::ffff:127.0.0.1`) — what a `127.0.0.1` client looks like
/// to a dual-stack `[::]` bind. Shared with the dist proxy, whose wire
/// `shutdown` fans out to every replica and so gets the same gate.
pub fn is_loopback_ip(ip: IpAddr) -> bool {
    match ip {
        IpAddr::V4(a) => a.is_loopback(),
        IpAddr::V6(a) => {
            if a.is_loopback() {
                return true;
            }
            let o = a.octets();
            o[..10] == [0u8; 10] && o[10..12] == [0xff, 0xff] && o[12] == 127
        }
    }
}

/// How one bounded line read ended.
#[derive(Debug, PartialEq, Eq)]
pub enum LineRead {
    /// `buf` holds one complete line (no trailing newline)
    Line,
    /// clean end of stream with nothing buffered
    Eof,
    /// the read-gap timeout fired, or a drip-fed line outlived the
    /// per-line deadline
    Idle,
    /// the line exceeded `max_len` with no newline in sight
    Overlong,
    /// I/O error: client gone / broken pipe
    Gone,
}

/// Read one newline-terminated line into `buf`, enforcing the `max_len`
/// cap and — because SO_RCVTIMEO only bounds the gap between reads, so a
/// client dripping one byte per interval would never trip it — a
/// deadline on assembling a single line. Generic over [`BufRead`]: the
/// serving listener reads sockets with [`MAX_LINE_BYTES`], the dist
/// layer reuses the same bounded reader with its larger frame cap
/// (per-shard `RidgeStats` frames carry an F×F Gram block).
pub fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
    max_len: usize,
    line_deadline: Option<Duration>,
) -> LineRead {
    buf.clear();
    let mut started: Option<Instant> = None;
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) if c.is_empty() => {
                // EOF: a final unterminated line still gets served
                return if buf.is_empty() { LineRead::Eof } else { LineRead::Line };
            }
            Ok(c) => c,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return LineRead::Idle;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue, // EINTR: retry
            Err(_) => return LineRead::Gone,
        };
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            if buf.len() + pos > max_len {
                return LineRead::Overlong;
            }
            buf.extend_from_slice(&chunk[..pos]);
            reader.consume(pos + 1);
            return LineRead::Line;
        }
        let n = chunk.len();
        if buf.len() + n > max_len {
            return LineRead::Overlong;
        }
        buf.extend_from_slice(chunk);
        reader.consume(n);
        match (started, line_deadline) {
            (None, _) => started = Some(Instant::now()),
            (Some(t0), Some(deadline)) if t0.elapsed() > deadline => return LineRead::Idle,
            _ => {}
        }
    }
}

fn read_loop(stream: TcpStream, shared: &Arc<Shared>, out: SyncSender<Outgoing>) {
    let idle = shared.idle_timeout;
    if let Some(idle) = idle {
        let _ = stream.set_read_timeout(Some(idle));
    }
    let peer_is_loopback = stream.peer_addr().map(|a| is_loopback_ip(a.ip())).unwrap_or(false);
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES, idle) {
            LineRead::Line => {}
            LineRead::Eof | LineRead::Gone => break,
            LineRead::Idle => {
                // tell the client why, then release the budget slot
                let _ = out.send(Outgoing::Last(wire::error_reply(
                    "idle timeout; closing connection",
                )));
                break;
            }
            LineRead::Overlong => {
                // there is no way to resynchronize mid-line: reply, close
                let _ = out.send(Outgoing::Last(wire::error_reply(&format!(
                    "request line exceeds {MAX_LINE_BYTES} bytes; closing connection"
                ))));
                break;
            }
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(l) => l.trim(),
            Err(_) => {
                if out.send(Outgoing::Line(wire::error_reply("request is not UTF-8"))).is_err() {
                    break;
                }
                continue;
            }
        };
        if line.is_empty() {
            continue;
        }
        let outgoing = match wire::parse_request(line) {
            Err(e) => Outgoing::Line(wire::error_reply(&e)),
            Ok(wire::Request::Ping) => Outgoing::Line(wire::ping_reply()),
            Ok(wire::Request::Models) => Outgoing::Line(shared.router.models_reply()),
            Ok(wire::Request::Stats) => Outgoing::Line(shared.router.stats_reply()),
            Ok(wire::Request::Metrics) => Outgoing::Line(wire::metrics_reply()),
            Ok(wire::Request::Shutdown) => {
                if !peer_is_loopback && !shared.allow_remote_shutdown {
                    crate::obs::warn(
                        "server.listener",
                        "shutdown refused from a non-loopback peer",
                        &[],
                    );
                    Outgoing::Line(wire::error_reply(
                        "shutdown refused from a non-loopback peer (the server \
                         must opt in with --allow-remote-shutdown)",
                    ))
                } else {
                    crate::obs::info("server.listener", "wire shutdown accepted", &[]);
                    let _ = out.send(Outgoing::Last(wire::shutdown_reply()));
                    shared.begin_shutdown();
                    break;
                }
            }
            Ok(wire::Request::Predict { model, x }) => {
                match shared.router.dispatch_predict(model.as_deref(), &x) {
                    Dispatch::Immediate(reply) => Outgoing::Line(reply),
                    Dispatch::Pending { model, rx, guard } => {
                        Outgoing::Reply { model, rx, guard }
                    }
                }
            }
        };
        if out.send(outgoing).is_err() {
            break; // writer exited (socket error): stop reading
        }
    }
    // dropping `out` lets the writer drain what is pending, then exit
}

fn write_loop(stream: TcpStream, rx: Receiver<Outgoing>) {
    let mut w = BufWriter::new(stream);
    loop {
        // Flush only when no reply is immediately ready: pipelined
        // clients get batched writes, a lone request is never delayed.
        let next = match rx.try_recv() {
            Ok(o) => o,
            Err(TryRecvError::Empty) => {
                if w.flush().is_err() {
                    return;
                }
                match rx.recv() {
                    Ok(o) => o,
                    Err(_) => return, // reader done, everything drained
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        let mut last = false;
        let line = match next {
            Outgoing::Line(l) => l,
            Outgoing::Last(l) => {
                last = true;
                l
            }
            Outgoing::Reply { model, rx: reply_rx, guard } => {
                let line = match reply_rx.recv() {
                    Ok(y) => wire::predict_reply(&model, &y)
                        .unwrap_or_else(|e| wire::error_reply(&e)),
                    Err(_) => {
                        // the route was swapped out mid-flight and its
                        // service exited: rare, and retriable by contract
                        wire::overload_reply(&format!(
                            "model {model:?} was reloaded mid-request; retry"
                        ))
                    }
                };
                drop(guard); // release the admission slot with the reply in hand
                line
            }
        };
        if writeln!(w, "{line}").is_err() {
            return;
        }
        if last {
            break;
        }
    }
    let _ = w.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_gate_recognizes_plain_and_ipv4_mapped_loopback() {
        let yes = ["127.0.0.1", "127.8.9.1", "::1", "::ffff:127.0.0.1", "::ffff:127.1.2.3"];
        for a in yes {
            assert!(is_loopback_ip(a.parse().unwrap()), "{a} should gate as loopback");
        }
        let no = ["10.0.0.1", "8.8.8.8", "::ffff:10.0.0.1", "2001:db8::1", "::"];
        for a in no {
            assert!(!is_loopback_ip(a.parse().unwrap()), "{a} must not gate as loopback");
        }
    }
}
