//! TCP accept loop and per-connection reader/writer threads.
//!
//! Each accepted connection gets **two** threads: a reader that parses
//! request lines and dispatches them (routing, admission, batcher submit
//! — none of which block), and a writer that awaits each dispatched
//! reply **in request order** and writes it back. Splitting the two is
//! what makes the protocol pipelined: a client may write many requests
//! without waiting, and consecutive requests from one connection land in
//! the same dynamic batch — the same amortization the paper's recurrence
//! gets from batched rows.
//!
//! Concurrency is bounded in two places, both sized from the
//! [`exec::Pool`](crate::exec::Pool) policy by default: the connection
//! budget (`max_conns`, default 8× the pool width — beyond it a
//! connection gets one `"retry":true` line and is closed), and per-model
//! admission ([`super::admission`]). The batch *compute* itself draws
//! from the global pool inside `PredictionService`, so reader/writer
//! threads stay I/O-only — the blocking discipline of DESIGN.md §2b.

use super::router::{Dispatch, Router};
use super::wire;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

/// State shared by the accept loop, every connection thread, the
/// hot-reload poller and the [`Server`](super::Server) handle.
pub(crate) struct Shared {
    pub router: Router,
    pub shutdown: AtomicBool,
    pub active_conns: AtomicUsize,
    pub max_conns: usize,
    pub addr: SocketAddr,
}

impl Shared {
    /// Begin shutdown exactly once: flip the flag and unblock the
    /// blocking `accept` with a throwaway self-connection. A wildcard
    /// bind (`0.0.0.0` / `::`) is not connectable on every platform, so
    /// the probe targets the matching loopback instead.
    pub(crate) fn begin_shutdown(&self) {
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            let mut addr = self.addr;
            if addr.ip().is_unspecified() {
                addr.set_ip(match addr {
                    SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(addr);
        }
    }
}

/// Accept until shutdown. Runs on the server's accept thread.
pub(crate) fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue, // transient accept failure; keep serving
        };
        // connection budget: reply-and-close instead of stalling the
        // accept queue (a client that sees "retry":true may back off)
        if shared.active_conns.fetch_add(1, Ordering::AcqRel) >= shared.max_conns {
            let mut s = &stream;
            let _ = writeln!(
                s,
                "{}",
                wire::overload_reply(&format!(
                    "server is at its connection budget ({}); retry after backoff",
                    shared.max_conns
                ))
            );
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
            continue;
        }
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            handle_conn(stream, &shared);
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
        });
    }
}

/// What the reader hands the writer, one entry per request line, in
/// order.
enum Outgoing {
    /// a complete reply line
    Line(String),
    /// an admitted predict: await the batcher, then reply
    Reply { model: String, rx: Receiver<Vec<f64>>, guard: super::admission::AdmissionGuard },
    /// write the line, then close the connection (shutdown ack)
    Last(String),
}

fn handle_conn(stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true); // request/reply lines, not bulk data
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = channel::<Outgoing>();
    let reader_shared = Arc::clone(shared);
    let reader = std::thread::spawn(move || read_loop(reader_stream, &reader_shared, tx));
    write_loop(stream, rx);
    let _ = reader.join();
}

fn read_loop(stream: TcpStream, shared: &Arc<Shared>, out: Sender<Outgoing>) {
    for line in BufReader::new(stream).lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client gone / broken pipe
        };
        if line.trim().is_empty() {
            continue;
        }
        let outgoing = match wire::parse_request(&line) {
            Err(e) => Outgoing::Line(wire::error_reply(&e)),
            Ok(wire::Request::Ping) => Outgoing::Line(wire::ping_reply()),
            Ok(wire::Request::Models) => Outgoing::Line(shared.router.models_reply()),
            Ok(wire::Request::Stats) => Outgoing::Line(shared.router.stats_reply()),
            Ok(wire::Request::Shutdown) => {
                let _ = out.send(Outgoing::Last(wire::shutdown_reply()));
                shared.begin_shutdown();
                break;
            }
            Ok(wire::Request::Predict { model, x }) => {
                match shared.router.dispatch_predict(model.as_deref(), &x) {
                    Dispatch::Immediate(reply) => Outgoing::Line(reply),
                    Dispatch::Pending { model, rx, guard } => {
                        Outgoing::Reply { model, rx, guard }
                    }
                }
            }
        };
        if out.send(outgoing).is_err() {
            break; // writer exited (socket error): stop reading
        }
    }
    // dropping `out` lets the writer drain what is pending, then exit
}

fn write_loop(stream: TcpStream, rx: Receiver<Outgoing>) {
    let mut w = BufWriter::new(stream);
    loop {
        // Flush only when no reply is immediately ready: pipelined
        // clients get batched writes, a lone request is never delayed.
        let next = match rx.try_recv() {
            Ok(o) => o,
            Err(TryRecvError::Empty) => {
                if w.flush().is_err() {
                    return;
                }
                match rx.recv() {
                    Ok(o) => o,
                    Err(_) => return, // reader done, everything drained
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        let mut last = false;
        let line = match next {
            Outgoing::Line(l) => l,
            Outgoing::Last(l) => {
                last = true;
                l
            }
            Outgoing::Reply { model, rx: reply_rx, guard } => {
                let line = match reply_rx.recv() {
                    Ok(y) => wire::predict_reply(&model, &y)
                        .unwrap_or_else(|e| wire::error_reply(&e)),
                    Err(_) => {
                        // the route was swapped out mid-flight and its
                        // service exited: rare, and retriable by contract
                        wire::overload_reply(&format!(
                            "model {model:?} was reloaded mid-request; retry"
                        ))
                    }
                };
                drop(guard); // release the admission slot with the reply in hand
                line
            }
        };
        if writeln!(w, "{line}").is_err() {
            return;
        }
        if last {
            break;
        }
    }
    let _ = w.flush();
}
