//! TCP accept loop and the state the serving tier shares.
//!
//! Connections are **not** handled here anymore: the accept loop's only
//! job is the connection budget and handing each accepted socket to one
//! of the event loops (round-robin — see [`super::mux`]), which own the
//! per-connection state machines. Thread count is O(event-loops +
//! exec pool), independent of connection count; that is what lifts the
//! realistic concurrency ceiling from hundreds (two OS threads per
//! connection) to the 1k–10k range the C10K bench sweeps.
//!
//! Concurrency is bounded in two places, both sized from the
//! [`exec::Pool`](crate::exec::Pool) policy by default: the connection
//! budget (`max_conns`, default 8× the pool width — beyond it a
//! connection gets one `"retry":true` line and is closed), and per-model
//! admission ([`super::admission`]). The batch *compute* itself draws
//! from the global pool inside `PredictionService`, so event-loop
//! threads stay I/O-only — the blocking discipline of DESIGN.md §2b.
//!
//! Every hardening bound on client-controlled bytes (the 1 MiB line
//! cap, the idle/assembly deadlines, reply backpressure, the
//! loopback-gated `shutdown`) lives on in the event loops — the mux
//! module doc maps each bound to its state transition. This module
//! keeps the bounded line reader itself ([`read_line_bounded`]), which
//! the dist layer's blocking sockets still use with their own frame
//! cap.

use super::mux::LoopHandle;
use super::router::Router;
use super::wire;
use std::io::{BufRead, ErrorKind, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Longest accepted request line (1 MiB — orders of magnitude beyond any
/// legitimate predict request). Without a cap, a client that streams
/// bytes without ever sending a newline grows the line buffer without
/// bound, bypassing both the connection budget and per-model admission.
/// The binary frame mode caps its payloads at the same bound.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// State shared by the accept loop, the event loops, the hot-reload
/// poller and the [`Server`](super::Server) handle.
pub(crate) struct Shared {
    pub router: Router,
    pub shutdown: AtomicBool,
    pub active_conns: AtomicUsize,
    pub max_conns: usize,
    pub addr: SocketAddr,
    /// close a connection after this long with no request bytes, so a
    /// silent half-open client cannot pin its connection-budget slot
    /// forever; `None` disables the policy
    pub idle_timeout: Option<Duration>,
    /// honor the wire `shutdown` command from non-loopback peers (off by
    /// default: with `--addr` on a public interface, an unauthenticated
    /// shutdown would be a one-line remote kill switch)
    pub allow_remote_shutdown: bool,
    /// the event loops; the accept loop deals connections round-robin
    /// and `begin_shutdown` rings every waker
    pub loops: Vec<Arc<LoopHandle>>,
}

impl Shared {
    /// Begin shutdown exactly once: flip the flag, unblock the blocking
    /// `accept` with a throwaway self-connection, and wake every event
    /// loop so each drains its in-flight replies and exits. A wildcard
    /// bind (`0.0.0.0` / `::`) is not connectable on every platform, so
    /// the probe targets the matching loopback instead.
    pub(crate) fn begin_shutdown(&self) {
        if !self.shutdown.swap(true, Ordering::AcqRel) {
            let mut addr = self.addr;
            if addr.ip().is_unspecified() {
                addr.set_ip(match addr {
                    SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                    SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
                });
            }
            let _ = TcpStream::connect(addr);
            for l in &self.loops {
                l.wake();
            }
        }
    }
}

/// Accept until shutdown. Runs on the server's accept thread.
pub(crate) fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut next = 0usize;
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue, // transient accept failure; keep serving
        };
        // connection budget: reply-and-close instead of stalling the
        // accept queue (a client that sees "retry":true may back off)
        if shared.active_conns.fetch_add(1, Ordering::AcqRel) >= shared.max_conns {
            crate::obs::counter("server.conns_rejected").inc();
            crate::obs::warn(
                "server.listener",
                "connection rejected: at the connection budget",
                &[("max_conns", shared.max_conns.into())],
            );
            let mut s = &stream;
            let _ = writeln!(
                s,
                "{}",
                wire::overload_reply(&format!(
                    "server is at its connection budget ({}); retry after backoff",
                    shared.max_conns
                ))
            );
            shared.active_conns.fetch_sub(1, Ordering::AcqRel);
            continue;
        }
        // round-robin across the event loops; the loop owns the
        // connection (and its budget slot) from here
        shared.loops[next % shared.loops.len()].enqueue_conn(stream);
        next = next.wrapping_add(1);
    }
}

/// Loopback test for the shutdown gate that also recognizes IPv4-mapped
/// loopback (`::ffff:127.0.0.1`) — what a `127.0.0.1` client looks like
/// to a dual-stack `[::]` bind. Shared with the dist proxy, whose wire
/// `shutdown` fans out to every replica and so gets the same gate.
pub fn is_loopback_ip(ip: IpAddr) -> bool {
    match ip {
        IpAddr::V4(a) => a.is_loopback(),
        IpAddr::V6(a) => {
            if a.is_loopback() {
                return true;
            }
            let o = a.octets();
            o[..10] == [0u8; 10] && o[10..12] == [0xff, 0xff] && o[12] == 127
        }
    }
}

/// How one bounded line read ended.
#[derive(Debug, PartialEq, Eq)]
pub enum LineRead {
    /// `buf` holds one complete line (no trailing newline)
    Line,
    /// clean end of stream with nothing buffered
    Eof,
    /// the read-gap timeout fired, or a drip-fed line outlived the
    /// per-line deadline
    Idle,
    /// the line exceeded `max_len` with no newline in sight
    Overlong,
    /// I/O error: client gone / broken pipe
    Gone,
}

/// Read one newline-terminated line into `buf`, enforcing the `max_len`
/// cap and — because SO_RCVTIMEO only bounds the gap between reads, so a
/// client dripping one byte per interval would never trip it — a
/// deadline on assembling a single line. Generic over [`BufRead`]: the
/// dist layer reads its blocking sockets with its own frame cap
/// (per-shard `RidgeStats` frames carry an F×F Gram block); the serving
/// listener's event loops enforce the same bounds on their nonblocking
/// receive buffers instead (see [`super::mux`]).
pub fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
    max_len: usize,
    line_deadline: Option<Duration>,
) -> LineRead {
    buf.clear();
    let mut started: Option<Instant> = None;
    loop {
        let chunk = match reader.fill_buf() {
            Ok(c) if c.is_empty() => {
                // EOF: a final unterminated line still gets served
                return if buf.is_empty() { LineRead::Eof } else { LineRead::Line };
            }
            Ok(c) => c,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return LineRead::Idle;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue, // EINTR: retry
            Err(_) => return LineRead::Gone,
        };
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            if buf.len() + pos > max_len {
                return LineRead::Overlong;
            }
            buf.extend_from_slice(&chunk[..pos]);
            reader.consume(pos + 1);
            return LineRead::Line;
        }
        let n = chunk.len();
        if buf.len() + n > max_len {
            return LineRead::Overlong;
        }
        buf.extend_from_slice(chunk);
        reader.consume(n);
        match (started, line_deadline) {
            (None, _) => started = Some(Instant::now()),
            (Some(t0), Some(deadline)) if t0.elapsed() > deadline => return LineRead::Idle,
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_gate_recognizes_plain_and_ipv4_mapped_loopback() {
        let yes = ["127.0.0.1", "127.8.9.1", "::1", "::ffff:127.0.0.1", "::ffff:127.1.2.3"];
        for a in yes {
            assert!(is_loopback_ip(a.parse().unwrap()), "{a} should gate as loopback");
        }
        let no = ["10.0.0.1", "8.8.8.8", "::ffff:10.0.0.1", "2001:db8::1", "::"];
        for a in no {
            assert!(!is_loopback_ip(a.parse().unwrap()), "{a} must not gate as loopback");
        }
    }
}
