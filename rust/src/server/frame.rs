//! Length-prefixed binary frames for the serving wire — the optional
//! per-connection fast path negotiated with the JSON `{"cmd":"binary"}`
//! upgrade (see [`super::wire`]).
//!
//! Frame layout, both directions, little-endian throughout (the same
//! float convention as the `GZKBIN01` dataset format in
//! [`crate::data`] — an `f64` crosses the wire as its 8 raw LE bytes, so
//! bit-exactness is free, no shortest-round-trip formatting needed):
//!
//! ```text
//! GZF1: magic "GZF1" (4 bytes) | payload_len u32 LE | payload
//! GZF2: magic "GZF2" (4 bytes) | payload_len u32 LE | tid u64 LE | payload
//!
//! request payload:
//!   op u8: 1 = predict | 2 = ping
//!   predict: model_len u16 LE | model utf8 (0 bytes = the single served
//!            model) | count u32 LE | count x f64 LE
//! reply payload:
//!   status u8: 0 = ok | 1 = error | 2 = overload ("retry":true twin)
//!              | 3 = pong
//!   ok:           count u32 LE | count x f64 LE
//!   error/retry:  utf8 message (the rest of the payload)
//!   pong:         empty
//! ```
//!
//! The payload cap is [`MAX_FRAME_PAYLOAD`] (= the JSON line cap: the
//! two modes bound a hostile client identically — the dist layer's
//! "cap every length you read off the wire" discipline,
//! [`crate::dist::wire::MAX_FRAME_BYTES`]). A length prefix beyond the
//! cap, a wrong magic, or a malformed payload each degrade to an error
//! reply or a closed connection, never an allocation sized by the
//! attacker: [`scan`] rejects the header *before* any payload buffer
//! exists.
//!
//! **GZF2** (negotiated with `{"cmd":"binary","v":2}` — see
//! [`super::wire`]) widens the request header by a fixed 8-byte
//! little-endian distributed trace ID slot (0 = untraced). The payload
//! grammar is unchanged, and the ID is observability metadata only —
//! the reply to a GZF2 request is a plain GZF1 frame with bytes
//! identical to the untraced case. A server that acked `"v":2` stays
//! liberal and accepts both magics on the same connection; a client
//! whose upgrade ack came back without `"v":2` must stick to GZF1.

use super::listener::MAX_LINE_BYTES;

/// Frame magic: "GZK Frame v1". A JSON client that accidentally writes a
/// line to a frame-mode connection fails the magic check on byte one.
pub const MAGIC: [u8; 4] = *b"GZF1";

/// Frame magic: "GZK Frame v2" — the trace-carrying header.
pub const MAGIC2: [u8; 4] = *b"GZF2";

/// Header bytes preceding a GZF1 payload: magic + u32 length.
pub const HEADER_BYTES: usize = 8;

/// Header bytes preceding a GZF2 payload: magic + u32 length + u64 tid.
pub const HEADER2_BYTES: usize = 16;

/// Largest accepted payload — the JSON line cap, so switching modes
/// never widens the hostile-input surface.
pub const MAX_FRAME_PAYLOAD: usize = MAX_LINE_BYTES;

/// Request op: predict one point.
pub const OP_PREDICT: u8 = 1;
/// Request op: liveness probe.
pub const OP_PING: u8 = 2;

/// Reply status: prediction follows.
pub const ST_OK: u8 = 0;
/// Reply status: non-retriable error, utf8 message follows.
pub const ST_ERR: u8 = 1;
/// Reply status: backpressure — retry after backoff is safe (the binary
/// twin of the JSON `"retry":true` contract).
pub const ST_RETRY: u8 = 2;
/// Reply status: pong.
pub const ST_PONG: u8 = 3;

/// What [`scan`] found at the head of a receive buffer.
#[derive(Debug, PartialEq, Eq)]
pub enum Scan {
    /// not enough bytes yet for a verdict; keep reading
    Incomplete,
    /// one complete frame of `total` bytes is buffered; the payload
    /// starts at `header` and `tid` is the trace ID (0 for GZF1)
    Frame { total: usize, header: usize, tid: u64 },
    /// the buffer starts with neither [`MAGIC`] nor [`MAGIC2`] —
    /// unrecoverable framing
    BadMagic,
    /// the length prefix exceeds [`MAX_FRAME_PAYLOAD`]
    Oversized(usize),
}

/// Classify the head of `buf` without allocating. Magic bytes are
/// checked as soon as they arrive (a flood of garbage is rejected at
/// byte one, not after 8 — GZF1 and GZF2 share the first three bytes,
/// so the verdict is only deferred to byte four between the two), and
/// an oversized length prefix is rejected from the header alone — no
/// payload buffer is ever sized by it.
pub fn scan(buf: &[u8]) -> Scan {
    let probe = buf.len().min(MAGIC.len());
    let v2 = if buf[..probe] == MAGIC[..probe] {
        // could still become GZF2 at byte four, but as a *prefix* the
        // two are indistinguishable until then; treat as GZF1-so-far
        false
    } else if buf[..probe] == MAGIC2[..probe] {
        true
    } else {
        return Scan::BadMagic;
    };
    let header = if v2 { HEADER2_BYTES } else { HEADER_BYTES };
    if buf.len() < header {
        return Scan::Incomplete;
    }
    let len = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Scan::Oversized(len);
    }
    let tid = if v2 {
        u64::from_le_bytes([
            buf[8], buf[9], buf[10], buf[11], buf[12], buf[13], buf[14], buf[15],
        ])
    } else {
        0
    };
    let total = header + len;
    if buf.len() < total {
        return Scan::Incomplete;
    }
    Scan::Frame { total, header, tid }
}

/// Wrap a payload in a framed header. Panics (programmer error, not
/// client input) if the payload exceeds the cap — every in-crate payload
/// builder stays far below it.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME_PAYLOAD, "frame payload exceeds the wire cap");
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Wrap a payload in a GZF2 header carrying `tid`. `tid` 0 degrades to
/// a plain GZF1 frame so an untraced request is byte-identical whether
/// it went through the traced builder or not.
pub fn frame_traced(payload: &[u8], tid: u64) -> Vec<u8> {
    if tid == 0 {
        return frame(payload);
    }
    assert!(payload.len() <= MAX_FRAME_PAYLOAD, "frame payload exceeds the wire cap");
    let mut out = Vec::with_capacity(HEADER2_BYTES + payload.len());
    out.extend_from_slice(&MAGIC2);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&tid.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// The payload slice of a complete frame (as returned by [`scan`] /
/// [`read_frame`]) — magic-aware, so both GZF1 and GZF2 frames work.
pub fn payload(frame: &[u8]) -> &[u8] {
    if frame.len() >= 4 && frame[..4] == MAGIC2 {
        &frame[HEADER2_BYTES..]
    } else {
        &frame[HEADER_BYTES..]
    }
}

/// The trace ID of a complete frame (0 for GZF1).
pub fn frame_tid(frame: &[u8]) -> u64 {
    if frame.len() >= HEADER2_BYTES && frame[..4] == MAGIC2 {
        u64::from_le_bytes(frame[8..16].try_into().expect("8-byte tid slot"))
    } else {
        0
    }
}

/// One parsed request payload.
#[derive(Debug, PartialEq)]
pub enum FrameRequest {
    Predict { model: Option<String>, x: Vec<f64> },
    Ping,
}

/// One parsed reply payload.
#[derive(Debug, PartialEq)]
pub enum FrameReply {
    Ok { y: Vec<f64> },
    Err { msg: String, retry: bool },
    Pong,
}

/// Build a predict request payload (the client side).
pub fn predict_payload(model: Option<&str>, x: &[f64]) -> Vec<u8> {
    let m = model.unwrap_or("").as_bytes();
    assert!(m.len() <= u16::MAX as usize, "model name exceeds the u16 length field");
    let mut p = Vec::with_capacity(1 + 2 + m.len() + 4 + 8 * x.len());
    p.push(OP_PREDICT);
    p.extend_from_slice(&(m.len() as u16).to_le_bytes());
    p.extend_from_slice(m);
    p.extend_from_slice(&(x.len() as u32).to_le_bytes());
    for v in x {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p
}

/// Build a ping request payload.
pub fn ping_payload() -> Vec<u8> {
    vec![OP_PING]
}

/// Build an ok reply payload carrying the prediction vector.
pub fn ok_payload(y: &[f64]) -> Vec<u8> {
    let mut p = Vec::with_capacity(1 + 4 + 8 * y.len());
    p.push(ST_OK);
    p.extend_from_slice(&(y.len() as u32).to_le_bytes());
    for v in y {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p
}

/// Build an error ([`ST_ERR`]) or backpressure ([`ST_RETRY`]) reply
/// payload.
pub fn status_payload(status: u8, msg: &str) -> Vec<u8> {
    debug_assert!(status == ST_ERR || status == ST_RETRY);
    let mut m = msg.as_bytes();
    if m.len() > MAX_FRAME_PAYLOAD - 1 {
        m = &m[..MAX_FRAME_PAYLOAD - 1]; // truncate, never overflow the cap
    }
    let mut p = Vec::with_capacity(1 + m.len());
    p.push(status);
    p.extend_from_slice(m);
    p
}

/// Build a pong reply payload.
pub fn pong_payload() -> Vec<u8> {
    vec![ST_PONG]
}

/// The status byte of a complete reply frame, if it has one. Replies
/// are always GZF1, but the check is magic-aware for symmetry.
pub fn reply_status(frame: &[u8]) -> Option<u8> {
    if frame.len() >= 4 && frame[..4] == MAGIC2 {
        frame.get(HEADER2_BYTES).copied()
    } else {
        frame.get(HEADER_BYTES).copied()
    }
}

/// Parse a request payload. Every byte is client-controlled: lengths are
/// cross-checked against the actual payload size before any slice, and a
/// non-finite float is refused exactly like the JSON parser refuses
/// `1e999` — frame mode must never widen what can reach the shared
/// batch.
pub fn parse_request(p: &[u8]) -> Result<FrameRequest, String> {
    match p.first().copied() {
        None => Err("empty frame payload".to_string()),
        Some(OP_PING) => {
            if p.len() != 1 {
                return Err("ping frame carries unexpected payload bytes".to_string());
            }
            Ok(FrameRequest::Ping)
        }
        Some(OP_PREDICT) => {
            if p.len() < 3 {
                return Err("predict frame truncated before the model length".to_string());
            }
            let mlen = u16::from_le_bytes([p[1], p[2]]) as usize;
            let xs_at = 3 + mlen;
            if p.len() < xs_at + 4 {
                return Err("predict frame truncated before the value count".to_string());
            }
            let model = match std::str::from_utf8(&p[3..xs_at]) {
                Ok("") => None,
                Ok(m) => Some(m.to_string()),
                Err(_) => return Err("predict frame model name is not UTF-8".to_string()),
            };
            let count =
                u32::from_le_bytes([p[xs_at], p[xs_at + 1], p[xs_at + 2], p[xs_at + 3]]) as usize;
            let body = &p[xs_at + 4..];
            if body.len() != 8 * count {
                return Err(format!(
                    "predict frame declares {count} values but carries {} bytes",
                    body.len()
                ));
            }
            if count == 0 {
                return Err("predict frame \"x\" must not be empty".to_string());
            }
            let x: Vec<f64> = body
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().expect("chunks_exact(8)")))
                .collect();
            if !x.iter().all(|v| v.is_finite()) {
                return Err("predict frame \"x\" contains a non-finite value".to_string());
            }
            Ok(FrameRequest::Predict { model, x })
        }
        Some(op) => Err(format!("unknown frame op {op}; known: 1 = predict, 2 = ping")),
    }
}

/// Parse a reply payload (the client side).
pub fn parse_reply(p: &[u8]) -> Result<FrameReply, String> {
    match p.first().copied() {
        None => Err("empty reply frame payload".to_string()),
        Some(ST_PONG) => Ok(FrameReply::Pong),
        Some(ST_OK) => {
            if p.len() < 5 {
                return Err("ok reply frame truncated before the value count".to_string());
            }
            let count = u32::from_le_bytes([p[1], p[2], p[3], p[4]]) as usize;
            let body = &p[5..];
            if body.len() != 8 * count {
                return Err(format!(
                    "ok reply frame declares {count} values but carries {} bytes",
                    body.len()
                ));
            }
            let y = body
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().expect("chunks_exact(8)")))
                .collect();
            Ok(FrameReply::Ok { y })
        }
        Some(st @ (ST_ERR | ST_RETRY)) => {
            let msg = String::from_utf8_lossy(&p[1..]).into_owned();
            Ok(FrameReply::Err { msg, retry: st == ST_RETRY })
        }
        Some(st) => Err(format!("unknown reply frame status {st}")),
    }
}

/// Read one complete frame from a blocking reader (the client /
/// proxy-upstream side; the server's event loop uses [`scan`] over its
/// nonblocking receive buffer instead). `Ok(None)` is a clean EOF **at a
/// frame boundary**; EOF mid-frame is an error. The length prefix is
/// validated against the cap before the payload buffer is allocated.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> Result<Option<Vec<u8>>, String> {
    let mut header = [0u8; HEADER_BYTES];
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("read frame header: {e}")),
        }
    }
    header[0] = first[0];
    r.read_exact(&mut header[1..]).map_err(|e| format!("read frame header: {e}"))?;
    let header_len = if header[..4] == MAGIC {
        HEADER_BYTES
    } else if header[..4] == MAGIC2 {
        HEADER2_BYTES
    } else {
        return Err("bad frame magic".to_string());
    };
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Err(format!("frame payload of {len} bytes exceeds the {MAX_FRAME_PAYLOAD} cap"));
    }
    let mut buf = vec![0u8; header_len + len];
    buf[..HEADER_BYTES].copy_from_slice(&header);
    r.read_exact(&mut buf[HEADER_BYTES..]).map_err(|e| format!("read frame payload: {e}"))?;
    Ok(Some(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_frames_round_trip_bit_exactly() {
        // awkward floats: subnormal, negative zero, many digits — raw LE
        // bytes make bit-exactness trivially true; assert it anyway
        let x = [1.0 / 3.0, -0.0, 5e-324, 1.23456789012345e300];
        let f = frame(&predict_payload(Some("ridge"), &x));
        let Scan::Frame { total, header, tid } = scan(&f) else {
            panic!("complete frame must scan")
        };
        assert_eq!(total, f.len());
        assert_eq!(header, HEADER_BYTES);
        assert_eq!(tid, 0);
        match parse_request(payload(&f)).unwrap() {
            FrameRequest::Predict { model, x: got } => {
                assert_eq!(model.as_deref(), Some("ridge"));
                assert_eq!(got.len(), x.len());
                for (a, b) in x.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        // unnamed model = single-model routing, same as JSON's omitted field
        match parse_request(&predict_payload(None, &x)).unwrap() {
            FrameRequest::Predict { model: None, .. } => {}
            other => panic!("{other:?}"),
        }
        let r = frame(&ok_payload(&x));
        match parse_reply(payload(&r)).unwrap() {
            FrameReply::Ok { y } => {
                for (a, b) in x.iter().zip(&y) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(reply_status(&r), Some(ST_OK));
        assert_eq!(parse_request(&ping_payload()).unwrap(), FrameRequest::Ping);
        assert_eq!(parse_reply(&pong_payload()).unwrap(), FrameReply::Pong);
    }

    #[test]
    fn status_replies_carry_the_retry_contract() {
        match parse_reply(&status_payload(ST_RETRY, "queue full")).unwrap() {
            FrameReply::Err { msg, retry } => {
                assert!(retry);
                assert_eq!(msg, "queue full");
            }
            other => panic!("{other:?}"),
        }
        match parse_reply(&status_payload(ST_ERR, "no model \"x\"")).unwrap() {
            FrameReply::Err { msg, retry } => {
                assert!(!retry);
                assert_eq!(msg, "no model \"x\"");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn scan_rejects_hostile_headers_before_any_allocation() {
        assert_eq!(scan(b""), Scan::Incomplete);
        assert_eq!(scan(b"GZ"), Scan::Incomplete); // magic prefix still possible
        assert_eq!(scan(b"GZF1\x01\x00"), Scan::Incomplete); // header incomplete
        assert_eq!(scan(b"JSON"), Scan::BadMagic);
        assert_eq!(scan(b"{\"cmd\":\"ping\"}"), Scan::BadMagic); // a stray JSON line
        // an attacker-controlled length prefix: rejected from the header,
        // no payload buffer is ever sized by it
        let mut huge = Vec::from(MAGIC);
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(scan(&huge), Scan::Oversized(u32::MAX as usize));
        // a frame arriving byte by byte stays Incomplete until whole
        let full = frame(&ping_payload());
        for cut in 0..full.len() {
            assert_eq!(scan(&full[..cut]), Scan::Incomplete, "cut at {cut}");
        }
        assert_eq!(
            scan(&full),
            Scan::Frame { total: full.len(), header: HEADER_BYTES, tid: 0 }
        );
    }

    #[test]
    fn gzf2_frames_carry_the_tid_and_interoperate_with_gzf1() {
        let x = [1.5, -2.5, 5e-324];
        let p = predict_payload(Some("ridge"), &x);
        let tid = 0xfeed_beef_0000_0042_u64;
        let f2 = frame_traced(&p, tid);
        assert_eq!(&f2[..4], &MAGIC2);
        // scan: same payload, wider header, tid recovered exactly
        match scan(&f2) {
            Scan::Frame { total, header, tid: got } => {
                assert_eq!(total, f2.len());
                assert_eq!(header, HEADER2_BYTES);
                assert_eq!(got, tid);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(frame_tid(&f2), tid);
        assert_eq!(frame_tid(&frame(&p)), 0);
        // byte-by-byte arrival stays Incomplete until whole (the header
        // verdict defers between GZF1/GZF2 only at byte four)
        for cut in 0..f2.len() {
            assert_eq!(scan(&f2[..cut]), Scan::Incomplete, "cut at {cut}");
        }
        // payload() is magic-aware: both framings parse to the same request
        assert_eq!(payload(&f2), &p[..]);
        assert_eq!(payload(&frame(&p)), &p[..]);
        assert_eq!(parse_request(payload(&f2)).unwrap(), parse_request(&p).unwrap());
        // tid 0 degrades to a plain GZF1 frame, byte-identical
        assert_eq!(frame_traced(&p, 0), frame(&p));
        // read_frame accepts both magics and returns the whole frame
        let mut both = frame(&p);
        both.extend_from_slice(&f2);
        let mut r = std::io::Cursor::new(both);
        let a = read_frame(&mut r).unwrap().unwrap();
        let b = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(a, frame(&p));
        assert_eq!(b, f2);
        assert!(read_frame(&mut r).unwrap().is_none());
        // reply_status peeks through either header width
        let reply = frame(&status_payload(ST_RETRY, "queue full"));
        assert_eq!(reply_status(&reply), Some(ST_RETRY));
        assert_eq!(reply_status(&frame_traced(&status_payload(ST_RETRY, "q"), 7)), Some(ST_RETRY));
    }

    #[test]
    fn malformed_payloads_are_errors_not_panics() {
        for bad in [
            &[] as &[u8],
            &[OP_PREDICT],                               // truncated before model len
            &[OP_PREDICT, 5, 0, b'a'],                   // model shorter than declared
            &[OP_PREDICT, 0, 0, 2, 0, 0, 0],             // count without values
            &[OP_PREDICT, 0, 0, 0, 0, 0, 0],             // empty x
            &[OP_PREDICT, 0, 0, 1, 0, 0, 0, 1, 2, 3],    // 3 bytes for 1 f64
            &[OP_PING, 9],                               // ping with payload
            &[99],                                       // unknown op
        ] {
            assert!(parse_request(bad).is_err(), "accepted {bad:?}");
        }
        // non-finite x refused, same as the JSON parser's 1e999 rule
        let mut p = vec![OP_PREDICT, 0, 0, 1, 0, 0, 0];
        p.extend_from_slice(&f64::NAN.to_le_bytes());
        assert!(parse_request(&p).unwrap_err().contains("non-finite"));
        for bad in [&[] as &[u8], &[ST_OK], &[ST_OK, 2, 0, 0, 0, 1], &[77]] {
            assert!(parse_reply(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn read_frame_handles_eof_and_caps() {
        let f = frame(&predict_payload(None, &[1.5, -2.5]));
        let mut two = Vec::new();
        two.extend_from_slice(&f);
        two.extend_from_slice(&f);
        let mut r = std::io::Cursor::new(two);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), f);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), f);
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at a boundary");
        // EOF mid-frame is an error, not a silent None
        let mut cut = std::io::Cursor::new(f[..f.len() - 3].to_vec());
        assert!(read_frame(&mut cut).is_err());
        // oversized prefix rejected before allocation
        let mut huge = Vec::from(MAGIC);
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = std::io::Cursor::new(huge);
        assert!(read_frame(&mut r).unwrap_err().contains("exceeds"));
    }
}
