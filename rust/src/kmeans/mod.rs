//! Kernel k-means in random-feature space (paper §6.3 / Appendix A.2).
//!
//! k-means++ initialization + Lloyd iterations on the feature rows; the
//! reported objective is the average squared distance to the assigned
//! centroid — exactly the quantity of the paper's Table 3. Theorem 10
//! (projection-cost preservation) is what licenses solving k-means on Z
//! instead of the kernel matrix.

use crate::exec::Pool;
use crate::linalg::Mat;
use crate::rng::Rng;

/// Result of a k-means run.
pub struct KmeansResult {
    pub assignments: Vec<usize>,
    pub centroids: Mat,
    /// average of squared distances to assigned centroid
    pub objective: f64,
    pub iterations: usize,
}

impl KmeansResult {
    /// Out-of-sample assignment: nearest fitted centroid per feature row.
    /// Ties break to the lowest index, exactly like the Lloyd assignment
    /// step, so on a converged fit the training rows reproduce
    /// `assignments`. This is what `model::KmeansModel::predict` serves.
    pub fn assign(&self, z: &Mat) -> Vec<usize> {
        assign_to_centroids(z, &self.centroids)
    }
}

/// Nearest-centroid assignment of feature rows (ties to the lowest index);
/// row parallelism from the global pool, clamped for tiny batches.
pub fn assign_to_centroids(z: &Mat, centroids: &Mat) -> Vec<usize> {
    assign_to_centroids_with(z, centroids, &Pool::for_rows(z.rows()))
}

/// [`assign_to_centroids`] on an explicit pool. Rows are independent, so
/// the scatter is bit-identical to the serial scan at every thread count.
pub fn assign_to_centroids_with(z: &Mat, centroids: &Mat, pool: &Pool) -> Vec<usize> {
    assert_eq!(z.cols(), centroids.cols(), "feature/centroid dim mismatch");
    let n = z.rows();
    let mut out = vec![0usize; n];
    pool.par_chunks(n, &mut out, |lo, _hi, block| {
        for (r, slot) in block.iter_mut().enumerate() {
            *slot = nearest_centroid(z.row(lo + r), centroids);
        }
    });
    out
}

/// Index of the nearest centroid to `row` (ties to the lowest index) —
/// the shared inner scan of Lloyd assignment, out-of-sample assignment
/// and the streaming absorber.
fn nearest_centroid(row: &[f64], centroids: &Mat) -> usize {
    let mut best = (f64::INFINITY, 0usize);
    for c in 0..centroids.rows() {
        let d = sq_dist(row, centroids.row(c));
        if d < best.0 {
            best = (d, c);
        }
    }
    best.1
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum()
}

/// k-means++ seeding [AV06].
fn kmeanspp_init(z: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = z.rows();
    let f = z.cols();
    let mut centroids = Mat::zeros(k, f);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(z.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| sq_dist(z.row(i), centroids.row(0))).collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut u = rng.uniform() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if u < w {
                    pick = i;
                    break;
                }
                u -= w;
            }
            pick
        };
        centroids.row_mut(c).copy_from_slice(z.row(pick));
        for i in 0..n {
            let nd = sq_dist(z.row(i), centroids.row(c));
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centroids
}

/// Lloyd's algorithm with k-means++ seeding on feature rows, drawing the
/// assignment scans from the global pool.
pub fn kmeans(z: &Mat, k: usize, max_iters: usize, seed: u64) -> KmeansResult {
    kmeans_with(z, k, max_iters, seed, &Pool::global())
}

/// [`kmeans`] on an explicit pool. The assignment step — the O(n k F)
/// bulk of each Lloyd iteration — scatters rows across the pool
/// (bit-identical to the serial scan); the centroid update keeps its
/// serial row-ascending accumulation so the whole fit is a pure function
/// of `(z, k, max_iters, seed)`, independent of thread count.
pub fn kmeans_with(z: &Mat, k: usize, max_iters: usize, seed: u64, pool: &Pool) -> KmeansResult {
    assert!(k >= 1 && z.rows() >= k);
    let n = z.rows();
    let f = z.cols();
    let mut rng = Rng::new(seed).fork(0x4B3A);
    let mut centroids = kmeanspp_init(z, k, &mut rng);
    let mut assignments = vec![0usize; n];
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // assignment step (parallel over rows; ties to the lowest index,
        // exactly like the serial scan)
        let new_assignments = assign_to_centroids_with(z, &centroids, pool);
        let changed = new_assignments != assignments;
        assignments = new_assignments;
        if !changed && it > 0 {
            break;
        }
        // update step
        let mut counts = vec![0usize; k];
        let mut sums = Mat::zeros(k, f);
        for i in 0..n {
            let c = assignments[i];
            counts[c] += 1;
            let srow = sums.row_mut(c);
            for (sv, &zv) in srow.iter_mut().zip(z.row(i)) {
                *sv += zv;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        sq_dist(z.row(a), centroids.row(assignments[a]))
                            .partial_cmp(&sq_dist(z.row(b), centroids.row(assignments[b])))
                            .unwrap()
                    })
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(z.row(far));
            } else {
                let inv = 1.0 / counts[c] as f64;
                let crow = centroids.row_mut(c);
                for (cv, &sv) in crow.iter_mut().zip(sums.row(c)) {
                    *cv = sv * inv;
                }
            }
        }
    }
    let objective = (0..n)
        .map(|i| sq_dist(z.row(i), centroids.row(assignments[i])))
        .sum::<f64>()
        / n as f64;
    KmeansResult { assignments, centroids, objective, iterations }
}

/// The kernel-space k-means objective for a given clustering, computed from
/// the exact Gram matrix (Appendix A.2):
/// (1/n) Tr(K - C C^T K C C^T) = (1/n) [sum_i K_ii - sum_c (1/|C_c|) sum_{i,j in C_c} K_ij].
pub fn kernel_objective(k_gram: &Mat, assignments: &[usize], k: usize) -> f64 {
    let n = k_gram.rows();
    assert_eq!(assignments.len(), n);
    let mut within = vec![0.0; k];
    let mut counts = vec![0usize; k];
    for (i, &ci) in assignments.iter().enumerate() {
        counts[ci] += 1;
        for (j, &cj) in assignments.iter().enumerate() {
            if ci == cj {
                within[ci] += k_gram[(i, j)] / 2.0; // count pairs once, fix below
            }
            let _ = j;
        }
    }
    // we added each ordered pair half -> within[c] = 0.5 sum_{i,j in c} K_ij
    let trace: f64 = (0..n).map(|i| k_gram[(i, i)]).sum();
    let mut obj = trace;
    for c in 0..k {
        if counts[c] > 0 {
            obj -= 2.0 * within[c] / counts[c] as f64;
        }
    }
    obj / n as f64
}

/// Mini-batch k-means [Sculley-style] over a feature stream — the
/// clustering companion of the coordinator's single-pass KRR: O(k F)
/// state, each batch touched once.
pub struct StreamingKmeans {
    centroids: Mat,
    counts: Vec<usize>,
    initialized: usize,
}

impl StreamingKmeans {
    pub fn new(k: usize, f_dim: usize) -> StreamingKmeans {
        StreamingKmeans { centroids: Mat::zeros(k, f_dim), counts: vec![0; k], initialized: 0 }
    }

    /// Start from explicit initial centroids (e.g. a reservoir sample of
    /// featurized rows — the chunked fit of `data::pipeline`). Each
    /// centroid starts with count 1, exactly like the bootstrap rows of
    /// [`absorb`](StreamingKmeans::absorb).
    pub fn with_centroids(centroids: Mat) -> StreamingKmeans {
        let k = centroids.rows();
        StreamingKmeans { centroids, counts: vec![1; k], initialized: k }
    }

    pub fn k(&self) -> usize {
        self.centroids.rows()
    }

    pub fn centroids(&self) -> &Mat {
        &self.centroids
    }

    /// Absorb one featurized mini-batch: assign to nearest centroid, move
    /// each centroid by the per-cluster learning rate 1/count.
    pub fn absorb(&mut self, z: &Mat) {
        self.absorb_flat(z.data());
    }

    /// [`absorb`](StreamingKmeans::absorb) over a flat row-major feature
    /// buffer — the chunk path folds its reused scratch slice directly.
    /// Strictly row-sequential, so absorbing the same rows in any chunking
    /// leaves bit-identical centroids (chunk invariance).
    pub fn absorb_flat(&mut self, z: &[f64]) {
        let k = self.centroids.rows();
        let f = self.centroids.cols();
        assert_eq!(z.len() % f.max(1), 0, "absorb_flat: buffer is not whole rows");
        for row in z.chunks_exact(f) {
            // bootstrap: first k distinct rows become the centroids
            if self.initialized < k {
                self.centroids.row_mut(self.initialized).copy_from_slice(row);
                self.counts[self.initialized] = 1;
                self.initialized += 1;
                continue;
            }
            let c = nearest_centroid(row, &self.centroids);
            self.counts[c] += 1;
            let eta = 1.0 / self.counts[c] as f64;
            let crow = self.centroids.row_mut(c);
            for (cv, &zv) in crow.iter_mut().zip(row) {
                *cv += eta * (zv - *cv);
            }
        }
    }

    /// Fold the squared distance of every row of a flat feature buffer to
    /// its nearest centroid into `total`, row by row — the objective pass
    /// of the chunked fit. Accumulating into the caller's running total
    /// (rather than returning a per-chunk subtotal) keeps the float
    /// addition order row-sequential across chunk boundaries, so the
    /// objective is bit-invariant to the chunking.
    pub fn accumulate_sq_dist(&self, z: &[f64], total: &mut f64) {
        let f = self.centroids.cols();
        for row in z.chunks_exact(f) {
            let c = nearest_centroid(row, &self.centroids);
            *total += sq_dist(row, self.centroids.row(c));
        }
    }

    /// Assign a batch to the current centroids.
    pub fn assign(&self, z: &Mat) -> Vec<usize> {
        assign_to_centroids(z, &self.centroids)
    }

    /// Average squared distance of a batch to its assigned centroids.
    pub fn objective(&self, z: &Mat) -> f64 {
        let assign = self.assign(z);
        (0..z.rows())
            .map(|i| sq_dist(z.row(i), self.centroids.row(assign[i])))
            .sum::<f64>()
            / z.rows() as f64
    }
}

/// Clustering accuracy against ground-truth labels via greedy cluster-to-
/// class matching (diagnostic only; the paper reports the objective).
pub fn greedy_accuracy(assignments: &[usize], labels: &[usize], k: usize) -> f64 {
    let n = assignments.len();
    let mut conf = vec![vec![0usize; k]; k];
    for i in 0..n {
        conf[assignments[i]][labels[i]] += 1;
    }
    let mut used = vec![false; k];
    let mut correct = 0usize;
    for row in conf.iter() {
        let mut best = (0usize, 0usize);
        for (c, &v) in row.iter().enumerate() {
            if !used[c] && v >= best.1 {
                best = (c, v);
            }
        }
        used[best.0] = true;
        correct += best.1;
    }
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs(n_per: usize) -> (Mat, Vec<usize>) {
        let mut rng = Rng::new(140);
        let mut z = Mat::zeros(2 * n_per, 2);
        let mut labels = Vec::new();
        for i in 0..2 * n_per {
            let c = i % 2;
            labels.push(c);
            let cx = if c == 0 { -2.0 } else { 2.0 };
            z[(i, 0)] = cx + 0.3 * rng.normal();
            z[(i, 1)] = 0.3 * rng.normal();
        }
        (z, labels)
    }

    #[test]
    fn separates_two_blobs() {
        let (z, labels) = two_blobs(100);
        let res = kmeans(&z, 2, 50, 1);
        let acc = greedy_accuracy(&res.assignments, &labels, 2);
        assert!(acc > 0.98, "accuracy {acc}");
        assert!(res.objective < 0.5, "objective {}", res.objective);
    }

    #[test]
    fn objective_decreases_with_k() {
        let (z, _) = two_blobs(80);
        let o1 = kmeans(&z, 1, 30, 2).objective;
        let o2 = kmeans(&z, 2, 30, 2).objective;
        let o4 = kmeans(&z, 4, 30, 2).objective;
        assert!(o2 < o1);
        assert!(o4 <= o2 + 1e-9);
    }

    #[test]
    fn out_of_sample_assign_reproduces_training_assignments() {
        // Lloyd exits when an assignment pass changes nothing, so the
        // fitted assignments ARE the nearest-centroid assignments of the
        // training rows — `assign` must reproduce them exactly
        let (z, _) = two_blobs(60);
        let res = kmeans(&z, 2, 100, 7);
        assert_eq!(res.assign(&z), res.assignments);
        // and genuinely out-of-sample points go to the nearest centroid
        let probe = Mat::from_vec(2, 2, vec![-2.0, 0.0, 2.0, 0.0]);
        let a = res.assign(&probe);
        assert_ne!(a[0], a[1], "blob centers must land in different clusters");
    }

    #[test]
    fn kernel_objective_matches_feature_objective_for_linear_kernel() {
        // with K = Z Z^T the kernel objective equals the feature-space
        // objective at the optimal (mean) centroids
        let (z, _) = two_blobs(40);
        let res = kmeans(&z, 2, 50, 3);
        let k = z.matmul_nt(&z);
        let ko = kernel_objective(&k, &res.assignments, 2);
        assert!(
            (ko - res.objective).abs() < 1e-8,
            "kernel {ko} vs feature {}",
            res.objective
        );
    }

    #[test]
    fn handles_k_equals_one_and_n() {
        let (z, _) = two_blobs(10);
        let r1 = kmeans(&z, 1, 10, 4);
        assert!(r1.objective > 0.0);
        let rn = kmeans(&z, z.rows(), 10, 4);
        assert!(rn.objective < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let (z, _) = two_blobs(50);
        let a = kmeans(&z, 3, 25, 9);
        let b = kmeans(&z, 3, 25, 9);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.objective, b.objective);
    }

    #[test]
    fn streaming_kmeans_tracks_batch_kmeans() {
        let (z, labels) = two_blobs(200);
        let mut sk = StreamingKmeans::new(2, 2);
        for lo in (0..z.rows()).step_by(32) {
            let hi = (lo + 32).min(z.rows());
            sk.absorb(&z.row_block(lo, hi));
        }
        let batch = kmeans(&z, 2, 50, 5);
        let stream_obj = sk.objective(&z);
        assert!(
            stream_obj < 2.0 * batch.objective + 0.05,
            "stream {stream_obj} vs batch {}",
            batch.objective
        );
        let acc = greedy_accuracy(&sk.assign(&z), &labels, 2);
        assert!(acc > 0.95, "{acc}");
    }

    #[test]
    fn streaming_kmeans_state_is_constant_size() {
        let mut sk = StreamingKmeans::new(3, 4);
        let mut rng = Rng::new(141);
        for _ in 0..20 {
            let z = Mat::from_fn(50, 4, |_, _| rng.normal());
            sk.absorb(&z);
        }
        assert_eq!(sk.centroids().rows(), 3);
        assert_eq!(sk.centroids().cols(), 4);
        let z = Mat::from_fn(10, 4, |_, _| rng.normal());
        assert_eq!(sk.assign(&z).len(), 10);
    }
}
