//! # gzk — Random Gegenbauer Features for Scalable Kernel Methods
//!
//! Rust + JAX + Pallas reproduction of Han, Zandieh & Avron (ICML 2022).
//!
//! The crate is organized in three layers (see `DESIGN.md`):
//!
//! * **Substrates** — everything the paper's system depends on, built from
//!   scratch: special functions ([`special`]), a PRNG ([`rng`]), dense
//!   linear algebra ([`linalg`], its hot products running on the
//!   register-blocked, cache-tiled [`linalg::microkernel`] engine),
//!   the parallel execution engine ([`exec`]:
//!   one thread pool + row-scatter primitives every layer draws from, with
//!   bit-identical results at every thread count), exact kernels
//!   ([`kernels`]), the data layer ([`data`]): synthetic generators
//!   plus the chunked out-of-core pipeline ([`data::DataSource`] /
//!   [`data::pipeline`]) every fit path consumes — working memory bounded
//!   by the chunk, never by n, bit-invariant to the chunking — and the
//!   observability layer ([`obs`]): a lock-free metrics registry,
//!   leveled structured events, and trace spans instrumenting every
//!   layer above without perturbing any result.
//! * **The paper's contribution** — random Gegenbauer features for the
//!   Generalized Zonal Kernel family ([`features::gegenbauer`]), baselines
//!   ([`features`]), the spec-driven registry that constructs them all
//!   ([`features::spec`]), downstream learners ([`krr`], [`kmeans`]) and
//!   the spectral-approximation validators ([`spectral`]).
//! * **The serving system** — the PJRT runtime that executes the AOT
//!   jax/Pallas artifacts ([`runtime`], behind the `pjrt` feature), the
//!   L3 coordinator implementing the one-round distributed protocol,
//!   single-pass streaming KRR and a dynamic prediction batcher
//!   ([`coordinator`]), and the fitted-model subsystem ([`model`]):
//!   ridge/k-means/KPCA models that bundle their feature spec with their
//!   learned state, serialize to versioned JSON artifacts, and persist in
//!   a [`model::ModelStore`] — fit once, reload and serve anywhere; and
//!   the network front-end ([`server`]): a std-only TCP server speaking
//!   newline-delimited JSON with multi-model routing over a store,
//!   manifest-poll hot-reload, bounded admission with backpressure
//!   replies, and a load-generation harness (`gzk server` /
//!   `gzk loadgen`) — predictions cross the wire bit-identical to a
//!   local `Model::predict`; and the distributed tier ([`dist`]): the
//!   one-round fit lifted over TCP (`gzk leader` / `gzk worker`, merge
//!   bit-identical to the in-process fit even across worker deaths) and
//!   a replica load balancer (`gzk proxy`) with retry-on-backpressure
//!   and eject-and-probe health.
//!
//! Every featurizer — the paper's and all baselines — is described by a
//! serializable [`features::FeatureSpec`] `(kernel, method, m, seed)` and
//! built through its registry; the coordinator broadcasts exactly that
//! spec, so "what the CLI parses" and "what goes over the wire" are the
//! same value.
//!
//! # Quick example
//!
//! ```
//! use gzk::features::{FeatureSpec, Featurizer, KernelSpec, Method};
//! use gzk::krr::FeatureRidge;
//! use gzk::linalg::Mat;
//! use gzk::rng::Rng;
//!
//! // toy data: y = x0 + x1 on S^2-ish points
//! let mut rng = Rng::new(7);
//! let x = Mat::from_fn(64, 3, |_, _| rng.normal() * 0.5);
//! let y: Vec<f64> = (0..64).map(|i| x[(i, 0)] + x[(i, 1)]).collect();
//!
//! // Gaussian kernel as a GZK (Eq. 23) via the paper's random Gegenbauer
//! // features (Def. 8): a 512-feature budget = 256 directions x s = 2
//! let spec = FeatureSpec::new(
//!     KernelSpec::Gaussian { bandwidth: 1.0 },
//!     Method::Gegenbauer { q: 10, s: 2 },
//!     /* feature budget m = */ 512,
//!     /* seed = */ 42,
//! );
//! let feat = spec.build(/* d = */ 3);
//! let z = feat.featurize(&x);
//! assert_eq!((z.rows(), z.cols()), (64, 512));
//! assert_eq!(spec.feature_dim(), 512); // derivable without building
//!
//! // the same spec round-trips through JSON (what the coordinator
//! // broadcasts) and rebuilds the identical map anywhere
//! let wire = FeatureSpec::from_json(&spec.to_json()).unwrap();
//! assert_eq!(wire.build(3).featurize(&x), z);
//!
//! // swap one field to benchmark a baseline through the same API
//! let rff = FeatureSpec::new(
//!     KernelSpec::Gaussian { bandwidth: 1.0 }, Method::Fourier, 512, 42,
//! );
//! assert_eq!(rff.build(3).featurize(&x).cols(), 512);
//!
//! // ridge regression in feature space
//! let model = FeatureRidge::fit(&z, &y, 1e-3);
//! let pred = model.predict(&z);
//! let mse: f64 =
//!     pred.iter().zip(&y).map(|(&p, &t)| (p - t) * (p - t)).sum::<f64>() / 64.0;
//! assert!(mse < 1e-2);
//! ```

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod exec;
pub mod experiments;
pub mod features;
pub mod kernels;
pub mod kmeans;
pub mod kpca;
pub mod krr;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod special;
pub mod spectral;
pub mod testutil;

pub use linalg::Mat;
