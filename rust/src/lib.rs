//! # gzk — Random Gegenbauer Features for Scalable Kernel Methods
//!
//! Rust + JAX + Pallas reproduction of Han, Zandieh & Avron (ICML 2022).
//!
//! The crate is organized in three layers (see `DESIGN.md`):
//!
//! * **Substrates** — everything the paper's system depends on, built from
//!   scratch: special functions ([`special`]), a PRNG ([`rng`]), dense
//!   linear algebra ([`linalg`]), exact kernels ([`kernels`]), synthetic
//!   datasets ([`data`]).
//! * **The paper's contribution** — random Gegenbauer features for the
//!   Generalized Zonal Kernel family ([`features::gegenbauer`]), baselines
//!   ([`features`]), downstream learners ([`krr`], [`kmeans`]) and the
//!   spectral-approximation validators ([`spectral`]).
//! * **The serving system** — the PJRT runtime that executes the AOT
//!   jax/Pallas artifacts ([`runtime`]) and the L3 coordinator implementing
//!   the one-round distributed protocol, single-pass streaming KRR and a
//!   dynamic prediction batcher ([`coordinator`]).
//!
//! # Quick example
//!
//! ```
//! use gzk::features::{Featurizer, GegenbauerFeatures, RadialTable};
//! use gzk::krr::FeatureRidge;
//! use gzk::linalg::Mat;
//! use gzk::rng::Rng;
//!
//! // toy data: y = x0 + x1 on S^2-ish points
//! let mut rng = Rng::new(7);
//! let x = Mat::from_fn(64, 3, |_, _| rng.normal() * 0.5);
//! let y: Vec<f64> = (0..64).map(|i| x[(i, 0)] + x[(i, 1)]).collect();
//!
//! // Gaussian kernel as a GZK (Eq. 23), 256 random directions (Def. 8)
//! let table = RadialTable::gaussian(/*d=*/ 3, /*q=*/ 10, /*s=*/ 2);
//! let feat = GegenbauerFeatures::new(table, 256, /*seed=*/ 42);
//! let z = feat.featurize(&x);
//! assert_eq!((z.rows(), z.cols()), (64, 512));
//!
//! // ridge regression in feature space
//! let model = FeatureRidge::fit(&z, &y, 1e-3);
//! let pred = model.predict(&z);
//! let mse: f64 =
//!     pred.iter().zip(&y).map(|(&p, &t)| (p - t) * (p - t)).sum::<f64>() / 64.0;
//! assert!(mse < 1e-2);
//! ```

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod features;
pub mod kernels;
pub mod kmeans;
pub mod kpca;
pub mod krr;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod special;
pub mod spectral;
pub mod testutil;

pub use linalg::Mat;
