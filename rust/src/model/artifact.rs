//! Versioned JSON artifact codec shared by every model type.
//!
//! An artifact is one self-describing JSON document:
//!
//! ```json
//! {
//!   "format": 1,
//!   "kind": "ridge" | "kmeans" | "kpca",
//!   // run metadata: pool width of the fitting process, plus — when the
//!   // fit announced them via `set_run_data` — the training dataset name
//!   // and row count (how `gzk serve` rebuilds its evaluation stream)
//!   "run": { "threads": N, "dataset": "elevation", "rows": R },
//!   "spec": { ...BoundSpec wire form, seed as a decimal string... },
//!   "nystrom_landmarks": { "rows": R, "cols": C, "data": [...] },  // data-dependent maps only
//!   "state": { ...kind-specific learned state... }
//! }
//! ```
//!
//! Floats are written with Rust's shortest round-trip formatting (`{:?}`)
//! and read back through `str::parse::<f64>`, so save → load is
//! **bit-exact** — the property `tests/model_props.rs` checks for every
//! registry method. The spec half reuses the seed-safe wire codec of
//! `features::spec` (seed travels as a decimal string, full `u64` range).

use super::ModelKind;
use crate::data::{DataSource, MatSource};
use crate::exec::Pool;
use crate::features::{BoundSpec, Featurizer, Method, NystromFeatures};
use crate::linalg::Mat;
use crate::runtime::Json;
use std::sync::Mutex;

/// The artifact format this build writes; readers reject anything newer.
pub const ARTIFACT_FORMAT: usize = 1;

/// Process-wide run context: the training dataset name and row count the
/// CLI announces before fitting, stamped into every envelope written
/// afterwards (alongside the pool width). `None` entries are simply
/// omitted from the JSON — run metadata is provenance, never required to
/// rebuild a model.
static RUN_DATA: Mutex<Option<(String, usize)>> = Mutex::new(None);

/// Announce the training dataset for subsequent artifact writes (the CLI
/// calls this once per fit; last call wins). `gzk serve` reads the
/// recorded name back to pick its evaluation stream.
pub fn set_run_data(dataset: &str, rows: usize) {
    *RUN_DATA.lock().expect("run data lock") = Some((dataset.to_string(), rows));
}

/// Run metadata recorded at fit time. All fields are optional on read:
/// artifacts written before a field existed still parse.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RunMeta {
    /// global pool width of the producing process
    pub threads: Option<usize>,
    /// training dataset name (a `SyntheticSource` name or `file:<path>`)
    pub dataset: Option<String>,
    /// number of training rows
    pub rows: Option<usize>,
}

/// A feature map *as fitted*: the serializable description plus, for
/// data-dependent methods, the learned state needed to reconstruct it
/// (Nystrom's landmark set). This is the half of a model artifact the
/// spec registry alone cannot rebuild — pairing it with learned weights /
/// centroids / projections makes a complete deployable model.
pub struct FittedMap {
    spec: BoundSpec,
    /// landmark rows of a fitted Nystrom map; `None` for oblivious methods
    nystrom_landmarks: Option<Mat>,
    feat: Box<dyn Featurizer>,
}

impl FittedMap {
    /// Fit the map described by `spec` on in-memory training rows —
    /// [`fit_source`](FittedMap::fit_source) over a borrowed [`MatSource`].
    pub fn fit(spec: BoundSpec, x_train: &Mat) -> Result<FittedMap, String> {
        Self::fit_source(spec, &MatSource::unlabeled(x_train))
    }

    /// Fit the map described by `spec` against any
    /// [`DataSource`](crate::data::DataSource). Oblivious methods never
    /// read the source; Nystrom gathers its O(m) candidate/pilot rows by
    /// random access, so even the data-dependent baseline fits without
    /// materializing n x d.
    pub fn fit_source(spec: BoundSpec, src: &dyn DataSource) -> Result<FittedMap, String> {
        if src.dim() != spec.d {
            return Err(format!(
                "training source has d={}, spec bound to d={}",
                src.dim(),
                spec.d
            ));
        }
        if matches!(spec.spec.method, Method::Nystrom { .. }) {
            let feat = spec.spec.build_nystrom_source(spec.d, src)?;
            let landmarks = feat.landmarks().clone();
            Ok(FittedMap { spec, nystrom_landmarks: Some(landmarks), feat: Box::new(feat) })
        } else {
            let feat = spec.spec.try_build(spec.d, None)?;
            Ok(FittedMap { spec, nystrom_landmarks: None, feat })
        }
    }

    /// Reconstruct a fitted map from its persisted parts: the spec alone
    /// for oblivious methods, spec + landmarks for Nystrom. Bit-identical
    /// to the original fit (`NystromFeatures::from_landmarks` is the same
    /// construction `fit` ends with).
    pub fn rebuild(spec: BoundSpec, nystrom_landmarks: Option<Mat>) -> Result<FittedMap, String> {
        let is_nystrom = matches!(spec.spec.method, Method::Nystrom { .. });
        match (is_nystrom, nystrom_landmarks) {
            (true, Some(landmarks)) => {
                if landmarks.cols() != spec.d {
                    return Err(format!(
                        "landmarks have d={}, spec bound to d={}",
                        landmarks.cols(),
                        spec.d
                    ));
                }
                let feat =
                    NystromFeatures::from_landmarks(spec.spec.kernel.to_kernel(), landmarks);
                Ok(FittedMap {
                    spec,
                    nystrom_landmarks: Some(feat.landmarks().clone()),
                    feat: Box::new(feat),
                })
            }
            (true, None) => {
                Err("nystrom artifact is missing its landmark set".to_string())
            }
            (false, Some(_)) => Err(format!(
                "landmarks supplied for the data-oblivious method {:?}",
                spec.spec.method.name()
            )),
            (false, None) => {
                let feat = spec.spec.try_build(spec.d, None)?;
                Ok(FittedMap { spec, nystrom_landmarks: None, feat })
            }
        }
    }

    pub fn spec(&self) -> &BoundSpec {
        &self.spec
    }

    /// Actual output dimension of the fitted map (for Nystrom this is the
    /// realized landmark count, which a small training set may cap below
    /// the nominal budget `m`).
    pub fn feature_dim(&self) -> usize {
        self.feat.dim()
    }

    pub fn nystrom_landmarks(&self) -> Option<&Mat> {
        self.nystrom_landmarks.as_ref()
    }

    /// The fitted featurizer itself — what the chunked trainers of
    /// `data::pipeline` drive directly.
    pub fn featurizer(&self) -> &dyn Featurizer {
        self.feat.as_ref()
    }

    /// Featurize raw inputs through the fitted map.
    pub fn featurize(&self, x: &Mat) -> Mat {
        assert_eq!(
            x.cols(),
            self.spec.d,
            "input dim {} != spec d {}",
            x.cols(),
            self.spec.d
        );
        self.feat.featurize(x)
    }

    /// [`featurize`](FittedMap::featurize) with row parallelism drawn from
    /// an explicit pool (bit-identical to the serial map).
    pub fn featurize_with(&self, x: &Mat, pool: &Pool) -> Mat {
        assert_eq!(
            x.cols(),
            self.spec.d,
            "input dim {} != spec d {}",
            x.cols(),
            self.spec.d
        );
        self.feat.featurize_par(x, pool)
    }
}

/// A parsed artifact: the common halves decoded, the kind-specific state
/// left as JSON for the concrete model type to interpret.
pub struct Envelope {
    pub kind: ModelKind,
    pub map: FittedMap,
    pub state: Json,
    /// Run metadata recorded at fit time (all fields optional on read).
    pub run: RunMeta,
}

/// Serialize the common envelope around a kind-specific `state` object.
/// Besides the model halves, the envelope records run metadata — the
/// global pool width of the writing process plus, when announced via
/// [`set_run_data`], the training dataset name and row count — so an
/// artifact documents the configuration and data that produced it.
pub fn envelope(kind: ModelKind, map: &FittedMap, state: &str) -> String {
    let mut run = format!(r#"{{"threads":{}"#, Pool::global().threads());
    if let Some((dataset, rows)) = RUN_DATA.lock().expect("run data lock").clone() {
        run.push_str(&format!(r#","dataset":{},"rows":{rows}"#, json_string(&dataset)));
    }
    run.push('}');
    let mut s = format!(
        r#"{{"format":{ARTIFACT_FORMAT},"kind":"{}","run":{run},"spec":{}"#,
        kind.name(),
        map.spec().to_json()
    );
    if let Some(landmarks) = map.nystrom_landmarks() {
        s.push_str(&format!(r#","nystrom_landmarks":{}"#, mat_to_json(landmarks)));
    }
    s.push_str(&format!(r#","state":{state}}}"#));
    s
}

/// The crate's one JSON string-literal writer (run metadata, the store
/// manifest, the serving wire protocol — dataset names may be `file:`
/// paths and error replies carry arbitrary text). Non-ASCII characters
/// are `\u`-escaped because the in-crate JSON parser reads string bytes
/// individually (multi-byte UTF-8 would be mangled on the way back);
/// codepoints above the BMP become U+FFFD — provenance stays readable,
/// never corrupt.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 || (c as u32) > 0x7e => {
                let cp = if (c as u32) > 0xFFFF { 0xFFFD } else { c as u32 };
                out.push_str(&format!("\\u{cp:04x}"));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse and validate the common envelope, rebuilding the feature map.
pub fn parse_envelope(text: &str) -> Result<Envelope, String> {
    let j = Json::parse(text).map_err(|e| format!("model artifact: {e}"))?;
    let format = req_usize(&j, "format")?;
    if format != ARTIFACT_FORMAT {
        return Err(format!(
            "model artifact format {format} not supported (this build reads format {ARTIFACT_FORMAT})"
        ));
    }
    let kind = ModelKind::from_name(req_str(&j, "kind")?)?;
    let spec = BoundSpec::from_json_value(req(&j, "spec")?)
        .map_err(|e| format!("model artifact: {e}"))?;
    let landmarks = match j.get("nystrom_landmarks") {
        Some(v) => Some(mat_from_json(v)?),
        None => None,
    };
    let run = match j.get("run") {
        Some(r) => RunMeta {
            threads: r.get("threads").and_then(|v| v.as_usize()),
            dataset: r.get("dataset").and_then(|v| v.as_str()).map(|s| s.to_string()),
            rows: r.get("rows").and_then(|v| v.as_usize()),
        },
        None => RunMeta::default(),
    };
    let map = FittedMap::rebuild(spec, landmarks)?;
    let state = req(&j, "state")?.clone();
    Ok(Envelope { kind, map, state, run })
}

/// Shortest representation that parses back to exactly the same bits.
pub fn fmt_f64(v: f64) -> String {
    assert!(v.is_finite(), "model artifact: cannot serialize non-finite value {v}");
    format!("{v:?}")
}

pub fn vec_to_json(v: &[f64]) -> String {
    let mut s = String::with_capacity(2 + 10 * v.len());
    s.push('[');
    for (i, &x) in v.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&fmt_f64(x));
    }
    s.push(']');
    s
}

pub fn mat_to_json(m: &Mat) -> String {
    format!(
        r#"{{"rows":{},"cols":{},"data":{}}}"#,
        m.rows(),
        m.cols(),
        vec_to_json(m.data())
    )
}

pub fn vec_from_json(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or_else(|| "model artifact: expected a number array".to_string())?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "model artifact: non-number in array".to_string()))
        .collect()
}

pub fn mat_from_json(j: &Json) -> Result<Mat, String> {
    let rows = req_usize(j, "rows")?;
    let cols = req_usize(j, "cols")?;
    let data = vec_from_json(req(j, "data")?)?;
    if data.len() != rows * cols {
        return Err(format!(
            "model artifact: matrix data length {} != {rows}x{cols}",
            data.len()
        ));
    }
    Ok(Mat::from_vec(rows, cols, data))
}

pub(super) fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("model artifact: missing {key:?}"))
}

pub(super) fn req_f64(j: &Json, key: &str) -> Result<f64, String> {
    req(j, key)?
        .as_f64()
        .ok_or_else(|| format!("model artifact: {key:?} is not a number"))
}

pub(super) fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    req(j, key)?
        .as_usize()
        .ok_or_else(|| format!("model artifact: {key:?} is not an integer"))
}

pub(super) fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    req(j, key)?
        .as_str()
        .ok_or_else(|| format!("model artifact: {key:?} is not a string"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_codec_is_bit_exact() {
        // shortest round-trip formatting through the in-crate JSON parser
        let vals = [
            0.0,
            -0.0,
            1.0,
            -1.5,
            1e-300,
            -2.2250738585072014e-308,
            std::f64::consts::PI,
            1.7976931348623157e308,
            3.0000000000000004,
        ];
        let text = vec_to_json(&vals);
        let back = vec_from_json(&Json::parse(&text).unwrap()).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
        }
    }

    #[test]
    fn mat_codec_roundtrip() {
        let m = Mat::from_fn(3, 2, |i, j| (i as f64) * 0.1 + (j as f64) * 7.3);
        let back = mat_from_json(&Json::parse(&mat_to_json(&m)).unwrap()).unwrap();
        assert_eq!(m, back);
        // shape/data mismatch is rejected
        let bad = r#"{"rows":2,"cols":2,"data":[1.0,2.0,3.0]}"#;
        assert!(mat_from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn refuses_non_finite_values() {
        let _ = fmt_f64(f64::NAN);
    }

    #[test]
    fn envelope_records_and_tolerates_run_metadata() {
        use crate::features::{FeatureSpec, KernelSpec, Method};
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Fourier,
            8,
            3,
        )
        .bind(2);
        let map = FittedMap::rebuild(spec, None).unwrap();
        // with announced run data: dataset + rows travel in the envelope
        set_run_data("elevation", 123);
        let text = envelope(ModelKind::Ridge, &map, r#"{"lambda":0.5,"weights":[]}"#);
        assert!(text.contains(r#""run":{"threads":"#), "{text}");
        assert!(text.contains(r#""dataset":"elevation","rows":123"#), "{text}");
        let env = parse_envelope(&text).unwrap();
        assert_eq!(env.run.threads, Some(Pool::global().threads()));
        assert_eq!(env.run.dataset.as_deref(), Some("elevation"));
        assert_eq!(env.run.rows, Some(123));
        // a file-path dataset name with JSON-hostile characters survives —
        // including non-ASCII, which must round-trip through \u escapes
        // (the in-crate parser reads string bytes individually)
        set_run_data("file:/tmp/we\"ird\\päth.csv", 7);
        let text2 = envelope(ModelKind::Ridge, &map, r#"{"lambda":0.5,"weights":[]}"#);
        let env2 = parse_envelope(&text2).unwrap();
        assert_eq!(env2.run.dataset.as_deref(), Some("file:/tmp/we\"ird\\päth.csv"));
        // artifacts without the run field (older writers) still parse
        let start = text.find(r#","run""#).unwrap();
        let end = text[start + 1..].find(r#","spec""#).unwrap() + start + 1;
        let stripped = format!("{}{}", &text[..start], &text[end..]);
        let env = parse_envelope(&stripped).unwrap();
        assert_eq!(env.run, RunMeta::default());
    }
}
