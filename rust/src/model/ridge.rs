//! Feature-space ridge regression as a deployable model: the fitted map
//! plus the solved weights — what the coordinator's one-round protocol
//! produces and the serving batcher consumes.

use super::artifact::{self, Envelope, FittedMap};
use super::{Model, ModelKind};
use crate::data::{pipeline, DataSource, MatSource};
use crate::exec::Pool;
use crate::features::BoundSpec;
use crate::krr::{FeatureRidge, RidgeStats};
use crate::linalg::Mat;

pub struct RidgeModel {
    map: FittedMap,
    ridge: FeatureRidge,
}

impl RidgeModel {
    /// Single-node fit on in-memory rows:
    /// [`fit_source`](RidgeModel::fit_source) over a borrowed
    /// [`MatSource`] — the in-memory path is a consumer of the same
    /// chunked pipeline as the out-of-core one (and bit-identical to it).
    pub fn fit(spec: BoundSpec, x: &Mat, y: &[f64], lambda: f64) -> Result<RidgeModel, String> {
        if x.rows() != y.len() {
            return Err(format!("{} rows but {} targets", x.rows(), y.len()));
        }
        Self::fit_source(spec, &MatSource::new(x, y), lambda, pipeline::DEFAULT_CHUNK_ROWS)
    }

    /// Single-pass fit over any [`DataSource`]: per chunk, featurize into
    /// one reused scratch and fold into `(Z^T Z, Z^T y)`; solve at
    /// `lambda`. Works for every registry method, including the
    /// data-dependent Nystrom baseline (its landmark sample is gathered by
    /// random access; the fitted landmarks travel inside the artifact).
    /// Peak feature memory is `chunk_rows x F` — never `n x F`.
    pub fn fit_source(
        spec: BoundSpec,
        src: &dyn DataSource,
        lambda: f64,
        chunk_rows: usize,
    ) -> Result<RidgeModel, String> {
        let map = FittedMap::fit_source(spec, src)?;
        // per-chunk featurization + absorb draw from the global pool
        // (bit-identical to serial at any width)
        let (stats, _) =
            pipeline::ridge_stats(map.featurizer(), src, chunk_rows, &Pool::global())?;
        Ok(RidgeModel { ridge: stats.solve(lambda), map })
    }

    /// Finish reduced sufficient statistics `(Z^T Z, Z^T y, n)` into a
    /// model: solve at `lambda` and bundle. For paths that hold stats but
    /// no solved weights yet — e.g. `StreamingKrr`'s accumulated state or
    /// a custom reduction. (`leader::fit_ridge` uses
    /// [`from_parts`](RidgeModel::from_parts) since the one-round protocol
    /// has already solved.)
    pub fn from_stats(map: FittedMap, stats: &RidgeStats, lambda: f64) -> RidgeModel {
        Self::from_parts(map, stats.solve(lambda))
    }

    /// Bundle an already-solved ridge with its fitted map.
    pub fn from_parts(map: FittedMap, ridge: FeatureRidge) -> RidgeModel {
        assert_eq!(
            ridge.weights.len(),
            map.feature_dim(),
            "ridge weights do not match the feature dimension"
        );
        RidgeModel { map, ridge }
    }

    pub fn ridge(&self) -> &FeatureRidge {
        &self.ridge
    }

    /// Predictions as a plain vector (one value per input row); row
    /// parallelism from the global pool, clamped for tiny batches.
    pub fn predict_vec(&self, x: &Mat) -> Vec<f64> {
        self.predict_vec_with(x, &Pool::for_rows(x.rows()))
    }

    /// [`predict_vec`](RidgeModel::predict_vec) on an explicit pool.
    pub fn predict_vec_with(&self, x: &Mat, pool: &Pool) -> Vec<f64> {
        self.ridge.predict_with(&self.map.featurize_with(x, pool), pool)
    }

    pub(super) fn from_envelope(env: Envelope) -> Result<RidgeModel, String> {
        let lambda = artifact::req_f64(&env.state, "lambda")?;
        let weights = artifact::vec_from_json(artifact::req(&env.state, "weights")?)?;
        if weights.len() != env.map.feature_dim() {
            return Err(format!(
                "ridge artifact has {} weights but the map emits {} features",
                weights.len(),
                env.map.feature_dim()
            ));
        }
        Ok(RidgeModel { map: env.map, ridge: FeatureRidge { weights, lambda } })
    }
}

impl Model for RidgeModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Ridge
    }

    fn feature_spec(&self) -> &BoundSpec {
        self.map.spec()
    }

    fn output_dim(&self) -> usize {
        1
    }

    fn predict(&self, x: &Mat) -> Mat {
        self.predict_with(x, &Pool::for_rows(x.rows()))
    }

    fn predict_with(&self, x: &Mat, pool: &Pool) -> Mat {
        let n = x.rows();
        Mat::from_vec(n, 1, self.predict_vec_with(x, pool))
    }

    fn to_artifact(&self) -> String {
        let state = format!(
            r#"{{"lambda":{},"weights":{}}}"#,
            artifact::fmt_f64(self.ridge.lambda),
            artifact::vec_to_json(&self.ridge.weights)
        );
        artifact::envelope(ModelKind::Ridge, &self.map, &state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureSpec, KernelSpec, Method};
    use crate::rng::Rng;

    fn toy() -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(300);
        let x = Mat::from_fn(50, 3, |_, _| rng.normal() * 0.5);
        let y: Vec<f64> = (0..50).map(|i| x[(i, 0)] + 2.0 * x[(i, 1)]).collect();
        (x, y)
    }

    #[test]
    fn fit_matches_manual_pipeline() {
        let (x, y) = toy();
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 8, s: 2 },
            64,
            9,
        )
        .bind(3);
        let model = RidgeModel::fit(spec.clone(), &x, &y, 1e-3).unwrap();
        use crate::features::Featurizer as _;
        let z = spec.build().featurize(&x);
        let reference = FeatureRidge::fit(&z, &y, 1e-3);
        assert_eq!(model.predict_vec(&x), reference.predict(&z));
        assert_eq!(model.output_dim(), 1);
        assert_eq!(model.kind(), ModelKind::Ridge);
    }

    #[test]
    fn from_stats_equals_fit() {
        // finishing accumulated stats == fitting directly on the features
        let (x, y) = toy();
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            48,
            17,
        )
        .bind(3);
        use crate::model::FittedMap;
        let map = FittedMap::fit(spec.clone(), &x).unwrap();
        let z = map.featurize(&x);
        let mut stats = RidgeStats::new(z.cols());
        stats.absorb(&z, &y);
        let from_stats = RidgeModel::from_stats(map, &stats, 1e-3);
        let fitted = RidgeModel::fit(spec, &x, &y, 1e-3).unwrap();
        assert_eq!(from_stats.predict_vec(&x), fitted.predict_vec(&x));
    }

    #[test]
    fn rejects_mismatched_targets() {
        let (x, y) = toy();
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Fourier,
            32,
            1,
        )
        .bind(3);
        assert!(RidgeModel::fit(spec, &x, &y[..10], 1e-3).is_err());
    }
}
