//! `ModelStore`: a directory of model artifacts with a manifest — the
//! train-once / serve-later boundary. `gzk fit` writes into a store;
//! `gzk predict` and the serving demo load from one, so a process that
//! serves never has to refit.
//!
//! Layout:
//!
//! ```text
//! <dir>/models.json           manifest: [{name, kind, file}, ...]
//! <dir>/<name>.model.json     one artifact per saved model
//! ```
//!
//! Concurrency contract: **one writer, any number of readers.** All
//! writes go through temp-file + rename, so readers never observe a
//! truncated artifact or manifest — but concurrent *writers* are not
//! coordinated (the manifest read-modify-write in [`ModelStore::save`]
//! has no lock), so two simultaneous `save`s can lose a manifest entry.
//! Run one fitting process per store at a time.

use super::artifact::json_string;
use super::{from_artifact_with_meta, Model, ModelKind, RunMeta};
use crate::runtime::Json;
use std::fs;
use std::path::{Path, PathBuf};

const MANIFEST_FILE: &str = "models.json";

/// One manifest row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreEntry {
    pub name: String,
    pub kind: ModelKind,
    pub file: String,
}

pub struct ModelStore {
    dir: PathBuf,
}

impl ModelStore {
    /// Open (creating the directory if needed) a store at `dir` — the
    /// writer-side open (`gzk fit`, `gzk serve`'s training path).
    pub fn open(dir: impl Into<PathBuf>) -> Result<ModelStore, String> {
        let dir = dir.into();
        fs::create_dir_all(&dir).map_err(|e| format!("create store dir {dir:?}: {e}"))?;
        Ok(ModelStore { dir })
    }

    /// Open a store that must already exist — the reader-side open
    /// (`gzk predict`), so a typo'd `--model-dir` is reported as missing
    /// instead of silently materializing an empty directory.
    pub fn open_existing(dir: impl Into<PathBuf>) -> Result<ModelStore, String> {
        let dir = dir.into();
        if !dir.is_dir() {
            return Err(format!("model store {dir:?} does not exist"));
        }
        Ok(ModelStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The manifest rows, sorted by name (empty for a fresh store).
    pub fn entries(&self) -> Result<Vec<StoreEntry>, String> {
        let path = self.dir.join(MANIFEST_FILE);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(format!("read {path:?}: {e}")),
        };
        let j = Json::parse(&text).map_err(|e| format!("store manifest: {e}"))?;
        let models = j
            .get("models")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| "store manifest: missing models[]".to_string())?;
        let mut entries = Vec::with_capacity(models.len());
        for m in models {
            let name = m
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| "store manifest: entry missing name".to_string())?
                .to_string();
            let kind = ModelKind::from_name(
                m.get("kind")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| format!("store manifest: {name:?} missing kind"))?,
            )?;
            let file = m
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("store manifest: {name:?} missing file"))?
                .to_string();
            entries.push(StoreEntry { name, kind, file });
        }
        Ok(entries)
    }

    /// Serialize `model` and record it under `name`, replacing any
    /// previous model of that name. Returns the artifact path.
    ///
    /// Both the artifact and the manifest are written via temp-file +
    /// rename, so a reader in another process (the train-once /
    /// serve-later workflow) never observes a truncated file and a crash
    /// mid-save cannot corrupt an existing artifact.
    pub fn save(&self, name: &str, model: &dyn Model) -> Result<PathBuf, String> {
        validate_name(name)?;
        // read the manifest FIRST: if it is unreadable, fail before
        // touching the existing artifact file, so a failed save never
        // destroys the previously saved model
        let mut entries = self.entries()?;
        let file = format!("{name}.model.json");
        let path = self.dir.join(&file);
        write_atomic(&path, &model.to_artifact())?;
        entries.retain(|e| e.name != name);
        entries.push(StoreEntry { name: name.to_string(), kind: model.kind(), file });
        entries.sort_by(|a, b| a.name.cmp(&b.name));
        self.write_manifest(&entries)?;
        Ok(path)
    }

    /// Load the model saved under `name`.
    pub fn load(&self, name: &str) -> Result<Box<dyn Model>, String> {
        Ok(self.load_with_meta(name)?.0)
    }

    /// [`load`](ModelStore::load) that also returns the artifact's run
    /// metadata (training dataset/rows/pool width).
    pub fn load_with_meta(&self, name: &str) -> Result<(Box<dyn Model>, RunMeta), String> {
        let entries = self.entries()?;
        let entry = entries.iter().find(|e| e.name == name).ok_or_else(|| {
            let have: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
            format!(
                "no model {name:?} in {:?} (have: {})",
                self.dir,
                if have.is_empty() { "none".to_string() } else { have.join(", ") }
            )
        })?;
        let path = self.dir.join(&entry.file);
        let text = fs::read_to_string(&path).map_err(|e| format!("read {path:?}: {e}"))?;
        let (model, run) =
            from_artifact_with_meta(&text).map_err(|e| format!("{path:?}: {e}"))?;
        if model.kind() != entry.kind {
            return Err(format!(
                "{path:?}: manifest says {} but artifact is {}",
                entry.kind.name(),
                model.kind().name()
            ));
        }
        Ok((model, run))
    }

    fn write_manifest(&self, entries: &[StoreEntry]) -> Result<(), String> {
        let rows: Vec<String> = entries
            .iter()
            .map(|e| {
                format!(
                    r#"{{"name":{},"kind":"{}","file":{}}}"#,
                    json_string(&e.name),
                    e.kind.name(),
                    json_string(&e.file)
                )
            })
            .collect();
        let text = format!(r#"{{"format":1,"models":[{}]}}"#, rows.join(","));
        write_atomic(&self.dir.join(MANIFEST_FILE), &text)
    }
}

/// Write via a sibling temp file + rename (atomic on POSIX within one
/// filesystem), so concurrent readers see either the old or the new
/// content, never a truncation.
fn write_atomic(path: &Path, content: &str) -> Result<(), String> {
    let tmp = path.with_extension("tmp");
    fs::write(&tmp, content).map_err(|e| format!("write {tmp:?}: {e}"))?;
    fs::rename(&tmp, path).map_err(|e| format!("rename {tmp:?} -> {path:?}: {e}"))
}

/// Names become file names; keep them simple and safe. Public so the CLI
/// can reject a bad `--name` up front as a usage error, before any I/O.
pub fn validate_model_name(name: &str) -> Result<(), String> {
    validate_name(name)
}

fn validate_name(name: &str) -> Result<(), String> {
    if name.is_empty() || name.len() > 64 {
        return Err("model name must be 1..=64 characters".to_string());
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_') {
        return Err(format!(
            "model name {name:?} may only contain [A-Za-z0-9_-]"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_validation() {
        assert!(validate_name("ridge-v2_final").is_ok());
        assert!(validate_name("").is_err());
        assert!(validate_name("../escape").is_err());
        assert!(validate_name("a b").is_err());
    }

    #[test]
    fn open_existing_refuses_missing_dirs() {
        let dir = std::env::temp_dir().join(format!("gzk-no-such-store-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let err = ModelStore::open_existing(&dir).unwrap_err();
        assert!(err.contains("does not exist"), "{err}");
        // and it must NOT have created the directory as a side effect
        assert!(!dir.exists());
    }

    #[test]
    fn empty_store_lists_nothing_and_load_names_the_miss() {
        let dir = std::env::temp_dir().join(format!("gzk-store-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let store = ModelStore::open(&dir).unwrap();
        assert!(store.entries().unwrap().is_empty());
        let err = store.load("ridge").unwrap_err();
        assert!(err.contains("no model"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
