//! Fitted-model subsystem: fit → persist → reload → serve.
//!
//! The paper's subspace-embedding guarantee (Thm. 10) is about the
//! *downstream* learner — KRR, kernel k-means, kernel PCA solved in
//! feature space. The deployable unit is therefore the feature map **plus**
//! the learned linear state, and this module makes that unit a durable
//! artifact:
//!
//! * [`Model`] — the shared trait: `predict` on raw inputs (featurization
//!   happens inside), the bundled [`feature_spec`](Model::feature_spec),
//!   and [`to_artifact`](Model::to_artifact) serialization;
//! * [`RidgeModel`] / [`KmeansModel`] / [`KpcaModel`] — the three model
//!   types, each pairing a [`FittedMap`] (spec + any data-dependent
//!   featurizer state, e.g. Nystrom landmarks) with its learned state
//!   (ridge weights / centroids / projection basis);
//! * [`artifact`] — the versioned JSON codec. Floats round-trip bit-exactly
//!   and the seed is seed-safe (decimal string, full `u64` range), so
//!   `fit → save → load → predict` equals in-memory prediction **bit for
//!   bit** for every registry method (`tests/model_props.rs`);
//! * [`ModelStore`] — a directory of artifacts with a manifest: the
//!   train-once / serve-later boundary the coordinator's batcher and the
//!   `gzk fit` / `gzk predict` subcommands share.
//!
//! ```
//! use gzk::features::{FeatureSpec, KernelSpec, Method};
//! use gzk::linalg::Mat;
//! use gzk::model::{from_artifact, Model, RidgeModel};
//! use gzk::rng::Rng;
//!
//! let mut rng = Rng::new(3);
//! let x = Mat::from_fn(40, 3, |_, _| rng.normal() * 0.5);
//! let y: Vec<f64> = (0..40).map(|i| x[(i, 0)] - x[(i, 2)]).collect();
//! let spec = FeatureSpec::new(
//!     KernelSpec::Gaussian { bandwidth: 1.0 },
//!     Method::Gegenbauer { q: 8, s: 2 },
//!     64,
//!     7,
//! )
//! .bind(3);
//! let model = RidgeModel::fit(spec, &x, &y, 1e-3).unwrap();
//! // the artifact IS the model: reload and predict bit-identically
//! let loaded = from_artifact(&model.to_artifact()).unwrap();
//! assert_eq!(loaded.predict(&x), Model::predict(&model, &x));
//! ```

pub mod artifact;
mod kmeans;
mod kpca;
mod ridge;
mod store;

pub use artifact::{set_run_data, FittedMap, RunMeta, ARTIFACT_FORMAT};
pub use kmeans::KmeansModel;
pub use kpca::KpcaModel;
pub use ridge::RidgeModel;
pub use store::{validate_model_name, ModelStore, StoreEntry};

use crate::exec::Pool;
use crate::features::BoundSpec;
use crate::linalg::Mat;

/// Which model type an artifact holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Ridge,
    Kmeans,
    Kpca,
}

impl ModelKind {
    pub const RIDGE: &'static str = "ridge";
    pub const KMEANS: &'static str = "kmeans";
    pub const KPCA: &'static str = "kpca";

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Ridge => Self::RIDGE,
            ModelKind::Kmeans => Self::KMEANS,
            ModelKind::Kpca => Self::KPCA,
        }
    }

    pub fn from_name(name: &str) -> Result<ModelKind, String> {
        match name {
            Self::RIDGE => Ok(ModelKind::Ridge),
            Self::KMEANS => Ok(ModelKind::Kmeans),
            Self::KPCA => Ok(ModelKind::Kpca),
            other => Err(format!(
                "unknown model kind {other:?}; registered: {}, {}, {}",
                Self::RIDGE,
                Self::KMEANS,
                Self::KPCA
            )),
        }
    }
}

/// A fitted, servable, persistable model. `Send + Sync` is part of the
/// contract: the serving batcher moves models into its service thread and
/// shares them across batches.
pub trait Model: Send + Sync {
    fn kind(&self) -> ModelKind;

    /// The feature map this model was fitted through (bound wire form).
    fn feature_spec(&self) -> &BoundSpec;

    /// Number of outputs per input row: 1 for ridge (the regression value)
    /// and k-means (the cluster index), `r` for KPCA (the projection).
    fn output_dim(&self) -> usize;

    /// Predict from **raw** inputs (n x d) — featurization happens inside,
    /// through the fitted map. Returns (n x output_dim).
    fn predict(&self, x: &Mat) -> Mat;

    /// [`predict`](Model::predict) with row parallelism drawn from an
    /// explicit pool — **bit-identical** to `predict` at every thread
    /// count (the parallel kernels fix their reduction order). The
    /// serving batcher calls this with [`Pool::for_rows`] so bulk batches
    /// fan out while single-row requests stay on the service thread.
    /// Default ignores the pool.
    fn predict_with(&self, x: &Mat, pool: &Pool) -> Mat {
        let _ = pool;
        self.predict(x)
    }

    /// Serialize to the versioned JSON artifact format.
    fn to_artifact(&self) -> String;
}

/// Deserialize any model artifact, dispatching on its `kind` field.
pub fn from_artifact(text: &str) -> Result<Box<dyn Model>, String> {
    Ok(from_artifact_with_meta(text)?.0)
}

/// [`from_artifact`] that also surfaces the artifact's run metadata —
/// `gzk serve` reads the recorded training dataset/rows to rebuild its
/// evaluation stream.
pub fn from_artifact_with_meta(text: &str) -> Result<(Box<dyn Model>, RunMeta), String> {
    let env = artifact::parse_envelope(text)?;
    let run = env.run.clone();
    let model: Box<dyn Model> = match env.kind {
        ModelKind::Ridge => Box::new(RidgeModel::from_envelope(env)?),
        ModelKind::Kmeans => Box::new(KmeansModel::from_envelope(env)?),
        ModelKind::Kpca => Box::new(KpcaModel::from_envelope(env)?),
    };
    Ok((model, run))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for kind in [ModelKind::Ridge, ModelKind::Kmeans, ModelKind::Kpca] {
            assert_eq!(ModelKind::from_name(kind.name()).unwrap(), kind);
        }
        assert!(ModelKind::from_name("svm").is_err());
    }

    #[test]
    fn from_artifact_rejects_garbage() {
        assert!(from_artifact("not json").is_err());
        assert!(from_artifact("{}").is_err());
        // future format versions are rejected, not misread
        let future = r#"{"format":99,"kind":"ridge","spec":{},"state":{}}"#;
        let err = from_artifact(future).unwrap_err();
        assert!(err.contains("format 99"), "{err}");
    }
}
