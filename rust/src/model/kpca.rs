//! Kernel PCA as a deployable model: the fitted map plus the feature-space
//! mean and top-r projection basis. `predict` embeds raw inputs into the
//! principal subspace.

use super::artifact::{self, Envelope, FittedMap};
use super::{Model, ModelKind};
use crate::data::{pipeline, DataSource, MatSource};
use crate::exec::Pool;
use crate::features::BoundSpec;
use crate::kpca::KernelPca;
use crate::linalg::Mat;

pub struct KpcaModel {
    map: FittedMap,
    pca: KernelPca,
}

impl KpcaModel {
    /// Fit on in-memory rows: [`fit_source`](KpcaModel::fit_source) over a
    /// borrowed [`MatSource`] — the same two-pass streaming pipeline as
    /// the out-of-core fit, bit-identical to the materialized
    /// [`KernelPca::fit`].
    pub fn fit(spec: BoundSpec, x: &Mat, rank: usize) -> Result<KpcaModel, String> {
        Self::fit_source(spec, &MatSource::unlabeled(x), rank, pipeline::DEFAULT_CHUNK_ROWS)
    }

    /// Chunked fit over any [`DataSource`]: pass 1 streams the
    /// feature-space mean, pass 2 the centered covariance, keeping the
    /// top-`rank` principal directions. O(F²) state; feature memory
    /// bounded by `chunk_rows x F`.
    pub fn fit_source(
        spec: BoundSpec,
        src: &dyn DataSource,
        rank: usize,
        chunk_rows: usize,
    ) -> Result<KpcaModel, String> {
        let map = FittedMap::fit_source(spec, src)?;
        // per-chunk featurization + covariance assembly draw from the
        // global pool (bit-identical to serial at any width)
        let (pca, _) =
            pipeline::kpca_chunked(map.featurizer(), src, rank, chunk_rows, &Pool::global())?;
        Ok(KpcaModel { pca, map })
    }

    pub fn pca(&self) -> &KernelPca {
        &self.pca
    }

    /// Project raw inputs onto the principal subspace: (n x r); row
    /// parallelism from the global pool, clamped for tiny batches.
    pub fn transform(&self, x: &Mat) -> Mat {
        self.transform_with(x, &Pool::for_rows(x.rows()))
    }

    /// [`transform`](KpcaModel::transform) on an explicit pool.
    pub fn transform_with(&self, x: &Mat, pool: &Pool) -> Mat {
        self.pca.transform_with(&self.map.featurize_with(x, pool), pool)
    }

    pub(super) fn from_envelope(env: Envelope) -> Result<KpcaModel, String> {
        let mean = artifact::vec_from_json(artifact::req(&env.state, "mean")?)?;
        let eigenvalues = artifact::vec_from_json(artifact::req(&env.state, "eigenvalues")?)?;
        let components = artifact::mat_from_json(artifact::req(&env.state, "components")?)?;
        if components.rows() != env.map.feature_dim() {
            return Err(format!(
                "kpca artifact components have {} rows but the map emits {} features",
                components.rows(),
                env.map.feature_dim()
            ));
        }
        if mean.len() != components.rows() || eigenvalues.len() != components.cols() {
            return Err("kpca artifact mean/eigenvalue shapes are inconsistent".to_string());
        }
        Ok(KpcaModel { map: env.map, pca: KernelPca::from_parts(mean, components, eigenvalues) })
    }
}

impl Model for KpcaModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Kpca
    }

    fn feature_spec(&self) -> &BoundSpec {
        self.map.spec()
    }

    fn output_dim(&self) -> usize {
        self.pca.rank()
    }

    fn predict(&self, x: &Mat) -> Mat {
        self.predict_with(x, &Pool::for_rows(x.rows()))
    }

    fn predict_with(&self, x: &Mat, pool: &Pool) -> Mat {
        self.transform_with(x, pool)
    }

    fn to_artifact(&self) -> String {
        let state = format!(
            r#"{{"mean":{},"eigenvalues":{},"components":{}}}"#,
            artifact::vec_to_json(self.pca.mean()),
            artifact::vec_to_json(&self.pca.eigenvalues),
            artifact::mat_to_json(self.pca.components())
        );
        artifact::envelope(ModelKind::Kpca, &self.map, &state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureSpec, KernelSpec, Method};
    use crate::rng::Rng;

    #[test]
    fn fit_transform_and_shapes() {
        let mut rng = Rng::new(320);
        let x = Mat::from_fn(50, 3, |_, _| rng.normal() * 0.6);
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            48,
            13,
        )
        .bind(3);
        let model = KpcaModel::fit(spec, &x, 3).unwrap();
        assert_eq!(model.output_dim(), 3);
        let emb = Model::predict(&model, &x);
        assert_eq!((emb.rows(), emb.cols()), (50, 3));
        assert_eq!(emb, model.transform(&x));
        // eigenvalues descending
        let ev = &model.pca().eigenvalues;
        assert!(ev[0] >= ev[1] && ev[1] >= ev[2]);
    }

    #[test]
    fn rejects_bad_rank() {
        let mut rng = Rng::new(321);
        let x = Mat::from_fn(20, 3, |_, _| rng.normal());
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Fourier,
            16,
            1,
        )
        .bind(3);
        assert!(KpcaModel::fit(spec.clone(), &x, 0).is_err());
        assert!(KpcaModel::fit(spec, &x, 1000).is_err());
    }
}
