//! Kernel k-means as a deployable model: the fitted map plus the final
//! centroids. `predict` is out-of-sample assignment — the operation
//! Theorem 10's projection-cost preservation licenses in feature space.

use super::artifact::{self, Envelope, FittedMap};
use super::{Model, ModelKind};
use crate::data::{pipeline, DataSource};
use crate::exec::Pool;
use crate::features::BoundSpec;
use crate::kmeans::{assign_to_centroids_with, kmeans_with};
use crate::linalg::Mat;

pub struct KmeansModel {
    map: FittedMap,
    /// (k x F) fitted centroids in feature space
    centroids: Mat,
    /// training objective (avg squared distance to assigned centroid)
    objective: f64,
}

impl KmeansModel {
    /// Featurize the training rows and run Lloyd's algorithm with
    /// k-means++ seeding; the clustering seed is the spec seed, so the
    /// whole model is a pure function of `(spec, x, k, max_iters)`.
    pub fn fit(
        spec: BoundSpec,
        x: &Mat,
        k: usize,
        max_iters: usize,
    ) -> Result<KmeansModel, String> {
        if k == 0 || x.rows() < k {
            return Err(format!("k={k} needs at least k training rows, got {}", x.rows()));
        }
        let seed = spec.spec.seed;
        let map = FittedMap::fit(spec, x)?;
        // training featurization + Lloyd assignment scans draw from the
        // global pool (bit-identical to serial at any width)
        let pool = Pool::global();
        let z = map.featurize_with(x, &pool);
        let res = kmeans_with(&z, k, max_iters, seed, &pool);
        Ok(KmeansModel { map, centroids: res.centroids, objective: res.objective })
    }

    /// Chunked out-of-core fit over any [`DataSource`]: reservoir-sampled
    /// initialization, then the streaming mini-batch absorb of
    /// `data::pipeline::kmeans_chunked` — O(k F) state, feature memory
    /// bounded by `chunk_rows x F`, bit-invariant to the chunking. (The
    /// in-memory [`fit`](KmeansModel::fit) keeps full Lloyd iterations,
    /// which need all feature rows resident; this is the fit that scales
    /// past RAM.)
    pub fn fit_source(
        spec: BoundSpec,
        src: &dyn DataSource,
        k: usize,
        chunk_rows: usize,
    ) -> Result<KmeansModel, String> {
        let seed = spec.spec.seed;
        let map = FittedMap::fit_source(spec, src)?;
        let (res, _) = pipeline::kmeans_chunked(
            map.featurizer(),
            src,
            k,
            chunk_rows,
            seed,
            &Pool::global(),
        )?;
        Ok(KmeansModel { map, centroids: res.centroids, objective: res.objective })
    }

    pub fn k(&self) -> usize {
        self.centroids.rows()
    }

    pub fn centroids(&self) -> &Mat {
        &self.centroids
    }

    pub fn objective(&self) -> f64 {
        self.objective
    }

    /// Out-of-sample cluster assignment for raw inputs; row parallelism
    /// from the global pool, clamped for tiny batches.
    pub fn assign(&self, x: &Mat) -> Vec<usize> {
        self.assign_with(x, &Pool::for_rows(x.rows()))
    }

    /// [`assign`](KmeansModel::assign) on an explicit pool.
    pub fn assign_with(&self, x: &Mat, pool: &Pool) -> Vec<usize> {
        assign_to_centroids_with(&self.map.featurize_with(x, pool), &self.centroids, pool)
    }

    pub(super) fn from_envelope(env: Envelope) -> Result<KmeansModel, String> {
        let objective = artifact::req_f64(&env.state, "objective")?;
        let centroids = artifact::mat_from_json(artifact::req(&env.state, "centroids")?)?;
        if centroids.cols() != env.map.feature_dim() {
            return Err(format!(
                "kmeans artifact centroids have {} columns but the map emits {} features",
                centroids.cols(),
                env.map.feature_dim()
            ));
        }
        if centroids.rows() == 0 {
            return Err("kmeans artifact has no centroids".to_string());
        }
        Ok(KmeansModel { map: env.map, centroids, objective })
    }
}

impl Model for KmeansModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Kmeans
    }

    fn feature_spec(&self) -> &BoundSpec {
        self.map.spec()
    }

    fn output_dim(&self) -> usize {
        1
    }

    /// Cluster index per row, as an (n x 1) matrix of whole numbers.
    fn predict(&self, x: &Mat) -> Mat {
        self.predict_with(x, &Pool::for_rows(x.rows()))
    }

    fn predict_with(&self, x: &Mat, pool: &Pool) -> Mat {
        let assign = self.assign_with(x, pool);
        Mat::from_vec(assign.len(), 1, assign.into_iter().map(|c| c as f64).collect())
    }

    fn to_artifact(&self) -> String {
        let state = format!(
            r#"{{"objective":{},"centroids":{}}}"#,
            artifact::fmt_f64(self.objective),
            artifact::mat_to_json(&self.centroids)
        );
        artifact::envelope(ModelKind::Kmeans, &self.map, &state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureSpec, KernelSpec, Method};
    use crate::rng::Rng;

    fn blobs() -> Mat {
        // two antipodal caps on S^2 — separable through a zonal kernel map
        let mut rng = Rng::new(310);
        Mat::from_fn(60, 3, |i, _| {
            let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
            sign + 0.2 * rng.normal()
        })
    }

    #[test]
    fn fit_assign_predict_agree() {
        let x = blobs();
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Gegenbauer { q: 6, s: 2 },
            48,
            11,
        )
        .bind(3);
        let model = KmeansModel::fit(spec, &x, 2, 40).unwrap();
        assert_eq!(model.k(), 2);
        let assign = model.assign(&x);
        let pred = Model::predict(&model, &x);
        assert_eq!(pred.rows(), 60);
        for (i, &c) in assign.iter().enumerate() {
            assert_eq!(pred[(i, 0)], c as f64);
        }
        // the two parity groups separate
        assert_ne!(assign[0], assign[1]);
        assert!(model.objective() >= 0.0);
    }

    #[test]
    fn rejects_k_larger_than_n() {
        let x = blobs();
        let spec = FeatureSpec::new(
            KernelSpec::Gaussian { bandwidth: 1.0 },
            Method::Fourier,
            32,
            1,
        )
        .bind(3);
        assert!(KmeansModel::fit(spec, &x, 100, 10).is_err());
    }
}
