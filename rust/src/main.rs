//! `gzk` — CLI for the Random Gegenbauer Features system.
//!
//! Subcommands map 1:1 to the paper's experiments plus the serving system:
//!
//!   gzk fig1      [--degree 15]                      Figure 1
//!   gzk table1    [--n 64 --d 3 --lambda 0.5]        Table 1 (bounds + empirical)
//!   gzk table2    [--scale 0.05 --m 1024]            Table 2 (KRR, 4 datasets)
//!   gzk table3    [--scale 0.05 --m 512]             Table 3 (k-means, 6 datasets)
//!   gzk spectral  [--n 64 --d 3 --lambda 0.1]        Eq.-1 quality sweep
//!   gzk leverage  [--n 24 --d 3 --lambda 0.1]        Lemma-7 leverage-score check
//!   gzk serve     [--n 20000 --m 512 --requests 2000] end-to-end serving demo
//!   gzk info                                          artifact manifest summary
//!
//! Subcommands that build a single featurizer (`serve`, `leverage`) share
//! one flag group — `--kernel/--method/--m/--seed` plus tuning knobs —
//! parsed once by `cli::Args::feature_spec` into a `features::FeatureSpec`
//! (run `gzk serve --method fourier` to broadcast a non-Gegenbauer map).
//! The table/spectral sweeps iterate the whole method registry and reject
//! those flags rather than silently ignoring them.

use gzk::cli::Args;
use gzk::coordinator::{fit_one_round, Backend, PredictionService};
use gzk::data;
use gzk::experiments::{fig1, spectral_quality, table1, table2, table3};
use gzk::features::FeatureSpec;
use gzk::krr::mse;
use std::time::{Duration, Instant};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    match args.subcommand.as_str() {
        "fig1" => {
            let curves = fig1::run(args.get_usize("degree", 15));
            fig1::print(&curves);
        }
        "table1" => {
            // sweeps its own method pair and feature ladder
            reject_sweep_flags(&args, "table1", &["kernel", "method", "m"]);
            let rows = table1::run_bounds();
            table1::print_bounds(&rows);
            let n = args.get_usize("n", 64);
            let d = args.get_usize("d", 3);
            let lam = args.get_f64("lambda", 0.5);
            let emp = table1::run_empirical(n, d, lam, 0.5, args.get_u64("seed", 1));
            table1::print_empirical(&emp, 0.5);
        }
        "table2" => {
            // sweeps the whole registry with per-dataset gaussian kernels
            reject_sweep_flags(&args, "table2", &["kernel", "method"]);
            let rows = table2::run_all(
                args.get_f64("scale", 0.05),
                args.get_usize("m", 1024),
                args.get_u64("seed", 1),
            );
            table2::print(&rows);
        }
        "table3" => {
            reject_sweep_flags(&args, "table3", &["kernel", "method"]);
            let rows = table3::run_all(
                args.get_f64("scale", 0.05),
                args.get_usize("m", 512),
                args.get_u64("seed", 1),
            );
            table3::print(&rows);
        }
        "spectral" => {
            reject_sweep_flags(&args, "spectral", &["kernel", "method", "m"]);
            let (s_lambda, rows) = spectral_quality::run(
                args.get_usize("n", 64),
                args.get_usize("d", 3),
                args.get_f64("lambda", 0.1),
                args.get_u64("seed", 1),
            );
            spectral_quality::print(s_lambda, &rows);
        }
        "leverage" => leverage_demo(&args),
        "serve" => serve_demo(&args),
        "info" => info(),
        other => {
            eprintln!("unknown subcommand {other:?}; see rust/src/main.rs header for usage");
            std::process::exit(2);
        }
    }
}

/// Parse the shared featurizer flag group, exiting with a usage error on
/// bad input (the one place CLI featurizer parsing happens).
fn parse_spec(args: &Args, default_m: usize) -> FeatureSpec {
    match args.feature_spec(default_m, 1) {
        Ok(spec) => spec,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    }
}

/// Registry-sweep subcommands construct their own spec ladders; reject the
/// single-featurizer flags instead of silently ignoring them.
fn reject_sweep_flags(args: &Args, subcommand: &str, flags: &[&str]) {
    for f in flags {
        if args.get(f).is_some() {
            eprintln!(
                "argument error: --{f} does not apply to {subcommand} \
                 (it sweeps the method registry with its own kernels)"
            );
            std::process::exit(2);
        }
    }
}

/// Lemma-7 validator: exact ridge leverage scores over random directions
/// vs the uniform bound, plus the Theorem-9 feature-count it implies.
fn leverage_demo(args: &Args) {
    use gzk::linalg::Mat;
    use gzk::rng::Rng;
    use gzk::spectral::{lemma7_bound, leverage_score, statistical_dimension, theorem9_feature_count};

    let n = args.get_usize("n", 24);
    let d = args.get_usize("d", 3);
    let lambda = args.get_f64("lambda", 0.1);
    let spec = parse_spec(args, 512);
    let mut rng = Rng::new(spec.seed);
    let x = Mat::from_fn(n, d, |_, _| rng.normal() * 0.6);
    let table = spec
        .radial_table(d)
        .expect("leverage demo analyses the Gegenbauer method (--method gegenbauer)");

    let bound = lemma7_bound(&table, &x, lambda);
    let k = table.gzk_gram(&x);
    let s_lam = statistical_dimension(&k, lambda);
    println!("n={n} d={d} lambda={lambda}: s_lambda = {s_lam:.2}, Lemma-7 bound = {bound:.2}");
    let mut w = vec![0.0; d];
    let mut max_tau: f64 = 0.0;
    let mut sum_tau = 0.0;
    let n_mc = 200;
    for _ in 0..n_mc {
        rng.sphere(&mut w);
        let tau = leverage_score(&table, &x, &w, lambda);
        max_tau = max_tau.max(tau);
        sum_tau += tau;
    }
    println!(
        "over {n_mc} random directions: max tau = {max_tau:.3} (<= bound {bound:.3}), \
         mean tau = {:.3} (~ s_lambda {s_lam:.3})",
        sum_tau / n_mc as f64
    );
    let m9 = theorem9_feature_count(&table, &x, lambda, 0.5, 0.1, s_lam);
    println!("Theorem-9 feature count for (eps=0.5, delta=0.1): m >= {m9:.0}");
}

/// End-to-end demo: train on synthetic elevation via the one-round
/// protocol with the spec from the shared flag group (any oblivious
/// method), then serve batched prediction requests and report latency.
fn serve_demo(args: &Args) {
    let n = args.get_usize("n", 20_000);
    let n_requests = args.get_usize("requests", 2_000);
    let n_workers = args.get_usize("workers", 4);
    let spec = parse_spec(args, 512).bind(3);
    if !spec.spec.method.is_oblivious() {
        eprintln!(
            "argument error: --method {} is data-dependent and cannot be broadcast \
             by the one-round protocol; pick an oblivious method",
            spec.spec.method.name()
        );
        std::process::exit(2);
    }
    let seed = spec.spec.seed;

    println!("== gzk serve: one-round distributed KRR + batched serving ==");
    println!("spec: {}", spec.to_json());
    let ds = data::elevation(n, seed);
    let (x_tr, y_tr, x_te, y_te) = data::split(&ds.x, &ds.y, 0.1, seed);
    let backend = if args.has("pjrt") {
        Backend::Pjrt { artifact_dir: gzk::runtime::default_artifact_dir() }
    } else {
        Backend::Native
    };
    let t0 = Instant::now();
    let fit = fit_one_round(&spec, &x_tr, &y_tr, 1e-2, n_workers, 2048, backend);
    println!(
        "trained on {} rows across {} workers / {} shards in {:.2}s (featurize CPU {:.2}s)",
        fit.stats.n,
        fit.n_workers,
        fit.n_shards,
        t0.elapsed().as_secs_f64(),
        fit.featurize_secs_total
    );

    let svc = PredictionService::start(spec, fit.model, 64, Duration::ZERO);
    let client = svc.client();
    // warm
    let _ = client.predict(x_te.row(0));
    let mut latencies = Vec::with_capacity(n_requests);
    let mut preds = Vec::with_capacity(n_requests);
    let t1 = Instant::now();
    for r in 0..n_requests {
        let i = r % x_te.rows();
        let t = Instant::now();
        preds.push(client.predict(x_te.row(i)).expect("served"));
        latencies.push(t.elapsed().as_secs_f64());
    }
    let wall = t1.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let truth: Vec<f64> = (0..n_requests).map(|r| y_te[r % y_te.len()]).collect();
    let metrics = svc.metrics();
    println!(
        "served {} requests in {:.2}s  ({:.0} req/s)",
        n_requests,
        wall,
        n_requests as f64 / wall
    );
    println!(
        "latency p50 {:.2}us  p99 {:.2}us   batches {} (max size {})",
        latencies[n_requests / 2] * 1e6,
        latencies[(n_requests * 99) / 100] * 1e6,
        metrics.batches,
        metrics.max_batch_seen
    );
    println!("test MSE over served predictions: {:.4}", mse(&preds, &truth));
}

fn info() {
    let dir = gzk::runtime::default_artifact_dir();
    println!("artifact dir: {dir:?}");
    match gzk::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("{} featurize artifacts, {} krr_solve artifacts", m.featurize.len(), m.krr_solve.len());
            for f in &m.featurize {
                println!(
                    "  featurize {} d={} q={} s={} tile {}x{}",
                    f.family, f.d, f.q, f.s, f.block_b, f.block_m
                );
            }
            for k in &m.krr_solve {
                println!("  krr_solve F={}", k.f);
            }
        }
        Err(e) => println!("no manifest: {e} (run `make artifacts`)"),
    }
}
